"""Pure-jnp reference implementations (correctness oracles) for every kernel.

These are the ground truth the Pallas kernels (and, transitively, the Rust
re-implementations) are validated against. Everything here is straight-line
jax.numpy with no tiling, so it is obviously correct but slow.

Conventions
-----------
* Weight matrices are ``W ∈ R^{n×m}`` (out_features × in_features), matching
  the paper's notation. Activations are ``x ∈ R^{M×m}`` (tokens × in),
  ``y = x @ Ŵᵀ ∈ R^{M×n}``.
* Quantized codes ``Q`` are stored as int32 indices into a codebook
  (look-up table) ``lut``; the dequantized value is ``lut[Q] * S`` where
  ``S`` is the elementwise scale matrix.
* Block-wise scaling uses contiguous blocks of size ``B`` along the *row*
  (in-features) direction, the layout used by bitsandbytes/QLoRA.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from scipy.stats import norm as _scipy_norm

# ---------------------------------------------------------------------------
# Codebooks (NormalFloat + integer grids)
# ---------------------------------------------------------------------------


def normal_float_codebook(bits: int) -> np.ndarray:
    """NormalFloat codebook of ``2**bits`` levels, following QLoRA.

    The NFk data type places quantiles of N(0, 1) so that each level is
    equally probable under a Gaussian weight prior, then rescales to [-1, 1].
    Like NF4 in bitsandbytes we build an *asymmetric* grid: 2^{k-1} negative
    levels, 2^{k-1} - 1 positive levels, and an exact zero, so that zero is
    exactly representable.
    """
    n = 1 << bits
    offset = 0.9677083  # bitsandbytes magic: 1 - 1/(2*16) quantile clip
    # negative half: 2^{k-1}+1 quantiles of [1-offset .. 0.5], drop the 0.5
    neg = _scipy_norm.ppf(np.linspace(1 - offset, 0.5, (n // 2) + 1))[:-1]
    pos = _scipy_norm.ppf(np.linspace(0.5, offset, n // 2))
    levels = np.concatenate([neg, pos])
    levels = levels / np.max(np.abs(levels))
    levels = np.sort(levels)
    levels[np.argmin(np.abs(levels))] = 0.0  # snap the central level to 0
    return levels.astype(np.float32)


def int_codebook(bits: int) -> np.ndarray:
    """Symmetric signed-integer grid scaled to [-1, 1] (e.g. INT4 = -7..7)."""
    qmax = (1 << (bits - 1)) - 1
    levels = np.arange(-qmax, qmax + 1, dtype=np.float32) / float(qmax)
    return levels.astype(np.float32)


def codebook(name: str) -> np.ndarray:
    """Look up a codebook by name: ``nf4``, ``nf3``, ``nf2``, ``int4``, ..."""
    if name.startswith("nf"):
        return normal_float_codebook(int(name[2:]))
    if name.startswith("int"):
        return int_codebook(int(name[3:]))
    raise ValueError(f"unknown codebook {name!r}")


# ---------------------------------------------------------------------------
# Block-wise scaling + quantization (the baseline LoRDS breaks)
# ---------------------------------------------------------------------------


def blockwise_scales(w: jnp.ndarray, block: int) -> jnp.ndarray:
    """Per-block absmax scales, shape (n, m/block). Zero-safe."""
    n, m = w.shape
    assert m % block == 0, (n, m, block)
    s = jnp.max(jnp.abs(w.reshape(n, m // block, block)), axis=-1)
    return jnp.where(s == 0.0, 1.0, s)


def expand_scales(s: jnp.ndarray, block: int) -> jnp.ndarray:
    """S = s ⊗ 1_{1×B}: broadcast block scales to the full (n, m) matrix."""
    return jnp.repeat(s, block, axis=1)


def quantize_codes(w: jnp.ndarray, s_full: jnp.ndarray, lut) -> jnp.ndarray:
    """Q_ij = argmin_v (S_ij · v − W_ij)² — nearest codebook level of W under S.

    This is the argmin form from Algorithm 1; for positive S it coincides
    with nearest-neighbour of W⊘S in the LUT, but the argmin form stays
    correct when refinement pushes scale entries negative, so both the
    reference and the Rust implementation use it verbatim.
    """
    lut = jnp.asarray(lut)
    resid = w[..., None] - s_full[..., None] * lut[None, None, :]
    return jnp.argmin(resid * resid, axis=-1).astype(jnp.int32)


def dequantize(codes: jnp.ndarray, s_full: jnp.ndarray, lut) -> jnp.ndarray:
    """Ŵ = lut[Q] ⊙ S."""
    return jnp.asarray(lut)[codes] * s_full


def blockwise_quantize(w: jnp.ndarray, block: int, lut):
    """Full block-wise round trip; returns (codes, block_scales, w_hat)."""
    s = blockwise_scales(w, block)
    s_full = expand_scales(s, block)
    codes = quantize_codes(w, s_full, lut)
    return codes, s, dequantize(codes, s_full, lut)


# ---------------------------------------------------------------------------
# LoRDS scaling decomposition
# ---------------------------------------------------------------------------


def parity_rank(n: int, m: int, block: int) -> int:
    """r = ⌊nm / (B(n+m))⌋ — scale-parameter parity with block size B (App. A)."""
    return max(1, (n * m) // (block * (n + m)))


def lords_init(w: jnp.ndarray, block: int, rank: int):
    """Truncated-SVD initialization of S = BA from block-wise absmax scales.

    Returns (B, A) with B ∈ R^{n×r}, A ∈ R^{r×m} such that BA exactly
    recovers the block-wise statistics when rank ≥ rank(S) (eq. 3).
    """
    s_full = expand_scales(blockwise_scales(w, block), block)
    u, sv, vt = jnp.linalg.svd(s_full, full_matrices=False)
    root = jnp.sqrt(sv[:rank])
    b = u[:, :rank] * root[None, :]
    a = root[:, None] * vt[:rank, :]
    return b, a


def lords_dequantize(codes, b, a, lut):
    """Ŵ = lut[Q] ⊙ (BA)."""
    return jnp.asarray(lut)[codes] * (b @ a)


# ---------------------------------------------------------------------------
# Matmul oracles (what the Pallas kernels must reproduce)
# ---------------------------------------------------------------------------


def lords_matmul_ref(x, codes, b, a, lut):
    """y = x · (Q ⊙ (BA))ᵀ — the LoRDS fused dequant-matmul."""
    w_hat = jnp.asarray(lut)[codes] * (b @ a)
    return x @ w_hat.T


def blockwise_matmul_ref(x, codes, scales, lut, block):
    """y = x · Ŵᵀ with block-wise scales (the bnb-NF4 baseline)."""
    w_hat = jnp.asarray(lut)[codes] * expand_scales(scales, block)
    return x @ w_hat.T


def qlora_matmul_ref(x, codes, scales, lut, block, lora_a, lora_b):
    """y = x · Ŵᵀ + (x · A_lᵀ) · B_lᵀ — NF4 base plus the unmergeable adapter.

    lora_a ∈ R^{r×m}, lora_b ∈ R^{n×r}; the adapter path is the extra work
    QLoRA pays on every forward because the fp adapter cannot be merged
    into the quantized weight.
    """
    base = blockwise_matmul_ref(x, codes, scales, lut, block)
    return base + (x @ lora_a.T) @ lora_b.T


# ---------------------------------------------------------------------------
# STE fake-quant (eqs. 4–5) reference
# ---------------------------------------------------------------------------


def fake_quant(w, b, a, lut):
    """Ŵ = ROUND(W ⊘ (BA)) ⊙ (BA) with ROUND = nearest codebook level."""
    s = b @ a
    codes = quantize_codes(w, s, lut)
    return jnp.asarray(lut)[codes] * s


def ste_grads(w, b, a, lut, g):
    """Reference STE gradients of a loss L with ∂L/∂Ŵ = g (eqs. 4–5).

    ∇_W L ≈ g;  ∇_S L ≈ g ⊙ (Q − W ⊘ S);  ∇_B = (∇_S) Aᵀ;  ∇_A = Bᵀ (∇_S).
    """
    s = b @ a
    q = jnp.asarray(lut)[quantize_codes(w, s, lut)]
    gs = g * (q - w / s)
    return g, gs @ a.T, b.T @ gs


# ---------------------------------------------------------------------------
# Error metrics
# ---------------------------------------------------------------------------


def nuclear_norm(x) -> jnp.ndarray:
    return jnp.sum(jnp.linalg.svd(x, compute_uv=False))


def quant_error_nuclear(w, w_hat) -> jnp.ndarray:
    """‖W − Ŵ‖_* — the paper's QuantError metric (Table 2)."""
    return nuclear_norm(w - w_hat)


def reduction_ratio(w, w_hat, w_nf4) -> jnp.ndarray:
    """1 − ‖W−Ŵ‖_* / ‖W−nf4(W)‖_* (Appendix B, Tables 8–9)."""
    return 1.0 - nuclear_norm(w - w_hat) / nuclear_norm(w - w_nf4)
