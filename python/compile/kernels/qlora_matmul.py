"""L1 — QLoRA baseline kernel: block-wise dequant-matmul + additive adapter.

``y = x · Ŵᵀ + (x · A_lᵀ) · B_lᵀ``. Because the fp adapter cannot be merged
into the quantized weight (precision mismatch), QLoRA pays the adapter GEMM
on *every* forward — the structural latency disadvantage LoRDS removes
(Figure 2 / Table 6).

The adapter contribution is distributed across the K loop using
``(Σ_k x_k A_kᵀ) B_lᵀ = Σ_k (x_k A_kᵀ) B_lᵀ`` so the kernel needs no scratch
accumulator; each grid step pays the two extra rank-r MXU matmuls
(bm×bk×r and bm×r×bn) that model the adapter's extra compute + HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lords_matmul import _tile, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK


def _qlora_kernel(x_ref, q_ref, s_ref, la_ref, lb_ref, lut_ref, o_ref, *, block):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Base path: block-wise NF4 dequant-matmul.
    s_tile = jnp.repeat(s_ref[...], block, axis=1)
    w_tile = jnp.take(lut_ref[...], q_ref[...], axis=0) * s_tile
    acc = jnp.dot(x_ref[...], w_tile.T, preferred_element_type=jnp.float32)
    # Adapter path: x_tile @ A_lᵀ (bm × r), then @ B_lᵀ (bm × bn).
    t = jnp.dot(x_ref[...], la_ref[...].T, preferred_element_type=jnp.float32)
    acc += jnp.dot(t, lb_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block", "bm", "bn", "bk"))
def qlora_matmul(x, codes, scales, lora_a, lora_b, lut, *, block,
                 bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """y[M,n] = x · dequant(codes, scales)ᵀ + x · lora_aᵀ · lora_bᵀ.

    Args:
      x: f32[M, m] activations.
      codes: int32[n, m] codebook indices.
      scales: f32[n, m/block] block scales.
      lora_a: f32[r, m] adapter down-projection.
      lora_b: f32[n, r] adapter up-projection.
      lut: f32[L] codebook.
      block: quantization block size B.
    """
    mm, m = x.shape
    n, m2 = codes.shape
    r = lora_a.shape[0]
    assert m == m2 and lora_a.shape == (r, m) and lora_b.shape == (n, r)
    assert m % block == 0 and scales.shape == (n, m // block)

    bm = _tile(mm, bm)
    bn = _tile(n, bn)
    bk = max(block, _tile(m, max(bk, block)))
    while m % bk != 0 or bk % block != 0:
        bk -= block
    grid = (mm // bm, n // bn, m // bk)

    return pl.pallas_call(
        functools.partial(_qlora_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),           # x
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),           # codes
            pl.BlockSpec((bn, bk // block), lambda i, j, k: (j, k)),  # scales
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),            # lora A
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),            # lora B
            pl.BlockSpec((lut.shape[0],), lambda i, j, k: (0,)),      # codebook
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), jnp.float32),
        interpret=True,
    )(x, codes, scales, lora_a, lora_b, lut)
