"""L1 — baseline block-wise (bitsandbytes-NF4 style) dequant-matmul kernel.

``y = x · (lut[Q] ⊙ (s ⊗ 1_{1×B}))ᵀ`` with per-block absmax scales — the
piecewise-constant scaling LoRDS "breaks". Serves as the bnb-NF4 baseline
of Figure 2 / Table 6 and as the base path of the QLoRA kernel.

The K tile is constrained to a multiple of the quant block size so each
grid step sees whole scale blocks; dequantization is then a broadcasted
multiply of the staged code tile by the repeated scale tile in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lords_matmul import _tile, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK


def _blockwise_kernel(x_ref, q_ref, s_ref, lut_ref, o_ref, *, block):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s_tile = jnp.repeat(s_ref[...], block, axis=1)  # (bn, bk) piecewise-constant
    w_tile = jnp.take(lut_ref[...], q_ref[...], axis=0) * s_tile
    o_ref[...] += jnp.dot(x_ref[...], w_tile.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "bm", "bn", "bk"))
def blockwise_matmul(x, codes, scales, lut, *, block,
                     bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """y[M,n] = x[M,m] · dequant(codes, scales)ᵀ with block-wise scaling.

    Args:
      x: f32[M, m] activations.
      codes: int32[n, m] codebook indices.
      scales: f32[n, m/block] per-block absmax scales.
      lut: f32[L] codebook.
      block: quantization block size B (must divide m).
    """
    mm, m = x.shape
    n, m2 = codes.shape
    assert m == m2 and m % block == 0 and scales.shape == (n, m // block)

    bm = _tile(mm, bm)
    bn = _tile(n, bn)
    # K tile must be a multiple of the scale block.
    bk = max(block, _tile(m, max(bk, block)))
    while m % bk != 0 or bk % block != 0:
        bk -= block
    grid = (mm // bm, n // bn, m // bk)

    return pl.pallas_call(
        functools.partial(_blockwise_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),             # x
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),             # codes
            pl.BlockSpec((bn, bk // block), lambda i, j, k: (j, k)),    # scales
            pl.BlockSpec((lut.shape[0],), lambda i, j, k: (0,)),        # codebook
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), jnp.float32),
        interpret=True,
    )(x, codes, scales, lut)
