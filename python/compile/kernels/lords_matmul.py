"""L1 — the LoRDS fused dequant-matmul Pallas kernel.

Computes ``y = x · (Q ⊙ (BA))ᵀ`` without ever materializing the full
``n × m`` scale matrix ``S = BA`` in HBM: each grid step reconstructs only
the ``(bn, bk)`` tile of ``S`` it needs, as a rank-r MXU matmul of a ``B``
row-tile with an ``A`` column-tile held in VMEM.

Hardware adaptation (paper: Triton/CUDA → here: Pallas/TPU)
-----------------------------------------------------------
The paper's Triton kernel stages int4 codes + per-block scales in shared
memory and fuses dequantization into the GEMM main loop of a threadblock
tile. On TPU the same insight maps to:

* threadblock (M, N) tile + K loop  →  3-D Pallas grid ``(M/bm, N/bn, K/bk)``
  with the K axis innermost; the HBM↔VMEM schedule the paper wrote with
  ``cp.async`` is expressed declaratively by the ``BlockSpec`` index maps.
* shared-memory staging                →  VMEM residency of the ``Q`` code
  tile, the ``B`` row-tile (bn × r) and the ``A`` column-tile (r × bk).
* tensor-core WMMA on dequantized fragments → an MXU matmul
  ``x_tile @ Ŵ_tileᵀ`` in f32 (bf16 on real hardware).

The only extra work LoRDS adds over plain block-wise dequant is the rank-r
outer product ``B_tile @ A_tile`` — O(r · bn · bk) MACs with r ≤ 24 — which
is why its latency tracks bitsandbytes-NF4 and beats QLoRA's extra adapter
GEMM (Figure 2 / Table 6).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for the Rust runtime;
real-TPU performance is estimated structurally in DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. bn/bk are multiples of the MXU lane width (128) on
# real hardware; trimmed automatically for small problem sizes.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _tile(dim: int, block: int) -> int:
    """Largest tile ≤ block that divides dim (keeps the grid exact)."""
    t = min(dim, block)
    while dim % t != 0:
        t -= 1
    return t


def _lords_kernel(x_ref, q_ref, b_ref, a_ref, lut_ref, o_ref, *, nsteps_k):
    """One (i, j, k) grid step: o[i,j] += x[i,k] · (lut[q[j,k]] ⊙ (B[j] A[k]))ᵀ."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Rank-r reconstruction of this tile of the scaling manifold: S = BA.
    s_tile = b_ref[...] @ a_ref[...]  # (bn, bk), r-deep MXU matmul
    # Codebook gather + elementwise scale = dequantized weight tile in VMEM.
    w_tile = jnp.take(lut_ref[...], q_ref[...], axis=0) * s_tile
    o_ref[...] += jnp.dot(x_ref[...], w_tile.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def lords_matmul(x, codes, b, a, lut, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """y[M,n] = x[M,m] · (lut[codes] ⊙ (b @ a))ᵀ, tiled LoRDS dequant-matmul.

    Args:
      x: activations, f32[M, m].
      codes: quantized weight codes, int32[n, m] (indices into ``lut``).
      b: scale factor, f32[n, r].
      a: scale factor, f32[r, m].
      lut: codebook levels, f32[L].
    """
    mm, m = x.shape
    n, m2 = codes.shape
    r = b.shape[1]
    assert m == m2 and b.shape == (n, r) and a.shape == (r, m), (x.shape, codes.shape, b.shape, a.shape)

    bm, bn, bk = _tile(mm, bm), _tile(n, bn), _tile(m, bk)
    grid = (mm // bm, n // bn, m // bk)

    return pl.pallas_call(
        functools.partial(_lords_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # x
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),  # codes
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),   # B row-tile
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),   # A col-tile
            pl.BlockSpec((lut.shape[0],), lambda i, j, k: (0,)),  # codebook
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), jnp.float32),
        interpret=True,
    )(x, codes, b, a, lut)


def vmem_bytes(bm: int, bn: int, bk: int, r: int, lut_len: int) -> int:
    """Estimated VMEM working set per grid step (f32 activations, i32 codes).

    Used by the perf pass to check the schedule fits the ~16 MiB/core VMEM
    budget on real TPU hardware (DESIGN.md §9).
    """
    return 4 * (bm * bk + bn * r + r * bk + bn * bk + bm * bn + lut_len) + 4 * (bn * bk)


def mxu_overhead_ratio(bm: int, bn: int, bk: int, r: int) -> float:
    """Extra MACs for the rank-r scale product relative to the main GEMM."""
    return (r * bn * bk) / float(bm * bn * bk)
