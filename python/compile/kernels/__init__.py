"""L1 Pallas kernels and their pure-jnp reference oracles.

* ``lords_matmul``     — fused LoRDS dequant-matmul ``x · (Q ⊙ (BA))ᵀ``.
* ``blockwise_matmul`` — block-wise NF4 baseline (bitsandbytes stand-in).
* ``qlora_matmul``     — block-wise base + unmergeable additive adapter.
* ``ref``              — straight-line jnp oracles + codebooks + metrics.
"""

from . import ref  # noqa: F401
from .blockwise_matmul import blockwise_matmul  # noqa: F401
from .lords_matmul import lords_matmul  # noqa: F401
from .qlora_matmul import qlora_matmul  # noqa: F401
