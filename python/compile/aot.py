"""AOT lowering: JAX/Pallas → HLO **text** artifacts + manifest for Rust.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime``) loads the HLO text via ``HloModuleProto::from_text_file``,
compiles it on the PJRT CPU client and executes it on the request path.

Interchange format is HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact inventory
------------------
* serving:   ``{mode}_prefill_b{B}`` / ``{mode}_decode_b{B}`` for
  mode ∈ {lords, nf4, qlora} — the Table-6 three-way comparison, executed by
  the Rust coordinator with bucketed batch shapes.
* eval:      ``{mode}_forward`` + ``fp_forward`` — perplexity scoring.
* training:  ``fp_step`` (testbed pre-training), ``qat_step`` (STE joint
  W/B/A), ``peft_step`` (B/A only) — loss+grads; AdamW lives in Rust.
* kernels:   ``{kind}_mm_m{M}`` micro-benchmarks for Figure 2 (LoRDS /
  blockwise-NF4 / QLoRA Pallas kernels + an fp GEMM roofline reference).

Every artifact is described in ``manifest.txt``: input/output names, dtypes
and shapes in execution order, plus the model config and the exact codebook
the codes were produced against. The manifest is the single source of truth
for the Rust side.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.blockwise_matmul import blockwise_matmul
from .kernels.lords_matmul import lords_matmul
from .kernels.qlora_matmul import qlora_matmul

# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the default printer elides
    big constants as ``{...}``, which the downstream text parser silently
    reads back as zeros — poisoning any artifact with a baked-in codebook
    LUT (caught by rust/tests/runtime_roundtrip.rs).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


class ManifestWriter:
    """Accumulates artifact descriptions and writes ``manifest.txt``."""

    def __init__(self, outdir: str, cfg: M.ModelConfig):
        self.outdir = outdir
        self.lines = []
        self.cfg = cfg
        self.lines.append("# lords-artifacts v1")
        self.lines.append(
            f"model vocab={cfg.vocab} d_model={cfg.d_model} n_layers={cfg.n_layers} "
            f"n_heads={cfg.n_heads} d_ff={cfg.d_ff} max_seq={cfg.max_seq} "
            f"block={cfg.block} codebook={cfg.codebook} qlora_rank={M.QLORA_RANK}"
        )
        lut = ref.codebook(cfg.codebook)
        self.lines.append("lut " + cfg.codebook + " " + ",".join(f"{v:.9g}" for v in lut))

    def add(self, name: str, fname: str, ins, outs):
        self.lines.append(f"artifact {name} {fname}")
        for nm, dt, shape in ins:
            dims = ",".join(str(d) for d in shape) if shape else "scalar"
            self.lines.append(f"in {nm} {_dtype_tag(dt)} {dims}")
        for nm, dt, shape in outs:
            dims = ",".join(str(d) for d in shape) if shape else "scalar"
            self.lines.append(f"out {nm} {_dtype_tag(dt)} {dims}")
        self.lines.append("end")

    def write(self):
        path = os.path.join(self.outdir, "manifest.txt")
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")
        print(f"[aot] wrote {path}")


def lower_artifact(mw: ManifestWriter, name: str, fn, in_specs, force: bool):
    """Lower ``fn(*flat_inputs)`` and persist HLO text + manifest entry.

    in_specs: list of (name, dtype, shape). fn must accept the flat inputs
    positionally and return a flat tuple; output specs are derived from the
    lowered signature.
    """
    fname = f"{name}.hlo.txt"
    path = os.path.join(mw.outdir, fname)
    avals = [jax.ShapeDtypeStruct(shape, dt) for (_, dt, shape) in in_specs]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*avals)
    out_avals = jax.eval_shape(fn, *avals)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    outs = [(f"out{i}", a.dtype, a.shape) for i, a in enumerate(out_avals)]
    if force or not os.path.exists(path):
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {name}: {len(text)/1e3:.0f} kB in {time.time()-t0:.1f}s")
    else:
        print(f"[aot] {name}: exists, skipped")
    mw.add(name, fname, in_specs, outs)


# ---------------------------------------------------------------------------
# Model artifact builders
# ---------------------------------------------------------------------------

MODE_NAMES = {
    "lords": (M.quant_param_names, M.quant_param_shape),
    "nf4": (M.nf4_param_names, M.nf4_param_shape),
    "qlora": (M.qlora_param_names, M.qlora_param_shape),
}


def _param_specs(cfg, names_fn, shape_fn):
    specs = []
    for n in names_fn(cfg):
        dt = jnp.int32 if n.endswith(".codes") else jnp.float32
        specs.append((n, dt, shape_fn(cfg, n)))
    return specs


def build_serving(mw, cfg, mode, prefill_batches, decode_batches, seq, force):
    names_fn, shape_fn = MODE_NAMES[mode]
    pspecs = _param_specs(cfg, names_fn, shape_fn)
    nparams = len(pspecs)

    for b in prefill_batches:
        def prefill_fn(*flat, _b=b):
            qparams = dict(zip([s[0] for s in pspecs], flat[:nparams]))
            tokens = flat[nparams]
            return M.prefill_mode(cfg, mode, qparams, tokens)

        ins = pspecs + [("tokens", jnp.int32, (b, seq))]
        lower_artifact(mw, f"{mode}_prefill_b{b}", prefill_fn, ins, force)

    cache_shape = (cfg.n_layers, None, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    for b in decode_batches:
        cs = tuple(b if d is None else d for d in cache_shape)

        def decode_fn(*flat, _b=b):
            qparams = dict(zip([s[0] for s in pspecs], flat[:nparams]))
            token, kc, vc, cur = flat[nparams:]
            return M.decode_mode(cfg, mode, qparams, token, kc, vc, cur)

        ins = pspecs + [
            ("token", jnp.int32, (b, 1)),
            ("k_cache", jnp.float32, cs),
            ("v_cache", jnp.float32, cs),
            ("cur", jnp.int32, ()),
        ]
        lower_artifact(mw, f"{mode}_decode_b{b}", decode_fn, ins, force)


def build_eval(mw, cfg, batch, seq, force):
    # fp forward (the unquantized reference row of Tables 1/4)
    fp_specs = [(n, jnp.float32, M.param_shape(cfg, n)) for n in M.param_names(cfg)]
    nfp = len(fp_specs)

    def fp_fwd(*flat):
        params = dict(zip([s[0] for s in fp_specs], flat[:nfp]))
        return (M.forward(cfg, params, flat[nfp]),)

    lower_artifact(mw, "fp_forward", fp_fwd,
                   fp_specs + [("tokens", jnp.int32, (batch, seq))], force)

    for mode in ("lords", "nf4", "qlora"):
        names_fn, shape_fn = MODE_NAMES[mode]
        pspecs = _param_specs(cfg, names_fn, shape_fn)
        np_ = len(pspecs)

        def fwd(*flat, _mode=mode, _pspecs=pspecs, _np=np_):
            qparams = dict(zip([s[0] for s in _pspecs], flat[:_np]))
            return (M.forward_mode(cfg, _mode, qparams, flat[_np]),)

        lower_artifact(mw, f"{mode}_forward", fwd,
                       pspecs + [("tokens", jnp.int32, (batch, seq))], force)


def build_training(mw, cfg, batch, seq, force):
    tok = [("tokens", jnp.int32, (batch, seq)), ("targets", jnp.int32, (batch, seq))]

    # fp pre-training step
    fp_names = M.param_names(cfg)
    fp_specs = [(n, jnp.float32, M.param_shape(cfg, n)) for n in fp_names]
    fp_fn = M.fp_grad_fn(cfg)

    def fp_step(*flat):
        return fp_fn(list(flat[: len(fp_specs)]), flat[-2], flat[-1])

    lower_artifact(mw, "fp_step", fp_step, fp_specs + tok, force)

    # QAT step (STE)
    qat_names = M.qat_param_names(cfg)
    qat_specs = []
    for n in qat_names:
        shape = M.quant_param_shape(cfg, n) if (n.endswith(".B") or n.endswith(".A")) \
            else M.param_shape(cfg, n)
        qat_specs.append((n, jnp.float32, shape))
    qat_fn = M.qat_grad_fn(cfg)

    def qat_step(*flat):
        return qat_fn(list(flat[: len(qat_specs)]), flat[-2], flat[-1])

    lower_artifact(mw, "qat_step", qat_step, qat_specs + tok, force)

    # PEFT step (B/A only, frozen codes)
    peft_specs = _param_specs(cfg, M.quant_param_names, M.quant_param_shape)
    peft_fn = M.peft_grad_fn(cfg)

    def peft_step(*flat):
        return peft_fn(list(flat[: len(peft_specs)]), flat[-2], flat[-1])

    lower_artifact(mw, "peft_step", peft_step, peft_specs + tok, force)


def build_kernels(mw, cfg, m_sweep, n, m, force):
    """Figure-2 micro-benchmark kernels at the scaled q_proj shape."""
    block = cfg.block
    r = ref.parity_rank(n, m, block)
    lut = ref.codebook(cfg.codebook)
    llen = len(lut)

    for mm in m_sweep:
        ins_common = [("x", jnp.float32, (mm, m)), ("codes", jnp.int32, (n, m))]
        lut_spec = ("lut", jnp.float32, (llen,))

        def lords_fn(x, codes, b, a, lutv):
            return (lords_matmul(x, codes, b, a, lutv),)

        lower_artifact(mw, f"lords_mm_m{mm}", lords_fn,
                       ins_common + [("B", jnp.float32, (n, r)),
                                     ("A", jnp.float32, (r, m)), lut_spec], force)

        def nf4_fn(x, codes, scales, lutv):
            return (blockwise_matmul(x, codes, scales, lutv, block=block),)

        lower_artifact(mw, f"nf4_mm_m{mm}", nf4_fn,
                       ins_common + [("scales", jnp.float32, (n, m // block)), lut_spec],
                       force)

        def qlora_fn(x, codes, scales, la, lb, lutv):
            return (qlora_matmul(x, codes, scales, la, lb, lutv, block=block),)

        lower_artifact(mw, f"qlora_mm_m{mm}", qlora_fn,
                       ins_common + [("scales", jnp.float32, (n, m // block)),
                                     ("lora_a", jnp.float32, (M.QLORA_RANK, m)),
                                     ("lora_b", jnp.float32, (n, M.QLORA_RANK)), lut_spec],
                       force)

        def fp_fn(x, w):
            return (x @ w.T,)

        lower_artifact(mw, f"fp_mm_m{mm}", fp_fn,
                       [("x", jnp.float32, (mm, m)), ("w", jnp.float32, (n, m))], force)


# ---------------------------------------------------------------------------
# Presets + main
# ---------------------------------------------------------------------------

PRESETS = {
    # the main testbed: ~7M params, 4 layers — big enough for real PPL
    # separation between quant methods, small enough for CPU serving.
    "default": M.ModelConfig(vocab=512, d_model=256, n_layers=4, n_heads=4,
                             d_ff=512, max_seq=256, block=64, codebook="nf4"),
    # minutes-fast preset used by pytest to validate the AOT path end-to-end.
    "mini": M.ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                          d_ff=64, max_seq=32, block=16, codebook="nf4"),
}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--outdir", default="../artifacts")
    p.add_argument("--preset", default="default", choices=sorted(PRESETS))
    p.add_argument("--force", action="store_true", help="re-lower even if file exists")
    p.add_argument("--only", default="", help="comma list: serving,eval,training,kernels")
    args = p.parse_args(argv)

    cfg = PRESETS[args.preset]
    os.makedirs(args.outdir, exist_ok=True)
    mw = ManifestWriter(args.outdir, cfg)

    only = set(args.only.split(",")) if args.only else {"serving", "eval", "training", "kernels"}
    seq = min(128, cfg.max_seq // 2)
    if "serving" in only:
        for mode in ("lords", "nf4", "qlora"):
            build_serving(mw, cfg, mode, prefill_batches=(1, 2, 4),
                          decode_batches=(1, 2, 4, 8), seq=seq, force=args.force)
    if "eval" in only:
        build_eval(mw, cfg, batch=4, seq=seq, force=args.force)
    if "training" in only:
        build_training(mw, cfg, batch=8, seq=seq, force=args.force)
    if "kernels" in only:
        n = m = 512 if cfg.d_model >= 128 else 64
        build_kernels(mw, cfg, m_sweep=(64, 256, 1024, 4096) if cfg.d_model >= 128 else (16,),
                      n=n, m=m, force=args.force)
    mw.write()
    print(f"[aot] done: preset={args.preset} outdir={args.outdir}")


if __name__ == "__main__":
    sys.exit(main())
