"""L2 — Llama-style decoder-only transformer with LoRDS fake-quant linears.

This is the build-time JAX model. It exists to be lowered once by
``aot.py`` into HLO-text artifacts that the Rust runtime executes; Python
never runs on the request path.

Three operating modes, all sharing the same parameter layout:

* ``forward``        — full-precision forward (testbed pre-training, the
                       fp baseline serving artifact).
* ``forward_lords``  — serving forward: every block linear is
                       ``x · (lut[Q] ⊙ (BA))ᵀ`` with frozen int codes; this
                       is what the prefill/decode artifacts lower.
* ``qat_loss`` / ``peft_loss`` — training losses. QAT fake-quantizes W
                       through the STE rule of eqs. (4)–(5) and
                       differentiates (W, B, A) jointly; PEFT freezes the
                       codes and differentiates (B, A) only (the update is
                       exactly the paper's multiplicative ΔW = Q ⊙ (B'A'−BA)).

Parameter layout (per layer ``l``):
  attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down
plus ``tok_emb``, ``final_norm``, ``lm_head``. Linears are stored as
``(out, in)`` matrices, matching the paper's W ∈ R^{n×m} convention.

The deterministic flattening order used for the AOT artifact signatures is
defined by :func:`param_names` / :func:`quant_param_names` and recorded in
the artifact manifest consumed by ``rust/src/runtime``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-Llama architecture used as the quantization testbed."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 256
    rope_theta: float = 10000.0
    # quantization knobs (used by fake-quant modes)
    codebook: str = "nf4"
    block: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def linear_shapes(self) -> Dict[str, tuple]:
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "w_gate": (f, d), "w_up": (f, d), "w_down": (d, f),
        }


LINEAR_NAMES = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]


# ---------------------------------------------------------------------------
# Parameter construction / flattening (deterministic order for the manifest)
# ---------------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> List[str]:
    """Full-precision parameter order: the AOT artifact input signature."""
    names = ["tok_emb"]
    for l in range(cfg.n_layers):
        names.append(f"l{l}.attn_norm")
        for w in LINEAR_NAMES:
            names.append(f"l{l}.{w}")
        names.append(f"l{l}.mlp_norm")
    names += ["final_norm", "lm_head"]
    return names


def param_shape(cfg: ModelConfig, name: str) -> tuple:
    if name in ("tok_emb", "lm_head"):
        return (cfg.vocab, cfg.d_model)
    if name == "final_norm":
        return (cfg.d_model,)
    _, field = name.split(".")
    if field.endswith("norm"):
        return (cfg.d_model,)
    return cfg.linear_shapes()[field]


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Scaled-Gaussian init (0.02, shrunk on residual-out projections)."""
    rng = np.random.default_rng(seed)
    params = {}
    resid_scale = 0.02 / np.sqrt(2.0 * cfg.n_layers)
    for name in param_names(cfg):
        shape = param_shape(cfg, name)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = resid_scale if name.split(".")[-1] in ("wo", "w_down") else 0.02
            params[name] = jnp.asarray(rng.standard_normal(shape) * std, jnp.float32)
    return params


def lords_rank(cfg: ModelConfig, name: str) -> int:
    n, m = param_shape(cfg, name)
    return ref.parity_rank(n, m, cfg.block)


def quant_param_names(cfg: ModelConfig) -> List[str]:
    """Quantized-model parameter order (serving + PEFT artifacts).

    Block linears expand to ``{name}.codes`` (int32), ``{name}.B``,
    ``{name}.A``; everything else stays a single fp32 tensor.
    """
    names = []
    for name in param_names(cfg):
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            names += [f"{name}.codes", f"{name}.B", f"{name}.A"]
        else:
            names.append(name)
    return names


def quant_param_shape(cfg: ModelConfig, qname: str) -> tuple:
    base, _, kind = qname.rpartition(".")
    if kind in ("codes", "B", "A") and base:
        n, m = param_shape(cfg, base)
        r = lords_rank(cfg, base)
        return {"codes": (n, m), "B": (n, r), "A": (r, m)}[kind]
    return param_shape(cfg, qname)


def quantize_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """LoRDS-quantize every block linear (SVD init, no refinement).

    Refinement happens in Rust (Algorithm 1) or via the QAT/PEFT artifacts;
    this produces the initial quantized checkpoint.
    """
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    out: Dict[str, jnp.ndarray] = {}
    for name in param_names(cfg):
        w = params[name]
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            r = lords_rank(cfg, name)
            b, a = ref.lords_init(w, cfg.block, r)
            codes = ref.quantize_codes(w, b @ a, lut)
            out[f"{name}.codes"] = codes
            out[f"{name}.B"] = b
            out[f"{name}.A"] = a
        else:
            out[name] = w
    return out


# --- block-wise NF4 + QLoRA serving layouts (Table 6 / Fig. 2 baselines) ---

QLORA_RANK = 16


def nf4_param_names(cfg: ModelConfig) -> List[str]:
    """bitsandbytes-style layout: ``codes`` + per-block ``scales``."""
    names = []
    for name in param_names(cfg):
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            names += [f"{name}.codes", f"{name}.scales"]
        else:
            names.append(name)
    return names


def nf4_param_shape(cfg: ModelConfig, qname: str) -> tuple:
    base, _, kind = qname.rpartition(".")
    if kind in ("codes", "scales") and base:
        n, m = param_shape(cfg, base)
        return {"codes": (n, m), "scales": (n, m // cfg.block)}[kind]
    return param_shape(cfg, qname)


def qlora_param_names(cfg: ModelConfig) -> List[str]:
    """QLoRA layout: NF4 base + unmergeable fp adapter per linear."""
    names = []
    for name in param_names(cfg):
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            names += [f"{name}.codes", f"{name}.scales", f"{name}.lora_a", f"{name}.lora_b"]
        else:
            names.append(name)
    return names


def qlora_param_shape(cfg: ModelConfig, qname: str) -> tuple:
    base, _, kind = qname.rpartition(".")
    if kind in ("codes", "scales", "lora_a", "lora_b") and base:
        n, m = param_shape(cfg, base)
        return {
            "codes": (n, m),
            "scales": (n, m // cfg.block),
            "lora_a": (QLORA_RANK, m),
            "lora_b": (n, QLORA_RANK),
        }[kind]
    return param_shape(cfg, qname)


def nf4_quantize_params(cfg: ModelConfig, params):
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    out = {}
    for name in param_names(cfg):
        w = params[name]
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            codes, scales, _ = ref.blockwise_quantize(w, cfg.block, lut)
            out[f"{name}.codes"] = codes
            out[f"{name}.scales"] = scales
        else:
            out[name] = w
    return out


def qlora_quantize_params(cfg: ModelConfig, params, seed: int = 1):
    rng = np.random.default_rng(seed)
    out = nf4_quantize_params(cfg, params)
    for name in param_names(cfg):
        if "." in name and name.split(".")[1] in LINEAR_NAMES:
            n, m = param_shape(cfg, name)
            # LoRA init: A ~ N(0, 1/r), B = 0 (standard Kaiming-zero pairing)
            out[f"{name}.lora_a"] = jnp.asarray(
                rng.standard_normal((QLORA_RANK, m)) / np.sqrt(QLORA_RANK), jnp.float32)
            out[f"{name}.lora_b"] = jnp.zeros((n, QLORA_RANK), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# STE fake-quant primitive (eqs. 4–5)
# ---------------------------------------------------------------------------


def make_fake_quant(lut: jnp.ndarray):
    """Returns the STE fake-quant fn Ŵ = ROUND(W ⊘ (BA)) ⊙ (BA) for one LUT."""

    @jax.custom_vjp
    def fake_quant(w, b, a):
        s = b @ a
        q = lut[ref.quantize_codes(w, s, lut)]
        return q * s

    def fwd(w, b, a):
        s = b @ a
        q = lut[ref.quantize_codes(w, s, lut)]
        return q * s, (q, w, s, b, a)

    def bwd(res, g):
        q, w, s, b, a = res
        # eq. (4): ∇_W ≈ g  |  eq. (5): ∇_S ≈ g ⊙ (Q − W ⊘ S), chained to B, A.
        gs = g * (q - w / s)
        return g, gs @ a.T, b.T @ gs

    fake_quant.defvjp(fwd, bwd)
    return fake_quant


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * gamma


def rope(x, pos, theta):
    """Rotary embedding; x: [b, seq, heads, head_dim], pos: [seq]."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]  # [seq, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def _linear(w, x):
    """Apply an effective weight; QLoRA weights are (base, lora_a, lora_b)
    tuples whose adapter path runs as separate matmuls (unmergeable)."""
    if isinstance(w, tuple):
        base, la, lb = w
        return x @ base.T + (x @ la.T) @ lb.T
    return x @ w.T


def _block_forward(cfg, x, pos, lw, kv=None):
    """One transformer block. ``lw`` maps field → effective fp weight.

    kv: optional (k_cache, v_cache, cur_pos) for incremental decoding with
    caches of static length ``cfg.max_seq``; returns (x, new_k, new_v).
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    hx = rmsnorm(x, lw["attn_norm"])
    q = _linear(lw["wq"], hx).reshape(b, s, h, hd)
    k = _linear(lw["wk"], hx).reshape(b, s, h, hd)
    v = _linear(lw["wv"], hx).reshape(b, s, h, hd)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    if kv is None:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
        att = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), v)
        new_k, new_v = k, v
    else:
        k_cache, v_cache, cur = kv
        new_k = jax.lax.dynamic_update_slice(k_cache, k, (0, cur, 0, 0))
        new_v = jax.lax.dynamic_update_slice(v_cache, v, (0, cur, 0, 0))
        # causal within the fresh chunk + visibility of all cached history
        kpos = jnp.arange(k_cache.shape[1])[None, :]
        qpos = cur + jnp.arange(s)[:, None]
        mask = kpos <= qpos
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, new_k) / jnp.sqrt(float(hd))
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
        att = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), new_v)
    x = x + _linear(lw["wo"], att.reshape(b, s, d))

    hx = rmsnorm(x, lw["mlp_norm"])
    gate = jax.nn.silu(_linear(lw["w_gate"], hx))
    up = _linear(lw["w_up"], hx)
    x = x + _linear(lw["w_down"], gate * up)
    return x, new_k, new_v


def _effective_weights(cfg, params, mode, lut=None, fake_quant=None):
    """Per-layer dict of *effective* fp weights under the given mode.

    mode: 'fp'    — params are fp tensors, used as-is.
          'lords' — params are quantized (codes/B/A); Ŵ = lut[Q] ⊙ (BA).
          'qat'   — params carry both W and (B, A); Ŵ = fake_quant(W, B, A).
    """
    layers = []
    for l in range(cfg.n_layers):
        lw = {}
        for field in ("attn_norm", "mlp_norm"):
            lw[field] = params[f"l{l}.{field}"]
        for field in LINEAR_NAMES:
            key = f"l{l}.{field}"
            if mode == "fp":
                lw[field] = params[key]
            elif mode == "lords":
                s = params[f"{key}.B"] @ params[f"{key}.A"]
                lw[field] = jnp.take(lut, params[f"{key}.codes"], axis=0) * s
            elif mode == "nf4":
                s_full = jnp.repeat(params[f"{key}.scales"], cfg.block, axis=1)
                lw[field] = jnp.take(lut, params[f"{key}.codes"], axis=0) * s_full
            elif mode == "qlora":
                s_full = jnp.repeat(params[f"{key}.scales"], cfg.block, axis=1)
                base = jnp.take(lut, params[f"{key}.codes"], axis=0) * s_full
                # the unmergeable adapter: effective W = Ŵ + B_l A_l, but the
                # adapter matmul cannot be folded at serving time — model the
                # extra work by keeping the two paths separate (see _block_qlora)
                lw[field] = (base, params[f"{key}.lora_a"], params[f"{key}.lora_b"])
            elif mode == "qat":
                lw[field] = fake_quant(params[key], params[f"{key}.B"], params[f"{key}.A"])
            else:
                raise ValueError(mode)
        layers.append(lw)
    return layers


def _trunk(cfg, params, layers, tokens, kv_caches=None, cur=None):
    """Shared embedding → blocks → final-norm → logits pipeline."""
    x = jnp.take(params["tok_emb"], tokens, axis=0)
    s = tokens.shape[1]
    pos = jnp.arange(s) if cur is None else cur + jnp.arange(s)
    new_ks, new_vs = [], []
    for l, lw in enumerate(layers):
        kv = None if kv_caches is None else (kv_caches[0][l], kv_caches[1][l], cur)
        x, nk, nv = _block_forward(cfg, x, pos, lw, kv)
        new_ks.append(nk)
        new_vs.append(nv)
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"].T
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


# ---------------------------------------------------------------------------
# Public forwards / losses
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, tokens):
    """Full-precision forward; logits [b, s, vocab]."""
    layers = _effective_weights(cfg, params, "fp")
    logits, _, _ = _trunk(cfg, params, layers, tokens)
    return logits


def forward_mode(cfg: ModelConfig, mode: str, qparams, tokens):
    """Serving forward on a quantized checkpoint; mode ∈ {lords, nf4, qlora}."""
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    layers = _effective_weights(cfg, qparams, mode, lut=lut)
    logits, _, _ = _trunk(cfg, qparams, layers, tokens)
    return logits


def forward_lords(cfg: ModelConfig, qparams, tokens):
    return forward_mode(cfg, "lords", qparams, tokens)


def prefill_mode(cfg: ModelConfig, mode: str, qparams, tokens):
    """Prefill: logits for the last position + populated KV caches.

    Caches have static length ``cfg.max_seq`` so decode steps keep a fixed
    signature. Returns (last_logits [b, vocab], k_cache, v_cache) with
    caches shaped [L, b, max_seq, h, hd].
    """
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    layers = _effective_weights(cfg, qparams, mode, lut=lut)
    b = tokens.shape[0]
    k0 = jnp.zeros((cfg.n_layers, b, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32)
    v0 = jnp.zeros_like(k0)
    logits, ks, vs = _trunk(cfg, qparams, layers, tokens,
                            kv_caches=(k0, v0), cur=jnp.int32(0))
    return logits[:, -1, :], ks, vs


def decode_mode(cfg: ModelConfig, mode: str, qparams, token, k_cache, v_cache, cur):
    """One decode step: token [b, 1] appended at position ``cur`` (int32)."""
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    layers = _effective_weights(cfg, qparams, mode, lut=lut)
    logits, ks, vs = _trunk(cfg, qparams, layers, token,
                            kv_caches=(k_cache, v_cache), cur=cur)
    return logits[:, -1, :], ks, vs


def lm_loss(logits, targets):
    """Mean token cross-entropy; targets [b, s] int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def fp_loss(cfg, params, tokens, targets):
    return lm_loss(forward(cfg, params, tokens), targets)


def qat_loss(cfg: ModelConfig, params, tokens, targets):
    """QAT objective: fake-quant every block linear via STE, differentiate
    jointly w.r.t. W, B, A (Section 3.3)."""
    lut = jnp.asarray(ref.codebook(cfg.codebook))
    fq = make_fake_quant(lut)
    layers = _effective_weights(cfg, params, "qat", fake_quant=fq)
    logits, _, _ = _trunk(cfg, params, layers, tokens)
    return lm_loss(logits, targets)


def peft_loss(cfg: ModelConfig, qparams, tokens, targets):
    """PEFT objective on frozen codes: exactly differentiable in (B, A) —
    the multiplicative update ΔW = Q ⊙ (B'A' − BA) of Section 3.4."""
    logits = forward_lords(cfg, qparams, tokens)
    return lm_loss(logits, targets)


# ---------------------------------------------------------------------------
# Grad functions (lowered by aot.py; the optimizer lives in Rust)
# ---------------------------------------------------------------------------


def peft_trainable(cfg: ModelConfig) -> List[str]:
    """Names of the PEFT-trainable tensors (every linear's B and A)."""
    return [n for n in quant_param_names(cfg) if n.endswith(".B") or n.endswith(".A")]


def qat_param_names(cfg: ModelConfig) -> List[str]:
    """QAT artifact signature: fp params plus (B, A) per block linear."""
    return param_names(cfg) + [
        f"l{l}.{w}.{ba}" for l in range(cfg.n_layers) for w in LINEAR_NAMES for ba in ("B", "A")
    ]


def qat_trainable(cfg: ModelConfig) -> List[str]:
    """QAT trains W jointly with B and A for every block linear."""
    return [
        f"l{l}.{w}{suffix}"
        for l in range(cfg.n_layers)
        for w in LINEAR_NAMES
        for suffix in ("", ".B", ".A")
    ]


def peft_grad_fn(cfg: ModelConfig):
    """(qparam_list, tokens, targets) → (loss, *grads over peft_trainable)."""
    qnames = quant_param_names(cfg)
    tnames = peft_trainable(cfg)

    def fn(plist, tokens, targets):
        qparams = dict(zip(qnames, plist))

        def loss_of(tvals):
            merged = dict(qparams)
            merged.update(dict(zip(tnames, tvals)))
            return peft_loss(cfg, merged, tokens, targets)

        tvals = [qparams[n] for n in tnames]
        loss, grads = jax.value_and_grad(loss_of)(tvals)
        return (loss, *grads)

    return fn


def qat_grad_fn(cfg: ModelConfig):
    """(qat_param_list, tokens, targets) → (loss, *grads over qat_trainable)."""
    names = qat_param_names(cfg)
    tnames = qat_trainable(cfg)

    def fn(plist, tokens, targets):
        params = dict(zip(names, plist))

        def loss_of(tvals):
            merged = dict(params)
            merged.update(dict(zip(tnames, tvals)))
            return qat_loss(cfg, merged, tokens, targets)

        tvals = [params[n] for n in tnames]
        loss, grads = jax.value_and_grad(loss_of)(tvals)
        return (loss, *grads)

    return fn


def fp_grad_fn(cfg: ModelConfig):
    """Full-precision pre-training step: grads for every parameter."""
    names = param_names(cfg)

    def fn(plist, tokens, targets):
        def loss_of(tvals):
            return fp_loss(cfg, dict(zip(names, tvals)), tokens, targets)

        loss, grads = jax.value_and_grad(loss_of)(list(plist))
        return (loss, *grads)

    return fn
