"""Build-time Python package: L1 Pallas kernels + L2 JAX model + AOT lowering.

Never imported at runtime — the Rust binary consumes only the HLO-text
artifacts this package emits into ``artifacts/``.
"""
