"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes, ranks, block sizes and codebooks; every case
asserts allclose against ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.blockwise_matmul import blockwise_matmul
from compile.kernels.lords_matmul import lords_matmul
from compile.kernels.qlora_matmul import qlora_matmul

RTOL, ATOL = 1e-4, 1e-5


def _mk_weight(rng, n, m, outliers=True):
    w = rng.standard_normal((n, m)).astype(np.float32) * 0.05
    if outliers:
        # heavy-tail channels, the regime where block scaling struggles
        cols = rng.choice(m, size=max(1, m // 32), replace=False)
        w[:, cols] *= 8.0
    return jnp.asarray(w)


dims = st.sampled_from([32, 64, 96])
blocks = st.sampled_from([16, 32])
cbs = st.sampled_from(["nf4", "nf2", "int4"])


@settings(max_examples=8, deadline=None)
@given(n=dims, m=dims, mm=st.sampled_from([8, 16]), block=blocks,
       cb=cbs, seed=st.integers(0, 2**16))
def test_lords_matmul_matches_ref(n, m, mm, block, cb, seed):
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(ref.codebook(cb))
    w = _mk_weight(rng, n, m)
    x = jnp.asarray(rng.standard_normal((mm, m)), jnp.float32)
    r = max(2, ref.parity_rank(n, m, block))
    b, a = ref.lords_init(w, block if m % block == 0 else 16, r)
    codes = ref.quantize_codes(w, b @ a, lut)
    y_ref = ref.lords_matmul_ref(x, codes, b, a, lut)
    y = lords_matmul(x, codes, b, a, lut, bm=16, bn=32, bk=32)
    np.testing.assert_allclose(y, y_ref, rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(n=dims, m=dims, mm=st.sampled_from([8, 16]), block=blocks,
       cb=cbs, seed=st.integers(0, 2**16))
def test_blockwise_matmul_matches_ref(n, m, mm, block, cb, seed):
    if m % block != 0:
        block = 16
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(ref.codebook(cb))
    w = _mk_weight(rng, n, m)
    x = jnp.asarray(rng.standard_normal((mm, m)), jnp.float32)
    codes, scales, _ = ref.blockwise_quantize(w, block, lut)
    y_ref = ref.blockwise_matmul_ref(x, codes, scales, lut, block)
    y = blockwise_matmul(x, codes, scales, lut, block=block, bm=16, bn=32, bk=32)
    np.testing.assert_allclose(y, y_ref, rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(n=dims, m=dims, mm=st.sampled_from([8, 16]), block=blocks,
       r=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16))
def test_qlora_matmul_matches_ref(n, m, mm, block, r, seed):
    if m % block != 0:
        block = 16
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(ref.codebook("nf4"))
    w = _mk_weight(rng, n, m)
    x = jnp.asarray(rng.standard_normal((mm, m)), jnp.float32)
    codes, scales, _ = ref.blockwise_quantize(w, block, lut)
    la = jnp.asarray(rng.standard_normal((r, m)) * 0.02, jnp.float32)
    lb = jnp.asarray(rng.standard_normal((n, r)) * 0.02, jnp.float32)
    y_ref = ref.qlora_matmul_ref(x, codes, scales, lut, block, la, lb)
    y = qlora_matmul(x, codes, scales, la, lb, lut, block=block, bm=16, bn=32, bk=32)
    np.testing.assert_allclose(y, y_ref, rtol=RTOL, atol=ATOL)


def test_lords_tile_shape_invariance():
    """Result must not depend on the tiling chosen."""
    rng = np.random.default_rng(7)
    lut = jnp.asarray(ref.codebook("nf4"))
    n = m = 128
    w = _mk_weight(rng, n, m)
    x = jnp.asarray(rng.standard_normal((64, m)), jnp.float32)
    b, a = ref.lords_init(w, 32, 4)
    codes = ref.quantize_codes(w, b @ a, lut)
    outs = [
        lords_matmul(x, codes, b, a, lut, bm=bm, bn=bn, bk=bk)
        for (bm, bn, bk) in [(64, 128, 128), (16, 32, 32), (32, 64, 128), (64, 16, 64)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_quantize_codes_argmin_semantics():
    """Codes must be the argmin of (S·v − W)² even with negative scales."""
    lut = jnp.asarray(ref.codebook("nf4"))
    w = jnp.asarray([[0.5, -0.5]], jnp.float32)
    s = jnp.asarray([[1.0, -1.0]], jnp.float32)  # negative scale flips sign
    codes = ref.quantize_codes(w, s, lut)
    w_hat = ref.dequantize(codes, s, lut)
    assert float(jnp.max(jnp.abs(w_hat - w))) < 0.1


def test_lords_exactly_recovers_blockwise_at_full_rank():
    """eq. 3: SVD init with rank ≥ rank(S) reproduces block-wise scaling."""
    rng = np.random.default_rng(3)
    lut = jnp.asarray(ref.codebook("nf4"))
    n, m, block = 64, 64, 16
    w = _mk_weight(rng, n, m, outliers=False)
    full_rank = m // block  # rank(S) ≤ m/B
    b, a = ref.lords_init(w, block, full_rank)
    s_block = ref.expand_scales(ref.blockwise_scales(w, block), block)
    np.testing.assert_allclose(b @ a, s_block, rtol=1e-4, atol=1e-5)


def test_lords_beats_blockwise_on_outliers():
    """The paper's core claim at the matrix level: with outlier channels and
    parity parameter budget, refined LoRDS reconstruction ≤ block-wise."""
    rng = np.random.default_rng(11)
    lut = jnp.asarray(ref.codebook("nf4"))
    n, m, block = 128, 128, 32
    w = _mk_weight(rng, n, m, outliers=True)
    # block-wise baseline
    _, _, w_nf4 = ref.blockwise_quantize(w, block, lut)
    err_block = float(jnp.linalg.norm(w - w_nf4))
    # LoRDS with parity rank + Algorithm-1 refinement (numpy AdamW on
    # ||W - (BA)⊙Q||², matching the Rust implementation)
    r = max(2, ref.parity_rank(n, m, block))
    b, a = ref.lords_init(w, block, r)
    b, a = np.array(b, copy=True), np.array(a, copy=True)
    wn = np.asarray(w)
    lutn = np.asarray(lut)
    mb, vb = np.zeros_like(b), np.zeros_like(b)
    ma, va = np.zeros_like(a), np.zeros_like(a)
    lr, b1, b2, eps = 0.05, 0.9, 0.999, 1e-8
    for t in range(1, 201):
        s = b @ a
        q = lutn[np.asarray(ref.quantize_codes(jnp.asarray(wn), jnp.asarray(s), lut))]
        gs = ((s * q) - wn) * q / (n * m)
        gb, ga = gs @ a.T, b.T @ gs
        for (p, g, m1, v1) in ((b, gb, mb, vb), (a, ga, ma, va)):
            m1[:] = b1 * m1 + (1 - b1) * g
            v1[:] = b2 * v1 + (1 - b2) * g * g
            p -= lr * (m1 / (1 - b1**t)) / (np.sqrt(v1 / (1 - b2**t)) + eps)
    s = b @ a
    q = lutn[np.asarray(ref.quantize_codes(jnp.asarray(wn), jnp.asarray(s), lut))]
    err_lords = float(np.linalg.norm(wn - s * q))
    assert err_lords < err_block, (err_lords, err_block)
