"""STE fake-quant: forward value and custom_vjp gradients (eqs. 4–5)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref


def _setup(seed, n=48, m=64, block=16, r=3):
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(ref.codebook("nf4"))
    w = jnp.asarray(rng.standard_normal((n, m)) * 0.05, jnp.float32)
    b, a = ref.lords_init(w, block, r)
    return lut, w, b, a


def test_fake_quant_forward_matches_ref():
    lut, w, b, a = _setup(0)
    fq = M.make_fake_quant(lut)
    np.testing.assert_allclose(fq(w, b, a), ref.fake_quant(w, b, a, lut),
                               rtol=1e-6, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_ste_grads_match_reference_formula(seed):
    lut, w, b, a = _setup(seed)
    fq = M.make_fake_quant(lut)
    g = jnp.asarray(np.random.default_rng(seed + 1).standard_normal(w.shape), jnp.float32)

    def loss(w_, b_, a_):
        return jnp.sum(fq(w_, b_, a_) * g)

    gw, gb, ga = jax.grad(loss, argnums=(0, 1, 2))(w, b, a)
    gw_ref, gb_ref, ga_ref = ref.ste_grads(w, b, a, lut, g)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, gb_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ga, ga_ref, rtol=1e-4, atol=1e-5)


def test_ste_weight_gradient_is_identity():
    """eq. 4: ∂L/∂W ≈ ∂L/∂Ŵ — the straight-through estimator."""
    lut, w, b, a = _setup(5)
    fq = M.make_fake_quant(lut)
    gw = jax.grad(lambda w_: jnp.sum(fq(w_, b, a)))(w)
    np.testing.assert_allclose(gw, jnp.ones_like(w), rtol=1e-6, atol=1e-6)


def test_scale_gradient_finite_difference():
    """∇_B matches finite differences of the *dequantized* loss surface when
    no code flips occur (the smooth region where eq. 5 is exact)."""
    lut, w, b, a = _setup(9)
    fq = M.make_fake_quant(lut)
    g = jnp.ones_like(w)

    def loss_ba(b_):
        # freeze the codes at their current values to stay in the smooth region
        s = b_ @ a
        codes = ref.quantize_codes(w, b @ a, lut)  # codes from unperturbed b
        return jnp.sum(lut[codes] * s * g)

    gb_analytic = jax.grad(loss_ba)(b)
    eps = 1e-3
    i, j = 2, 1
    bp = b.at[i, j].add(eps)
    bm = b.at[i, j].add(-eps)
    fd = (loss_ba(bp) - loss_ba(bm)) / (2 * eps)
    np.testing.assert_allclose(gb_analytic[i, j], fd, rtol=1e-2)
