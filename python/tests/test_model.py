"""L2 model: shapes, quantized-forward consistency, prefill/decode equality,
PEFT/QAT gradient plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=48,
                    max_seq=32, block=16, codebook="nf4")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 16)), jnp.int32)


def test_forward_shape(params, tokens):
    logits = M.forward(CFG, params, tokens)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_quantized_forward_close_to_fp(params, tokens):
    """4-bit LoRDS logits should stay close to fp logits on a tiny model."""
    qparams = M.quantize_params(CFG, params)
    lfp = M.forward(CFG, params, tokens)
    lq = M.forward_mode(CFG, "lords", qparams, tokens)
    # small-weight regime: quantization noise must not blow up the logits
    assert float(jnp.max(jnp.abs(lfp - lq))) < 0.5 * float(jnp.max(jnp.abs(lfp)) + 1.0)


@pytest.mark.parametrize("mode,quantizer", [
    ("lords", M.quantize_params),
    ("nf4", M.nf4_quantize_params),
    ("qlora", M.qlora_quantize_params),
])
def test_prefill_decode_matches_full_forward(params, tokens, mode, quantizer):
    """Incremental decoding must agree with the full causal forward."""
    qparams = quantizer(CFG, params)
    full = M.forward_mode(CFG, mode, qparams, tokens)

    s = tokens.shape[1]
    last, kc, vc = M.prefill_mode(CFG, mode, qparams, tokens[:, : s - 1])
    np.testing.assert_allclose(last, full[:, s - 2, :], rtol=1e-4, atol=1e-4)

    logit, kc, vc = M.decode_mode(CFG, mode, qparams, tokens[:, s - 1 :],
                                  kc, vc, jnp.int32(s - 1))
    np.testing.assert_allclose(logit, full[:, s - 1, :], rtol=1e-4, atol=1e-4)


def test_qlora_zero_adapter_equals_nf4(params, tokens):
    """With B_l = 0 the QLoRA forward must equal the plain NF4 forward."""
    nf4 = M.nf4_quantize_params(CFG, params)
    ql = M.qlora_quantize_params(CFG, params)
    l1 = M.forward_mode(CFG, "nf4", nf4, tokens)
    l2 = M.forward_mode(CFG, "qlora", ql, tokens)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_peft_grads_cover_exactly_ba(params, tokens):
    qparams = M.quantize_params(CFG, params)
    fn = M.peft_grad_fn(CFG)
    qnames = M.quant_param_names(CFG)
    plist = [qparams[n] for n in qnames]
    targets = jnp.roll(tokens, -1, axis=1)
    out = fn(plist, tokens, targets)
    loss, grads = out[0], out[1:]
    tnames = M.peft_trainable(CFG)
    assert len(grads) == len(tnames)
    assert np.isfinite(float(loss))
    # at least the A matrices get signal (B can start near-dense too)
    nonzero = sum(float(jnp.max(jnp.abs(g))) > 0 for g in grads)
    assert nonzero >= len(grads) // 2


def test_qat_grads_shapes(params, tokens):
    qparams = M.quantize_params(CFG, params)
    names = M.qat_param_names(CFG)
    merged = dict(params)
    for n in names:
        if n.endswith(".B") or n.endswith(".A"):
            merged[n] = qparams[n]
    fn = M.qat_grad_fn(CFG)
    plist = [merged[n] for n in names]
    targets = jnp.roll(tokens, -1, axis=1)
    out = fn(plist, tokens, targets)
    loss, grads = out[0], out[1:]
    tnames = M.qat_trainable(CFG)
    assert len(grads) == len(tnames)
    for g, n in zip(grads, tnames):
        key = n
        expected = merged[key].shape
        assert g.shape == expected, (n, g.shape, expected)
    assert np.isfinite(float(loss))


def test_param_name_order_is_stable():
    names = M.param_names(CFG)
    assert names[0] == "tok_emb" and names[-1] == "lm_head"
    qnames = M.quant_param_names(CFG)
    assert f"l0.wq.codes" in qnames and qnames.index("l0.wq.codes") < qnames.index("l0.wq.B")


def test_parity_rank_matches_paper_table7():
    """Appendix A, Table 7: exact ranks for the paper's real module shapes."""
    cases = [
        (4096, 4096, 128, 16), (4096, 4096, 256, 8),
        (1024, 4096, 128, 6), (1024, 4096, 256, 3),
        (14336, 4096, 128, 24), (14336, 4096, 256, 12),
        (4096, 14336, 128, 24), (4096, 14336, 256, 12),
        (12288, 4096, 128, 24), (12288, 4096, 256, 12),
        (4096, 2560, 128, 12), (4096, 2560, 256, 6),
        (1024, 2560, 128, 5), (1024, 2560, 256, 2),
        (9728, 2560, 128, 15), (9728, 2560, 256, 7),
        (2560, 9728, 128, 15), (2560, 9728, 256, 7),
    ]
    from compile.kernels import ref
    for n, m, block, want in cases:
        assert ref.parity_rank(n, m, block) == want, (n, m, block)
