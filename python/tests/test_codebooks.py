"""Codebook construction properties (NF4/NF2/INTk)."""

import numpy as np
import pytest

from compile.kernels import ref


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_normal_float_shape_and_range(bits):
    lut = ref.normal_float_codebook(bits)
    assert lut.shape == (1 << bits,)
    assert lut.min() == -1.0 and lut.max() == 1.0
    assert np.all(np.diff(lut) > 0), "levels must be strictly increasing"


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_normal_float_contains_exact_zero(bits):
    lut = ref.normal_float_codebook(bits)
    assert 0.0 in lut.tolist(), "zero must be exactly representable"


def test_nf4_matches_published_levels():
    """Spot-check against the bitsandbytes NF4 levels (sign-mirrored variant)."""
    lut = ref.normal_float_codebook(4)
    published = np.sort(-np.array([
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.72295683622360229, 1.0,
    ]))
    assert np.allclose(np.sort(np.abs(lut)), np.sort(np.abs(published)), atol=1e-4)


@pytest.mark.parametrize("bits", [3, 4, 8])
def test_int_codebook(bits):
    lut = ref.int_codebook(bits)
    qmax = (1 << (bits - 1)) - 1
    assert lut.shape == (2 * qmax + 1,)
    assert lut[0] == -1.0 and lut[-1] == 1.0 and 0.0 in lut.tolist()
    # uniform spacing
    assert np.allclose(np.diff(lut), 1.0 / qmax)


def test_codebook_lookup_by_name():
    assert ref.codebook("nf4").shape == (16,)
    assert ref.codebook("nf2").shape == (4,)
    assert ref.codebook("int4").shape == (15,)
    with pytest.raises(ValueError):
        ref.codebook("fp4")
