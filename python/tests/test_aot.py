"""AOT pipeline: manifest consistency + HLO text well-formedness.

Uses the ``mini`` preset (seconds, not minutes). The full round-trip —
loading these artifacts through PJRT from Rust — is covered by
``rust/tests/runtime_roundtrip.rs``.
"""

import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("arts"))
    aot.main(["--preset", "mini", "--outdir", d])
    return d


def _parse_manifest(path):
    arts = {}
    cur = None
    model_line = None
    lut = None
    with open(path) as f:
        for line in f:
            parts = line.strip().split()
            if not parts or parts[0].startswith("#"):
                continue
            if parts[0] == "model":
                model_line = dict(kv.split("=") for kv in parts[1:])
            elif parts[0] == "lut":
                lut = [float(v) for v in parts[2].split(",")]
            elif parts[0] == "artifact":
                cur = {"file": parts[2], "in": [], "out": []}
                arts[parts[1]] = cur
            elif parts[0] in ("in", "out"):
                shape = [] if parts[3] == "scalar" else [int(d) for d in parts[3].split(",")]
                cur[parts[0]].append((parts[1], parts[2], shape))
            elif parts[0] == "end":
                cur = None
    return model_line, lut, arts


def test_manifest_and_files(outdir):
    model, lut, arts = _parse_manifest(os.path.join(outdir, "manifest.txt"))
    assert model["codebook"] == "nf4" and len(lut) == 16
    expected = {"fp_forward", "lords_forward", "nf4_forward", "qlora_forward",
                "fp_step", "qat_step", "peft_step",
                "lords_prefill_b1", "lords_decode_b8", "qlora_decode_b1"}
    assert expected.issubset(arts.keys())
    for name, a in arts.items():
        path = os.path.join(outdir, a["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, name


def test_manifest_signatures(outdir):
    cfg = aot.PRESETS["mini"]
    model, lut, arts = _parse_manifest(os.path.join(outdir, "manifest.txt"))

    # serving artifact inputs = quant params + tokens (+ caches for decode)
    names = M.quant_param_names(cfg)
    pre = arts["lords_prefill_b2"]
    assert [i[0] for i in pre["in"]][: len(names)] == names
    assert pre["in"][-1][0] == "tokens" and pre["in"][-1][2][0] == 2

    dec = arts["lords_decode_b4"]
    tail = [i[0] for i in dec["in"]][-4:]
    assert tail == ["token", "k_cache", "v_cache", "cur"]
    # prefill outputs: last_logits, k_cache, v_cache
    assert len(pre["out"]) == 3
    assert pre["out"][0][2] == [2, cfg.vocab]

    # training artifacts: loss + one grad per trainable
    peft = arts["peft_step"]
    assert len(peft["out"]) == 1 + len(M.peft_trainable(cfg))
    qat = arts["qat_step"]
    assert len(qat["out"]) == 1 + len(M.qat_trainable(cfg))

    # codes inputs are i32, everything else f32
    for nm, dt, _ in pre["in"]:
        assert dt == ("i32" if nm.endswith(".codes") or nm == "tokens" else "f32"), nm


def test_incremental_skip(outdir, capsys):
    """Re-running aot without --force must skip existing HLO files."""
    aot.main(["--preset", "mini", "--outdir", outdir, "--only", "eval"])
    out = capsys.readouterr().out
    assert "exists, skipped" in out
