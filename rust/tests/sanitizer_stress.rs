//! Sanitizer-targeted concurrency stress: small, deterministic workloads
//! shaped to let Miri and ThreadSanitizer prove (or refute) the three
//! load-bearing claims the serving core's `unsafe` rests on:
//!
//! 1. `SharedMut` disjoint-range writes through `ThreadPool::parallel_for`
//!    never alias (the GEMM / quantizer / batched-attention pattern),
//! 2. trace segments published by short-lived threads stay readable after
//!    those threads exit (the registry `Arc`-retains their buffers),
//! 3. `KvPool` seal/release bookkeeping converges under cross-thread
//!    contention (blocks are freed exactly once, no storage leaks),
//! 4. the fused bit-packed matmul's parallel fan-out stays bitwise
//!    faithful, and the atomic metrics registry counts exactly under
//!    unsynchronized multi-thread hammering.
//!
//! Sizes shrink under `cfg!(miri)` so the whole file finishes in seconds
//! under both interpreters; assertions are exact, never statistical.

use std::sync::{Arc, Mutex};

use lords::kvquant::attention::{decode_packed, decode_packed_batch};
use lords::kvquant::{KvBits, KvPool, KvQuantCfg};
use lords::obs::Registry;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{Codebook, QuantizedLinear};
use lords::tensor::{matmul_transb, Matrix};
use lords::util::pool::{SharedMut, ThreadPool};
use lords::util::prop::max_abs_diff;
use lords::util::Rng;

/// The canonical disjoint-writer pattern, reduced to its essence: every
/// worker writes only its own `[lo, hi)` chunk through the smuggled
/// pointer, and the buffer is read only after `parallel_for` joins.
#[test]
fn shared_mut_disjoint_writes_are_race_free() {
    let n = if cfg!(miri) { 257 } else { 40_003 };
    let pool = ThreadPool::new(4);
    let mut out = vec![0u64; n];
    {
        let op = SharedMut(out.as_mut_ptr());
        let opr = &op;
        pool.parallel_for(n, move |lo, hi| {
            for i in lo..hi {
                // SAFETY: chunks partition [0, n) disjointly, so index `i`
                // is written by exactly one worker, and `out` is read only
                // after parallel_for joins every worker.
                // UNSAFE-OK: this test exists to exercise the SharedMut
                // contract under Miri/TSan; production unsafe stays in the
                // audited modules.
                unsafe { *opr.0.add(i) = i as u64 * 3 + 1 };
            }
        });
    }
    for (i, &v) in out.iter().enumerate() {
        assert_eq!(v, i as u64 * 3 + 1, "index {i} written wrong or torn");
    }
}

/// `ThreadPool::map` drives the same pointer smuggling internally; check
/// order preservation with enough elements to span several chunks.
#[test]
fn pool_map_is_exact_under_interpreters() {
    let n = if cfg!(miri) { 123 } else { 10_000 };
    let pool = ThreadPool::new(3);
    let out = pool.map(n, |i| (i * i) as u64);
    for (i, &v) in out.iter().enumerate() {
        assert_eq!(v, (i * i) as u64);
    }
}

/// Spans recorded by threads that exit before `drain` must still be
/// collected: the registry retains each thread's segment chain by `Arc`,
/// and the producer publishes slots with release stores that `drain`
/// acquire-loads. TSan verifies the publish/consume edge; Miri verifies
/// the retained buffers are not use-after-free.
#[test]
fn trace_spans_survive_worker_thread_exit() {
    let threads = if cfg!(miri) { 4 } else { 16 };
    let per_thread = if cfg!(miri) { 8 } else { 400 };
    lords::obs::trace::set_enabled(true);
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            std::thread::spawn(move || {
                for i in 0..per_thread {
                    let g = lords::obs::trace::SpanGuard::begin(
                        "stress.exited_thread",
                        (t * per_thread + i) as u64,
                    );
                    drop(g);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    lords::obs::trace::set_enabled(false);
    // Other tests in this binary may trace concurrently; count only ours.
    let spans = lords::obs::trace::drain();
    let mut args: Vec<u64> = spans
        .iter()
        .filter(|s| s.name == "stress.exited_thread")
        .map(|s| s.arg)
        .collect();
    args.sort_unstable();
    let want: Vec<u64> = (0..(threads * per_thread) as u64).collect();
    assert_eq!(args, want, "spans lost or duplicated across thread exit");
}

/// Hammer `KvPool` seal/release from several threads sharing one mutex:
/// each thread appends, commits, reads back, and releases its own
/// sequences. Afterwards the pool must be exactly empty — every sealed
/// block freed once, no staging tail leaked.
#[test]
fn kvpool_concurrent_seal_release_converges() {
    let (threads, rounds) = if cfg!(miri) { (3, 2) } else { (8, 12) };
    let (bt, d, layers) = (4usize, 8usize, 2usize);
    let tokens = 2 * bt + 1; // two sealed blocks + a staged tail row
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: bt };
    let pool = Arc::new(Mutex::new(KvPool::new(kv, layers, d, threads * 8)));

    let handles: Vec<_> = (0..threads as u64)
        .map(|t| {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + t);
                for round in 0..rounds as u64 {
                    let seq = t * 1_000 + round;
                    let mut k = Matrix::zeros(tokens, d);
                    let mut v = Matrix::zeros(tokens, d);
                    rng.fill_normal(&mut k.data, 0.0, 1.0);
                    rng.fill_normal(&mut v.data, 0.0, 1.0);
                    {
                        let mut p = pool.lock().unwrap();
                        for layer in 0..layers {
                            p.append_rows(seq, layer, 0, &k, &v).unwrap();
                        }
                        p.commit(seq, tokens);
                    }
                    // Reacquire so seal and read interleave across threads.
                    {
                        let p = pool.lock().unwrap();
                        assert_eq!(p.seq_len(seq), Some(tokens));
                        let view = p.view(seq, layers - 1, tokens);
                        assert_eq!(view.len, tokens);
                    }
                    let mut p = pool.lock().unwrap();
                    assert!(p.release(seq), "double or missing release for {seq}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let p = pool.lock().unwrap();
    assert_eq!(p.used_blocks(), 0, "sealed blocks leaked after release");
    for t in 0..threads as u64 {
        for round in 0..rounds as u64 {
            assert_eq!(p.seq_len(t * 1_000 + round), None, "sequence survived release");
        }
    }
}

/// The batched pooled-attention kernel carves one output row per sequence
/// through `SharedMut` across the global pool; it must be bitwise equal
/// to the serial per-sequence path. Run small enough for Miri to walk the
/// whole packed-code decode.
#[test]
fn batched_pooled_attention_matches_serial() {
    let (n_seqs, len) = if cfg!(miri) { (2, 6) } else { (6, 19) };
    let (d, n_heads, bt) = (16usize, 2usize, 4usize);
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: bt };
    let mut pool = KvPool::new(kv, 1, d, 64);
    let mut rng = Rng::new(7);
    for s in 0..n_seqs as u64 {
        let mut k = Matrix::zeros(len, d);
        let mut v = Matrix::zeros(len, d);
        rng.fill_normal(&mut k.data, 0.0, 1.0);
        rng.fill_normal(&mut v.data, 0.0, 1.0);
        pool.append_rows(s, 0, 0, &k, &v).unwrap();
        pool.commit(s, len);
    }
    let mut q = Matrix::zeros(n_seqs, d);
    rng.fill_normal(&mut q.data, 0.0, 1.0);

    let views: Vec<_> = (0..n_seqs as u64).map(|s| pool.view(s, 0, len)).collect();
    let mut got = Matrix::zeros(n_seqs, d);
    decode_packed_batch(&q, &views, n_heads, &mut got);
    for s in 0..n_seqs {
        let qi = Matrix::from_vec(1, d, q.row(s).to_vec());
        let want = decode_packed(&qi, &views[s], n_heads);
        assert_eq!(got.row(s), want.row(0), "batched row {s} diverges from serial");
    }
}

/// Small fused-kernel parity case: the bit-packed LoRDS matmul fans its
/// output columns across workers through `SharedMut`; it must match the
/// dequantize-then-GEMM reference. A racy or misaligned carve shows up as
/// numeric drift here and as a report from the interpreter.
#[test]
fn fused_packed_matmul_matches_dense_reference() {
    let (n, m, t) = if cfg!(miri) { (6, 16, 2) } else { (24, 32, 5) };
    let cb = Codebook::normal_float(4);
    let mut rng = Rng::new(11);
    let w = Matrix::randn(n, m, 1.0, &mut rng);
    let refine = RefineCfg { steps: 2, ..Default::default() };
    let (q, _) = LordsQuant::quantize(&w, 8, &cb, refine);
    let w_hat = q.dequantize();
    let x = Matrix::randn(t, m, 1.0, &mut rng);
    let diff = max_abs_diff(&q.matmul_transb(&x).data, &matmul_transb(&x, &w_hat).data);
    assert!(diff <= 1e-4, "fused vs dense max-abs diff {diff} > 1e-4");
}

/// Unsynchronized hammering of one shared counter and histogram: the
/// registry hands out `Arc`-backed atomic handles, so totals must be
/// exact — a lost update means a broken RMW, which TSan would also flag.
#[test]
fn metrics_registry_contention_counts_exactly() {
    // `per` stays even so the alternating 0/1 observations sum to per/2.
    let (threads, per) = if cfg!(miri) { (4, 24) } else { (8, 10_000) };
    let reg = Arc::new(Registry::new());
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let c = reg.counter("stress_hits_total", &[]);
                let h = reg.histogram("stress_halves", &[], &[0.5, 1.5]);
                for i in 0..per {
                    c.inc();
                    h.observe((i % 2) as f64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = (threads * per) as u64;
    assert_eq!(reg.counter("stress_hits_total", &[]).get(), total);
    let h = reg.histogram("stress_halves", &[], &[0.5, 1.5]);
    assert_eq!(h.count(), total);
    assert_eq!(h.sum(), (threads * per / 2) as f64, "histogram sum drifted");
}
