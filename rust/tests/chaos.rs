//! Seeded chaos suite: randomized fault schedules driven through the
//! process-global fault plane ([`lords::fault`]), asserting the
//! self-healing serving invariants end to end:
//!
//! * **No leaks** — after a drain, the KV pool holds zero blocks, zero
//!   staging bytes, and zero active sequences, and every adapter's pin
//!   count is zero, whatever faults fired.
//! * **No panics** — every fault becomes a per-sequence `Event::Failed`
//!   (or a degraded cache path), never a tick-poisoning error.
//! * **Isolation** — sequences the schedule never touched produce
//!   bitwise-identical token streams to a fault-free run; retried
//!   sequences that complete reproduce the fault-free tokens exactly
//!   (retry-by-re-prefill regenerates, greedy decode is deterministic).
//! * **Replay** — the same spec + seed fires the same schedule, so two
//!   runs produce bit-identical (normalized) event streams.
//!
//! The base seed comes from `LORDS_CHAOS_SEED` (default 1); CI pins a
//! few fixed seeds so failures reproduce with
//! `LORDS_CHAOS_SEED=<seed> cargo test --test chaos`.
//!
//! The fault plane is process-global, so every test serializes on one
//! mutex and resets the plane on exit (panic included) via an RAII guard.

use lords::adapters::AdapterFactors;
use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::{Event, NativeEngine, Request, Server};
use lords::fault;
use lords::kvquant::{KvBits, KvQuantCfg};
use lords::model::Model;
use lords::util::Rng;
use std::sync::Mutex;

static SERIAL: Mutex<()> = Mutex::new(());

/// Hold the serialization lock and reset the global fault plane on drop,
/// so a panicking test never bleeds its schedule into the next one.
struct PlaneGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl<'a> PlaneGuard<'a> {
    fn lock() -> PlaneGuard<'a> {
        let g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        fault::reset();
        PlaneGuard(g)
    }
}

impl Drop for PlaneGuard<'_> {
    fn drop(&mut self) {
        fault::reset();
    }
}

fn chaos_seed() -> u64 {
    std::env::var("LORDS_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn serve_cfg() -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits: 32,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 8,
        retry_backoff_ticks: 1,
        ..ServeCfg::default()
    }
}

fn engine(model_seed: u64) -> NativeEngine {
    let kv = KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: 8 };
    NativeEngine::with_kv(Model::init(&tiny_cfg(), model_seed), "chaos", kv)
}

fn requests(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
    let mut rng = Rng::new(5);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(32)).collect(), max_new)
        })
        .collect()
}

/// Drive submitted work to quiescence (bounded), then drain. Returns
/// every event in order. Panics if the server fails to converge — the
/// livelock form of a leak.
fn run_to_drain(srv: &mut Server<NativeEngine>, reqs: Vec<Request>) -> Vec<Event> {
    let mut events = Vec::new();
    let mut pending: std::collections::VecDeque<Request> = reqs.into();
    let mut ticks = 0usize;
    while !pending.is_empty() || !srv.is_idle() {
        while let Some(r) = pending.pop_front() {
            if srv.submit(r).is_err() {
                break;
            }
        }
        events.extend(srv.step().expect("faults must never poison a tick"));
        ticks += 1;
        assert!(ticks < 10_000, "server failed to quiesce under faults");
    }
    events.extend(srv.drain(10_000).expect("drain must never error"));
    events
}

/// Leak audit: a drained server holds nothing, whatever the schedule did.
fn assert_no_leaks(srv: &Server<NativeEngine>, adapters: &[&str]) {
    let pool = srv.engine.kv_pool();
    assert_eq!(pool.active_sequences(), 0, "leaked KV sequences");
    assert_eq!(pool.used_blocks(), 0, "leaked KV blocks");
    assert_eq!(pool.staging_bytes(), 0, "leaked staging bytes");
    for id in adapters {
        assert_eq!(srv.engine.registry().pins(id), 0, "leaked pin on adapter '{id}'");
    }
}

/// Normalize an event stream to its replay-comparable projection
/// (timings carried by `Done` responses are wall-clock and excluded;
/// everything that identifies the schedule is kept, tokens included).
fn sig(events: &[Event]) -> Vec<String> {
    events
        .iter()
        .map(|e| match e {
            Event::Token { id, token, index } => format!("tok {id} {token} {index}"),
            Event::Done { response } => {
                format!("done {} {:?}", response.id, response.tokens)
            }
            Event::Rejected { id, reason } => format!("rej {id} {}", reason.key()),
            Event::Cancelled { id } => format!("can {id}"),
            Event::Failed { id, reason, retryable } => {
                format!("fail {id} {reason} {retryable}")
            }
        })
        .collect()
}

/// Completed responses keyed by id -> token stream.
fn completions(events: &[Event]) -> std::collections::HashMap<u64, Vec<usize>> {
    events
        .iter()
        .filter_map(|e| match e {
            Event::Done { response } => Some((response.id, response.tokens.clone())),
            _ => None,
        })
        .collect()
}

/// Every id that entered the server resolves to exactly one terminal
/// event (done / terminal failure / cancellation / rejection).
fn assert_single_terminal(events: &[Event], ids: impl Iterator<Item = u64>) {
    let mut terminal: std::collections::HashMap<u64, usize> = Default::default();
    for e in events {
        let id = match e {
            Event::Done { response } => Some(response.id),
            Event::Failed { id, retryable: false, .. } => Some(*id),
            Event::Cancelled { id } => Some(*id),
            Event::Rejected { id, .. } => Some(*id),
            _ => None,
        };
        if let Some(id) = id {
            *terminal.entry(id).or_default() += 1;
        }
    }
    for id in ids {
        assert_eq!(
            terminal.get(&id).copied().unwrap_or(0),
            1,
            "id {id} must resolve exactly once (events: {:?})",
            sig(events)
        );
    }
}

/// A fault-free reference run over the same request set.
fn clean_run(reqs: Vec<Request>) -> Vec<Event> {
    fault::reset();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let events = run_to_drain(&mut srv, reqs);
    assert_no_leaks(&srv, &[]);
    events
}

#[test]
fn engine_err_faults_are_contained_and_retries_reproduce_clean_tokens() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed();
    let reqs = requests(8, 12, 6);
    let clean = completions(&clean_run(reqs.clone()));
    assert_eq!(clean.len(), 8, "reference run must complete everything");

    fault::configure(&format!(
        "site=engine.decode,p=0.08,kind=err,seed={seed};\
         site=engine.prefill,p=0.05,kind=err,seed={}",
        seed ^ 0xA5A5
    ))
    .unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let events = run_to_drain(&mut srv, reqs);
    fault::reset();

    assert_single_terminal(&events, 0..8);
    assert_no_leaks(&srv, &[]);
    // every sequence that completed — faulted-then-retried or untouched —
    // reproduces the fault-free tokens exactly
    for (id, tokens) in completions(&events) {
        assert_eq!(tokens, clean[&id], "seq {id} diverged from the fault-free run");
    }
}

#[test]
fn kv_alloc_and_seal_faults_leak_nothing() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(1);
    let reqs = requests(8, 12, 6);
    let clean = completions(&clean_run(reqs.clone()));

    fault::configure(&format!("site=kv.*,p=0.05,kind=alloc,seed={seed}")).unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let events = run_to_drain(&mut srv, reqs);
    fault::reset();

    assert_single_terminal(&events, 0..8);
    assert_no_leaks(&srv, &[]);
    for (id, tokens) in completions(&events) {
        assert_eq!(tokens, clean[&id], "seq {id} diverged from the fault-free run");
    }
}

#[test]
fn logit_corruption_quarantines_only_the_victims() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(2);
    let reqs = requests(8, 12, 6);
    let clean = completions(&clean_run(reqs.clone()));

    fault::configure(&format!("site=engine.logits,p=0.02,kind=logit,seed={seed}")).unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let events = run_to_drain(&mut srv, reqs);
    fault::reset();

    assert_single_terminal(&events, 0..8);
    assert_no_leaks(&srv, &[]);
    let quarantined: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            Event::Failed { id, reason: "nonfinite_logits", retryable } => {
                assert!(!retryable, "quarantine must be terminal");
                Some(*id)
            }
            _ => None,
        })
        .collect();
    let done = completions(&events);
    for id in &quarantined {
        assert!(!done.contains_key(id), "quarantined seq {id} must not also complete");
    }
    // untouched sequences match the fault-free run bitwise
    for (id, tokens) in &done {
        assert_eq!(tokens, &clean[id], "untouched seq {id} diverged");
    }
    assert_eq!(srv.metrics.quarantined, quarantined.len());
}

#[test]
fn adapter_resolve_faults_retry_and_release_all_pins() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(3);
    let model = Model::init(&tiny_cfg(), 3);
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(17);
    let factors = [base.perturbed(0.05, &mut arng), base.perturbed(0.05, &mut arng)];
    let build = || {
        let kv = KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: 8 };
        let mut e = NativeEngine::with_kv(model.clone(), "chaos-mt", kv);
        e.register_adapter("t0", factors[0].clone()).unwrap();
        e.register_adapter("t1", factors[1].clone()).unwrap();
        Server::new(e, serve_cfg()).unwrap()
    };
    let tenants = ["base", "t0", "t1"];
    let reqs = || -> Vec<Request> {
        requests(6, 12, 6)
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.with_adapter(tenants[i % 3]))
            .collect()
    };
    fault::reset();
    let mut clean_srv = build();
    let clean = completions(&run_to_drain(&mut clean_srv, reqs()));
    assert_eq!(clean.len(), 6);

    fault::configure(&format!("site=adapter.resolve,p=0.15,kind=adapter,seed={seed}"))
        .unwrap();
    let mut srv = build();
    let events = run_to_drain(&mut srv, reqs());
    fault::reset();

    assert_single_terminal(&events, 0..6);
    assert_no_leaks(&srv, &["t0", "t1"]);
    for (id, tokens) in completions(&events) {
        assert_eq!(tokens, clean[&id], "seq {id} diverged from the fault-free run");
    }
}

#[test]
fn cancel_storm_under_wildcard_faults_leaks_nothing() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(4);
    fault::configure(&format!("site=*,p=0.03,kind=err,seed={seed}")).unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let reqs = requests(12, 12, 6);
    let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
    let mut events = Vec::new();
    for r in reqs {
        let _ = srv.submit(r);
    }
    // storm: cancel every odd id across the first ticks, mid-prefill and
    // mid-decode, while the wildcard schedule fires everywhere
    for tick in 0..6 {
        events.extend(srv.step().expect("faults must never poison a tick"));
        if tick < ids.len() / 2 {
            srv.cancel(ids[tick * 2 + 1]);
        }
    }
    let mut ticks = 0;
    while !srv.is_idle() {
        events.extend(srv.step().expect("faults must never poison a tick"));
        ticks += 1;
        assert!(ticks < 10_000, "server failed to quiesce under cancel storm");
    }
    events.extend(srv.drain(10_000).unwrap());
    fault::reset();
    assert_single_terminal(&events, ids.into_iter());
    assert_no_leaks(&srv, &[]);
}

#[test]
fn same_seed_replays_a_bit_identical_event_stream() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(5);
    let spec = format!(
        "site=engine.*,p=0.1,kind=err,seed={seed};site=kv.*,p=0.05,kind=alloc,seed={seed}"
    );
    let run = |spec: &str| {
        fault::reset();
        fault::configure(spec).unwrap();
        let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
        let events = run_to_drain(&mut srv, requests(8, 12, 6));
        assert_no_leaks(&srv, &[]);
        sig(&events)
    };
    let a = run(&spec);
    let b = run(&spec);
    fault::reset();
    assert_eq!(a, b, "same spec + seed must replay bit-identically");
}

#[test]
fn prefix_cache_faults_degrade_without_changing_tokens() {
    let _g = PlaneGuard::lock();
    let seed = chaos_seed().wrapping_add(6);
    // shared-prefix sessions: same prompt so later ones fork from cache
    let prompt: Vec<usize> = {
        let mut rng = Rng::new(9);
        (0..16).map(|_| rng.below(32)).collect()
    };
    let shared_reqs =
        || -> Vec<Request> { (0..4).map(|i| Request::new(i, prompt.clone(), 6)).collect() };
    fault::reset();
    let mut clean_srv = Server::new(engine(3), serve_cfg()).unwrap();
    let clean = completions(&run_to_drain(&mut clean_srv, shared_reqs()));
    assert_eq!(clean.len(), 4);

    fault::configure(&format!(
        "site=prefix.claim,p=0.5,kind=err,seed={seed};\
         site=prefix.publish,p=0.5,kind=err,seed={seed}"
    ))
    .unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let events = run_to_drain(&mut srv, shared_reqs());
    fault::reset();

    // cache faults only degrade (counted miss / dropped publish): every
    // session completes, tokens bitwise-identical, nothing leaks
    let done = completions(&events);
    assert_eq!(done.len(), 4, "cache degradation must not fail sequences");
    for (id, tokens) in &done {
        assert_eq!(tokens, &clean[id], "shared-prefix seq {id} diverged");
    }
    assert_no_leaks(&srv, &[]);
}

#[test]
fn deadlines_expire_in_flight_and_release_everything() {
    let _g = PlaneGuard::lock();
    // latency faults stretch ticks so a short deadline expires mid-run
    let seed = chaos_seed().wrapping_add(7);
    fault::configure(&format!("site=engine.decode,p=1.0,kind=latency,seed={seed}")).unwrap();
    let mut srv = Server::new(engine(3), serve_cfg()).unwrap();
    let mut reqs = requests(4, 12, 6);
    for r in reqs.iter_mut() {
        // comfortably admits, expires during the slowed decode ticks below
        r.deadline_ms = 5;
    }
    let mut events = Vec::new();
    for r in reqs {
        let _ = srv.submit(r); // racing the deadline at the door is fine
    }
    let mut ticks = 0;
    while !srv.is_idle() {
        std::thread::sleep(std::time::Duration::from_millis(2));
        events.extend(srv.step().unwrap());
        ticks += 1;
        assert!(ticks < 10_000);
    }
    events.extend(srv.drain(10_000).unwrap());
    fault::reset();
    let deadline_events = events
        .iter()
        .filter(|e| {
            matches!(e, Event::Failed { reason: "deadline", retryable: false, .. })
                || matches!(e, Event::Rejected { reason, .. }
                    if *reason == lords::coordinator::RejectReason::DeadlineInfeasible)
        })
        .count();
    assert!(deadline_events > 0, "short deadlines must expire: {:?}", sig(&events));
    assert_no_leaks(&srv, &[]);
}
