//! Multi-tenant adapter gate: the fused kernels serving a tenant's
//! (B′, A′) scale override must match the dense-merged reference within
//! 1e-4 across {2, 3, 4}-bit codes, and a served batch mixing ≥ 3 adapters
//! over one shared `PackedCodes` base must reproduce each tenant's
//! dedicated single-tenant serve exactly — the acceptance bar for the
//! `adapters` subsystem.

use lords::adapters::{AdapterFactors, AdapterRegistry, BASE_ADAPTER};
use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::engine::{Engine, SeqState};
use lords::coordinator::{NativeEngine, Request, Server};
use lords::model::{KvCache, LinearWeight, Model};
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::Codebook;
use lords::report::testbed::{llm_like_weight, ModuleShape};
use lords::tensor::{matmul, matmul_transb, Matrix};
use lords::util::prop::{max_abs_diff, prop_check};
use lords::util::Rng;

const TOL: f32 = 1e-4;

#[test]
fn fused_with_adapter_matches_dense_merged_all_bit_widths() {
    for bits in [2u32, 3, 4] {
        let cb = Codebook::normal_float(bits);
        prop_check(6, |g| {
            let n = g.usize(4..=40);
            let m = g.usize(2..=6) * 8;
            let t = g.usize(1..=10);
            let base_rank = g.usize(1..=3);
            let adapter_rank = g.usize(1..=4); // may differ from base_rank
            let mut rng = g.rng().fork(300 + bits as u64);
            let w = llm_like_weight(ModuleShape { name: "W", n, m }, &mut rng);
            let cfg = RefineCfg { steps: 8, ..Default::default() };
            let (q, _) = LordsQuant::quantize_with_rank(&w, 8, base_rank, &cb, cfg);
            if !q.b.all_finite() || !q.a.all_finite() {
                return Err(format!("non-finite scale factors at {n}x{m}"));
            }
            // tenant factors: a PEFT-shaped perturbation at its own rank
            let b2 = Matrix::randn(n, adapter_rank, 0.25, &mut rng);
            let a2 = Matrix::randn(adapter_rank, m, 0.25, &mut rng);
            let w_merged = q.dequantize_with(&b2, &a2);
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            let fwd = q.matmul_transb_with(&x, &b2, &a2);
            let want = matmul_transb(&x, &w_merged);
            let diff = max_abs_diff(&fwd.data, &want.data);
            if diff > TOL {
                return Err(format!("nf{bits} fwd {n}x{m} t={t}: {diff} > {TOL}"));
            }
            let gup = Matrix::randn(t, n, 1.0, &mut rng);
            let bwd = q.matmul_with(&gup, &b2, &a2);
            let want_b = matmul(&gup, &w_merged);
            let diff_b = max_abs_diff(&bwd.data, &want_b.data);
            if diff_b > TOL {
                return Err(format!("nf{bits} bwd {n}x{m} t={t}: {diff_b} > {TOL}"));
            }
            Ok(())
        });
    }
}

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn lords_model(cfg: &ModelCfg, seed: u64) -> Model {
    let mut model = Model::init(cfg, seed);
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 3, ..Default::default() },
        false,
    );
    model
}

fn serve_cfg() -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits: 32,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 0,
        ..ServeCfg::default()
    }
}

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(77);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

/// The acceptance criterion: one shared packed base, a served batch mixing
/// ≥ 3 adapters (+ the base tenant), and every tenant's output must match
/// its dense-merged reference — token streams exactly, logits ≤ 1e-4.
#[test]
fn served_mixed_batch_matches_per_tenant_dense_references() {
    let cfg = tiny_cfg();
    let model = lords_model(&cfg, 11);
    let base_factors = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(12);
    let tenants = ["tenant-a", "tenant-b", "tenant-c"];
    let factors: Vec<AdapterFactors> =
        tenants.iter().map(|_| base_factors.perturbed(0.08, &mut arng)).collect();

    // --- multi-tenant serve: 8 requests cycling base + 3 adapters
    let mut engine = NativeEngine::new(model.clone(), "mt");
    for (t, f) in tenants.iter().zip(&factors) {
        engine.register_adapter(t, f.clone()).unwrap();
    }
    let cycle = [BASE_ADAPTER, tenants[0], tenants[1], tenants[2]];
    let mut reqs = requests(8, 10, 5, cfg.vocab);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.adapter = cycle[i % cycle.len()].to_string();
    }
    let mut server = Server::new(engine, serve_cfg()).unwrap();
    let mixed = server.run_trace(reqs).unwrap();
    assert_eq!(mixed.metrics.completed, 8);
    assert!(
        mixed.metrics.per_adapter.len() >= 4,
        "batch must have mixed ≥ 3 adapters + base: {:?}",
        mixed.metrics.per_adapter.keys().collect::<Vec<_>>()
    );

    // --- per-tenant references: merge each adapter into its own copy of
    // the base and serve that tenant's requests alone
    for (ti, tenant) in cycle.iter().enumerate() {
        let mut merged = model.clone();
        if *tenant != BASE_ADAPTER {
            factors[ti - 1].apply_to(&mut merged).unwrap();
        }
        let mut single = Server::new(NativeEngine::new(merged, tenant), serve_cfg()).unwrap();
        let solo_reqs: Vec<Request> = requests(8, 10, 5, cfg.vocab)
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % cycle.len() == ti)
            .map(|(_, r)| r)
            .collect();
        let solo = single.run_trace(solo_reqs).unwrap();
        for want in &solo.responses {
            let got = mixed.responses.iter().find(|r| r.id == want.id).unwrap();
            assert_eq!(got.adapter, *tenant);
            assert_eq!(
                got.tokens, want.tokens,
                "tenant {tenant} req {}: mixed-batch serve diverged from its \
                 dense-merged single-tenant reference",
                want.id
            );
        }
    }

    // --- logits-level bound vs a fully dense merged model (≤ 1e-4)
    let mut rng = Rng::new(13);
    let prompt: Vec<usize> = (0..10).map(|_| rng.below(cfg.vocab)).collect();
    for (tenant_f, _) in factors.iter().zip(tenants.iter()) {
        let mut dense_ref = model.clone();
        tenant_f.apply_to(&mut dense_ref).unwrap();
        dense_ref.map_linears(|w| LinearWeight::Dense(w.clone()));
        let mut c1 = KvCache::new(&cfg);
        let mut c2 = KvCache::new(&cfg);
        let fused = model.prefill_with(&prompt, &mut c1, Some(tenant_f));
        let dense = dense_ref.prefill(&prompt, &mut c2);
        let diff = max_abs_diff(&fused, &dense);
        assert!(diff <= TOL, "adapted prefill vs dense-merged: {diff} > {TOL}");
    }
}

#[test]
fn inflight_eviction_is_deferred_at_the_engine() {
    let cfg = tiny_cfg();
    let model = lords_model(&cfg, 21);
    let base_factors = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(22);
    let mut engine =
        NativeEngine::with_registry(model, "evict", AdapterRegistry::unbounded());
    engine.register_adapter("t0", base_factors.perturbed(0.05, &mut arng)).unwrap();

    let mut rng = Rng::new(23);
    let prompt: Vec<usize> = (0..8).map(|_| rng.below(cfg.vocab)).collect();
    let mut seqs =
        vec![SeqState::admit(&Request::new(1, prompt, 4).with_adapter("t0"), cfg.max_seq)];
    engine.prefill(&mut seqs).unwrap();
    assert_eq!(engine.registry().pins("t0"), 1);

    // evicting a pinned adapter is deferred; the in-flight sequence keeps
    // decoding against it, but new sequences can no longer pin it
    assert!(!engine.evict_adapter("t0"));
    assert!(engine.registry().get("t0").is_some());
    let next = seqs[0].next_token();
    seqs[0].tokens.push(next);
    engine.decode(&mut seqs).unwrap();
    assert!(!engine.registry().contains("t0"));

    // releasing the sequence fires the deferred eviction
    engine.release(1);
    assert!(engine.registry().get("t0").is_none());
    assert_eq!(engine.registry().stats().deferred_evictions, 1);
}
