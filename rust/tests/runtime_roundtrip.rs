//! Integration: the full Python-AOT → Rust-PJRT boundary.
//!
//! Requires `make artifacts` (skips cleanly otherwise). Verifies that
//! * kernel artifacts reproduce the Rust-native fused kernels' numerics,
//! * the serving artifacts' prefill/decode agree with the native model,
//! * the PJRT PEFT train step reduces the loss and its gradients flow only
//!   into (B, A).

use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::runtime::bridge::collect_params;
use lords::runtime::{HostTensor, Manifest, Runtime};
use lords::tensor::Matrix;
use lords::util::prop::assert_allclose;
use lords::util::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn lords_kernel_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let name = "lords_mm_m64";
    if rt.manifest.artifact(name).is_err() {
        return;
    }
    let (n, m, block) = (512, 512, 64);
    let cb = Codebook::from_levels(&rt.manifest.lut_name, rt.manifest.lut.clone());
    let mut rng = Rng::new(0);
    let w = Matrix::randn(n, m, 0.05, &mut rng);
    let (q, _) = lords::quant::LordsQuant::quantize(&w, block, &cb, RefineCfg { steps: 5, ..Default::default() });
    let x = Matrix::randn(64, m, 1.0, &mut rng);
    let y_native = q.matmul_transb(&x);

    let out = rt
        .execute(
            name,
            &[
                HostTensor::from_matrix(&x),
                HostTensor::I32(q.codes.iter().map(|c| c as i32).collect(), vec![n, m]),
                HostTensor::from_matrix(&q.b),
                HostTensor::from_matrix(&q.a),
                HostTensor::F32(rt.manifest.lut.clone(), vec![rt.manifest.lut.len()]),
            ],
        )
        .expect("execute");
    let y_pjrt = out[0].to_matrix();
    assert_allclose(&y_pjrt.data, &y_native.data, 2e-3, 2e-3, "pjrt lords kernel vs native");
}

#[test]
fn serving_forward_matches_native_model() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.model.clone();
    let mut model = lords::model::Model::init(&cfg, 3);
    let cb = Codebook::from_levels(&rt.manifest.lut_name, rt.manifest.lut.clone());
    model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 3, ..Default::default() }, false);

    let art = rt.manifest.artifact("lords_forward").unwrap().clone();
    let tokens_spec = art.inputs.last().unwrap();
    let (b, s) = (tokens_spec.dims[0], tokens_spec.dims[1]);
    let mut rng = Rng::new(4);
    let tokens: Vec<usize> = (0..b * s).map(|_| rng.below(cfg.vocab)).collect();

    let mut inputs = collect_params(&model, &art.inputs);
    inputs.push(HostTensor::I32(tokens.iter().map(|&t| t as i32).collect(), vec![b, s]));
    let out = rt.execute("lords_forward", &inputs).expect("execute");
    let logits_pjrt = out[0].f32s();

    let logits_native = model.forward(&tokens, b, s);
    // compare the final position of each row (what serving consumes)
    for bi in 0..b {
        let row = bi * s + (s - 1);
        let native = logits_native.row(row);
        let pjrt = &logits_pjrt[(row) * cfg.vocab..(row + 1) * cfg.vocab];
        assert_allclose(pjrt, native, 5e-2, 5e-2, &format!("logits row {row}"));
    }
}

#[test]
fn pjrt_peft_step_trains_and_touches_only_ba() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.model.clone();
    let mut model = lords::model::Model::init(&cfg, 5);
    let cb = Codebook::from_levels(&rt.manifest.lut_name, rt.manifest.lut.clone());
    model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 3, ..Default::default() }, false);

    let art = rt.manifest.artifact("peft_step").unwrap().clone();
    let pspecs: Vec<_> = art.inputs.iter().take_while(|s| s.name != "tokens").cloned().collect();
    let mut params = collect_params(&model, &pspecs);
    let tokens_spec = &art.inputs[art.inputs.len() - 2];
    let (b, s) = (tokens_spec.dims[0], tokens_spec.dims[1]);
    let mut rng = Rng::new(6);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
    let targets: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();

    let mut last_loss = f32::INFINITY;
    for step in 0..4 {
        let mut inputs = params.clone();
        inputs.push(HostTensor::I32(tokens.clone(), vec![b, s]));
        inputs.push(HostTensor::I32(targets.clone(), vec![b, s]));
        let out = rt.execute("peft_step", &inputs).expect("peft step");
        let loss = out[0].f32s()[0];
        assert!(loss.is_finite());
        // grads come back for every *.B / *.A in order
        let tnames: Vec<&str> = pspecs
            .iter()
            .filter(|p| p.name.ends_with(".B") || p.name.ends_with(".A"))
            .map(|p| p.name.as_str())
            .collect();
        assert_eq!(out.len(), 1 + tnames.len());
        // SGD update on B/A only (fixed batch ⇒ loss must drop)
        let mut gi = 1;
        for (i, spec) in pspecs.iter().enumerate() {
            if spec.name.ends_with(".B") || spec.name.ends_with(".A") {
                let g = out[gi].f32s();
                if let HostTensor::F32(data, _) = &mut params[i] {
                    for (p, gv) in data.iter_mut().zip(g) {
                        *p -= 0.5 * gv;
                    }
                }
                gi += 1;
            }
        }
        if step == 3 {
            assert!(loss < last_loss, "loss should drop on a fixed batch");
        }
        if step == 0 {
            last_loss = loss;
        }
    }
}
