//! Online serving API gate: the `run_trace` shim must be token-identical
//! to the pre-redesign closed-loop `run()` (batching-invariant golden
//! check + incremental submit/step equivalence), cancellation at random
//! mid-decode steps must never leak KV blocks or adapter pins (100+
//! cancels), seeded `SamplingParams` must replay bit-identically, and
//! KV-aware admission must pack short requests past the old
//! `max_seq`-worst-case limit.

use lords::adapters::AdapterFactors;
use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::{
    run_open_loop, Engine, Event, NativeEngine, RejectReason, Request, SamplingParams, Server,
};
use lords::model::Model;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::util::prop::prop_check;
use lords::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn serve_cfg() -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits: 32,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 0,
        ..ServeCfg::default()
    }
}

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(7);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

/// The acceptance criterion: `run_trace` is a faithful shim. Its token
/// streams are batching-invariant (each request reproduces its dedicated
/// single-request serve exactly — the property the pre-redesign `run()`
/// was gated on, so equality here is equality with the old driver), and
/// the raw submit/step session produces the same streams again.
#[test]
fn run_trace_shim_is_token_identical_to_golden_single_streams() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 5);

    let mut srv = Server::new(NativeEngine::new(model.clone(), "shim"), serve_cfg()).unwrap();
    let trace = srv.run_trace(requests(8, 12, 6, cfg.vocab)).unwrap();
    assert_eq!(trace.metrics.completed, 8);

    // golden reference: every request served alone in a fresh server
    for want in &trace.responses {
        let mut single = Server::new(NativeEngine::new(model.clone(), "solo"), serve_cfg()).unwrap();
        let one = requests(8, 12, 6, cfg.vocab).remove(want.id as usize);
        let solo = single.run_trace(vec![one]).unwrap();
        assert_eq!(
            solo.responses[0].tokens, want.tokens,
            "req {}: trace shim diverged from its single-stream golden",
            want.id
        );
    }

    // incremental session: submit everything, step to completion by hand
    let mut online = Server::new(NativeEngine::new(model, "online"), serve_cfg()).unwrap();
    for r in requests(8, 12, 6, cfg.vocab) {
        online.submit(r).unwrap();
    }
    let mut responses = Vec::new();
    while !online.is_idle() {
        for ev in online.step().unwrap() {
            if let Event::Done { response } = ev {
                responses.push(response);
            }
        }
    }
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 8);
    for (got, want) in responses.iter().zip(&trace.responses) {
        assert_eq!(got.tokens, want.tokens, "req {}: session API diverged from shim", got.id);
    }
}

/// The acceptance criterion: 100+ cancellations at random points of a
/// request's lifetime (queued, mid-chunked-prefill, mid-decode), with
/// multi-tenant requests in flight and half of them sharing a prefix-
/// cacheable prompt, leak zero KV blocks and zero adapter pins. Shared
/// prefix blocks survive their sequences by design (the cache retains
/// them) — the refcounts-hit-zero check is that flushing the cache after
/// the drain returns the pool to exactly empty.
#[test]
fn random_mid_decode_cancels_leak_nothing() {
    let cfg = tiny_cfg();
    let mut model = Model::init(&cfg, 13);
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 2, ..Default::default() },
        false,
    );
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(14);
    let t0 = base.perturbed(0.05, &mut arng);
    let t1 = base.perturbed(0.05, &mut arng);
    let tenants = ["base", "t0", "t1"];

    // 50 cases x 2+ cancels each ≥ 100 random mid-decode cancels total
    prop_check(50, |g| {
        let mut engine = NativeEngine::new(model.clone(), "cancel");
        engine.register_adapter("t0", t0.clone()).unwrap();
        engine.register_adapter("t1", t1.clone()).unwrap();
        // half the cases spread prefill across ticks (block_tokens = 16),
        // so cancels also land on sequences still in the prefilling set
        let mut scfg = serve_cfg();
        scfg.prefill_chunk_tokens = *g.pick(&[0usize, 16]);
        let mut srv = Server::new(engine, scfg).unwrap();

        let n = g.usize(4..=8);
        let mut ids: Vec<u64> = Vec::new();
        // even-indexed requests share one 20-token prompt (one sealed
        // block is prefix-shareable per tenant); odd ones stay unique
        let mut prng = g.rng().fork(9);
        let shared: Vec<usize> = (0..20).map(|_| prng.below(32)).collect();
        let mut reqs: Vec<Request> = (0..n)
            .map(|i| {
                let prompt = if i % 2 == 0 {
                    shared.clone()
                } else {
                    (0..12).map(|_| prng.below(32)).collect()
                };
                Request::new(i as u64, prompt, 8)
            })
            .collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            r.adapter = tenants[i % tenants.len()].to_string();
            ids.push(r.id);
        }
        for r in reqs {
            srv.submit(r).map_err(|e| format!("submit rejected: {e}"))?;
        }
        // advance into decode, then cancel 2–3 random requests (each at a
        // random point of its lifetime: queued, mid-decode, or finished)
        let mut cancelled = 0usize;
        let planned = g.usize(2..=3).max(2);
        while cancelled < planned {
            let steps = g.usize(1..=4);
            for _ in 0..steps {
                srv.step().map_err(|e| format!("step failed: {e}"))?;
            }
            let victim = ids[g.usize(0..=ids.len() - 1)];
            srv.cancel(victim); // false when already finished — still a draw
            cancelled += 1;
        }
        // drain the remainder
        let mut guard = 0;
        while !srv.is_idle() {
            srv.step().map_err(|e| format!("drain step failed: {e}"))?;
            guard += 1;
            if guard > 1000 {
                return Err("server failed to drain after cancels".into());
            }
        }
        // zero leaked sequences and pins; the only blocks still held are
        // the shared prompt's cached prefix (≤ one block per tenant chain,
        // 12-token unique prompts never seal a 16-token block)
        if srv.engine.kv_pool().active_sequences() != 0 {
            return Err(format!(
                "{} sequences leaked",
                srv.engine.kv_pool().active_sequences()
            ));
        }
        let cached = srv.engine.kv_pool().used_blocks();
        if cached > tenants.len() {
            return Err(format!(
                "{cached} blocks held after drain — more than the {} shareable prefix blocks",
                tenants.len()
            ));
        }
        for t in ["t0", "t1"] {
            if srv.engine.registry().pins(t) != 0 {
                return Err(format!("adapter '{t}' leaked {} pins", srv.engine.registry().pins(t)));
            }
        }
        // refcounts hit zero: with no sequences alive, dropping the cache's
        // own retains must free every last block
        srv.engine.flush_prefix_cache();
        if srv.engine.kv_pool().used_blocks() != 0 {
            return Err(format!(
                "{} KV blocks leaked after prefix-cache flush",
                srv.engine.kv_pool().used_blocks()
            ));
        }
        Ok(())
    });
}

/// Seeded sampling: two identical runs replay identical token streams;
/// a different sampling seed produces a different stream.
#[test]
fn seeded_sampling_is_deterministic_across_runs() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 21);
    let sampled = |sample_seed: u64| -> Vec<Vec<usize>> {
        let mut srv = Server::new(NativeEngine::new(model.clone(), "sampled"), serve_cfg()).unwrap();
        let reqs: Vec<Request> = requests(4, 10, 6, cfg.vocab)
            .into_iter()
            .map(|r| {
                r.with_sampling(SamplingParams {
                    temperature: 0.8,
                    top_k: 8,
                    seed: sample_seed,
                })
            })
            .collect();
        let rep = srv.run_trace(reqs).unwrap();
        assert_eq!(rep.metrics.completed, 4);
        rep.responses.iter().map(|r| r.tokens.clone()).collect()
    };
    let a = sampled(42);
    let b = sampled(42);
    assert_eq!(a, b, "same sampling seed must replay the same streams");
    let c = sampled(43);
    assert_ne!(a, c, "a different sampling seed must explore a different stream");
    // sampled tokens are still valid vocabulary entries
    for stream in &a {
        assert_eq!(stream.len(), 6);
        assert!(stream.iter().all(|&t| t < cfg.vocab));
    }
}

/// KV-aware admission: a budget holding exactly one `max_seq` worst case
/// (3 blocks + 1 staging tail) now serves two short requests
/// *concurrently* — admission and reservation price prompt + max_new
/// instead of max_seq — while never committing more bytes than the
/// budget (staging tails included).
#[test]
fn kv_aware_admission_packs_short_requests() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 31);
    let mut serve = serve_cfg();
    // 8 KiB: exactly one worst-case sequence (3 x 2 KiB blocks + 2 KiB tail)
    let budget_bytes = 8192usize;
    serve.kv_budget_mib = budget_bytes as f64 / (1024.0 * 1024.0);
    let mut srv = Server::new(NativeEngine::new(model, "tight"), serve).unwrap();

    // short requests: 8-token prompt + 4 new = 12 tokens = 1 block each
    let report = srv.run_trace(requests(6, 8, 4, cfg.vocab)).unwrap();
    assert_eq!(report.metrics.completed, 6, "tight budget must still serve short requests");

    let pool = srv.engine.kv_pool();
    assert_eq!(pool.capacity_blocks(), 3, "budget sized for one worst-case sequence");
    // the old max_seq-worst-case accounting admits one sequence at a time…
    assert!(!pool.can_admit_n(2, cfg.max_seq));
    // …but actual-length admission packs two 12-token sequences (a third
    // would fit the blocks, but its staging tail would overshoot the
    // byte budget — admission must stay honest)
    assert!(srv.engine.kv_can_admit(&[12, 12]));
    assert!(!srv.engine.kv_can_admit(&[12, 12, 12]));
    // two really were resident at once, and the budget was never exceeded
    assert!(
        pool.peak_bytes() >= 2 * (pool.block_bytes() + pool.staging_bytes()),
        "peak {} B shows no concurrency under the tight budget",
        pool.peak_bytes()
    );
    assert!(
        pool.peak_bytes() <= budget_bytes,
        "peak {} B overshot the {budget_bytes} B budget",
        pool.peak_bytes()
    );
}

/// A tenant evicted while its request waits in the queue surfaces as an
/// `Event::Rejected` for that request only — the batch is not poisoned.
#[test]
fn eviction_while_queued_rejects_only_that_request() {
    let cfg = tiny_cfg();
    let mut model = Model::init(&cfg, 41);
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 2, ..Default::default() },
        false,
    );
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(42);
    let mut engine = NativeEngine::new(model, "evict");
    engine.register_adapter("doomed", base.perturbed(0.05, &mut arng)).unwrap();
    let mut srv = Server::new(engine, serve_cfg()).unwrap();

    let mut reqs = requests(3, 8, 3, cfg.vocab);
    reqs[1].adapter = "doomed".into();
    for r in reqs {
        srv.submit(r).unwrap();
    }
    // evict before any step: request 1 is queued, nothing is pinned yet
    assert!(srv.engine.evict_adapter("doomed"));
    let mut rejected = Vec::new();
    let mut done = 0;
    while !srv.is_idle() {
        for ev in srv.step().unwrap() {
            match ev {
                Event::Rejected { id, reason } => {
                    assert_eq!(reason, RejectReason::UnknownAdapter);
                    rejected.push(id);
                }
                Event::Done { .. } => done += 1,
                _ => {}
            }
        }
    }
    assert_eq!(rejected, vec![1]);
    assert_eq!(done, 2);
}

/// The open-loop driver resolves every request and reports streaming
/// percentiles from per-token timestamps.
#[test]
fn open_loop_driver_resolves_all_requests_with_latency_metrics() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 51);
    let mut srv = Server::new(NativeEngine::new(model, "open"), serve_cfg()).unwrap();
    // high rate: arrivals bunch up and the queue actually forms
    let report = run_open_loop(&mut srv, requests(8, 10, 5, cfg.vocab), 500.0, 3).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.responses.len(), 8);
    assert_eq!(report.metrics.ttft.len(), 8, "one TTFT sample per request");
    assert_eq!(report.metrics.itl.len(), 8 * 4, "ITL gap per generated token after the first");
    for r in &report.responses {
        assert_eq!(r.tokens.len(), 5);
        assert!(r.ttft_s >= 0.0);
    }
    assert!(report.metrics.wall_secs > 0.0);
    assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
}
