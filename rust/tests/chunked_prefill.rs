//! Continuous-batching gate: chunked prefill must be **bitwise
//! token-identical** to whole-prompt prefill — at the model level across
//! {f32, int8, int4} KV under random block-aligned chunk schedules, and at
//! the serving level where the chunked schedule (and the shared-prefix
//! fork path it enables) must reproduce the lockstep servers' token
//! streams exactly. Plus the latency property the whole feature exists
//! for: a short request streams its first token while a long prompt is
//! still prefilling, and a second session over a shared prompt is served
//! its prefix from cache without recomputing or re-storing it.

use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::{Engine, Event, NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvPool, KvQuantCfg};
use lords::model::Model;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::util::prop::prop_check;
use lords::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn quantized_model(cfg: &ModelCfg, seed: u64) -> Model {
    let mut model = Model::init(cfg, seed);
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 2, ..Default::default() },
        false,
    );
    model
}

fn serve_cfg(prefill_chunk_tokens: usize) -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits: 32,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens,
        ..ServeCfg::default()
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

/// The identity gate: a prompt prefilled in random block-aligned chunks
/// must leave *exactly* the state of a whole-prompt prefill — final
/// logits bitwise, every layer's stored K/V bitwise, and the decode tail
/// that continues from it bitwise — for every KV format.
#[test]
fn chunked_prefill_is_bitwise_identical_to_whole_prefill() {
    let cfg = tiny_cfg();
    let model = quantized_model(&cfg, 7);
    prop_check(12, |g| {
        let bits = *g.pick(&[KvBits::F32, KvBits::Int8, KvBits::Int4]);
        let bt = *g.pick(&[4usize, 8]);
        let kv = KvQuantCfg { bits, rank: 1, block_tokens: bt };
        let plen = g.usize(5..=40);
        let mut rng = g.rng().fork(5);
        let prompt: Vec<usize> = (0..plen).map(|_| rng.below(cfg.vocab)).collect();

        let mut whole = KvPool::new(kv, cfg.n_layers, cfg.d_model, 64);
        let mut chunked = KvPool::new(kv, cfg.n_layers, cfg.d_model, 64);
        let want = model.prefill_pooled(&prompt, &mut whole, 1, None).unwrap();
        // random schedule: 1..=3 blocks per chunk, final chunk may be ragged
        let mut pos = 0usize;
        let mut got = None;
        while pos < plen {
            let end = (pos + g.usize(1..=3) * bt).min(plen);
            got = model
                .prefill_chunk_pooled(&prompt[pos..end], pos, plen, &mut chunked, 1, None)
                .map_err(|e| format!("{bits:?} bt={bt} chunk {pos}..{end}: {e}"))?;
            if (end < plen) != got.is_none() {
                return Err(format!(
                    "{bits:?} bt={bt}: logits must appear exactly on the final chunk"
                ));
            }
            pos = end;
        }
        let got = got.expect("loop ends on the final chunk");
        if got != want {
            return Err(format!(
                "{bits:?} bt={bt} plen={plen}: chunked logits diverge from whole prefill"
            ));
        }
        // the stored KV is the same, bit for bit, in every layer
        for layer in 0..cfg.n_layers {
            let (wk, wv) = whole.dense_kv(1, layer, plen);
            let (ck, cv) = chunked.dense_kv(1, layer, plen);
            if wk.data != ck.data || wv.data != cv.data {
                return Err(format!(
                    "{bits:?} bt={bt} plen={plen} layer {layer}: stored K/V differ"
                ));
            }
        }
        // and a greedy decode tail continues identically from both states
        let (mut tw, mut tc) = (argmax(&want), argmax(&got));
        for step in 0..2 {
            let lw = model.decode_pooled(tw, &mut whole, 1, None).unwrap();
            let lc = model.decode_pooled(tc, &mut chunked, 1, None).unwrap();
            if lw != lc {
                return Err(format!(
                    "{bits:?} bt={bt} plen={plen}: decode step {step} diverged"
                ));
            }
            tw = argmax(&lw);
            tc = argmax(&lc);
        }
        Ok(())
    });
}

/// Serving-level identity: the continuous-batching schedule (small
/// per-tick chunk budget), the lockstep-equivalent schedule (budget 0),
/// and a no-prefix-sharing baseline all emit exactly the same token
/// streams — scheduling and KV sharing change *when* work happens, never
/// *what* is generated. The trace includes duplicate prompts so the
/// prefix fork + private-suffix path is exercised, and the shared servers
/// must actually report cache hits.
#[test]
fn chunked_schedule_and_prefix_sharing_preserve_token_streams() {
    let cfg = tiny_cfg();
    let model = quantized_model(&cfg, 17);
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 8 };
    let requests = || -> Vec<Request> {
        let mut rng = Rng::new(23);
        let shared: Vec<usize> = (0..20).map(|_| rng.below(cfg.vocab)).collect();
        (0..6u64)
            .map(|id| {
                let prompt = if id % 4 == 0 {
                    shared.clone()
                } else {
                    (0..10 + id as usize).map(|_| rng.below(cfg.vocab)).collect()
                };
                Request::new(id, prompt, 6)
            })
            .collect()
    };
    let run = |chunk: usize, sharing: bool| {
        let mut engine = NativeEngine::with_kv(model.clone(), "sched", kv);
        engine.set_prefix_sharing(sharing);
        let mut srv = Server::new(engine, serve_cfg(chunk)).unwrap();
        let report = srv.run_trace(requests()).unwrap();
        assert_eq!(report.metrics.completed, 6);
        report
    };
    let lockstep = run(0, true);
    let chunked = run(8, true);
    let unshared = run(8, false);
    for (want, (a, b)) in lockstep
        .responses
        .iter()
        .zip(chunked.responses.iter().zip(&unshared.responses))
    {
        assert_eq!(
            want.tokens, a.tokens,
            "req {}: chunked schedule changed the token stream",
            want.id
        );
        assert_eq!(
            want.tokens, b.tokens,
            "req {}: prefix sharing changed the token stream",
            want.id
        );
    }
    // requests 0 and 4 share a 20-token prompt (16 block-aligned tokens
    // shareable at block_tokens = 8): both shared servers must have served
    // request 4's prefix from cache, the baseline must not have
    assert_eq!(lockstep.metrics.prefix_hit_tokens, 16);
    assert_eq!(chunked.metrics.prefix_hit_tokens, 16);
    assert_eq!(unshared.metrics.prefix_hit_tokens, 0);
    // cache hits mean fewer prompt tokens were actually computed
    assert_eq!(
        chunked.metrics.prefill_tokens + 16,
        unshared.metrics.prefill_tokens
    );
    // the chunked schedule really ran in several chunks per long prompt
    assert!(
        chunked.metrics.prefill_chunks > lockstep.metrics.prefill_chunks,
        "chunked {} vs lockstep {} prefill chunks",
        chunked.metrics.prefill_chunks,
        lockstep.metrics.prefill_chunks
    );
}

/// The latency property continuous batching buys: with a per-tick chunk
/// budget, a short request admitted alongside a long prompt streams its
/// first token while the long prompt is *still prefilling* — instead of
/// stalling behind the whole prompt as the lockstep schedule did.
#[test]
fn short_request_streams_while_long_prompt_still_prefilling() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 29);
    let kv = KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: 8 };
    let engine = NativeEngine::with_kv(model, "interleave", kv);
    let mut srv = Server::new(engine, serve_cfg(8)).unwrap();

    let mut rng = Rng::new(31);
    let long: Vec<usize> = (0..40).map(|_| rng.below(cfg.vocab)).collect();
    let short: Vec<usize> = (0..8).map(|_| rng.below(cfg.vocab)).collect();
    srv.submit(Request::new(0, long, 4)).unwrap();
    srv.submit(Request::new(1, short, 4)).unwrap();

    let mut interleaved = false;
    let mut done = 0;
    let mut guard = 0;
    while !srv.is_idle() {
        let events = srv.step().unwrap();
        for ev in events {
            match ev {
                Event::Token { id: 1, .. } if srv.num_prefilling() > 0 => interleaved = true,
                Event::Done { .. } => done += 1,
                _ => {}
            }
        }
        guard += 1;
        assert!(guard < 100, "server failed to drain");
    }
    assert_eq!(done, 2, "both requests complete");
    assert!(
        interleaved,
        "the short request must stream tokens while the long prompt prefills"
    );
    // the 40-token prompt was spread across 8-token ticks, not one call
    assert!(srv.metrics.prefill_chunks >= 6);
    assert_eq!(srv.metrics.prefill_tokens, 48);
}

/// Shared-prefix reuse end to end: after one session over a prompt, later
/// sessions over the same prompt are admitted with the block-aligned
/// prefix attached (not recomputed, not re-stored) — concurrent sharers
/// hold the prefix blocks once, and flushing the cache after the last
/// session drains the pool completely.
#[test]
fn second_session_reuses_shared_prefix_blocks() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 37);
    let kv = KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: 8 };
    let engine = NativeEngine::with_kv(model, "prefix", kv);
    let mut srv = Server::new(engine, serve_cfg(0)).unwrap();

    let mut rng = Rng::new(41);
    let prompt: Vec<usize> = (0..20).map(|_| rng.below(cfg.vocab)).collect();
    let drain = |srv: &mut Server<NativeEngine>| -> Vec<Vec<usize>> {
        let mut streams = Vec::new();
        while !srv.is_idle() {
            for ev in srv.step().unwrap() {
                if let Event::Done { response } = ev {
                    streams.push((response.id, response.tokens));
                }
            }
        }
        streams.sort_by_key(|(id, _)| *id);
        streams.into_iter().map(|(_, t)| t).collect()
    };

    // first session: full prefill, then its sealed prompt blocks stay cached
    srv.submit(Request::new(0, prompt.clone(), 4)).unwrap();
    let first = drain(&mut srv);
    assert_eq!(srv.metrics.prefill_tokens, 20);
    assert_eq!(srv.metrics.prefix_hit_tokens, 0);
    // 20 tokens at block_tokens = 8 seal two full blocks; both are cached
    assert_eq!(srv.engine.prefix_cache().cached_blocks(), 2);
    assert_eq!(srv.engine.kv_pool().used_blocks(), 2);
    assert_eq!(srv.engine.prefix_hit_tokens("base", &prompt), 16);

    // two concurrent sessions over the same prompt: the 16 shared tokens
    // are attached at admission, each computes only its 4-token suffix
    srv.submit(Request::new(1, prompt.clone(), 4)).unwrap();
    srv.submit(Request::new(2, prompt.clone(), 4)).unwrap();
    let mut peak_used = 0usize;
    let mut later: Vec<Vec<usize>> = Vec::new();
    while !srv.is_idle() {
        for ev in srv.step().unwrap() {
            if let Event::Done { response } = ev {
                later.push(response.tokens);
            }
        }
        peak_used = peak_used.max(srv.engine.kv_pool().used_blocks());
    }
    assert_eq!(srv.metrics.prefix_hit_tokens, 2 * 16);
    assert_eq!(srv.metrics.prefill_tokens, 20 + 2 * 4);
    // each session needs 3 blocks (20 prompt + 4 new); sharing holds the
    // 2 prefix blocks once: 2 shared + 2 private tails, not 2 x 3
    assert!(
        peak_used <= 4,
        "{peak_used} blocks used concurrently — the prefix was duplicated"
    );
    // every session over the shared prompt generated the same tokens,
    // and they match a fresh server that never had a cache to hit
    assert_eq!(later.len(), 2);
    for (i, stream) in later.iter().enumerate() {
        assert_eq!(
            *stream, first[0],
            "shared session {i} diverged from the uncached first session"
        );
    }
    let mut check = Server::new(
        NativeEngine::with_kv(Model::init(&cfg, 37), "solo", kv),
        serve_cfg(0),
    ).unwrap();
    check.submit(Request::new(0, prompt.clone(), 4)).unwrap();
    let solo = drain(&mut check);
    assert_eq!(first, solo, "cached-prefix serving changed the stream");

    // after the last session only the cached prefix remains; flushing it
    // returns the pool to empty
    assert_eq!(srv.engine.kv_pool().active_sequences(), 0);
    assert_eq!(srv.engine.kv_pool().used_blocks(), 2);
    srv.engine.flush_prefix_cache();
    assert_eq!(srv.engine.prefix_cache().cached_blocks(), 0);
    assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
}
