//! Integration: the PTQ → QAT → PEFT → serve pipeline on the native stack
//! (no artifacts required), plus cross-method sanity on a shared testbed.

use lords::config::{ModelCfg, QuantCfg, QuantMethod, ServeCfg, TrainCfg};
use lords::coordinator::{NativeEngine, Request, Server};
use lords::data::corpus::{Corpus, CorpusKind};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::methods::{quantize_model, CalibSet};
use lords::train::{NativeTrainer, TrainKind};
use lords::util::Rng;

fn cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 48,
        max_seq: 64,
        block: 16,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn pretrained() -> (lords::model::Model, Corpus) {
    let c = cfg();
    let corpus = Corpus::generate(CorpusKind::Wiki, c.vocab, 20_000, 4_000, 0);
    let mut model = lords::model::Model::init(&c, 0);
    let tcfg = TrainCfg { steps: 50, batch: 4, seq: 32, peak_lr: 3e-3, ..Default::default() };
    let mut tr = NativeTrainer::new(tcfg, TrainKind::Pretrain);
    tr.run(&mut model, &corpus);
    (model, corpus)
}

#[test]
fn quantization_degrades_less_with_lords_than_nf4() {
    let (model, corpus) = pretrained();
    let fp = lords::eval::perplexity(&model, &corpus, 32, 6).ppl;

    let mut m_nf4 = model.clone();
    m_nf4.quantize_blockwise(16, &Codebook::normal_float(2)); // 2-bit stresses the gap
    let p_nf4 = lords::eval::perplexity(&m_nf4, &corpus, 32, 6).ppl;

    let mut m_lords = model.clone();
    m_lords.quantize_lords(16, &Codebook::normal_float(2),
                           RefineCfg { steps: 80, ..Default::default() }, false);
    let p_lords = lords::eval::perplexity(&m_lords, &corpus, 32, 6).ppl;

    assert!(fp <= p_lords * 1.01, "fp {fp} should be best");
    assert!(
        p_lords < p_nf4,
        "LoRDS PPL {p_lords} must beat 2-bit blockwise {p_nf4} (fp {fp})"
    );
}

#[test]
fn qat_then_peft_then_serve() {
    let (model, corpus) = pretrained();
    let c = cfg();
    // QAT
    let mut m = model.clone();
    m.quantize_lords(c.block, &Codebook::normal_float(4),
                     RefineCfg { steps: 20, ..Default::default() }, true);
    let mut qat = NativeTrainer::new(
        TrainCfg { steps: 15, batch: 4, seq: 32, peak_lr: 3e-4, warmup_ratio: 0.3, ..Default::default() },
        TrainKind::Qat,
    );
    let qlog = qat.run(&mut m, &corpus);
    assert!(qlog.final_loss.is_finite());

    // PEFT on a shift
    let target = Corpus::generate(CorpusKind::Ptb, c.vocab, 20_000, 4_000, 5);
    let before = lords::eval::perplexity(&m, &target, 32, 4).ppl;
    let mut peft = NativeTrainer::new(
        TrainCfg { steps: 30, batch: 4, seq: 32, peak_lr: 2e-3, ..Default::default() },
        TrainKind::Peft,
    );
    peft.run(&mut m, &target);
    let after = lords::eval::perplexity(&m, &target, 32, 4).ppl;
    assert!(after < before, "PEFT must improve target PPL: {before} -> {after}");

    // Serve
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request::new(i, (0..16).map(|_| rng.below(c.vocab)).collect(), 8))
        .collect();
    let mut server = Server::new(
        NativeEngine::new(m, "lords"),
        ServeCfg { decode_buckets: vec![1, 2, 4], prefill_buckets: vec![1, 2, 4], ..Default::default() },
    ).unwrap();
    let report = server.run_trace(reqs).unwrap();
    assert_eq!(report.metrics.completed, 5);
    assert!(report.responses.iter().all(|r| r.tokens.len() == 8));
}

#[test]
fn every_method_preserves_model_usability() {
    let (model, corpus) = pretrained();
    let c = cfg();
    let fp = lords::eval::perplexity(&model, &corpus, 32, 4).ppl;
    let calib = CalibSet::synthetic(&[c.d_model, c.d_ff], 48, 3);
    for method in [
        QuantMethod::Nf4Blockwise,
        QuantMethod::Int4Blockwise,
        QuantMethod::Gptq,
        QuantMethod::Awq,
        QuantMethod::LoftQ,
        QuantMethod::QPissa,
        QuantMethod::QLora,
        QuantMethod::Lords,
    ] {
        let mut m = model.clone();
        let qcfg = QuantCfg { method, block: 16, refine_steps: 15, adapter_rank: 4, ..Default::default() };
        quantize_model(&mut m, &qcfg, Some(&calib), 0);
        let ppl = lords::eval::perplexity(&m, &corpus, 32, 4);
        assert!(!ppl.diverged, "{method:?} diverged");
        assert!(
            ppl.ppl < fp * 3.0,
            "{method:?}: 4-bit PPL {} vs fp {fp} — too much damage",
            ppl.ppl
        );
    }
}
