//! Kernel parity gate: for each representation {LoRDS, blockwise, QLoRA}
//! × bit width {2, 3, 4}, the fused bit-packed matmul must match the
//! dequantize-then-`matmul_transb` reference within 1e-4 max-abs-diff on
//! randomized shapes — the acceptance bar for the `kernels` subsystem.

use lords::quant::baselines::QloraLinear;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::testbed::{llm_like_weight, ModuleShape};
use lords::tensor::{matmul, matmul_transb, Matrix};
use lords::util::prop::{max_abs_diff, prop_check};
use lords::util::Rng;

const TOL: f32 = 1e-4;

/// Same LLM-like weight statistics (Gaussian bulk + outlier channels) as
/// the fig2 bench, so the parity gate and the perf numbers cover the same
/// distribution.
fn weights(rng: &mut Rng, n: usize, m: usize) -> Matrix {
    llm_like_weight(ModuleShape { name: "W", n, m }, rng)
}

fn check(label: String, fused: &Matrix, reference: &Matrix) -> Result<(), String> {
    let diff = max_abs_diff(&fused.data, &reference.data);
    if diff <= TOL {
        Ok(())
    } else {
        Err(format!("{label}: max |fused − dense| = {diff} > {TOL}"))
    }
}

#[test]
fn lords_fused_matches_dequant_gemm_all_bit_widths() {
    for bits in [2u32, 3, 4] {
        let cb = Codebook::normal_float(bits);
        prop_check(6, |g| {
            let n = g.usize(4..=40);
            let m = g.usize(2..=6) * 8;
            let t = g.usize(1..=10);
            let rank = g.usize(1..=3);
            let mut rng = g.rng().fork(bits as u64);
            let w = weights(&mut rng, n, m);
            let cfg = RefineCfg { steps: 8, ..Default::default() };
            let (q, _) = LordsQuant::quantize_with_rank(&w, 8, rank, &cb, cfg);
            if !q.b.all_finite() || !q.a.all_finite() {
                return Err(format!("non-finite scale factors at {n}x{m}"));
            }
            let w_hat = q.dequantize();
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            check(
                format!("lords nf{bits} fwd {n}x{m} t={t}"),
                &q.matmul_transb(&x),
                &matmul_transb(&x, &w_hat),
            )?;
            let gup = Matrix::randn(t, n, 1.0, &mut rng);
            check(
                format!("lords nf{bits} bwd {n}x{m} t={t}"),
                &q.matmul(&gup),
                &matmul(&gup, &w_hat),
            )
        });
    }
}

#[test]
fn blockwise_fused_matches_dequant_gemm_all_bit_widths() {
    for bits in [2u32, 3, 4] {
        let cb = Codebook::normal_float(bits);
        prop_check(6, |g| {
            let n = g.usize(2..=48);
            let m = g.usize(1..=6) * 8;
            let t = g.usize(1..=10);
            let mut rng = g.rng().fork(100 + bits as u64);
            let w = weights(&mut rng, n, m);
            let q = BlockwiseQuant::quantize(&w, 8, &cb);
            let w_hat = q.dequantize();
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            check(
                format!("blockwise nf{bits} fwd {n}x{m} t={t}"),
                &q.matmul_transb(&x),
                &matmul_transb(&x, &w_hat),
            )?;
            let gup = Matrix::randn(t, n, 1.0, &mut rng);
            check(
                format!("blockwise nf{bits} bwd {n}x{m} t={t}"),
                &q.matmul(&gup),
                &matmul(&gup, &w_hat),
            )
        });
    }
}

#[test]
fn qlora_fused_matches_dequant_gemm_all_bit_widths() {
    for bits in [2u32, 3, 4] {
        let cb = Codebook::normal_float(bits);
        prop_check(6, |g| {
            let n = g.usize(4..=40);
            let m = g.usize(2..=6) * 8;
            let t = g.usize(1..=10);
            let rank = g.usize(1..=4);
            let mut rng = g.rng().fork(200 + bits as u64);
            let w = weights(&mut rng, n, m);
            let mut q = QloraLinear::new(&w, 8, rank, &cb, &mut rng);
            // non-zero adapter = post-finetuning state
            rng.fill_normal(&mut q.lora_b.data, 0.0, 0.05);
            let w_hat = q.dequantize();
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            check(
                format!("qlora nf{bits} fwd {n}x{m} t={t}"),
                &q.forward(&x),
                &matmul_transb(&x, &w_hat),
            )
        });
    }
}

#[test]
fn packed_codes_survive_the_full_quantize_path() {
    // End-to-end: packing must be lossless — dequantize() (via per-element
    // get) and the fused kernels (via row unpack) must agree exactly.
    let mut rng = Rng::new(42);
    for bits in [2u32, 3, 4] {
        let cb = Codebook::normal_float(bits);
        let w = weights(&mut rng, 24, 40);
        let (q, _) = LordsQuant::quantize_with_rank(&w, 8, 2, &cb, RefineCfg { steps: 4, ..Default::default() });
        let x = Matrix::eye(40); // x = I ⇒ y = Ŵᵀ exactly
        let y = q.matmul_transb(&x);
        let w_hat = q.dequantize();
        let diff = max_abs_diff(&y.data, &w_hat.transpose().data);
        assert!(diff <= 1e-6, "nf{bits}: packed roundtrip drift {diff}");
    }
}
