//! Quantized paged KV-cache gate: the fused packed-KV attention path must
//! match the dense per-sequence cache within 1e-2 logit tolerance at
//! 8-bit (token-identical on a served trace), 4-bit must degrade
//! gracefully (bounded error, no NaNs), and the pool must uphold the
//! allocator's invariants over real storage: no leak, no aliasing,
//! eviction-safety — the acceptance bar for the `kvquant` subsystem.

use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::{NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvPool, KvQuantCfg};
use lords::model::{KvCache, Model};
use lords::tensor::Matrix;
use lords::util::prop::{max_abs_diff, prop_check};
use lords::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn serve_cfg(kv_bits: u32) -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 0,
        ..ServeCfg::default()
    }
}

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(99);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

/// 8-bit packed KV vs the dense per-sequence cache: logits within 1e-2
/// through prefill and a decode tail.
#[test]
fn int8_kv_matches_dense_within_logit_tolerance() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 31);
    let mut rng = Rng::new(32);
    let tokens: Vec<usize> = (0..20).map(|_| rng.below(cfg.vocab)).collect();

    let mut cache = KvCache::new(&cfg);
    let mut want = vec![model.prefill(&tokens[..14], &mut cache)];
    for &t in &tokens[14..] {
        want.push(model.decode(t, &mut cache));
    }

    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 4 };
    let mut pool = KvPool::new(kv, cfg.n_layers, cfg.d_model, 16);
    let mut got = vec![model.prefill_pooled(&tokens[..14], &mut pool, 1, None).unwrap()];
    for &t in &tokens[14..] {
        got.push(model.decode_pooled(t, &mut pool, 1, None).unwrap());
    }
    for (step, (g, w)) in got.iter().zip(&want).enumerate() {
        let diff = max_abs_diff(g, w);
        assert!(diff <= 1e-2, "step {step}: 8-bit KV logit drift {diff} > 1e-2");
    }
}

/// 4-bit packed KV degrades gracefully: logits stay finite and bounded.
#[test]
fn int4_kv_degrades_gracefully() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 33);
    let mut rng = Rng::new(34);
    let tokens: Vec<usize> = (0..18).map(|_| rng.below(cfg.vocab)).collect();

    let mut cache = KvCache::new(&cfg);
    let mut want = vec![model.prefill(&tokens[..12], &mut cache)];
    for &t in &tokens[12..] {
        want.push(model.decode(t, &mut cache));
    }

    for rank in [1usize, 2] {
        let kv = KvQuantCfg { bits: KvBits::Int4, rank, block_tokens: 4 };
        let mut pool = KvPool::new(kv, cfg.n_layers, cfg.d_model, 16);
        let mut got = vec![model.prefill_pooled(&tokens[..12], &mut pool, 1, None).unwrap()];
        for &t in &tokens[12..] {
            got.push(model.decode_pooled(t, &mut pool, 1, None).unwrap());
        }
        for (step, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(g.iter().all(|v| v.is_finite()), "rank {rank} step {step}: NaN/inf logits");
            let diff = max_abs_diff(g, w);
            assert!(diff <= 0.5, "rank {rank} step {step}: 4-bit drift {diff} unbounded");
        }
    }
}

/// The acceptance trace: a batched serve at 8-bit KV must emit exactly
/// the token streams of the dense-KV serve.
#[test]
fn served_trace_token_match_at_8bit() {
    let cfg = tiny_cfg();
    let model = Model::init(&cfg, 41);

    let mut dense_srv = Server::new(NativeEngine::new(model.clone(), "kv32"), serve_cfg(32)).unwrap();
    let dense = dense_srv.run_trace(requests(6, 12, 6, cfg.vocab)).unwrap();
    assert_eq!(dense.metrics.completed, 6);

    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 8 };
    let mut packed_srv =
        Server::new(NativeEngine::with_kv(model, "kv8", kv), serve_cfg(8)).unwrap();
    let packed = packed_srv.run_trace(requests(6, 12, 6, cfg.vocab)).unwrap();
    assert_eq!(packed.metrics.completed, 6);

    for (d, p) in dense.responses.iter().zip(&packed.responses) {
        assert_eq!(d.id, p.id);
        assert_eq!(
            d.tokens, p.tokens,
            "req {}: 8-bit KV serve diverged from the dense trace",
            d.id
        );
    }
    // the packed pool really is smaller per block
    let pool = packed_srv.engine.kv_pool();
    assert!(pool.block_bytes() * 2 < pool.dense_block_bytes());
}

/// Fixed byte budget ⇒ quantized KV admits ≥ 2x (4-bit: ≥ 3.5x bytes,
/// ≥ 2x sequences) the concurrent sequences of dense f32.
#[test]
fn fixed_budget_concurrency_gain() {
    let (layers, d, bt, max_seq) = (4usize, 256usize, 16usize, 256usize);
    let budget = 32 << 20;
    let mk = |bits| {
        KvPool::with_byte_budget(
            KvQuantCfg { bits, rank: 1, block_tokens: bt },
            layers,
            d,
            budget,
            max_seq,
        )
    };
    let dense = mk(KvBits::F32);
    let int8 = mk(KvBits::Int8);
    let int4 = mk(KvBits::Int4);
    let bytes_ratio_4 = dense.block_bytes() as f64 / int4.block_bytes() as f64;
    assert!(bytes_ratio_4 >= 3.5, "4-bit KV bytes reduction {bytes_ratio_4} < 3.5x");
    let conc = |p: &KvPool| p.max_concurrent_full_seqs(max_seq);
    assert!(
        conc(&int4) >= 2 * conc(&dense),
        "4-bit concurrency {} < 2x dense {}",
        conc(&int4),
        conc(&dense)
    );
    assert!(conc(&int8) > conc(&dense), "8-bit must beat dense concurrency");
}

/// Pool property gate over real storage: interleaved reserve / append /
/// release must never leak blocks, never alias two sequences' data, and
/// survive release + reuse (eviction-safety). Dense mode makes the check
/// exact: every live sequence must read back exactly what it appended.
#[test]
fn pool_no_leak_no_aliasing_eviction_safe() {
    prop_check(24, |g| {
        let bt = *g.pick(&[2usize, 4]);
        let d = 4usize;
        let capacity = g.usize(2..=12);
        let kv = KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: bt };
        let mut pool = KvPool::new(kv, 1, d, capacity);
        let mut rng = g.rng().fork(17);
        // mirror of appended rows per live sequence
        let mut live: Vec<(u64, Matrix)> = Vec::new();
        for step in 0..60u64 {
            let grow = g.bool() && !live.is_empty();
            if grow {
                // grow a random live sequence by 1..=bt rows
                let idx = rng.below(live.len());
                let (seq, mirror) = &mut live[idx];
                let n = 1 + rng.below(bt);
                let k = Matrix::randn(n, d, 1.0, &mut rng);
                if pool.append_rows(*seq, 0, mirror.rows, &k, &k).is_ok() {
                    let mut grown = Matrix::zeros(mirror.rows + n, d);
                    grown.paste(0, 0, mirror);
                    grown.paste(mirror.rows, 0, &k);
                    *mirror = grown;
                    pool.commit(*seq, mirror.rows);
                }
            } else if g.bool() || live.is_empty() {
                // admit a new sequence
                let seq = 1000 + step;
                let n = 1 + rng.below(2 * bt);
                let k = Matrix::randn(n, d, 1.0, &mut rng);
                if pool.append_rows(seq, 0, 0, &k, &k).is_ok() {
                    pool.commit(seq, n);
                    live.push((seq, k));
                } else {
                    pool.release(seq); // clean up the empty entry
                }
            } else {
                let idx = rng.below(live.len());
                let (seq, _) = live.swap_remove(idx);
                if !pool.release(seq) {
                    return Err(format!("release of live seq {seq} reported unknown"));
                }
                if pool.release(seq) {
                    return Err(format!("double release of seq {seq} reported success"));
                }
            }
            // no leak: allocator arithmetic must always balance
            if pool.used_blocks() + pool.free_blocks() != capacity {
                return Err(format!(
                    "leak at step {step}: used {} + free {} != cap {capacity}",
                    pool.used_blocks(),
                    pool.free_blocks()
                ));
            }
            // no aliasing / eviction-safety: every live sequence reads back
            // exactly its own rows (a shared or stale block would corrupt)
            for (seq, mirror) in &live {
                let (dk, dv) = pool.dense_kv(*seq, 0, mirror.rows);
                if dk.data != mirror.data || dv.data != mirror.data {
                    return Err(format!("seq {seq} read back foreign/stale data"));
                }
            }
        }
        Ok(())
    });
}

/// The packed formats uphold the same storage invariants (bounded error
/// instead of exactness for sealed rows).
#[test]
fn packed_pool_survives_reuse() {
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 4 };
    let mut pool = KvPool::new(kv, 2, 8, 6);
    let mut rng = Rng::new(55);
    for round in 0..5u64 {
        let k = Matrix::randn(9, 8, 0.5, &mut rng);
        let v = Matrix::randn(9, 8, 0.5, &mut rng);
        for layer in 0..2 {
            pool.append_rows(round, layer, 0, &k, &v).unwrap();
        }
        pool.commit(round, 9);
        let tol = 0.03 * k.abs_max().max(v.abs_max());
        for layer in 0..2 {
            let (dk, dv) = pool.dense_kv(round, layer, 9);
            for (a, b) in dk.data.iter().zip(&k.data).chain(dv.data.iter().zip(&v.data)) {
                assert!((a - b).abs() <= tol, "round {round}: stale or aliased block");
            }
        }
        assert!(pool.release(round));
        assert_eq!(pool.used_blocks(), 0, "round {round} leaked blocks");
    }
}
