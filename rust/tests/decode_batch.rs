//! Batched decode tick gate: one serving tick must be **token-identical**
//! to the old per-sequence decode loop, bitwise — across mixed tenants
//! (base + two adapters), ragged sequence lengths, and every KV format
//! ({f32, int8, int4}) — while streaming each packed weight once per
//! tenant-group instead of once per sequence.
//!
//! Three layers of gate:
//! * model level — `Model::decode_batch_pooled` vs a `decode_pooled` loop
//!   over property-sampled tenancy/length/bit-width mixes;
//! * engine level — `Engine::decode` (batched) vs
//!   `NativeEngine::decode_reference` across ragged admission waves, plus
//!   the tenant-group count the tick amortizes weight streaming over;
//! * serving level — a mixed-tenant quantized `run_trace` reproduces each
//!   request's dedicated single-stream golden (the pre-batching serving
//!   behavior), so the `serve_online` goldens are unchanged.

use lords::adapters::AdapterFactors;
use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::engine::SeqState;
use lords::coordinator::{Engine, NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvPool, KvQuantCfg};
use lords::model::{DecodeRow, DecodeScratch, Model};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::util::prop::prop_check;
use lords::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn quantized_model(cfg: &ModelCfg, seed: u64) -> Model {
    let mut model = Model::init(cfg, seed);
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 2, ..Default::default() },
        false,
    );
    model
}

/// Model-level property: mixed tenants (base + 2 adapters guaranteed in
/// every case), mixed prompt lengths, {f32, int8, int4} KV — the batched
/// tick's logits equal the per-sequence loop's, bitwise, tick after tick.
#[test]
fn batched_tick_is_token_identical_across_tenants_lengths_and_kv_formats() {
    let cfg = tiny_cfg();
    let model = quantized_model(&cfg, 11);
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(12);
    let adapters = [base.perturbed(0.05, &mut arng), base.perturbed(0.05, &mut arng)];
    let factors = |t: usize| -> Option<&AdapterFactors> {
        match t {
            0 => None,
            i => Some(&adapters[i - 1]),
        }
    };
    prop_check(8, |g| {
        let bits = *g.pick(&[KvBits::F32, KvBits::Int8, KvBits::Int4]);
        let kv = KvQuantCfg { bits, rank: 1, block_tokens: 4 };
        let nseq = g.usize(3..=6);
        let mut rng = g.rng().fork(3);
        let mut pool_ref = KvPool::new(kv, cfg.n_layers, cfg.d_model, 256);
        let mut pool_bat = KvPool::new(kv, cfg.n_layers, cfg.d_model, 256);
        // base + both adapters always present; extra sequences random
        let tenancy: Vec<usize> =
            (0..nseq).map(|i| if i < 3 { i } else { g.usize(0..=2) }).collect();
        let lens: Vec<usize> = (0..nseq).map(|_| g.usize(1..=10)).collect();
        let mut last = Vec::new();
        for i in 0..nseq {
            let prompt: Vec<usize> = (0..lens[i]).map(|_| rng.below(cfg.vocab)).collect();
            let seq = i as u64 + 1;
            let la = model
                .prefill_pooled(&prompt, &mut pool_ref, seq, factors(tenancy[i]))
                .unwrap();
            let lb = model
                .prefill_pooled(&prompt, &mut pool_bat, seq, factors(tenancy[i]))
                .unwrap();
            assert_eq!(la, lb, "prefill must agree before the tick comparison");
            last.push(argmax(&la));
        }
        // the engine stable-groups by tenant before the batched call
        let mut order: Vec<usize> = (0..nseq).collect();
        order.sort_by_key(|&i| tenancy[i]);
        let mut scratch = DecodeScratch::new();
        for tick in 0..3 {
            let mut ref_logits: Vec<Vec<f32>> = Vec::with_capacity(nseq);
            for i in 0..nseq {
                ref_logits.push(
                    model
                        .decode_pooled(last[i], &mut pool_ref, i as u64 + 1, factors(tenancy[i]))
                        .unwrap(),
                );
            }
            let rows: Vec<DecodeRow> = order
                .iter()
                .map(|&i| DecodeRow {
                    seq: i as u64 + 1,
                    token: last[i],
                    adapter: factors(tenancy[i]),
                })
                .collect();
            let groups = model.decode_batch_pooled(&rows, &mut pool_bat, &mut scratch).unwrap();
            let mut distinct = tenancy.clone();
            distinct.sort_unstable();
            distinct.dedup();
            if groups != distinct.len() {
                return Err(format!(
                    "{bits:?} nseq={nseq}: {groups} tenant-groups formed, expected {}",
                    distinct.len()
                ));
            }
            for (r, &i) in order.iter().enumerate() {
                if scratch.logits().row(r) != ref_logits[i].as_slice() {
                    return Err(format!(
                        "{bits:?} nseq={nseq} tick {tick} seq {i} (tenant {}): \
                         batched logits diverge from per-sequence reference",
                        tenancy[i]
                    ));
                }
            }
            last = ref_logits.iter().map(|l| argmax(l)).collect();
        }
        Ok(())
    });
}

/// Engine-level gate: `Engine::decode` (the batched tick) matches
/// `decode_reference` bitwise across ragged admission waves, and the tick
/// forms exactly one tenant-group per distinct resident adapter.
#[test]
fn engine_batched_decode_matches_reference_across_admission_waves() {
    let cfg = tiny_cfg();
    let model = quantized_model(&cfg, 21);
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(22);
    let a0 = base.perturbed(0.05, &mut arng);
    let a1 = base.perturbed(0.05, &mut arng);
    let mut batched = NativeEngine::new(model.clone(), "batched");
    let mut reference = NativeEngine::new(model, "reference");
    for eng in [&mut batched, &mut reference] {
        eng.register_adapter("t0", a0.clone()).unwrap();
        eng.register_adapter("t1", a1.clone()).unwrap();
    }

    let tenants = ["base", "t0", "t1", "t0"];
    let admit = |eng: &mut NativeEngine, ids: std::ops::Range<u64>, plen: usize| {
        let mut rng = Rng::new(100 + ids.start);
        let mut seqs: Vec<SeqState> = ids
            .map(|id| {
                let prompt: Vec<usize> =
                    (0..plen + id as usize % 3).map(|_| rng.below(32)).collect();
                let req = Request::new(id, prompt, 8)
                    .with_adapter(tenants[id as usize % tenants.len()]);
                SeqState::admit(&req, 32)
            })
            .collect();
        eng.prefill(&mut seqs).unwrap();
        seqs
    };

    let mut seqs_b = admit(&mut batched, 0..3, 4);
    let mut seqs_r = admit(&mut reference, 0..3, 4);
    for wave in 0..2 {
        for _tick in 0..3 {
            for (b, r) in seqs_b.iter_mut().zip(seqs_r.iter_mut()) {
                assert_eq!(b.last_logits, r.last_logits, "logits diverged before tick");
                let tok = b.next_token();
                b.tokens.push(tok);
                let tok_r = r.next_token();
                r.tokens.push(tok_r);
                assert_eq!(tok, tok_r, "sampled tokens diverged");
            }
            batched.decode(&mut seqs_b).unwrap();
            reference.decode_reference(&mut seqs_r).unwrap();
            for (b, r) in seqs_b.iter().zip(seqs_r.iter()) {
                assert_eq!(
                    b.last_logits, r.last_logits,
                    "wave {wave}: batched tick diverged from per-sequence loop (seq {})",
                    b.id
                );
            }
            // one weight stream per distinct tenant in the running set
            let mut distinct: Vec<&str> =
                seqs_b.iter().map(|s| s.adapter.as_str()).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert_eq!(batched.last_decode_groups(), distinct.len());
        }
        if wave == 0 {
            // second admission wave lands at a different cache position —
            // the running set becomes ragged in both position and tenant
            seqs_b.extend(admit(&mut batched, 3..5, 6));
            seqs_r.extend(admit(&mut reference, 3..5, 6));
        }
    }
}

/// Serving-level gate: a mixed-tenant, quantized-KV `run_trace` still
/// reproduces every request's dedicated single-stream golden — the same
/// property the pre-batching serving loop was gated on, so the
/// `serve_online` goldens are unchanged by the batched tick.
#[test]
fn mixed_tenant_quantized_serve_matches_single_stream_goldens() {
    let cfg = tiny_cfg();
    let serve = ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 6,
        workers: 1,
        kv_bits: 8,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 0,
        ..ServeCfg::default()
    };
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 8 };
    let model = quantized_model(&cfg, 31);
    let base = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(32);
    let adapters = [base.perturbed(0.05, &mut arng), base.perturbed(0.05, &mut arng)];
    let build = || {
        let mut engine = NativeEngine::with_kv(model.clone(), "mt", kv);
        engine.register_adapter("t0", adapters[0].clone()).unwrap();
        engine.register_adapter("t1", adapters[1].clone()).unwrap();
        Server::new(engine, serve.clone()).unwrap()
    };
    let requests = |only: Option<u64>| -> Vec<Request> {
        let mut rng = Rng::new(33);
        let tenants = ["base", "t0", "t1"];
        (0..6u64)
            .map(|id| {
                let prompt: Vec<usize> =
                    (0..6 + id as usize % 4).map(|_| rng.below(cfg.vocab)).collect();
                Request::new(id, prompt, 6).with_adapter(tenants[id as usize % 3])
            })
            .filter(|r| match only {
                None => true,
                Some(id) => r.id == id,
            })
            .collect()
    };
    let mut srv = build();
    let report = srv.run_trace(requests(None)).unwrap();
    assert_eq!(report.metrics.completed, 6);
    assert!(report.metrics.avg_decode_batch() > 1.0, "ticks actually batched");
    for want in &report.responses {
        let mut solo = build();
        let golden = solo.run_trace(requests(Some(want.id))).unwrap();
        assert_eq!(
            golden.responses[0].tokens, want.tokens,
            "req {} ({}): batched serve diverged from its single-stream golden",
            want.id, want.adapter
        );
    }
}
