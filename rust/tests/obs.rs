//! Observability gate. The load-bearing guarantee: instrumentation must
//! never perturb serving — token streams are bitwise identical with
//! tracing on vs off (greedy and seeded-sampled requests alike). Around
//! it, the exposition contracts: a Prometheus text golden (family
//! ordering, label escaping, cumulative `le` buckets, empty-histogram
//! rendering), the JSON snapshot round-trip, Chrome-trace export that
//! parses back, registry handle semantics under thread contention, and
//! the flight recorder's lifecycle + anomaly behavior through the real
//! server.

use lords::config::{ModelCfg, ServeCfg};
use lords::coordinator::{Event, NativeEngine, RejectReason, Request, SamplingParams, Server};
use lords::kvquant::{KvBits, KvQuantCfg};
use lords::model::Model;
use lords::obs::json::Json;
use lords::obs::{trace, AdminServer, FlightKind, Registry, Snapshot};
use lords::util::Rng;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 32,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 24,
        max_seq: 48,
        block: 8,
        codebook: "nf4".into(),
        qlora_rank: 4,
    }
}

fn serve_cfg() -> ServeCfg {
    ServeCfg {
        decode_buckets: vec![1, 2, 4],
        prefill_buckets: vec![1, 2, 4],
        batch_window_us: 0,
        max_queue: 64,
        max_new_tokens: 8,
        workers: 1,
        kv_bits: 32,
        kv_budget_mib: 0.0,
        rate_rps: 0.0,
        prefill_chunk_tokens: 8,
        ..ServeCfg::default()
    }
}

fn tiny_server(seed: u64) -> Server<NativeEngine> {
    let cfg = tiny_cfg();
    Server::new(NativeEngine::new(Model::init(&cfg, seed), "obs"), serve_cfg()).unwrap()
}

/// Half greedy, half seeded-sampled — sampling exercises the paths most
/// sensitive to perturbation.
fn requests(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
    let mut rng = Rng::new(7);
    let sampled = SamplingParams { temperature: 0.8, top_k: 8, seed: 3 };
    (0..n)
        .map(|i| {
            let req = Request::new(
                i as u64,
                (0..prompt_len).map(|_| rng.below(32)).collect(),
                max_new,
            );
            if i % 2 == 1 {
                req.with_sampling(sampled.clone())
            } else {
                req
            }
        })
        .collect()
}

/// The acceptance criterion, plus the export path: tracing on must not
/// change a single token, and the recorded spans must cover the tick
/// phases and render as parseable Chrome-trace JSON.
///
/// Kept as ONE test because the enabled flag and drain cursors are
/// process-global — splitting it would let the toggles race.
#[test]
fn tracing_on_is_bitwise_identical_and_exports_chrome_trace() {
    let off = tiny_server(5).run_trace(requests(6, 12, 6)).unwrap();
    assert_eq!(off.metrics.completed, 6);

    trace::set_enabled(true);
    let on = tiny_server(5).run_trace(requests(6, 12, 6)).unwrap();
    trace::set_enabled(false);

    assert_eq!(on.responses.len(), off.responses.len());
    for (a, b) in off.responses.iter().zip(&on.responses) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}: tracing perturbed the token stream", a.id);
    }

    let spans = trace::drain();
    for want in
        ["server.tick", "server.admit", "server.prefill", "server.decode", "engine.decode"]
    {
        assert!(
            spans.iter().any(|s| s.name == want),
            "no {want} span recorded (got {:?})",
            trace::phase_totals(&spans).iter().map(|t| t.0.clone()).collect::<Vec<_>>()
        );
    }
    // every prompt prefilled through the chunked path (block rounding
    // lets a 12-token prompt finish in one 16-token-block chunk)
    let chunks = spans.iter().filter(|s| s.name == "engine.prefill_chunk").count();
    assert!(chunks >= 6, "expected one chunk per request at least, saw {chunks}");

    let doc = Json::parse(&trace::render_chrome(&spans)).expect("chrome trace must parse");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), spans.len());
    for ev in events {
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert!(ev.get("ts").unwrap().as_num().is_some());
        assert!(ev.get("dur").unwrap().as_num().is_some());
    }
    // per-phase totals account for every span exactly once
    let total: u64 = trace::phase_totals(&spans).iter().map(|(_, n, _)| n).sum();
    assert_eq!(total as usize, spans.len());
}

#[test]
fn prometheus_exposition_golden() {
    let reg = Registry::new();
    reg.gauge("demo_depth", &[]).set(-2);
    reg.counter_with_help("demo_jobs_total", &[], "Jobs processed.").add(1);
    reg.histogram("demo_empty", &[], &[1.0]);
    let h = reg.histogram("demo_lat", &[], &[0.5, 1.0, 2.5]);
    h.observe(0.5); // boundary lands in le="0.5" (inclusive)
    h.observe(2.0);
    h.observe(99.0); // +Inf only
    reg.counter("demo_requests_total", &[("tenant", "a\"b\\c\nd")]).add(3);

    let want = concat!(
        "# TYPE demo_depth gauge\n",
        "demo_depth -2\n",
        "# TYPE demo_empty histogram\n",
        "demo_empty_bucket{le=\"1\"} 0\n",
        "demo_empty_bucket{le=\"+Inf\"} 0\n",
        "demo_empty_sum 0\n",
        "demo_empty_count 0\n",
        "# HELP demo_jobs_total Jobs processed.\n",
        "# TYPE demo_jobs_total counter\n",
        "demo_jobs_total 1\n",
        "# TYPE demo_lat histogram\n",
        "demo_lat_bucket{le=\"0.5\"} 1\n",
        "demo_lat_bucket{le=\"1\"} 1\n",
        "demo_lat_bucket{le=\"2.5\"} 2\n",
        "demo_lat_bucket{le=\"+Inf\"} 3\n",
        "demo_lat_sum 101.5\n",
        "demo_lat_count 3\n",
        "# TYPE demo_requests_total counter\n",
        "demo_requests_total{tenant=\"a\\\"b\\\\c\\nd\"} 3\n",
    );
    assert_eq!(reg.render_prometheus(), want);
}

#[test]
fn json_snapshot_round_trips() {
    let reg = Registry::new();
    reg.counter("c_total", &[("k", "v"), ("a", "z")]).add(41);
    reg.gauge("g_now", &[]).set(-7);
    let h = reg.histogram("h_lat", &[], &[0.25, 1.0]);
    h.observe(0.1);
    h.observe(0.75);
    h.observe(3.0);

    let snap = reg.snapshot();
    let text = snap.to_json();
    let back = Snapshot::from_json(&text).expect("snapshot JSON must parse back");
    assert_eq!(back, snap);
    // and the registry's own render is the same document
    assert_eq!(reg.render_json(), text);
    assert!(Json::parse(&text).is_ok());
}

#[test]
fn registry_handles_are_safe_under_contention() {
    let reg = Registry::new();
    let shared = reg.counter("smoke_total", &[]);
    let hist = reg.histogram("smoke_lat", &[], &[8.0, 64.0]);
    std::thread::scope(|s| {
        for t in 0..8 {
            let shared = shared.clone();
            let hist = hist.clone();
            let reg = &reg;
            s.spawn(move || {
                for i in 0..1000 {
                    shared.inc();
                    hist.observe(i as f64);
                    // get-or-register from many threads resolves to the
                    // same underlying series
                    reg.counter("smoke_total_b", &[("t", if t % 2 == 0 { "even" } else { "odd" })])
                        .inc();
                }
            });
        }
    });
    assert_eq!(shared.get(), 8000);
    assert_eq!(hist.count(), 8000);
    assert_eq!(hist.bucket_counts().iter().sum::<u64>(), 8000);
    assert!((hist.sum() - 8.0 * (0..1000).sum::<u64>() as f64).abs() < 1e-6);
    assert_eq!(reg.counter("smoke_total_b", &[("t", "even")]).get(), 4000);
    assert_eq!(reg.counter("smoke_total_b", &[("t", "odd")]).get(), 4000);
}

#[test]
fn serving_populates_registry_and_flight_recorder() {
    let mut srv = tiny_server(0);
    let report = srv.run_trace(requests(5, 12, 6)).unwrap();
    assert_eq!(report.metrics.completed, 5);

    // cumulative registry survives the windowed report's reset
    let snap = srv.obs.registry.snapshot();
    let counter = |name: &str| {
        snap.counters
            .iter()
            .find(|c| c.name == name)
            .unwrap_or_else(|| panic!("missing counter {name}"))
            .value
    };
    assert_eq!(counter("lords_completed_total"), 5);
    assert_eq!(counter("lords_requests_total"), 5); // adapter="base"
    assert_eq!(counter("lords_prefill_tokens_total"), 5 * 12);
    assert!(counter("lords_decode_ticks_total") > 0);
    assert!(counter("lords_decode_tokens_total") >= 5 * 6);
    let gauge = |name: &str| {
        snap.gauges
            .iter()
            .find(|g| g.name == name)
            .unwrap_or_else(|| panic!("missing gauge {name}"))
            .value
    };
    assert!(gauge("lords_kv_blocks_capacity") > 0);
    assert_eq!(gauge("lords_kv_active_sequences"), 0, "trace drained");
    assert_eq!(gauge("lords_queue_depth"), 0);
    assert!(snap.histograms.iter().any(|h| h.name == "lords_decode_batch_size" && h.count > 0));
    assert!(snap.histograms.iter().any(|h| h.name == "lords_ttft_seconds" && h.count == 5));
    let text = srv.obs.registry.render_prometheus();
    assert!(text.contains("lords_requests_total{adapter=\"base\"} 5"), "{text}");
    assert!(text.contains("# TYPE lords_decode_batch_size histogram"));

    // the flight recorder holds request 0's full lifecycle, in order
    let kinds: Vec<&FlightKind> =
        srv.obs.flight.events().filter(|e| e.seq == 0).map(|e| &e.kind).collect();
    assert_eq!(kinds.first(), Some(&&FlightKind::Submitted));
    assert!(kinds.iter().any(|k| matches!(k, FlightKind::Admitted { .. })));
    assert!(kinds.iter().any(|k| matches!(k, FlightKind::PrefillChunk { .. })));
    assert!(kinds.contains(&&FlightKind::FirstToken));
    assert!(kinds.iter().any(|k| matches!(k, FlightKind::Done { generated: 6 })));
    assert_eq!(kinds.last(), Some(&&FlightKind::Released));
    // no anomaly on a healthy run, and the dump parses
    assert!(srv.obs.flight.take_anomaly().is_none());
    let dump = Json::parse(&srv.obs.flight.dump()).expect("flight dump must parse");
    assert!(!dump.get("events").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn rejection_storm_trips_an_anomaly_dump() {
    let mut srv = tiny_server(0);
    for i in 0..8u64 {
        assert_eq!(
            srv.submit(Request::new(i, vec![], 4)),
            Err(RejectReason::EmptyPrompt)
        );
    }
    let anomaly = srv.obs.flight.take_anomaly().expect("8 rejections in <1s must trip");
    assert!(anomaly.reason.contains("rejection storm"), "{}", anomaly.reason);
    let dump = Json::parse(&anomaly.dump).expect("anomaly dump must parse");
    let events = dump.get("events").unwrap().as_arr().unwrap();
    assert!(events
        .iter()
        .all(|e| e.get("kind").unwrap().as_str() == Some("rejected")
            && e.get("reason").unwrap().as_str() == Some("empty_prompt")));
    // the reason-labelled counter saw all of them
    assert_eq!(
        srv.obs
            .registry
            .counter("lords_rejected_total", &[("reason", "empty_prompt")])
            .get(),
        8
    );
    // tripwire re-armed
    assert!(srv.obs.flight.take_anomaly().is_none());
}

/// Cancellation shows up in both the registry and the flight recorder
/// (and the cancelled counter feeds `print_adapters`' new column).
#[test]
fn cancellation_is_observable() {
    let mut srv = tiny_server(0);
    for r in requests(4, 12, 8) {
        srv.submit(r).unwrap();
    }
    srv.step().unwrap(); // admit + first chunk
    assert!(srv.cancel(2));
    while !srv.is_idle() {
        for ev in srv.step().unwrap() {
            if let Event::Rejected { id, reason } = ev {
                panic!("unexpected rejection of {id}: {reason}");
            }
        }
    }
    assert_eq!(srv.obs.registry.counter("lords_cancelled_total", &[]).get(), 1);
    assert_eq!(srv.obs.registry.counter("lords_completed_total", &[]).get(), 3);
    let kinds: Vec<&FlightKind> =
        srv.obs.flight.events().filter(|e| e.seq == 2).map(|e| &e.kind).collect();
    assert!(kinds.contains(&&FlightKind::Cancelled));
    assert_eq!(kinds.last(), Some(&&FlightKind::Released), "cancel released its KV");
    assert_eq!(srv.metrics.cancelled, 1);
}

/// Quality telemetry's non-perturbation contract: running the logit-drift
/// sentinel every tick must not change a single served token, for every
/// KV tier. And because the batched decode tick is bitwise identical to
/// the reference path it replays, the sentinel must report perfect top-1
/// agreement with exactly zero drift — any other reading is a real bug.
#[test]
fn sentinel_on_is_bitwise_identical_across_kv_tiers() {
    for kv_bits in [32u32, 8, 4] {
        let cfg = tiny_cfg();
        let kv = KvQuantCfg::with_bits(KvBits::parse(kv_bits).unwrap());
        let server_with = |sentinel: usize| {
            let engine = NativeEngine::with_kv(Model::init(&cfg, 11), "sentinel", kv);
            let serve =
                ServeCfg { kv_bits, sentinel_every_n_ticks: sentinel, ..serve_cfg() };
            Server::new(engine, serve).unwrap()
        };
        let off = server_with(0).run_trace(requests(6, 12, 6)).unwrap();
        let mut srv = server_with(1);
        let on = srv.run_trace(requests(6, 12, 6)).unwrap();
        assert_eq!(off.responses.len(), on.responses.len());
        for (a, b) in off.responses.iter().zip(&on.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "kv{kv_bits} req {}: sentinel perturbed the token stream",
                a.id
            );
        }
        let snap = srv.obs.registry.snapshot();
        let probes = snap
            .counters
            .iter()
            .find(|c| c.name == "lords_sentinel_probes_total")
            .expect("probe counter registered")
            .value;
        assert!(probes > 0, "kv{kv_bits}: the sentinel never ran");
        let agree = snap
            .histograms
            .iter()
            .find(|h| h.name == "lords_sentinel_top1_agree")
            .expect("agreement histogram registered");
        assert_eq!(agree.count, probes, "kv{kv_bits}: every probe records agreement");
        assert_eq!(
            agree.sum, probes as f64,
            "kv{kv_bits}: served and reference logits must agree on top-1"
        );
        let drift = snap
            .histograms
            .iter()
            .find(|h| h.name == "lords_sentinel_logit_drift")
            .expect("drift histogram registered");
        assert_eq!(drift.count, probes);
        assert_eq!(drift.sum, 0.0, "kv{kv_bits}: the reference replay must be exact");
        // the shadow sequence never leaks KV state past a probe
        assert_eq!(srv.engine.kv_pool().active_sequences(), 0);
    }
}

/// The live admin endpoint, end to end over real TCP: bind an ephemeral
/// port on a serving stack with int8 KV and the sentinel armed, fetch
/// `/metrics` and `/quality` **mid-run** (while sequences are decoding),
/// and validate the exposition: Prometheus grammar, the quality families,
/// and live (non-zero) decode counters.
#[test]
fn admin_endpoint_serves_live_metrics_mid_run() {
    use std::io::{Read as _, Write as _};
    use std::net::TcpStream;
    use std::sync::Arc;

    let cfg = tiny_cfg();
    let kv = KvQuantCfg::with_bits(KvBits::Int8);
    let engine = NativeEngine::with_kv(Model::init(&cfg, 3), "admin", kv);
    let serve = ServeCfg { kv_bits: 8, sentinel_every_n_ticks: 2, ..serve_cfg() };
    let mut srv = Server::new(engine, serve).unwrap();
    let admin =
        AdminServer::bind("127.0.0.1:0", Arc::clone(&srv.obs.registry)).expect("bind port 0");
    let addr = admin.local_addr();
    let get = |path: &str| -> String {
        let mut s = TcpStream::connect(addr).expect("connect to admin endpoint");
        write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };

    let health = get("/healthz");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.ends_with("ok\n"), "{health}");

    // readiness is a separate signal: it flips with `set_ready` while
    // liveness stays green, and the reason rides in the 503 body
    let ready = get("/readyz");
    assert!(ready.starts_with("HTTP/1.1 200"), "{ready}");
    admin.set_ready(false, "draining");
    let not_ready = get("/readyz");
    assert!(not_ready.starts_with("HTTP/1.1 503"), "{not_ready}");
    assert!(not_ready.ends_with("draining\n"), "{not_ready}");
    assert!(get("/healthz").starts_with("HTTP/1.1 200"), "liveness must survive not-ready");
    admin.set_ready(true, "");
    assert!(get("/readyz").starts_with("HTTP/1.1 200"));

    // the fault-plane read-out is wired even when no faults are armed
    let fault = get("/fault");
    assert!(fault.starts_with("HTTP/1.1 200"), "{fault}");
    let fbody = fault.split("\r\n\r\n").nth(1).expect("fault body");
    let fdoc = Json::parse(fbody).expect("fault status JSON parses");
    assert!(
        matches!(fdoc.get("enabled"), Some(Json::Bool(false))),
        "no faults armed in this test binary: {fbody}"
    );

    for r in requests(5, 18, 6) {
        srv.submit(r).unwrap();
    }
    // prompts of 18 tokens seal at least one int8 block each (block = 16)
    let mut mid_run: Option<(String, String)> = None;
    while !srv.is_idle() {
        srv.step().unwrap();
        if mid_run.is_none()
            && srv.num_running() > 0
            && srv.obs.registry.counter("lords_decode_tokens_total", &[]).get() > 0
        {
            mid_run = Some((get("/metrics"), get("/quality")));
        }
    }
    let (metrics, quality) = mid_run.expect("never caught the server mid-decode");

    assert!(metrics.starts_with("HTTP/1.1 200"), "{metrics}");
    let body = metrics.split("\r\n\r\n").nth(1).expect("metrics body");
    // Prometheus text grammar: comments are HELP/TYPE, samples are
    // `series value` with a parseable float value
    for line in body.lines() {
        if let Some(comment) = line.strip_prefix('#') {
            let ok = comment.starts_with(" TYPE ") || comment.starts_with(" HELP ");
            assert!(ok, "unexpected comment line: {line}");
        } else {
            let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("{line}"));
            assert!(!series.is_empty(), "{line}");
            assert!(value.parse::<f64>().is_ok(), "bad sample value: {line}");
        }
    }
    // live serving counters, captured while sequences were still running
    let decoded: f64 = body
        .lines()
        .find_map(|l| l.strip_prefix("lords_decode_tokens_total "))
        .expect("decode tokens sample present")
        .parse()
        .unwrap();
    assert!(decoded > 0.0, "mid-run exposition must show live decode progress");
    // the quality families rode along: seal error (int8 tier), sentinel
    // agreement, and the per-layer weight-error gauges
    assert!(body.contains("# TYPE lords_kv_seal_rel_error histogram"), "{body}");
    assert!(body.contains("lords_kv_seal_rel_error_bucket{kv=\"int8\",le="), "{body}");
    assert!(body.contains("# TYPE lords_sentinel_top1_agree histogram"), "{body}");
    assert!(body.contains("lords_weight_quant_rel_error_ppm{layer="), "{body}");
    assert!(body.contains("# HELP lords_decode_tokens_total "), "{body}");

    let qbody = quality.split("\r\n\r\n").nth(1).expect("quality body");
    let qdoc = Json::parse(qbody).expect("quality JSON parses");
    let hists = qdoc.get("histograms").unwrap().as_arr().unwrap();
    assert!(
        hists
            .iter()
            .any(|h| h.get("name").unwrap().as_str() == Some("lords_kv_seal_rel_error")),
        "quality snapshot carries the seal-error family"
    );
}

// ------------------------------------------------------ failure telemetry

use lords::coordinator::engine::SeqState;
use lords::coordinator::Engine;

/// Deterministic failure harness for the telemetry tests: a delegating
/// [`Engine`] over [`NativeEngine`] that fails the first
/// `decode_failures_left` decode calls outright and, independently,
/// overwrites one victim sequence's logits with NaN exactly once.
///
/// Unlike the process-global fault plane (`lords::fault`), failures here
/// are scheduled by call count on a private engine, so the metric
/// assertions below are exact rather than probabilistic — and the test
/// binary's other tests can't be perturbed.
struct FlakyEngine {
    inner: NativeEngine,
    decode_failures_left: usize,
    corrupt_once: Option<u64>,
}

impl FlakyEngine {
    fn new(seed: u64, decode_failures_left: usize, corrupt_once: Option<u64>) -> FlakyEngine {
        FlakyEngine {
            inner: NativeEngine::new(Model::init(&tiny_cfg(), seed), "obs"),
            decode_failures_left,
            corrupt_once,
        }
    }
}

impl Engine for FlakyEngine {
    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }
    fn prefill(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        self.inner.prefill(seqs)
    }
    fn supports_chunked_prefill(&self) -> bool {
        self.inner.supports_chunked_prefill()
    }
    fn admit_seqs(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        self.inner.admit_seqs(seqs)
    }
    fn prefill_chunk(&mut self, seq: &mut SeqState, budget: usize) -> anyhow::Result<usize> {
        self.inner.prefill_chunk(seq, budget)
    }
    fn prefix_hit_tokens(&self, adapter: &str, prompt: &[usize]) -> usize {
        self.inner.prefix_hit_tokens(adapter, prompt)
    }
    fn decode(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        if self.decode_failures_left > 0 {
            self.decode_failures_left -= 1;
            anyhow::bail!("injected decode failure (test harness)");
        }
        self.inner.decode(seqs)?;
        if let Some(victim) = self.corrupt_once.take() {
            match seqs.iter_mut().find(|s| s.id == victim) {
                Some(s) => s.last_logits.iter_mut().for_each(|v| *v = f32::NAN),
                None => self.corrupt_once = Some(victim), // not decoding yet
            }
        }
        Ok(())
    }
    fn release(&mut self, id: u64) {
        self.inner.release(id);
    }
    fn name(&self) -> String {
        self.inner.name()
    }
    fn kv_init(&mut self, budget_bytes: Option<usize>, max_concurrent: usize) {
        self.inner.kv_init(budget_bytes, max_concurrent);
    }
    fn kv_can_admit(&self, seq_tokens: &[usize]) -> bool {
        self.inner.kv_can_admit(seq_tokens)
    }
    fn supports_adapter(&self, adapter: &str) -> bool {
        self.inner.supports_adapter(adapter)
    }
    fn observe(&mut self, reg: &Registry) {
        self.inner.observe(reg);
    }
    fn install_quality(&mut self, reg: &std::sync::Arc<Registry>, seal_err_threshold: f64) {
        self.inner.install_quality(reg, seal_err_threshold);
    }
    fn sentinel_probe(&mut self, s: &SeqState) -> Option<(bool, f64)> {
        self.inner.sentinel_probe(s)
    }
    fn flush_caches(&mut self) {
        self.inner.flush_caches();
    }
}

/// A retryable engine failure leaves a complete audit trail: the
/// reason-labelled failure counter, the retry counter, and the flight
/// recorder's failed → released → retried → done lifecycle — and
/// retry-by-re-prefill reproduces the exact tokens a clean run serves.
#[test]
fn engine_failures_surface_in_metrics_flight_and_retry_counters() {
    // 4 requests fill the top decode bucket, so all of them are running
    // when the one injected decode failure lands: exactly 4 failures,
    // 4 retries, 4 completions.
    let reqs = || requests(4, 12, 6);
    let clean = tiny_server(9).run_trace(reqs()).unwrap();
    assert_eq!(clean.metrics.completed, 4);

    let mut srv = Server::new(FlakyEngine::new(9, 1, None), serve_cfg()).unwrap();
    let report = srv.run_trace(reqs()).unwrap();

    assert_eq!(report.metrics.completed, 4);
    assert_eq!(report.metrics.failed, 4);
    assert_eq!(report.metrics.retries, 4);
    for (a, b) in clean.responses.iter().zip(&report.responses) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}: retry must reproduce the clean tokens", a.id);
    }

    let failed = srv
        .obs
        .registry
        .counter("lords_failed_total", &[("reason", "engine_error")])
        .get();
    assert_eq!(failed, 4);
    assert_eq!(srv.obs.registry.counter("lords_retries_total", &[]).get(), 4);
    let text = srv.obs.registry.render_prometheus();
    assert!(text.contains("lords_failed_total{reason=\"engine_error\"} 4"), "{text}");
    assert!(text.contains("lords_retries_total 4"), "{text}");
    assert!(text.contains("# HELP lords_failed_total "), "{text}");

    // request 0's flight trail: fail, release, retry, then a clean finish
    let kinds: Vec<&FlightKind> =
        srv.obs.flight.events().filter(|e| e.seq == 0).map(|e| &e.kind).collect();
    assert!(
        kinds.contains(&&FlightKind::Failed { reason: "engine_error", retryable: true }),
        "{kinds:?}"
    );
    assert!(kinds.contains(&&FlightKind::Retried), "{kinds:?}");
    assert!(kinds.iter().any(|k| matches!(k, FlightKind::Done { .. })), "{kinds:?}");
    assert_eq!(kinds.last(), Some(&&FlightKind::Released));
    // the dump renders the failure fields
    let dump = Json::parse(&srv.obs.flight.dump()).expect("flight dump must parse");
    let has_failed = dump.get("events").unwrap().as_arr().unwrap().iter().any(|e| {
        e.get("kind").unwrap().as_str() == Some("failed")
            && e.get("reason").and_then(Json::as_str) == Some("engine_error")
            && matches!(e.get("retryable"), Some(Json::Bool(true)))
    });
    assert!(has_failed, "dump carries reason + retryable on failed events");
}

/// Non-finite logits quarantine exactly the victim — terminally, with
/// the quarantine counter, the flight kind, and the anomaly tripwire all
/// firing — while every untouched sequence completes.
#[test]
fn logit_corruption_is_quarantined_and_observable() {
    let mut srv = Server::new(FlakyEngine::new(9, 0, Some(1)), serve_cfg()).unwrap();
    let report = srv.run_trace(requests(4, 12, 6)).unwrap();

    assert_eq!(report.metrics.quarantined, 1);
    assert_eq!(report.metrics.failed, 1, "quarantine is terminal, not retried");
    assert_eq!(report.metrics.retries, 0);
    assert_eq!(report.metrics.completed, 3);
    assert!(report.responses.iter().all(|r| r.id != 1), "the victim must not complete");

    let q = srv
        .obs
        .registry
        .counter("lords_quarantined_total", &[("reason", "nonfinite_logits")])
        .get();
    assert_eq!(q, 1);
    let text = srv.obs.registry.render_prometheus();
    assert!(text.contains("lords_quarantined_total{reason=\"nonfinite_logits\"} 1"), "{text}");
    assert!(text.contains("lords_failed_total{reason=\"nonfinite_logits\"} 1"), "{text}");

    let kinds: Vec<&FlightKind> =
        srv.obs.flight.events().filter(|e| e.seq == 1).map(|e| &e.kind).collect();
    assert!(kinds.contains(&&FlightKind::Quarantined), "{kinds:?}");
    assert!(
        kinds.contains(&&FlightKind::Failed { reason: "nonfinite_logits", retryable: false }),
        "{kinds:?}"
    );
    assert_eq!(kinds.last(), Some(&&FlightKind::Released), "quarantine released its KV");

    let anomaly = srv.obs.flight.take_anomaly().expect("quarantine must trip the recorder");
    assert!(anomaly.reason.contains("non-finite"), "{}", anomaly.reason);
    assert!(Json::parse(&anomaly.dump).is_ok());
}
