//! Quickstart: quantize one LLM-like weight matrix with block-wise NF4 and
//! with LoRDS at the same parameter budget, and watch LoRDS win after
//! Algorithm-1 refinement.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lords::quant::error::{quant_error_nuclear, reduction_ratio_vs};
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::testbed::{llm_like_weight, ModuleShape};
use lords::util::Rng;

fn main() {
    // An out-projection-shaped weight with realistic outlier channels.
    let mut rng = Rng::new(42);
    let w = llm_like_weight(ModuleShape { name: "Q", n: 256, m: 256 }, &mut rng);
    let block = 64;
    let nf4 = Codebook::normal_float(4);

    // --- the baseline the paper breaks: block-wise NF4 -------------------
    let bw = BlockwiseQuant::quantize(&w, block, &nf4);
    let e_bw = quant_error_nuclear(&w, &bw.dequantize());
    println!("block-wise NF4 : nuclear err {e_bw:8.3}  float params {}", bw.float_params());

    // --- LoRDS: SVD init only (recovers block-wise statistics) -----------
    let (init, _) = LordsQuant::quantize(&w, block, &nf4, RefineCfg { steps: 0, ..Default::default() });
    let e_init = quant_error_nuclear(&w, &init.dequantize());
    println!(
        "LoRDS @ init   : nuclear err {e_init:8.3}  float params {} (rank {})",
        init.float_params(),
        init.rank
    );

    // --- LoRDS after iterative refinement (Algorithm 1) ------------------
    let (refined, report) =
        LordsQuant::quantize(&w, block, &nf4, RefineCfg { steps: 300, lr: 0.05, requant_every: 5 });
    let e_ref = quant_error_nuclear(&w, &refined.dequantize());
    println!(
        "LoRDS refined  : nuclear err {e_ref:8.3}  (frobenius {:.4} → {:.4} over {} steps)",
        report.initial_frob,
        report.final_frob,
        report.trace.last().map(|t| t.0).unwrap_or(0),
    );
    println!(
        "reduction ratio vs NF4: {:.1}%  (paper Table 8 reports ~6-12% at 4-bit)",
        reduction_ratio_vs(&w, &refined.dequantize(), &bw.dequantize())
    );

    // --- the fused inference kernel --------------------------------------
    let x = lords::tensor::Matrix::randn(8, 256, 1.0, &mut rng);
    let y = refined.matmul_transb(&x);
    println!("fused y = x·Ŵᵀ: {}x{} (no dense Ŵ materialized)", y.rows, y.cols);

    assert!(e_ref < e_bw, "LoRDS must beat block-wise at parity budget");
    println!("\nOK: LoRDS beats block-wise NF4 at the same scale-parameter budget.");
}
