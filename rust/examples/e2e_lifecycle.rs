//! END-TO-END driver — the full-system proof that all three layers compose
//! (DESIGN.md §5). On one run it:
//!
//!  1. pre-trains a tiny-Llama testbed on the synthetic corpus, logging the
//!     loss curve (PJRT `fp_step` artifact when available, native backprop
//!     otherwise);
//!  2. LoRDS-PTQ quantizes it (Algorithm 1) and compares against NF4;
//!  3. QAT-recovers with STE;
//!  4. PEFT-adapts only (B, A) to a shifted corpus — via the PJRT
//!     `peft_step` artifact when available;
//!  5. serves batched requests through the coordinator, reporting
//!     prefill/decode/total throughput.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_lifecycle
//! ```

use lords::config::{ServeCfg, TrainCfg};
use lords::coordinator::{NativeEngine, Request, Server};
use lords::data::corpus::{Corpus, CorpusKind};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::eval_model;
use lords::report::testbed::Testbed;
use lords::runtime::executor::Executor;
use lords::train::pjrt::PjrtTrainer;
use lords::train::{NativeTrainer, TrainKind};
use lords::util::Rng;

fn main() -> anyhow::Result<()> {
    lords::util::logging::init();
    let cfg = lords::config::ModelCfg::default();
    let pretrain_steps = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(200);

    println!("== stage 1: pre-train the testbed ({pretrain_steps} steps) ==");
    let executor = Executor::spawn("artifacts").ok();
    let wiki = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 200_000, 20_000, 0);

    let mut model;
    if let Some(exec) = &executor {
        // PJRT pre-training: fp_step artifact (batch 8, seq 128 per manifest)
        let manifest = lords::runtime::Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
        let art = manifest.artifact("fp_step").map_err(anyhow::Error::msg)?;
        model = lords::model::Model::init(&cfg, 0);
        let named: Vec<(String, lords::runtime::HostTensor)> = art
            .inputs
            .iter()
            .take_while(|s| s.name != "tokens")
            .map(|s| (s.name.clone(), lords::runtime::bridge::resolve(&model, &s.name)))
            .collect();
        let (batch, seq) = (art.inputs.last().unwrap().dims[0], art.inputs.last().unwrap().dims[1]);
        let tcfg = TrainCfg {
            steps: pretrain_steps,
            batch,
            seq,
            peak_lr: 3e-3,
            warmup_ratio: 0.05,
            weight_decay: 0.01,
            seed: 0,
            log_every: (pretrain_steps / 10).max(1),
        };
        let mut tr = PjrtTrainer::new(exec.handle(), "fp_step", tcfg, named);
        let log = tr.run(&wiki)?;
        println!("loss curve (pjrt fp_step): {:?}", log.losses);
        for (name, t) in tr.trained_params() {
            lords::runtime::bridge::write_back(&mut model, &name, t.f32s());
        }
    } else {
        println!("(PJRT unavailable — native pre-training)");
        model = lords::model::Model::init(&cfg, 0);
        let tcfg = TrainCfg {
            steps: pretrain_steps,
            batch: 8,
            seq: 64,
            peak_lr: 3e-3,
            warmup_ratio: 0.05,
            weight_decay: 0.01,
            seed: 0,
            log_every: (pretrain_steps / 10).max(1),
        };
        let mut tr = NativeTrainer::new(tcfg, TrainKind::Pretrain);
        let log = tr.run(&mut model, &wiki);
        println!("loss curve (native): {:?}", log.losses);
    }
    let tb = Testbed { name: "e2e".into(), cfg: cfg.clone(), model: model.clone(), wiki: wiki.clone(),
        ptb: Corpus::generate(CorpusKind::Ptb, cfg.vocab, 50_000, 20_000, 1),
        suite: lords::data::TaskSuite::generate(&wiki, 24, 2) };
    let fp_eval = eval_model(&tb.model, &tb, 8, 16);
    println!("fp testbed: wiki PPL {} | avg acc {:.1}%", fp_eval.wiki.display(), fp_eval.avg);

    println!("\n== stage 2: PTQ — NF4 vs LoRDS (Algorithm 1) ==");
    let cb = Codebook::normal_float(4);
    let mut m_nf4 = tb.model.clone();
    m_nf4.quantize_blockwise(cfg.block, &cb);
    let e_nf4 = eval_model(&m_nf4, &tb, 8, 16);
    let mut m_lords = tb.model.clone();
    m_lords.quantize_lords(cfg.block, &cb, RefineCfg { steps: 150, lr: 0.05, requant_every: 5 }, false);
    let e_lords = eval_model(&m_lords, &tb, 8, 16);
    println!("NF4  : wiki PPL {} | avg {:.1}%", e_nf4.wiki.display(), e_nf4.avg);
    println!("LoRDS: wiki PPL {} | avg {:.1}%", e_lords.wiki.display(), e_lords.avg);

    println!("\n== stage 3: QAT recovery (STE, eqs. 4-5) ==");
    let mut m_qat = tb.model.clone();
    m_qat.quantize_lords(cfg.block, &cb, RefineCfg { steps: 60, ..Default::default() }, true);
    let mut qat = NativeTrainer::new(
        TrainCfg { steps: 40, batch: 8, seq: 64, peak_lr: 3e-4, warmup_ratio: 0.3, ..Default::default() },
        TrainKind::Qat,
    );
    qat.run(&mut m_qat, &tb.wiki);
    let e_qat = eval_model(&m_qat, &tb, 8, 16);
    println!("LoRDS-QAT: wiki PPL {} | avg {:.1}%", e_qat.wiki.display(), e_qat.avg);

    println!("\n== stage 4: PEFT on a shifted corpus (B/A only) ==");
    let target = Corpus::generate(CorpusKind::Ptb, cfg.vocab, 80_000, 10_000, 9);
    let before = lords::eval::perplexity(&m_lords, &target, 64, 8);
    let mut m_peft = m_lords.clone();
    if let Some(exec) = &executor {
        let manifest = lords::runtime::Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
        let art = manifest.artifact("peft_step").map_err(anyhow::Error::msg)?;
        let named: Vec<(String, lords::runtime::HostTensor)> = art
            .inputs
            .iter()
            .take_while(|s| s.name != "tokens")
            .map(|s| (s.name.clone(), lords::runtime::bridge::resolve(&m_peft, &s.name)))
            .collect();
        let (batch, seq) = (art.inputs.last().unwrap().dims[0], art.inputs.last().unwrap().dims[1]);
        let tcfg = TrainCfg { steps: 80, batch, seq, peak_lr: 1e-3, ..Default::default() };
        let mut tr = PjrtTrainer::new(exec.handle(), "peft_step", tcfg, named);
        let log = tr.run(&target)?;
        for (name, t) in tr.trained_params() {
            lords::runtime::bridge::write_back(&mut m_peft, &name, t.f32s());
        }
        println!("peft loss curve (pjrt peft_step): {:?}", log.losses);
    } else {
        let mut tr = NativeTrainer::new(
            TrainCfg { steps: 60, batch: 8, seq: 64, peak_lr: 1e-3, ..Default::default() },
            TrainKind::Peft,
        );
        tr.run(&mut m_peft, &target);
    }
    let after = lords::eval::perplexity(&m_peft, &target, 64, 8);
    println!("PEFT: target PPL {} → {} (#Train {})", before.display(), after.display(), m_peft.train_params());

    println!("\n== stage 5: serve the adapted model through the coordinator ==");
    let mut rng = Rng::new(3);
    let plen = cfg.max_seq / 2;
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request::new(i as u64, (0..plen).map(|_| rng.below(cfg.vocab)).collect(), 24))
        .collect();
    let mut server = Server::new(NativeEngine::new(m_peft, "lords-peft"), ServeCfg::default()).unwrap();
    let report = server.run_trace(reqs)?;
    report.metrics.print(&report.engine);

    println!("\nE2E complete — all five lifecycle stages ran on one checkpoint.");
    Ok(())
}
