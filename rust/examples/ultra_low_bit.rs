//! Ultra-low-bit robustness demo (the Table-3 phenomenon on one matrix):
//! at NF2, block-wise scaling collapses while LoRDS keeps reconstructing.
//!
//! ```bash
//! cargo run --release --example ultra_low_bit
//! ```

use lords::quant::error::quant_error_nuclear;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::mixed::MixedSchedule;
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::testbed::{llm_like_weight, ModuleShape};
use lords::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let w = llm_like_weight(ModuleShape { name: "Up", n: 384, m: 256 }, &mut rng);
    let block = 64;

    println!("{:<8} {:>14} {:>14} {:>9}", "bits", "NF err", "LoRDS err", "gain");
    for bits in [4u32, 3, 2] {
        let cb = Codebook::normal_float(bits);
        let bw = BlockwiseQuant::quantize(&w, block, &cb);
        let e_bw = quant_error_nuclear(&w, &bw.dequantize());
        let (lq, _) =
            LordsQuant::quantize(&w, block, &cb, RefineCfg { steps: 250, lr: 0.05, requant_every: 5 });
        let e_lq = quant_error_nuclear(&w, &lq.dequantize());
        println!("NF{bits:<6} {e_bw:>14.3} {e_lq:>14.3} {:>8.1}%", 100.0 * (1.0 - e_lq / e_bw));
    }

    // the paper's mixed schedules
    println!("\nmixed-precision layer schedules (32-layer model):");
    for bits in [3.0f32, 2.5, 2.25, 2.0] {
        let s = MixedSchedule::for_bits(bits, 32);
        println!(
            "  {:>4}-bit → {} NF4 layers + {} NF2 layers (avg {:.2} bits)",
            s.bits_label,
            s.nf4_layers(),
            32 - s.nf4_layers(),
            s.average_bits()
        );
    }
    println!("\n(expected: the LoRDS gain grows as bits shrink — Table 9's trend)");
}
