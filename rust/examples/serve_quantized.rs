//! Serve batched requests against a LoRDS-quantized model through the
//! coordinator (router → dynamic batcher → KV admission → prefill/decode),
//! via the PJRT artifact engine when `artifacts/` exists, falling back to
//! the native engine otherwise. Prints latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_quantized
//! ```

use lords::config::ServeCfg;
use lords::coordinator::{NativeEngine, PjrtEngine, Request, Server};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{model_zoo, Testbed};
use lords::runtime::executor::Executor;
use lords::util::Rng;

fn main() -> anyhow::Result<()> {
    lords::util::logging::init();
    let mut rng = Rng::new(0);
    let n_requests = 12;
    let max_new = 24;

    match Executor::spawn("artifacts") {
        Ok(exec) => {
            println!("engine: PJRT (AOT Pallas artifacts)");
            let manifest = lords::runtime::Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
            let cfg = manifest.model.clone();
            let tb = Testbed::build("llama3-mini", &cfg, 120, 0);
            let mut model = tb.model.clone();
            let cb = Codebook::from_levels(&manifest.lut_name, manifest.lut.clone());
            model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 30, ..Default::default() }, false);
            let art = manifest.artifact("lords_prefill_b1").map_err(anyhow::Error::msg)?;
            let params = lords::runtime::bridge::collect_params(&model, &art.inputs);
            let engine = PjrtEngine::new(exec.handle(), &manifest, "lords", params)?;
            let plen = engine.prefill_seq;
            let reqs: Vec<Request> = (0..n_requests)
                .map(|i| Request::new(i as u64, (0..plen).map(|_| rng.below(cfg.vocab)).collect(), max_new))
                .collect();
            let mut server = Server::new(engine, ServeCfg::default()).unwrap();
            let report = server.run_trace(reqs)?;
            report.metrics.print(&report.engine);
            println!("first completion: {:?}", &report.responses[0].tokens[..8.min(report.responses[0].tokens.len())]);
        }
        Err(e) => {
            println!("engine: native (PJRT unavailable: {e})");
            let (name, cfg) = model_zoo().remove(0);
            let tb = Testbed::build(name, &cfg, 120, 0);
            let mut model = tb.model.clone();
            let cb = Codebook::normal_float(4);
            model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 30, ..Default::default() }, false);
            let plen = cfg.max_seq / 2;
            let reqs: Vec<Request> = (0..n_requests)
                .map(|i| Request::new(i as u64, (0..plen).map(|_| rng.below(cfg.vocab)).collect(), max_new))
                .collect();
            let mut server = Server::new(NativeEngine::new(model, "lords"), ServeCfg::default()).unwrap();
            let report = server.run_trace(reqs)?;
            report.metrics.print(&report.engine);
        }
    }
    Ok(())
}
