//! The online serving API end to end: submit sessions with per-request
//! sampling policies, observe tokens as they stream out of `step()`,
//! cancel one request mid-decode (its KV blocks are released on the
//! spot), then replay the same workload open-loop at a fixed arrival
//! rate and read the TTFT / ITL / queue-wait percentiles.
//!
//! ```bash
//! cargo run --release --example serve_streaming
//! ```
//!
//! Set `LORDS_TRACE_OUT=trace.json` to record tracing spans and write
//! them as Chrome-trace JSON on exit, and `LORDS_METRICS_OUT=m.prom`
//! to dump the server's cumulative registry in Prometheus text format
//! (this is what CI's examples-smoke job validates). Set
//! `LORDS_ADMIN_ADDR=127.0.0.1:8841` to serve `/metrics`, `/quality`,
//! `/trace`, `/flight`, and `/healthz` live while the demo runs
//! (`LORDS_ADMIN_LINGER_MS` keeps the endpoint up after the run so an
//! external scraper can catch it — CI curls it from a parallel shell).

use lords::config::ServeCfg;
use lords::coordinator::{
    run_open_loop, Event, NativeEngine, Request, SamplingParams, Server,
};
use lords::kvquant::{KvBits, KvQuantCfg};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{model_zoo, Testbed};
use lords::util::Rng;

fn main() -> anyhow::Result<()> {
    lords::util::logging::init();
    let trace_out = std::env::var("LORDS_TRACE_OUT").ok();
    let metrics_out = std::env::var("LORDS_METRICS_OUT").ok();
    if trace_out.is_some() {
        lords::obs::trace::set_enabled(true);
    }
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, 80, 0);
    let mut model = tb.model.clone();
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 20, ..Default::default() },
        false,
    );

    // int8 paged KV under the default byte budget; logit-drift sentinel
    // on a slow cadence so the quality families populate live
    let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 16 };
    let engine = NativeEngine::with_kv(model, "stream", kv);
    let serve = ServeCfg { sentinel_every_n_ticks: 4, ..ServeCfg::default() };
    let mut server = Server::new(engine, serve).unwrap();
    // base weight quant error vs the pre-quantization reference weights
    lords::obs::quality::record_weight_errors(
        &server.obs.registry,
        "base",
        &tb.model,
        &server.engine.model,
    );
    let admin = if let Ok(addr) = std::env::var("LORDS_ADMIN_ADDR") {
        let a = lords::obs::AdminServer::bind(
            &addr,
            std::sync::Arc::clone(&server.obs.registry),
        )?;
        println!("admin endpoint listening on http://{}", a.local_addr());
        Some(a)
    } else {
        None
    };

    // four sessions: two greedy, two sampled (seeded — reruns replay)
    let mut rng = Rng::new(1);
    let plen = cfg.max_seq / 4;
    let sampled = SamplingParams { temperature: 0.8, top_k: 16, seed: 7 };
    for i in 0..4u64 {
        let prompt: Vec<usize> = (0..plen).map(|_| rng.below(cfg.vocab)).collect();
        let mut req = Request::new(i, prompt, 24);
        if i % 2 == 1 {
            req = req.with_sampling(sampled.clone());
        }
        let id = server.submit(req).map_err(|e| anyhow::anyhow!("submit {i}: {e}"))?;
        println!("submitted session {id} ({})", if i % 2 == 1 { "sampled" } else { "greedy" });
    }

    // stream: print each session's tokens as they are produced; cancel
    // session 2 after its fifth token
    println!("\nstreaming (cancelling session 2 at token 5):");
    let mut streams: Vec<Vec<usize>> = vec![Vec::new(); 4];
    while !server.is_idle() {
        for ev in server.step()? {
            match ev {
                Event::Token { id, token, index } => {
                    streams[id as usize].push(token);
                    if id == 2 && index == 4 {
                        server.cancel(2);
                    }
                }
                Event::Done { response } => println!(
                    "  session {} done: {} tokens, ttft {:.2} ms",
                    response.id,
                    response.tokens.len(),
                    response.ttft_s * 1e3
                ),
                Event::Cancelled { id } => println!("  session {id} cancelled mid-decode"),
                Event::Rejected { id, reason } => println!("  session {id} rejected: {reason}"),
            }
        }
    }
    for (id, s) in streams.iter().enumerate() {
        println!("  session {id} streamed {} tokens: {:?}...", s.len(), &s[..s.len().min(6)]);
    }
    // blocks still held belong to the shared-prefix cache, not to leaked
    // sequences — flushing it drains the pool completely
    let cached = server.engine.kv_pool().used_blocks();
    server.engine.flush_prefix_cache();
    let pool = server.engine.kv_pool();
    println!(
        "pool after cancel + drain: {cached} prefix-cached blocks, {} used after flush, \
         {} active sequences (leak-free)",
        pool.used_blocks(),
        pool.active_sequences()
    );
    server.metrics.print("session API");
    server.metrics.print_streaming();
    server.reset_metrics();

    // open loop: same engine, Poisson-like arrivals, latency percentiles
    println!("\nopen-loop at 200 req/s (deterministic seeded arrivals):");
    let reqs: Vec<Request> = (0..16u64)
        .map(|i| {
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(cfg.vocab)).collect();
            Request::new(100 + i, prompt, 16)
        })
        .collect();
    let report = run_open_loop(&mut server, reqs, 200.0, 11)?;
    report.metrics.print(&report.engine);
    report.metrics.print_streaming();
    println!(
        "(expected: every request resolves; TTFT grows with queue depth at this rate, \
         ITL tracks the decode step)"
    );

    if let Some(path) = trace_out {
        lords::obs::trace::set_enabled(false);
        let spans = lords::obs::trace::drain();
        lords::obs::trace::write_chrome(&path, &spans)?;
        println!("trace: {} spans -> {path}", spans.len());
    }
    if let Some(path) = metrics_out {
        std::fs::write(&path, server.obs.registry.render_prometheus())?;
        println!("metrics: prometheus text -> {path}");
    }
    if let Some(a) = &admin {
        a.publish_flight(server.obs.flight.dump());
        // keep the endpoint up so an external scraper (CI) can fetch the
        // final exposition after the run completes
        let linger: u64 = std::env::var("LORDS_ADMIN_LINGER_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        if linger > 0 {
            println!("admin endpoint lingering {linger} ms for scrapers");
            std::thread::sleep(std::time::Duration::from_millis(linger));
        }
    }
    Ok(())
}
