//! PEFT comparison on a distribution shift: QLoRA's additive adapters vs
//! LoRDS's multiplicative scaling adaptation, from the same pre-trained
//! 4-bit checkpoint. Reports target-corpus perplexity before/after and the
//! effective rank of the weight update (the Figure-3 phenomenon).
//!
//! ```bash
//! cargo run --release --example peft_adaptation
//! ```

use lords::config::TrainCfg;
use lords::data::corpus::{Corpus, CorpusKind};
use lords::linalg::svd;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{model_zoo, Testbed};
use lords::train::{NativeTrainer, TrainKind};

fn main() {
    lords::util::logging::init();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, 120, 0);
    let target = Corpus::generate(CorpusKind::Ptb, cfg.vocab, 80_000, 10_000, 9);
    let cb = Codebook::normal_float(4);
    let tcfg = TrainCfg { steps: 60, batch: 8, seq: 64, peak_lr: 1e-3, ..Default::default() };

    for method in ["QLoRA", "LoRDS"] {
        let mut model = tb.model.clone();
        match method {
            "QLoRA" => model.quantize_qlora(cfg.block, 16, &cb, 0),
            _ => model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 60, ..Default::default() }, false),
        }
        let w_pre = model.layers[0].wq.effective();
        let before = lords::eval::perplexity(&model, &target, 64, 8);
        let mut tr = NativeTrainer::new(tcfg.clone(), TrainKind::Peft);
        let log = tr.run(&mut model, &target);
        let after = lords::eval::perplexity(&model, &target, 64, 8);
        let dw = model.layers[0].wq.effective().sub(&w_pre);
        let sv = svd(&dw).s;
        let eff = sv.iter().filter(|&&s| s > 1e-3 * sv[0].max(1e-20)).count();
        println!(
            "{method:<6} target PPL {:>8} → {:<8} | #Train {:>8} #Float {:>8} | ΔW effective rank {eff}/{} | final loss {:.3}",
            before.display(),
            after.display(),
            model.train_params(),
            model.float_params(),
            sv.len(),
            log.final_loss,
        );
    }
    println!("\n(expected: LoRDS reaches lower PPL with half the float budget and a full-rank ΔW)");
}
