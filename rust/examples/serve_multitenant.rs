//! Multi-tenant adapter serving end to end: PEFT-train two tenant
//! adapters on different corpora, export them as artifacts, hot-register
//! them on one shared LoRDS packed base, serve a mixed-tenant request
//! trace through the coordinator, then demonstrate budgeted LRU eviction
//! and a hot swap.
//!
//! ```bash
//! cargo run --release --example serve_multitenant
//! ```

use lords::adapters::{AdapterFactors, AdapterRegistry, BASE_ADAPTER};
use lords::config::{ServeCfg, TrainCfg};
use lords::coordinator::{NativeEngine, Request, Server};
use lords::data::corpus::{Corpus, CorpusKind};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{model_zoo, Testbed};
use lords::train::{NativeTrainer, TrainKind};
use lords::util::Rng;

fn main() -> anyhow::Result<()> {
    lords::util::logging::init();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, 120, 0);
    let cb = Codebook::normal_float(4);

    // one quantized base, shared by every tenant
    let mut base = tb.model.clone();
    base.quantize_lords(cfg.block, &cb, RefineCfg { steps: 30, ..Default::default() }, false);
    let base_bytes = base.weight_bytes();

    // PEFT two tenants on different distributions, exporting an adapter each
    let tcfg = TrainCfg { steps: 20, batch: 4, seq: 32, peak_lr: 1e-3, ..Default::default() };
    let corpora = [
        ("tenant-wiki", Corpus::generate(CorpusKind::Wiki, cfg.vocab, 40_000, 4_000, 3)),
        ("tenant-ptb", Corpus::generate(CorpusKind::Ptb, cfg.vocab, 40_000, 4_000, 4)),
    ];
    let mut artifacts = Vec::new();
    for (id, corpus) in &corpora {
        let mut tenant_model = base.clone();
        let mut tr = NativeTrainer::new(tcfg.clone(), TrainKind::Peft);
        let log = tr.run(&mut tenant_model, corpus);
        let art = tr.export_adapter(&tenant_model, id)?;
        println!(
            "trained {id}: final loss {:.3}, adapter {:.1} KiB ({} factor pairs)",
            log.final_loss,
            art.factors.bytes() as f64 / 1024.0,
            art.factors.n_pairs()
        );
        artifacts.push(art);
    }

    // a third synthetic tenant, to mix ≥ 3 adapters in one batch
    let mut rng = Rng::new(9);
    let synth = AdapterFactors::from_model(&base).perturbed(0.05, &mut rng);

    // registry budget: room for exactly three resident adapters, so the
    // hot registration at the end must LRU-evict one
    let budget = 3 * synth.bytes() + 1;
    let mut engine = NativeEngine::with_registry(base, "mt", AdapterRegistry::new(budget));
    for art in &artifacts {
        engine.register_adapter(&art.id, art.factors.clone())?;
    }
    engine.register_adapter("tenant-synth", synth.clone())?;
    println!(
        "\nserving {} tenants over one packed base: base {:.2} MiB + adapters {:.2} MiB \
         (per-tenant cost {:.1}% of the base)",
        engine.registry().len() + 1,
        base_bytes as f64 / (1024.0 * 1024.0),
        engine.registry().used_bytes() as f64 / (1024.0 * 1024.0),
        100.0 * synth.bytes() as f64 / base_bytes as f64,
    );

    // mixed-tenant trace: every batch interleaves all four tenants
    let tenants = [BASE_ADAPTER, "tenant-wiki", "tenant-ptb", "tenant-synth"];
    let plen = cfg.max_seq / 2;
    let reqs: Vec<Request> = (0..16)
        .map(|i| {
            Request::new(i as u64, (0..plen).map(|_| rng.below(cfg.vocab)).collect(), 16)
                .with_adapter(tenants[i % tenants.len()])
        })
        .collect();
    let mut server = Server::new(engine, ServeCfg::default()).unwrap();
    let report = server.run_trace(reqs)?;
    report.metrics.print(&report.engine);
    report.metrics.print_adapters();
    // the batched tick streams each packed weight once per tenant-group,
    // not once per sequence — with 4 tenants in flight a full batch of B
    // sequences reads ≤ 4 x bytes(W) per tick instead of B x bytes(W)
    println!(
        "    avg decode batch {:.1} seqs/tick over {} ticks; last tick formed {} tenant-group(s)",
        report.metrics.avg_decode_batch(),
        report.metrics.decode_ticks,
        server.engine.last_decode_groups(),
    );

    // hot swap + LRU eviction: a new tenant displaces the least recently
    // used one (the budget holds only three adapters)
    let fresh = synth.perturbed(0.05, &mut rng);
    server.engine.register_adapter("tenant-new", fresh)?;
    let stats = server.engine.registry().stats();
    println!(
        "\nafter hot-registering tenant-new: residents {:?} ({} eviction(s), {:.1} KiB / {:.1} KiB budget)",
        server.engine.registry().resident_ids(),
        stats.evictions,
        stats.used_bytes as f64 / 1024.0,
        stats.budget_bytes as f64 / 1024.0,
    );
    println!("(expected: 4 tenants share one base; N adapters ≈ the cost of N rank-r factor sets)");
    Ok(())
}
