//! Synthetic data substrate standing in for the paper's corpora (see
//! DESIGN.md §3 for the substitution rationale):
//!
//! * [`corpus`] — Markov-Zipf token streams (two entropy presets = the
//!   WikiText-2 vs PTB pair), batching, and a calibration sampler.
//! * [`tasks`]  — a 7-task "commonsense-style" suite scored by LM
//!   likelihood, mirroring the zero-shot accuracy columns.

pub mod corpus;
pub mod tasks;

pub use corpus::{Corpus, CorpusKind};
pub use tasks::{TaskSuite, TaskExample};
