//! Markov-Zipf synthetic corpora.
//!
//! Token streams are generated from an order-1 Markov chain whose rows are
//! Zipf-weighted permutations — giving natural-language-like unigram
//! frequencies *and* learnable bigram structure (so a trained LM beats the
//! unigram entropy and quantization damage shows up as a PPL gap).
//!
//! Two presets stand in for the paper's two perplexity corpora:
//! * `Wiki` — lower temperature, more predictable (≈ WikiText-2 role)
//! * `Ptb`  — higher entropy (≈ PTB role, larger absolute PPL)

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    Wiki,
    Ptb,
}

/// A generated corpus with train/eval splits.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub train: Vec<usize>,
    pub eval: Vec<usize>,
    pub kind: CorpusKind,
}

impl Corpus {
    /// Generate `train_len` + `eval_len` tokens with the preset's entropy.
    pub fn generate(kind: CorpusKind, vocab: usize, train_len: usize, eval_len: usize, seed: u64) -> Corpus {
        // branching factor and skew control the achievable perplexity
        let (branch, skew) = match kind {
            CorpusKind::Wiki => (8usize, 1.2f64),
            CorpusKind::Ptb => (24usize, 1.05f64),
        };
        let mut rng = Rng::new(seed ^ 0xDA7A);
        // per-state successor tables: `branch` candidates, Zipf-weighted
        let succ: Vec<Vec<usize>> = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.zipf(vocab, skew)).collect())
            .collect();
        let weights: Vec<f32> = (0..branch).map(|i| 1.0 / (1.0 + i as f32).powf(skew as f32)).collect();

        let mut gen = |len: usize, rng: &mut Rng| -> Vec<usize> {
            let mut out = Vec::with_capacity(len);
            let mut state = rng.below(vocab);
            for _ in 0..len {
                // occasional jump keeps the chain ergodic
                if rng.f32() < 0.02 {
                    state = rng.zipf(vocab, skew);
                }
                let choice = rng.categorical(&weights);
                state = succ[state][choice];
                out.push(state);
            }
            out
        };
        let train = gen(train_len, &mut rng);
        let eval = gen(eval_len, &mut rng);
        Corpus { vocab, train, eval, kind }
    }

    /// Sample a (tokens, targets) LM batch from the train split.
    /// Both are batch×seq flattened row-major; targets are shift-by-one.
    pub fn sample_batch(&self, batch: usize, seq: usize, rng: &mut Rng) -> (Vec<usize>, Vec<usize>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below(self.train.len() - seq - 1);
            tokens.extend_from_slice(&self.train[start..start + seq]);
            targets.extend_from_slice(&self.train[start + 1..start + seq + 1]);
        }
        (tokens, targets)
    }

    /// Deterministic eval windows covering the eval split.
    pub fn eval_windows(&self, seq: usize, max_windows: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
        let mut out = Vec::new();
        let mut start = 0;
        while start + seq + 1 <= self.eval.len() && out.len() < max_windows {
            out.push((
                self.eval[start..start + seq].to_vec(),
                self.eval[start + 1..start + seq + 1].to_vec(),
            ));
            start += seq;
        }
        out
    }

    /// Calibration batch for PTQ methods (GPTQ/AWQ): random train windows.
    pub fn calibration(&self, n_windows: usize, seq: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed ^ 0xCA11B);
        (0..n_windows)
            .map(|_| {
                let start = rng.below(self.train.len() - seq);
                self.train[start..start + seq].to_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(CorpusKind::Wiki, 64, 1000, 100, 7);
        let b = Corpus::generate(CorpusKind::Wiki, 64, 1000, 100, 7);
        assert_eq!(a.train, b.train);
        assert_eq!(a.eval, b.eval);
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::generate(CorpusKind::Ptb, 32, 500, 100, 1);
        assert!(c.train.iter().all(|&t| t < 32));
        assert!(c.eval.iter().all(|&t| t < 32));
    }

    #[test]
    fn wiki_is_more_predictable_than_ptb() {
        // bigram conditional entropy should be lower for the Wiki preset
        let entropy = |c: &Corpus| -> f64 {
            let v = c.vocab;
            let mut counts = vec![0f64; v * v];
            let mut row = vec![0f64; v];
            for w in c.train.windows(2) {
                counts[w[0] * v + w[1]] += 1.0;
                row[w[0]] += 1.0;
            }
            let mut h = 0.0;
            let total: f64 = row.iter().sum();
            for s in 0..v {
                if row[s] == 0.0 {
                    continue;
                }
                let ps = row[s] / total;
                for t in 0..v {
                    let c2 = counts[s * v + t];
                    if c2 > 0.0 {
                        let p = c2 / row[s];
                        h -= ps * p * p.ln();
                    }
                }
            }
            h
        };
        let wiki = Corpus::generate(CorpusKind::Wiki, 64, 20_000, 100, 3);
        let ptb = Corpus::generate(CorpusKind::Ptb, 64, 20_000, 100, 3);
        assert!(entropy(&wiki) < entropy(&ptb), "{} vs {}", entropy(&wiki), entropy(&ptb));
    }

    #[test]
    fn batches_are_shifted_pairs() {
        let c = Corpus::generate(CorpusKind::Wiki, 32, 2000, 200, 2);
        let mut rng = Rng::new(0);
        let (tokens, targets) = c.sample_batch(3, 16, &mut rng);
        assert_eq!(tokens.len(), 48);
        // within each row, targets = tokens shifted by one
        for b in 0..3 {
            for i in 0..15 {
                assert_eq!(tokens[b * 16 + i + 1], targets[b * 16 + i]);
            }
        }
    }

    #[test]
    fn eval_windows_cover_split() {
        let c = Corpus::generate(CorpusKind::Wiki, 32, 500, 330, 4);
        let ws = c.eval_windows(64, 100);
        assert_eq!(ws.len(), 5); // floor((330-1)/64)
        for (t, y) in &ws {
            assert_eq!(t.len(), 64);
            assert_eq!(y.len(), 64);
        }
    }
}
