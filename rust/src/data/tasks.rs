//! Synthetic "commonsense-style" evaluation suite.
//!
//! Seven tasks mirror the paper's zero-shot columns (BoolQ, PIQA, SIQA/HS,
//! WG, ARC-e, ARC-c, OBQA in spirit): each example is a context token
//! sequence plus K candidate continuations, exactly one of which follows the
//! corpus's Markov dynamics; the model answers by likelihood, so accuracy
//! measures how much of the learned distribution survives quantization —
//! the same mechanism lm-eval-harness uses.
//!
//! Task difficulty is controlled by (a) continuation length and (b) how
//! distractors are drawn (uniform = easy, Zipf-plausible = hard), producing
//! an accuracy spread comparable to the paper's 30–85% range.

use super::corpus::Corpus;
use crate::util::Rng;

/// One multiple-choice example.
#[derive(Clone, Debug)]
pub struct TaskExample {
    pub context: Vec<usize>,
    /// candidate continuations; `answer` indexes the correct one.
    pub choices: Vec<Vec<usize>>,
    pub answer: usize,
}

/// A named task = a bag of examples with a shared difficulty profile.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: &'static str,
    pub examples: Vec<TaskExample>,
}

/// The 7-task suite.
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub tasks: Vec<Task>,
}

struct TaskSpec {
    name: &'static str,
    ctx_len: usize,
    cont_len: usize,
    n_choices: usize,
    /// true → distractors sampled Zipf-plausibly (harder)
    hard_negatives: bool,
}

const SPECS: [TaskSpec; 7] = [
    TaskSpec { name: "BoolQ", ctx_len: 24, cont_len: 2, n_choices: 2, hard_negatives: false },
    TaskSpec { name: "PIQA", ctx_len: 16, cont_len: 4, n_choices: 2, hard_negatives: true },
    TaskSpec { name: "HS", ctx_len: 20, cont_len: 6, n_choices: 4, hard_negatives: true },
    TaskSpec { name: "WG", ctx_len: 12, cont_len: 3, n_choices: 2, hard_negatives: true },
    TaskSpec { name: "ARC-e", ctx_len: 16, cont_len: 3, n_choices: 4, hard_negatives: false },
    TaskSpec { name: "ARC-c", ctx_len: 16, cont_len: 5, n_choices: 4, hard_negatives: true },
    TaskSpec { name: "OBQA", ctx_len: 10, cont_len: 6, n_choices: 4, hard_negatives: true },
];

impl TaskSuite {
    /// Build the suite from held-out corpus text so the correct continuation
    /// is genuinely on-distribution.
    pub fn generate(corpus: &Corpus, per_task: usize, seed: u64) -> TaskSuite {
        let mut rng = Rng::new(seed ^ 0x7A5C);
        let text = &corpus.eval;
        let tasks = SPECS
            .iter()
            .map(|spec| {
                let examples = (0..per_task)
                    .map(|_| {
                        let total = spec.ctx_len + spec.cont_len;
                        let start = rng.below(text.len() - total - 1);
                        let context = text[start..start + spec.ctx_len].to_vec();
                        let correct = text[start + spec.ctx_len..start + total].to_vec();
                        let answer = rng.below(spec.n_choices);
                        let choices = (0..spec.n_choices)
                            .map(|c| {
                                if c == answer {
                                    correct.clone()
                                } else if spec.hard_negatives {
                                    // a plausible span from elsewhere in text
                                    let s2 = rng.below(text.len() - spec.cont_len - 1);
                                    text[s2..s2 + spec.cont_len].to_vec()
                                } else {
                                    (0..spec.cont_len).map(|_| rng.below(corpus.vocab)).collect()
                                }
                            })
                            .collect();
                        TaskExample { context, choices, answer }
                    })
                    .collect();
                Task { name: spec.name, examples }
            })
            .collect();
        TaskSuite { tasks }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.tasks.iter().map(|t| t.name).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusKind;

    fn suite() -> TaskSuite {
        let c = Corpus::generate(CorpusKind::Wiki, 64, 4000, 2000, 0);
        TaskSuite::generate(&c, 20, 1)
    }

    #[test]
    fn seven_tasks_with_examples() {
        let s = suite();
        assert_eq!(s.tasks.len(), 7);
        assert_eq!(s.names(), vec!["BoolQ", "PIQA", "HS", "WG", "ARC-e", "ARC-c", "OBQA"]);
        for t in &s.tasks {
            assert_eq!(t.examples.len(), 20);
        }
    }

    #[test]
    fn answers_are_valid_indices() {
        let s = suite();
        for t in &s.tasks {
            for e in &t.examples {
                assert!(e.answer < e.choices.len());
                let lens: Vec<usize> = e.choices.iter().map(|c| c.len()).collect();
                assert!(lens.iter().all(|&l| l == lens[0]), "choices must be same length");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = suite();
        let b = suite();
        assert_eq!(a.tasks[3].examples[5].context, b.tasks[3].examples[5].context);
        assert_eq!(a.tasks[3].examples[5].answer, b.tasks[3].examples[5].answer);
    }

    #[test]
    fn correct_choice_comes_from_text() {
        let c = Corpus::generate(CorpusKind::Wiki, 64, 4000, 2000, 0);
        let s = TaskSuite::generate(&c, 10, 1);
        // the correct continuation must be a subsequence of eval text
        let hay = &c.eval;
        let ex = &s.tasks[0].examples[0];
        let needle = &ex.choices[ex.answer];
        let found = hay.windows(needle.len()).any(|w| w == needle.as_slice());
        assert!(found);
    }
}
