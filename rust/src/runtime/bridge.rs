//! Model ⇄ artifact parameter bridge: resolves the manifest's named input
//! specs against a native [`Model`], producing the flat `HostTensor` lists
//! the PJRT artifacts expect, and writes updated tensors back.
//!
//! Name scheme (mirrors `python/compile/model.py`):
//!   tok_emb, final_norm, lm_head,
//!   l{i}.attn_norm, l{i}.mlp_norm,
//!   l{i}.{wq|wk|wv|wo|w_gate|w_up|w_down}           (dense weight)
//!   ...{linear}.codes / .B / .A                      (LoRDS layout)
//!   ...{linear}.scales                               (NF4 layout)
//!   ...{linear}.lora_a / .lora_b                     (QLoRA layout)

use super::manifest::TensorSpec;
use super::runtime::HostTensor;
use crate::model::{LinearWeight, Model};
use crate::tensor::Matrix;

fn linear<'m>(model: &'m Model, layer: usize, field: &str) -> &'m LinearWeight {
    let l = &model.layers[layer];
    match field {
        "wq" => &l.wq,
        "wk" => &l.wk,
        "wv" => &l.wv,
        "wo" => &l.wo,
        "w_gate" => &l.w_gate,
        "w_up" => &l.w_up,
        "w_down" => &l.w_down,
        _ => panic!("unknown linear {field}"),
    }
}

fn linear_mut<'m>(model: &'m mut Model, layer: usize, field: &str) -> &'m mut LinearWeight {
    let l = &mut model.layers[layer];
    match field {
        "wq" => &mut l.wq,
        "wk" => &mut l.wk,
        "wv" => &mut l.wv,
        "wo" => &mut l.wo,
        "w_gate" => &mut l.w_gate,
        "w_up" => &mut l.w_up,
        "w_down" => &mut l.w_down,
        _ => panic!("unknown linear {field}"),
    }
}

fn mat(m: &Matrix) -> HostTensor {
    HostTensor::F32(m.data.clone(), vec![m.rows, m.cols])
}

fn vecf(v: &[f32]) -> HostTensor {
    HostTensor::F32(v.to_vec(), vec![v.len()])
}

/// Resolve one named parameter from the model.
pub fn resolve(model: &Model, name: &str) -> HostTensor {
    match name {
        "tok_emb" => mat(&model.tok_emb),
        "lm_head" => mat(&model.lm_head),
        "final_norm" => vecf(&model.final_norm),
        _ => {
            let (layer_part, rest) = name.split_once('.').expect("layered name");
            let layer: usize = layer_part[1..].parse().expect("layer index");
            match rest {
                "attn_norm" => vecf(&model.layers[layer].attn_norm),
                "mlp_norm" => vecf(&model.layers[layer].mlp_norm),
                _ => {
                    // l{i}.{field}[.kind]
                    let (field, kind) = match rest.rsplit_once('.') {
                        Some((f, k)) if ["codes", "B", "A", "scales", "lora_a", "lora_b"].contains(&k) => {
                            (f, Some(k))
                        }
                        _ => (rest, None),
                    };
                    let lw = linear(model, layer, field);
                    match (lw, kind) {
                        (lw, None) => mat(&lw.effective()),
                        (LinearWeight::Lords { q, .. }, Some("codes")) => HostTensor::I32(
                            q.codes.iter().map(|c| c as i32).collect(),
                            vec![q.rows, q.cols],
                        ),
                        (LinearWeight::Lords { q, .. }, Some("B")) => mat(&q.b),
                        (LinearWeight::Lords { q, .. }, Some("A")) => mat(&q.a),
                        (LinearWeight::Blockwise(q), Some("codes")) => HostTensor::I32(
                            q.codes.iter().map(|c| c as i32).collect(),
                            vec![q.rows, q.cols],
                        ),
                        (LinearWeight::Blockwise(q), Some("scales")) => mat(&q.scales),
                        (LinearWeight::Qlora(q), Some("codes")) => HostTensor::I32(
                            q.base.codes.iter().map(|c| c as i32).collect(),
                            vec![q.base.rows, q.base.cols],
                        ),
                        (LinearWeight::Qlora(q), Some("scales")) => mat(&q.base.scales),
                        (LinearWeight::Qlora(q), Some("lora_a")) => mat(&q.lora_a),
                        (LinearWeight::Qlora(q), Some("lora_b")) => mat(&q.lora_b),
                        (lw, Some(k)) => panic!("cannot resolve {name}: repr {lw:?} has no {k}"),
                    }
                }
            }
        }
    }
}

/// Collect all params named by `specs` (stopping before non-param inputs
/// like `tokens`, `targets`, caches).
pub fn collect_params(model: &Model, specs: &[TensorSpec]) -> Vec<HostTensor> {
    specs
        .iter()
        .take_while(|s| !matches!(s.name.as_str(), "tokens" | "targets" | "token" | "k_cache" | "v_cache" | "cur"))
        .map(|s| {
            let t = resolve(model, &s.name);
            assert_eq!(t.dims(), s.dims.as_slice(), "{}: model/manifest shape mismatch", s.name);
            t
        })
        .collect()
}

/// Write an updated f32 tensor back into the model (trainable params only).
pub fn write_back(model: &mut Model, name: &str, data: &[f32]) {
    match name {
        "tok_emb" => model.tok_emb.data.copy_from_slice(data),
        "lm_head" => model.lm_head.data.copy_from_slice(data),
        "final_norm" => model.final_norm.copy_from_slice(data),
        _ => {
            let (layer_part, rest) = name.split_once('.').expect("layered name");
            let layer: usize = layer_part[1..].parse().unwrap();
            match rest {
                "attn_norm" => model.layers[layer].attn_norm.copy_from_slice(data),
                "mlp_norm" => model.layers[layer].mlp_norm.copy_from_slice(data),
                _ => {
                    let (field, kind) = match rest.rsplit_once('.') {
                        Some((f, k)) if ["B", "A", "lora_a", "lora_b"].contains(&k) => (f, Some(k)),
                        _ => (rest, None),
                    };
                    let lw = linear_mut(model, layer, field);
                    match (lw, kind) {
                        (LinearWeight::Lords { q, .. }, Some("B")) => q.b.data.copy_from_slice(data),
                        (LinearWeight::Lords { q, .. }, Some("A")) => q.a.data.copy_from_slice(data),
                        (LinearWeight::Qlora(q), Some("lora_a")) => q.lora_a.data.copy_from_slice(data),
                        (LinearWeight::Qlora(q), Some("lora_b")) => q.lora_b.data.copy_from_slice(data),
                        (LinearWeight::Dense(w), None) => w.data.copy_from_slice(data),
                        (LinearWeight::Lords { shadow_w: Some(w), .. }, None) => {
                            w.data.copy_from_slice(data)
                        }
                        (lw, k) => panic!("cannot write back {name} ({k:?}) into {lw:?}"),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::quant::lords::RefineCfg;
    use crate::quant::Codebook;
    use crate::runtime::manifest::DType;

    fn cfg() -> ModelCfg {
        ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        }
    }

    #[test]
    fn resolve_lords_layout() {
        let c = cfg();
        let mut m = Model::init(&c, 0);
        m.quantize_lords(c.block, &Codebook::normal_float(4),
                         RefineCfg { steps: 0, ..Default::default() }, false);
        let t = resolve(&m, "l0.wq.codes");
        assert_eq!(t.dims(), &[16, 16]);
        assert!(matches!(t, HostTensor::I32(..)));
        let b = resolve(&m, "l0.wq.B");
        assert_eq!(b.dims()[0], 16);
        let emb = resolve(&m, "tok_emb");
        assert_eq!(emb.dims(), &[32, 16]);
        let norm = resolve(&m, "l0.attn_norm");
        assert_eq!(norm.dims(), &[16]);
    }

    #[test]
    fn collect_stops_at_tokens() {
        let c = cfg();
        let mut m = Model::init(&c, 1);
        m.quantize_lords(c.block, &Codebook::normal_float(4),
                         RefineCfg { steps: 0, ..Default::default() }, false);
        let specs = vec![
            TensorSpec { name: "tok_emb".into(), dtype: DType::F32, dims: vec![32, 16] },
            TensorSpec { name: "l0.attn_norm".into(), dtype: DType::F32, dims: vec![16] },
            TensorSpec { name: "tokens".into(), dtype: DType::I32, dims: vec![2, 8] },
        ];
        let params = collect_params(&m, &specs);
        assert_eq!(params.len(), 2);
    }

    #[test]
    fn write_back_roundtrip() {
        let c = cfg();
        let mut m = Model::init(&c, 2);
        m.quantize_lords(c.block, &Codebook::normal_float(4),
                         RefineCfg { steps: 0, ..Default::default() }, false);
        let b0 = resolve(&m, "l0.wq.B");
        let new: Vec<f32> = b0.f32s().iter().map(|v| v + 1.0).collect();
        write_back(&mut m, "l0.wq.B", &new);
        let b1 = resolve(&m, "l0.wq.B");
        assert_eq!(b1.f32s(), new.as_slice());
    }
}
