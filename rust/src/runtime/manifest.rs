//! Parser for `artifacts/manifest.txt` — the single source of truth for
//! artifact signatures (input/output names, dtypes, shapes in execution
//! order), the model config they were lowered against, and the exact
//! codebook LUT baked into the HLO.

use crate::config::ModelCfg;
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(format!("unknown dtype {other}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    /// empty = scalar
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl Artifact {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelCfg,
    pub lut_name: String,
    pub lut: Vec<f32>,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{path}: {e} (run `make artifacts` first)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut model = ModelCfg::default();
        let mut lut_name = String::new();
        let mut lut = Vec::new();
        let mut artifacts = BTreeMap::new();
        let mut cur: Option<Artifact> = None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            match tag {
                "model" => {
                    for kv in &rest {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| format!("line {}: bad model kv {kv}", ln + 1))?;
                        match k {
                            "vocab" => model.vocab = v.parse().unwrap(),
                            "d_model" => model.d_model = v.parse().unwrap(),
                            "n_layers" => model.n_layers = v.parse().unwrap(),
                            "n_heads" => model.n_heads = v.parse().unwrap(),
                            "d_ff" => model.d_ff = v.parse().unwrap(),
                            "max_seq" => model.max_seq = v.parse().unwrap(),
                            "block" => model.block = v.parse().unwrap(),
                            "codebook" => model.codebook = v.to_string(),
                            "qlora_rank" => model.qlora_rank = v.parse().unwrap(),
                            _ => {}
                        }
                    }
                }
                "lut" => {
                    lut_name = rest[0].to_string();
                    lut = rest[1]
                        .split(',')
                        .map(|v| v.parse::<f32>().map_err(|e| format!("lut: {e}")))
                        .collect::<Result<_, _>>()?;
                }
                "artifact" => {
                    if let Some(a) = cur.take() {
                        artifacts.insert(a.name.clone(), a);
                    }
                    cur = Some(Artifact {
                        name: rest[0].to_string(),
                        file: rest[1].to_string(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" | "out" => {
                    let a = cur
                        .as_mut()
                        .ok_or_else(|| format!("line {}: {tag} outside artifact", ln + 1))?;
                    let dims = if rest[2] == "scalar" {
                        vec![]
                    } else {
                        rest[2]
                            .split(',')
                            .map(|d| d.parse::<usize>().map_err(|e| format!("dims: {e}")))
                            .collect::<Result<_, _>>()?
                    };
                    let spec = TensorSpec {
                        name: rest[0].to_string(),
                        dtype: DType::parse(rest[1])?,
                        dims,
                    };
                    if tag == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => {
                    if let Some(a) = cur.take() {
                        artifacts.insert(a.name.clone(), a);
                    }
                }
                other => return Err(format!("line {}: unknown tag {other}", ln + 1)),
            }
        }
        if let Some(a) = cur.take() {
            artifacts.insert(a.name.clone(), a);
        }
        if lut.is_empty() {
            return Err("manifest missing lut".into());
        }
        Ok(Manifest { model, lut_name, lut, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact {name} not in manifest ({} known)", self.artifacts.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# lords-artifacts v1
model vocab=64 d_model=32 n_layers=1 n_heads=2 d_ff=64 max_seq=32 block=16 codebook=nf4 qlora_rank=16
lut nf4 -1.0,-0.5,0.0,0.5,1.0
artifact fp_mm fp_mm.hlo.txt
in x f32 8,32
in w f32 16,32
out out0 f32 8,16
end
artifact dec dec.hlo.txt
in token i32 2,1
in cur i32 scalar
out out0 f32 2,64
end
";

    #[test]
    fn parses_model_and_lut() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.vocab, 64);
        assert_eq!(m.model.block, 16);
        assert_eq!(m.model.codebook, "nf4");
        assert_eq!(m.lut, vec![-1.0, -0.5, 0.0, 0.5, 1.0]);
    }

    #[test]
    fn parses_artifacts() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.artifact("fp_mm").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![8, 32]);
        assert_eq!(a.inputs[1].dtype, DType::F32);
        assert_eq!(a.outputs[0].dims, vec![8, 16]);
        let d = m.artifact("dec").unwrap();
        assert_eq!(d.inputs[1].dims, Vec::<usize>::new());
        assert_eq!(d.inputs[1].dtype, DType::I32);
        assert_eq!(d.inputs[1].elements(), 1);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.contains_key("lords_forward"));
            assert_eq!(m.lut.len(), 16);
        }
    }
}
