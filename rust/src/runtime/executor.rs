//! The executor thread: owns the (non-`Send`) [`Runtime`] and serves
//! execution requests from any thread through a channel — the pattern a
//! real serving stack uses for a single accelerator context.

use super::runtime::{HostTensor, Runtime};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Msg {
    Execute {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: Sender<anyhow::Result<Vec<HostTensor>>>,
    },
    /// Pre-compile an artifact (warmup).
    Warm {
        artifact: String,
        reply: Sender<anyhow::Result<()>>,
    },
    Stats {
        reply: Sender<super::runtime::RuntimeStats>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: Sender<Msg>,
}

pub struct Executor {
    handle: ExecutorHandle,
    join: Option<JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; fails fast if the artifacts dir is absent.
    pub fn spawn(artifacts_dir: &str) -> anyhow::Result<Executor> {
        // validate the manifest on the caller thread for a clean error
        super::manifest::Manifest::load(artifacts_dir).map_err(anyhow::Error::msg)?;
        let dir = artifacts_dir.to_string();
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let rt = match Runtime::new(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Execute { artifact, inputs, reply } => {
                            let _ = reply.send(rt.execute(&artifact, &inputs));
                        }
                        Msg::Warm { artifact, reply } => {
                            let _ = reply.send(rt.executable(&artifact).map(|_| ()));
                        }
                        Msg::Stats { reply } => {
                            let _ = reply.send(rt.stats.borrow().clone());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow::anyhow!("executor thread died"))??;
        Ok(Executor { handle: ExecutorHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecutorHandle {
    /// Synchronous execute (blocks the calling thread until the reply).
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> anyhow::Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    /// Pre-compile an artifact so first-request latency is flat.
    pub fn warm(&self, artifact: &str) -> anyhow::Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))?
    }

    pub fn stats(&self) -> anyhow::Result<super::runtime::RuntimeStats> {
        let (reply, rx) = channel();
        self.tx.send(Msg::Stats { reply }).map_err(|_| anyhow::anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor dropped reply"))
    }
}
