//! The PJRT runtime proper: client + per-artifact compile cache + typed
//! host↔device marshalling.
//!
//! NOT `Send` (the xla crate's client is `Rc`-based); wrap in
//! [`super::executor::ExecutorHandle`] to use from the coordinator's threads.
//!
//! The `xla` PJRT binding is not a crates.io dependency — it must be
//! vendored and enabled with the `pjrt` cargo feature. Without the feature
//! this module compiles a **stub** [`Runtime`] with the same surface that
//! fails cleanly at [`Runtime::new`], so the executor, `PjrtEngine`, and
//! `PjrtTrainer` all type-check and every PJRT call site degrades to its
//! documented "artifacts unavailable" fallback.

use super::manifest::Manifest;
#[cfg(feature = "pjrt")]
use super::manifest::{Artifact, DType};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn from_matrix(m: &crate::tensor::Matrix) -> HostTensor {
        HostTensor::F32(m.data.clone(), vec![m.rows, m.cols])
    }

    pub fn to_matrix(&self) -> crate::tensor::Matrix {
        match self {
            HostTensor::F32(data, dims) => {
                assert!(dims.len() <= 2, "to_matrix on rank-{} tensor", dims.len());
                let rows = if dims.len() == 2 { dims[0] } else { 1 };
                let cols = *dims.last().unwrap_or(&1);
                crate::tensor::Matrix::from_vec(rows, cols, data.clone())
            }
            HostTensor::I32(..) => panic!("to_matrix on i32 tensor"),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, d) | HostTensor::I32(_, d) => d,
        }
    }

    pub fn f32s(&self) -> &[f32] {
        match self {
            HostTensor::F32(v, _) => v,
            HostTensor::I32(..) => panic!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match self {
            HostTensor::I32(v, _) => v,
            HostTensor::F32(..) => panic!("expected i32 tensor"),
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(data, dims) => {
                let v = xla::Literal::vec1(data.as_slice());
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    v.reshape(&d)?
                }
            }
            HostTensor::I32(data, dims) => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let v = xla::Literal::vec1(data.as_slice());
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    v.reshape(&d)?
                }
            }
        };
        Ok(lit)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal, dims: Vec<usize>, dtype: DType) -> anyhow::Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?, dims),
            DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?, dims),
        })
    }
}

#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

/// PJRT runtime (single-threaded owner).
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: String,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative (compiles, executions) for the perf report
    pub stats: RefCell<RuntimeStats>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn new(artifacts_dir: &str) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir).map_err(anyhow::Error::msg)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifacts_dir.to_string(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Compile (or fetch cached) executable for `name`.
    pub fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let art = self.manifest.artifact(name).map_err(anyhow::Error::msg)?;
        let path = format!("{}/{}", self.dir, art.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        crate::debug_log!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute artifact `name` with typed host tensors; returns the tuple
    /// elements as host tensors (shapes from the manifest).
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let art = self.manifest.artifact(name).map_err(anyhow::Error::msg)?.clone();
        self.check_inputs(&art, inputs)?;
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let t0 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == art.outputs.len(),
            "{name}: {} outputs, manifest says {}",
            parts.len(),
            art.outputs.len()
        );
        parts
            .iter()
            .zip(&art.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec.dims.clone(), spec.dtype))
            .collect()
    }

    fn check_inputs(&self, art: &Artifact, inputs: &[HostTensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            inputs.len() == art.inputs.len(),
            "{}: got {} inputs, manifest says {}",
            art.name,
            inputs.len(),
            art.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&art.inputs) {
            anyhow::ensure!(
                t.dims() == spec.dims.as_slice(),
                "{}/{}: got dims {:?}, want {:?}",
                art.name,
                spec.name,
                t.dims(),
                spec.dims
            );
            let dtype_ok = matches!(
                (t, spec.dtype),
                (HostTensor::F32(..), DType::F32) | (HostTensor::I32(..), DType::I32)
            );
            anyhow::ensure!(dtype_ok, "{}/{}: dtype mismatch", art.name, spec.name);
        }
        Ok(())
    }
}

/// Stand-in executable handle for the stub runtime (never instantiated —
/// [`Runtime::new`] fails first).
#[cfg(not(feature = "pjrt"))]
pub struct StubExecutable;

/// Stub runtime compiled when the `pjrt` feature (and with it the vendored
/// `xla` binding) is absent. Same surface as the real [`Runtime`];
/// construction always fails with an actionable error.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
    #[allow(dead_code)]
    cache: RefCell<HashMap<String, Rc<StubExecutable>>>,
    pub stats: RefCell<RuntimeStats>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new(artifacts_dir: &str) -> anyhow::Result<Runtime> {
        // Parse the manifest anyway so callers get the more specific
        // "artifacts missing" error when that is the actual problem.
        let _ = Manifest::load(artifacts_dir).map_err(anyhow::Error::msg)?;
        anyhow::bail!(
            "PJRT support not compiled in: rebuild with `--features pjrt` \
             and a vendored `xla` crate (see rust/src/runtime/runtime.rs)"
        )
    }

    pub fn executable(&self, _name: &str) -> anyhow::Result<Rc<StubExecutable>> {
        anyhow::bail!("PJRT support not compiled in (enable the `pjrt` feature)")
    }

    pub fn execute(&self, _name: &str, _inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::bail!("PJRT support not compiled in (enable the `pjrt` feature)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.f32s(), &[1.0, 2.0, 3.0, 4.0]);
        let m = t.to_matrix();
        assert_eq!(m.shape(), (2, 2));
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.i32s(), &[7]);
        assert!(s.dims().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_cleanly() {
        // No artifacts dir → manifest error; with one → feature-gate error.
        let err = Runtime::new("definitely-not-a-dir").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.txt"), "unexpected error: {msg}");
    }
}
