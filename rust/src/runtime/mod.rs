//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client, and
//! executes them from the serving / training hot paths.
//!
//! The `xla` crate's client is `Rc`-based (neither `Send` nor `Sync`), so
//! the runtime is owned by a single **executor thread**
//! ([`executor::ExecutorHandle`] is the `Send` front door the coordinator
//! uses). Executables are compiled on demand and cached by artifact name.

pub mod bridge;
pub mod executor;
pub mod manifest;
#[allow(clippy::module_inception)]
pub mod runtime;

pub use executor::ExecutorHandle;
pub use manifest::{Artifact, DType, Manifest, TensorSpec};
pub use runtime::{HostTensor, Runtime};
