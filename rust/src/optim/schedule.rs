//! Learning-rate schedules matching the paper's training protocols:
//! cosine with linear warmup (QAT §4.2: warmup ratio 0.3, peak 2e-5) and
//! linear decay (PEFT §4.3: linear scheduler, peak 1e-4).

pub trait LrSchedule {
    /// Learning rate at 0-based step `t` of `total` steps.
    fn lr(&self, t: u64, total: u64) -> f32;
}

/// Flat learning rate.
#[derive(Clone, Copy, Debug)]
pub struct ConstantLr(pub f32);

impl LrSchedule for ConstantLr {
    fn lr(&self, _t: u64, _total: u64) -> f32 {
        self.0
    }
}

/// Linear warmup to `peak` over `warmup_ratio * total` steps, then cosine
/// decay to `min_lr`.
#[derive(Clone, Copy, Debug)]
pub struct CosineWarmup {
    pub peak: f32,
    pub warmup_ratio: f32,
    pub min_lr: f32,
}

impl CosineWarmup {
    pub fn new(peak: f32, warmup_ratio: f32) -> Self {
        CosineWarmup { peak, warmup_ratio, min_lr: 0.0 }
    }
}

impl LrSchedule for CosineWarmup {
    fn lr(&self, t: u64, total: u64) -> f32 {
        let total = total.max(1);
        let warm = ((total as f32) * self.warmup_ratio).max(1.0);
        let t = t as f32;
        if t < warm {
            return self.peak * (t + 1.0) / warm;
        }
        let progress = ((t - warm) / ((total as f32 - warm).max(1.0))).clamp(0.0, 1.0);
        self.min_lr
            + (self.peak - self.min_lr) * 0.5 * (1.0 + (std::f32::consts::PI * progress).cos())
    }
}

/// Linear warmup then linear decay to zero.
#[derive(Clone, Copy, Debug)]
pub struct LinearDecay {
    pub peak: f32,
    pub warmup_ratio: f32,
}

impl LinearDecay {
    pub fn new(peak: f32, warmup_ratio: f32) -> Self {
        LinearDecay { peak, warmup_ratio }
    }
}

impl LrSchedule for LinearDecay {
    fn lr(&self, t: u64, total: u64) -> f32 {
        let total = total.max(1);
        let warm = ((total as f32) * self.warmup_ratio).max(1.0);
        let t = t as f32;
        if t < warm {
            return self.peak * (t + 1.0) / warm;
        }
        let progress = ((t - warm) / ((total as f32 - warm).max(1.0))).clamp(0.0, 1.0);
        self.peak * (1.0 - progress)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_warmup_profile() {
        let s = CosineWarmup::new(1.0, 0.1);
        let total = 100;
        assert!(s.lr(0, total) < 0.2); // warming
        assert!((s.lr(9, total) - 1.0).abs() < 1e-5); // at peak after warmup
        assert!(s.lr(50, total) < 1.0);
        assert!(s.lr(99, total) < 0.01); // decayed
        // monotone decay after warmup
        let mut prev = s.lr(10, total);
        for t in 11..100 {
            let cur = s.lr(t, total);
            assert!(cur <= prev + 1e-6);
            prev = cur;
        }
    }

    #[test]
    fn linear_decay_profile() {
        let s = LinearDecay::new(2.0, 0.0);
        assert!((s.lr(0, 100) - 2.0).abs() < 0.05);
        assert!((s.lr(50, 100) - 1.0).abs() < 0.05);
        assert!(s.lr(99, 100) < 0.05);
    }

    #[test]
    fn constant_is_constant() {
        let s = ConstantLr(0.5);
        assert_eq!(s.lr(0, 10), 0.5);
        assert_eq!(s.lr(9, 10), 0.5);
    }
}
