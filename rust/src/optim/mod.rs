//! Optimizers + learning-rate schedules. AdamW drives both the LoRDS PTQ
//! adaptation step (Algorithm 1, step 2.2) and the QAT/PEFT training loops;
//! schedules mirror the paper's protocols (cosine with linear warmup for
//! QAT, linear decay for PEFT).

pub mod adamw;
pub mod schedule;
pub mod sgd;

pub use adamw::AdamW;
pub use schedule::{ConstantLr, CosineWarmup, LinearDecay, LrSchedule};
pub use sgd::Sgd;

/// A parameter-group optimizer over flat f32 buffers.
pub trait Optimizer {
    /// In-place update of `param` given `grad` at global step `step` (0-based)
    /// using learning rate `lr`. `slot` identifies the parameter so the
    /// optimizer can keep per-parameter state.
    fn step(&mut self, slot: usize, param: &mut [f32], grad: &[f32], lr: f32);

    /// Advance the shared step counter (call once per optimization step,
    /// after updating every parameter group).
    fn next_step(&mut self);
}
