//! AdamW (decoupled weight decay), the optimizer of Algorithm 1 and the
//! QAT/PEFT trainers.

use super::Optimizer;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    step: u64,
    /// slot → (m, v) first/second moment buffers
    state: HashMap<usize, (Vec<f32>, Vec<f32>)>,
}

impl AdamW {
    pub fn new(weight_decay: f32) -> Self {
        AdamW { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay, step: 0, state: HashMap::new() }
    }

    pub fn with_betas(mut self, b1: f32, b2: f32) -> Self {
        self.beta1 = b1;
        self.beta2 = b2;
        self
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, slot: usize, param: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(param.len(), grad.len());
        let (m, v) = self
            .state
            .entry(slot)
            .or_insert_with(|| (vec![0.0; param.len()], vec![0.0; param.len()]));
        assert_eq!(m.len(), param.len(), "slot {slot} reused with different size");
        let t = (self.step + 1) as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..param.len() {
            let g = grad[i];
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            // decoupled weight decay
            param[i] -= lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * param[i]);
        }
    }

    fn next_step(&mut self) {
        self.step += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x - target)² — AdamW should converge
        let target = [3.0f32, -2.0, 0.5];
        let mut x = [0.0f32; 3];
        let mut opt = AdamW::new(0.0);
        for _ in 0..2000 {
            let grad: Vec<f32> = x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect();
            opt.step(0, &mut x, &grad, 0.01);
            opt.next_step();
        }
        for (xi, ti) in x.iter().zip(&target) {
            assert!((xi - ti).abs() < 1e-2, "{xi} vs {ti}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut x = [10.0f32];
        let mut opt = AdamW::new(0.1);
        for _ in 0..100 {
            opt.step(0, &mut x, &[0.0], 0.1);
            opt.next_step();
        }
        assert!(x[0] < 10.0 * 0.9);
    }

    #[test]
    fn separate_slots_keep_separate_state() {
        let mut a = [0.0f32];
        let mut b = [0.0f32];
        let mut opt = AdamW::new(0.0);
        for _ in 0..10 {
            opt.step(0, &mut a, &[1.0], 0.1);
            opt.step(1, &mut b, &[-1.0], 0.1);
            opt.next_step();
        }
        assert!(a[0] < 0.0 && b[0] > 0.0);
        assert!((a[0] + b[0]).abs() < 1e-6, "symmetric streams should mirror");
    }

    #[test]
    #[should_panic(expected = "slot")]
    fn slot_size_mismatch_panics() {
        let mut opt = AdamW::new(0.0);
        let mut x = [0.0f32; 2];
        opt.step(0, &mut x, &[1.0, 1.0], 0.1);
        let mut y = [0.0f32; 3];
        opt.step(0, &mut y, &[1.0, 1.0, 1.0], 0.1);
    }
}
