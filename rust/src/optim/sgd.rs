//! SGD with optional momentum — baseline optimizer and the cheap choice for
//! the PTQ refinement ablation (DESIGN.md §8).

use super::Optimizer;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Sgd {
    pub momentum: f32,
    step: u64,
    velocity: HashMap<usize, Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Self {
        Sgd { momentum, step: 0, velocity: HashMap::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, slot: usize, param: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(param.len(), grad.len());
        if self.momentum == 0.0 {
            for (p, g) in param.iter_mut().zip(grad) {
                *p -= lr * g;
            }
            return;
        }
        let v = self.velocity.entry(slot).or_insert_with(|| vec![0.0; param.len()]);
        for i in 0..param.len() {
            v[i] = self.momentum * v[i] + grad[i];
            param[i] -= lr * v[i];
        }
    }

    fn next_step(&mut self) {
        self.step += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_descends() {
        let mut x = [4.0f32];
        let mut opt = Sgd::new(0.0);
        for _ in 0..200 {
            let g = [2.0 * x[0]];
            opt.step(0, &mut x, &g, 0.1);
            opt.next_step();
        }
        assert!(x[0].abs() < 1e-3);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mom: f32| {
            let mut x = [4.0f32];
            let mut opt = Sgd::new(mom);
            for _ in 0..30 {
                let g = [2.0 * x[0]];
                opt.step(0, &mut x, &g, 0.02);
                opt.next_step();
            }
            x[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }
}
