//! Deterministic fault-injection plane.
//!
//! Serving code plants **named injection sites** with
//! [`fault::point!`](crate::fault_point) — the fault-plane sibling of
//! `obs::span!`. When the plane is disabled (the default) a site costs a
//! single relaxed atomic load; `benches/serve_online.rs` asserts that
//! bound as part of bench smoke. When enabled, each site consults the
//! installed fault specs and either fires a fault or falls through.
//!
//! ## Spec grammar
//!
//! A plane configuration is a `;`-separated list of specs, each a
//! `,`-separated list of `key=value` fields:
//!
//! ```text
//! site=kv.seal,p=0.01,kind=err,seed=7;site=engine.*,p=0.001,kind=latency,seed=7
//! ```
//!
//! | key | meaning | default |
//! |-----|---------|---------|
//! | `site` | site name, exact or trailing-`*` prefix pattern | (required) |
//! | `p` | firing probability per visit, in `[0, 1]` | `1.0` |
//! | `kind` | `err`, `latency`, `logit`, `alloc`, `adapter` | `err` |
//! | `seed` | RNG seed for this spec's deterministic draws | `0` |
//!
//! ## Determinism
//!
//! Every spec keeps an independent visit counter **per site it
//! matches**; the draw at visit *n* is a pure function of
//! `(seed, site, n)`. Replaying the same workload against the same spec
//! therefore fires the same faults at the same visits, which is what
//! lets `tests/chaos.rs` assert bit-identical event streams for a
//! repeated seed. Counters on one site never perturb draws on another.
//!
//! ## Fault kinds and how sites honor them
//!
//! | kind | behavior at a site that honors it |
//! |------|-----------------------------------|
//! | `err` | the operation returns an injected `anyhow` error |
//! | `latency` | the site spins a fixed bounded loop, then proceeds normally |
//! | `logit` | a decode-output logit is overwritten with a non-finite value |
//! | `alloc` | treated like `err` at allocation/budget sites (pool-exhausted shape) |
//! | `adapter` | adapter-artifact resolve fails (corrupt / unreadable artifact) |
//!
//! A kind a given site cannot express is a **no-op** at that site (the
//! draw still advances, keeping replay deterministic). Infallible sites
//! degrade instead of erroring: the prefix cache treats a fired fault as
//! a miss on claim and drops the publish; `KvPool::release` honors only
//! `latency`, because releasing storage must never fail.
//!
//! The serving site catalog lives in the README section *"Failure model
//! & fault injection"*; repolint rule `E0008` enforces that every
//! `fault::point!` literal in `rust/src` is documented there.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::obs::json::Json;

/// Global enable flag — the only state a disabled site touches.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the fault plane enabled? One relaxed atomic load; this is the
/// entire disabled-path cost of a `fault::point!` site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A named fault-injection site. Expands to a relaxed atomic load when
/// the plane is disabled; when enabled, evaluates the installed specs
/// and returns `Some(kind)` if a fault fires at this visit.
///
/// ```ignore
/// if let Some(kind) = crate::fault::point!("kv.seal") {
///     crate::fault::apply_fallible("kv.seal", kind)?;
/// }
/// ```
#[macro_export]
macro_rules! fault_point {
    ($site:expr) => {
        if $crate::fault::enabled() {
            $crate::fault::trigger($site)
        } else {
            ::core::option::Option::None
        }
    };
}

pub use crate::fault_point as point;

/// What an injected fault does at the site where it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation returns an injected error.
    Err,
    /// The site spins a fixed bounded loop, then proceeds.
    Latency,
    /// A decode-output logit is overwritten with a non-finite value.
    CorruptLogits,
    /// Allocation/budget failure (pool-exhausted shape).
    Alloc,
    /// Adapter-artifact resolve fails (corrupt / unreadable artifact).
    CorruptAdapter,
}

impl FaultKind {
    /// Grammar name, as accepted by `kind=` in a spec.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Err => "err",
            FaultKind::Latency => "latency",
            FaultKind::CorruptLogits => "logit",
            FaultKind::Alloc => "alloc",
            FaultKind::CorruptAdapter => "adapter",
        }
    }

    fn parse(s: &str) -> anyhow::Result<FaultKind> {
        match s {
            "err" => Ok(FaultKind::Err),
            "latency" => Ok(FaultKind::Latency),
            "logit" => Ok(FaultKind::CorruptLogits),
            "alloc" => Ok(FaultKind::Alloc),
            "adapter" => Ok(FaultKind::CorruptAdapter),
            other => anyhow::bail!(
                "unknown fault kind '{other}' (expected err|latency|logit|alloc|adapter)"
            ),
        }
    }
}

/// One parsed `site=…,p=…,kind=…,seed=…` spec.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Site name, exact or trailing-`*` prefix pattern.
    pub site: String,
    /// Firing probability per visit, in `[0, 1]`.
    pub p: f64,
    /// What the fault does where it fires.
    pub kind: FaultKind,
    /// Seed for this spec's deterministic draws.
    pub seed: u64,
}

impl FaultSpec {
    fn matches(&self, site: &str) -> bool {
        if self.site == "*" {
            return true;
        }
        if let Some(prefix) = self.site.strip_suffix('*') {
            return site.starts_with(prefix);
        }
        self.site == site
    }
}

/// Parse a `;`-separated spec list. Empty input parses to no specs.
pub fn parse_specs(input: &str) -> anyhow::Result<Vec<FaultSpec>> {
    let mut specs = Vec::new();
    for raw in input.split(';') {
        let raw = raw.trim();
        if raw.is_empty() {
            continue;
        }
        let mut site = None;
        let mut p = 1.0f64;
        let mut kind = FaultKind::Err;
        let mut seed = 0u64;
        for field in raw.split(',') {
            let field = field.trim();
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec field '{field}' is not key=value"))?;
            match key.trim() {
                "site" => site = Some(value.trim().to_string()),
                "p" => {
                    p = value
                        .trim()
                        .parse::<f64>()
                        .map_err(|e| anyhow::anyhow!("fault spec p '{value}': {e}"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "fault spec p must be in [0, 1], got {p}"
                    );
                }
                "kind" => kind = FaultKind::parse(value.trim())?,
                "seed" => {
                    seed = value
                        .trim()
                        .parse::<u64>()
                        .map_err(|e| anyhow::anyhow!("fault spec seed '{value}': {e}"))?;
                }
                other => anyhow::bail!(
                    "unknown fault spec key '{other}' (expected site|p|kind|seed)"
                ),
            }
        }
        let site = site.ok_or_else(|| anyhow::anyhow!("fault spec '{raw}' is missing site="))?;
        anyhow::ensure!(!site.is_empty(), "fault spec site must be non-empty");
        specs.push(FaultSpec { site, p, kind, seed });
    }
    Ok(specs)
}

struct Plane {
    specs: Vec<FaultSpec>,
    /// Per-(spec index, site hash) visit counters driving the draws.
    counters: HashMap<(usize, u64), u64>,
    /// Per-site fired tally, for the admin read-out.
    fired: HashMap<String, u64>,
    checks: u64,
    fired_total: u64,
}

impl Plane {
    fn clear(&mut self) {
        self.specs.clear();
        self.counters.clear();
        self.fired.clear();
        self.checks = 0;
        self.fired_total = 0;
    }
}

fn plane() -> &'static Mutex<Plane> {
    static PLANE: OnceLock<Mutex<Plane>> = OnceLock::new();
    PLANE.get_or_init(|| {
        Mutex::new(Plane {
            specs: Vec::new(),
            counters: HashMap::new(),
            fired: HashMap::new(),
            checks: 0,
            fired_total: 0,
        })
    })
}

fn lock_plane() -> std::sync::MutexGuard<'static, Plane> {
    // Poisoning is recoverable here: the plane holds plain counters.
    plane().lock().unwrap_or_else(|e| e.into_inner())
}

/// Parse `input` and install it as the process-global fault
/// configuration, replacing whatever was installed before and resetting
/// all visit counters. An empty input disables the plane. Returns the
/// number of installed specs.
pub fn configure(input: &str) -> anyhow::Result<usize> {
    let specs = parse_specs(input)?;
    let n = specs.len();
    let mut plane = lock_plane();
    plane.clear();
    plane.specs = specs;
    drop(plane);
    ENABLED.store(n > 0, Ordering::Relaxed);
    Ok(n)
}

/// Disable the plane and clear all specs and counters.
pub fn reset() {
    ENABLED.store(false, Ordering::Relaxed);
    lock_plane().clear();
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Enabled-path body of [`point!`]: evaluate the installed specs at
/// `site`. The first spec (in install order) whose deterministic draw
/// fires wins; every matching spec's counter advances regardless, so
/// the draw stream at each site is independent of the others.
pub fn trigger(site: &str) -> Option<FaultKind> {
    let site_hash = fnv1a(site);
    let mut plane = lock_plane();
    plane.checks += 1;
    let mut hit = None;
    for i in 0..plane.specs.len() {
        if !plane.specs[i].matches(site) {
            continue;
        }
        let n = {
            let c = plane.counters.entry((i, site_hash)).or_insert(0);
            let n = *c;
            *c += 1;
            n
        };
        let spec = &plane.specs[i];
        let draw = splitmix64(spec.seed ^ site_hash ^ n.wrapping_mul(0x2545_F491_4F6C_DD1D));
        // Top 53 bits → uniform in [0, 1).
        let u = (draw >> 11) as f64 / (1u64 << 53) as f64;
        if hit.is_none() && u < spec.p {
            hit = Some(spec.kind);
        }
    }
    if let Some(kind) = hit {
        plane.fired_total += 1;
        *plane.fired.entry(site.to_string()).or_insert(0) += 1;
        crate::warn_log!(
            "fault: injected kind={} at site={site}",
            kind.name()
        );
    }
    hit
}

/// Bounded deterministic spin used by the `latency` kind. No clocks —
/// the iteration count is fixed so replays stay deterministic.
pub fn latency_spin() {
    for i in 0u64..20_000 {
        std::hint::black_box(i);
        std::hint::spin_loop();
    }
}

/// Standard handling for fallible sites: `err`/`alloc` return an
/// injected error, `latency` spins then proceeds, and kinds the site
/// cannot express are no-ops.
pub fn apply_fallible(site: &str, kind: FaultKind) -> anyhow::Result<()> {
    match kind {
        FaultKind::Err | FaultKind::Alloc => Err(injected(site, kind)),
        FaultKind::Latency => {
            latency_spin();
            Ok(())
        }
        FaultKind::CorruptLogits | FaultKind::CorruptAdapter => Ok(()),
    }
}

/// Standard handling for infallible sites that degrade gracefully
/// (e.g. prefix-cache claim → miss). Returns `true` when the site
/// should take its degraded path; `latency` spins and returns `false`.
pub fn degrades(kind: FaultKind) -> bool {
    match kind {
        FaultKind::Latency => {
            latency_spin();
            false
        }
        _ => true,
    }
}

/// The error an injected `err`/`alloc` fault surfaces as.
pub fn injected(site: &str, kind: FaultKind) -> anyhow::Error {
    anyhow::anyhow!("injected fault at site {site} (kind {})", kind.name())
}

/// JSON snapshot for the admin `/fault` route: installed specs plus
/// visit/fire tallies. Read-only; the admin endpoint stays POST-free.
pub fn status_json() -> String {
    let plane = lock_plane();
    let specs = plane
        .specs
        .iter()
        .map(|s| {
            Json::Obj(vec![
                ("site".into(), Json::Str(s.site.clone())),
                ("p".into(), Json::Num(s.p)),
                ("kind".into(), Json::Str(s.kind.name().into())),
                ("seed".into(), Json::Num(s.seed as f64)),
            ])
        })
        .collect::<Vec<_>>();
    let mut fired = plane
        .fired
        .iter()
        .map(|(site, n)| (site.clone(), *n))
        .collect::<Vec<_>>();
    fired.sort();
    let fired = fired
        .into_iter()
        .map(|(site, n)| (site, Json::Num(n as f64)))
        .collect::<Vec<_>>();
    Json::Obj(vec![
        ("enabled".into(), Json::Bool(enabled())),
        ("specs".into(), Json::Arr(specs)),
        ("checks".into(), Json::Num(plane.checks as f64)),
        ("fired_total".into(), Json::Num(plane.fired_total as f64)),
        ("fired_by_site".into(), Json::Obj(fired)),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The plane is process-global and these tests mutate it, so they
    // serialize on one lock and use `testonly.*` site names that no
    // serving-path site matches — concurrently running server tests in
    // this binary stay unperturbed.
    static SERIAL: Mutex<()> = Mutex::new(());

    struct PlaneGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

    impl<'a> PlaneGuard<'a> {
        fn new() -> Self {
            let g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
            reset();
            PlaneGuard(g)
        }
    }

    impl Drop for PlaneGuard<'_> {
        fn drop(&mut self) {
            reset();
        }
    }

    #[test]
    fn grammar_parses_full_and_defaulted_specs() {
        let specs =
            parse_specs("site=kv.seal,p=0.01,kind=err,seed=7; site=testonly.*,kind=latency")
                .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].site, "kv.seal");
        assert!((specs[0].p - 0.01).abs() < 1e-12);
        assert_eq!(specs[0].kind, FaultKind::Err);
        assert_eq!(specs[0].seed, 7);
        assert_eq!(specs[1].site, "testonly.*");
        assert_eq!(specs[1].p, 1.0);
        assert_eq!(specs[1].kind, FaultKind::Latency);
        assert_eq!(specs[1].seed, 0);
        assert!(parse_specs("").unwrap().is_empty());
    }

    #[test]
    fn grammar_rejects_malformed_specs() {
        assert!(parse_specs("p=0.5").is_err()); // missing site
        assert!(parse_specs("site=a,p=1.5").is_err()); // p out of range
        assert!(parse_specs("site=a,kind=explode").is_err()); // unknown kind
        assert!(parse_specs("site=a,seed=x").is_err()); // bad seed
        assert!(parse_specs("site=a,wat=1").is_err()); // unknown key
        assert!(parse_specs("site=a p=1").is_err()); // not key=value
    }

    #[test]
    fn disabled_plane_never_fires() {
        let _g = PlaneGuard::new();
        assert!(!enabled());
        for _ in 0..100 {
            assert_eq!(crate::fault::point!("testonly.off"), None);
        }
    }

    #[test]
    fn p_one_always_fires_and_p_zero_never_does() {
        let _g = PlaneGuard::new();
        configure("site=testonly.hot,p=1,kind=alloc;site=testonly.cold,p=0").unwrap();
        assert!(enabled());
        for _ in 0..10 {
            assert_eq!(crate::fault::point!("testonly.hot"), Some(FaultKind::Alloc));
            assert_eq!(crate::fault::point!("testonly.cold"), None);
        }
    }

    #[test]
    fn wildcard_patterns_match_prefixes() {
        let _g = PlaneGuard::new();
        configure("site=testonly.*,p=1,kind=err").unwrap();
        assert_eq!(trigger("testonly.a"), Some(FaultKind::Err));
        assert_eq!(trigger("testonly.b.c"), Some(FaultKind::Err));
        assert_eq!(trigger("other.site"), None);
    }

    #[test]
    fn same_seed_replays_the_same_firing_pattern() {
        let _g = PlaneGuard::new();
        let run = || {
            configure("site=testonly.rep,p=0.3,kind=err,seed=42").unwrap();
            (0..200)
                .map(|_| trigger("testonly.rep").is_some())
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.iter().any(|f| *f), "p=0.3 over 200 visits should fire");
        assert!(!a.iter().all(|f| *f), "p=0.3 should not always fire");

        // A different seed gives a different schedule.
        configure("site=testonly.rep,p=0.3,kind=err,seed=43").unwrap();
        let c = (0..200)
            .map(|_| trigger("testonly.rep").is_some())
            .collect::<Vec<_>>();
        assert_ne!(a, c);
    }

    #[test]
    fn per_site_counters_are_independent() {
        let _g = PlaneGuard::new();
        // Visits to one site must not shift another site's draw stream.
        configure("site=testonly.*,p=0.5,kind=err,seed=9").unwrap();
        let a1 = (0..50)
            .map(|_| trigger("testonly.x").is_some())
            .collect::<Vec<_>>();
        configure("site=testonly.*,p=0.5,kind=err,seed=9").unwrap();
        for _ in 0..17 {
            trigger("testonly.noise");
        }
        let a2 = (0..50)
            .map(|_| trigger("testonly.x").is_some())
            .collect::<Vec<_>>();
        assert_eq!(a1, a2);
    }

    #[test]
    fn status_json_reports_specs_and_tallies() {
        let _g = PlaneGuard::new();
        configure("site=testonly.stat,p=1,kind=latency,seed=3").unwrap();
        trigger("testonly.stat");
        let parsed = Json::parse(&status_json()).unwrap();
        assert_eq!(parsed.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(
            parsed.get("specs").unwrap().as_arr().unwrap()[0].get("kind"),
            Some(&Json::Str("latency".into()))
        );
        assert_eq!(
            parsed.get("fired_by_site").unwrap().get("testonly.stat"),
            Some(&Json::Num(1.0))
        );
        reset();
        let parsed = Json::parse(&status_json()).unwrap();
        assert_eq!(parsed.get("enabled"), Some(&Json::Bool(false)));
        assert_eq!(parsed.get("checks"), Some(&Json::Num(0.0)));
    }

    #[test]
    fn helper_semantics_match_their_docs() {
        assert!(apply_fallible("testonly.h", FaultKind::Err).is_err());
        assert!(apply_fallible("testonly.h", FaultKind::Alloc).is_err());
        assert!(apply_fallible("testonly.h", FaultKind::Latency).is_ok());
        assert!(apply_fallible("testonly.h", FaultKind::CorruptLogits).is_ok());
        assert!(degrades(FaultKind::Err));
        assert!(degrades(FaultKind::CorruptAdapter));
        assert!(!degrades(FaultKind::Latency));
    }
}
