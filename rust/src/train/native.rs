//! Native trainer: drives `model::Model` (manual backprop) with AdamW and
//! the paper's schedules. Handles all three regimes — the regime is implied
//! by the model's linear representations:
//!
//! * Dense linears            → pre-training (all params trained)
//! * Lords without shadow W   → PEFT (B/A only)
//! * Lords with shadow W      → QAT (W + B/A via STE)
//! * QLoRA                    → adapter-only fine-tuning

use crate::config::TrainCfg;
use crate::data::corpus::Corpus;
use crate::model::transformer::{LayerGrads, ModelGrads};
use crate::model::{LinearWeight, Model};
use crate::optim::{AdamW, CosineWarmup, LrSchedule, Optimizer};
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainKind {
    Pretrain,
    Qat,
    Peft,
}

/// Loss trace of a run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub steps: usize,
}

pub struct NativeTrainer {
    pub cfg: TrainCfg,
    pub kind: TrainKind,
    opt: AdamW,
    sched: CosineWarmup,
}

impl NativeTrainer {
    pub fn new(cfg: TrainCfg, kind: TrainKind) -> Self {
        let sched = CosineWarmup::new(cfg.peak_lr, cfg.warmup_ratio);
        NativeTrainer { opt: AdamW::new(cfg.weight_decay), sched, cfg, kind }
    }

    /// Run the loop on `model` sampling batches from `corpus`.
    pub fn run(&mut self, model: &mut Model, corpus: &Corpus) -> TrainLog {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7124);
        let mut log = TrainLog::default();
        for step in 0..self.cfg.steps {
            let (tokens, targets) = corpus.sample_batch(self.cfg.batch, self.cfg.seq, &mut rng);
            let loss = self.step(model, &tokens, &targets);
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                log.losses.push((step, loss));
                crate::info!("{:?} step {step}/{} loss {loss:.4}", self.kind, self.cfg.steps);
            }
            log.final_loss = loss;
        }
        log.steps = self.cfg.steps;
        if self.kind == TrainKind::Qat {
            // bake shadow weights into final codes
            for layer in model.layers.iter_mut() {
                for (_, lw) in layer.linears_mut() {
                    lw.finalize_qat();
                }
            }
        }
        log
    }

    /// Package the PEFT-tuned (B′, A′) scale factors of `model` as a named,
    /// servable adapter artifact — the hand-off from fine-tuning to the
    /// multi-tenant serving side (`adapters::AdapterRegistry`). The packed
    /// codes stay with the shared base; the artifact carries only the
    /// rank-r factors (~r·(n+m) floats per linear).
    pub fn export_adapter(
        &self,
        model: &Model,
        id: &str,
    ) -> anyhow::Result<crate::adapters::AdapterArtifact> {
        anyhow::ensure!(
            self.kind == TrainKind::Peft,
            "adapter export is a PEFT-path operation (trainer kind is {:?})",
            self.kind
        );
        crate::adapters::AdapterArtifact::from_model(model, id)
    }

    /// One optimization step on an explicit batch; returns the loss.
    pub fn step(&mut self, model: &mut Model, tokens: &[usize], targets: &[usize]) -> f32 {
        let (loss, grads) = model.loss_and_grads(tokens, targets, self.cfg.batch, tokens.len() / self.cfg.batch);
        let lr = self.sched.lr(self.opt.current_step(), self.cfg.steps as u64);
        self.apply(model, &grads, lr);
        loss
    }

    /// Apply gradients with stable slot ids (layer-major, field-major).
    fn apply(&mut self, model: &mut Model, grads: &ModelGrads, lr: f32) {
        let train_embeddings = self.kind == TrainKind::Pretrain;
        let mut slot = 0usize;
        // embeddings + head + final norm only in pre-training
        if train_embeddings {
            if let Some(g) = &grads.tok_emb {
                self.opt.step(slot, &mut model.tok_emb.data, &g.data, lr);
            }
            slot += 1;
            if let Some(g) = &grads.lm_head {
                self.opt.step(slot, &mut model.lm_head.data, &g.data, lr);
            }
            slot += 1;
            self.opt.step(slot, &mut model.final_norm, &grads.final_norm, lr);
            slot += 1;
        } else {
            slot += 3;
        }
        for (li, layer) in model.layers.iter_mut().enumerate() {
            let lg: &LayerGrads = &grads.layers[li];
            if train_embeddings {
                self.opt.step(slot, &mut layer.attn_norm, &lg.attn_norm, lr);
            }
            slot += 1;
            if train_embeddings {
                self.opt.step(slot, &mut layer.mlp_norm, &lg.mlp_norm, lr);
            }
            slot += 1;
            let fields = [
                (&mut layer.wq, &lg.wq),
                (&mut layer.wk, &lg.wk),
                (&mut layer.wv, &lg.wv),
                (&mut layer.wo, &lg.wo),
                (&mut layer.w_gate, &lg.w_gate),
                (&mut layer.w_up, &lg.w_up),
                (&mut layer.w_down, &lg.w_down),
            ];
            for (lw, g) in fields {
                match lw {
                    LinearWeight::Dense(w) => {
                        if let Some(dw) = &g.d_w {
                            self.opt.step(slot, &mut w.data, &dw.data, lr);
                        }
                        slot += 3;
                    }
                    LinearWeight::Lords { q, shadow_w } => {
                        if let Some(db) = &g.d_b {
                            self.opt.step(slot, &mut q.b.data, &db.data, lr);
                        }
                        slot += 1;
                        if let Some(da) = &g.d_a {
                            self.opt.step(slot, &mut q.a.data, &da.data, lr);
                        }
                        slot += 1;
                        if let (Some(w), Some(dw)) = (shadow_w.as_mut(), g.d_w.as_ref()) {
                            self.opt.step(slot, &mut w.data, &dw.data, lr);
                        }
                        slot += 1;
                    }
                    LinearWeight::Blockwise(_) => {
                        slot += 3;
                    }
                    LinearWeight::Qlora(q) => {
                        if let Some(dlb) = &g.d_lora_b {
                            self.opt.step(slot, &mut q.lora_b.data, &dlb.data, lr);
                        }
                        slot += 1;
                        if let Some(dla) = &g.d_lora_a {
                            self.opt.step(slot, &mut q.lora_a.data, &dla.data, lr);
                        }
                        slot += 2;
                    }
                }
            }
        }
        self.opt.next_step();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::data::corpus::CorpusKind;
    use crate::quant::lords::RefineCfg;
    use crate::quant::Codebook;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 48,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        }
    }

    fn train_cfg(steps: usize, lr: f32) -> TrainCfg {
        TrainCfg { steps, batch: 4, seq: 16, peak_lr: lr, warmup_ratio: 0.1, weight_decay: 0.0, seed: 0, log_every: 1000 }
    }

    #[test]
    fn pretraining_reduces_loss() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 0);
        let corpus = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 8000, 1000, 0);
        let mut tr = NativeTrainer::new(train_cfg(40, 3e-3), TrainKind::Pretrain);
        let log = tr.run(&mut model, &corpus);
        let first = log.losses.first().unwrap().1;
        assert!(
            log.final_loss < first - 0.2,
            "loss did not decrease: {first} -> {}",
            log.final_loss
        );
    }

    #[test]
    fn peft_improves_quantized_model_loss() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 1);
        let corpus = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 8000, 1000, 1);
        // brief pretrain so there is something to preserve
        let mut tr = NativeTrainer::new(train_cfg(30, 3e-3), TrainKind::Pretrain);
        tr.run(&mut model, &corpus);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 5, ..Default::default() }, false);
        let before = crate::eval::perplexity(&model, &corpus, 16, 4).ppl;
        let mut peft = NativeTrainer::new(train_cfg(25, 2e-3), TrainKind::Peft);
        let log = peft.run(&mut model, &corpus);
        let after = crate::eval::perplexity(&model, &corpus, 16, 4).ppl;
        assert!(log.final_loss.is_finite());
        assert!(after < before * 1.05, "PEFT hurt badly: {before} -> {after}");
    }

    #[test]
    fn peft_does_not_touch_codes_or_frozen_parts() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 2);
        let corpus = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 6000, 500, 2);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        let codes_before = if let LinearWeight::Lords { q, .. } = &model.layers[0].wq {
            q.codes.clone()
        } else {
            unreachable!()
        };
        let emb_before = model.tok_emb.clone();
        let b_before = if let LinearWeight::Lords { q, .. } = &model.layers[0].wq {
            q.b.clone()
        } else {
            unreachable!()
        };
        let mut peft = NativeTrainer::new(train_cfg(5, 2e-3), TrainKind::Peft);
        peft.run(&mut model, &corpus);
        if let LinearWeight::Lords { q, .. } = &model.layers[0].wq {
            assert_eq!(q.codes, codes_before, "codes must stay frozen");
            assert_ne!(q.b.data, b_before.data, "B must move");
        }
        assert_eq!(model.tok_emb.data, emb_before.data, "embeddings frozen in PEFT");
    }

    #[test]
    fn peft_run_exports_a_servable_adapter() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 5);
        let corpus = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 6000, 500, 5);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        let pristine = crate::adapters::AdapterFactors::from_model(&model);
        let mut peft = NativeTrainer::new(train_cfg(5, 2e-3), TrainKind::Peft);
        peft.run(&mut model, &corpus);
        let art = peft.export_adapter(&model, "tenant-a").unwrap();
        assert_eq!(art.id, "tenant-a");
        assert_ne!(art.factors, pristine, "PEFT must have moved the factors");
        // the artifact applies cleanly onto a fresh copy of the same base
        let mut fresh = Model::init(&cfg, 5);
        fresh.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        art.factors.validate_against(&fresh).unwrap();
        art.factors.apply_to(&mut fresh).unwrap();
        assert_eq!(crate::adapters::AdapterFactors::from_model(&fresh), art.factors);
        // a pre-training trainer must refuse to export
        let pre = NativeTrainer::new(train_cfg(1, 1e-3), TrainKind::Pretrain);
        assert!(pre.export_adapter(&model, "x").is_err());
    }

    #[test]
    fn qat_trains_and_finalizes() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 3);
        let corpus = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 6000, 500, 3);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, true);
        let mut qat = NativeTrainer::new(train_cfg(10, 1e-3), TrainKind::Qat);
        let log = qat.run(&mut model, &corpus);
        assert!(log.final_loss.is_finite());
        // after run(), shadow weights are absorbed
        for layer in &model.layers {
            for (_, lw) in layer.linears() {
                if let LinearWeight::Lords { shadow_w, .. } = lw {
                    assert!(shadow_w.is_none(), "QAT must finalize");
                }
            }
        }
    }
}
