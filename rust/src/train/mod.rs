//! Training loops: full-precision pre-training (builds the testbed
//! checkpoints), QAT (STE joint training of W, B, A — §4.2), and PEFT
//! (B/A-only multiplicative adaptation — §4.3).
//!
//! Two engines share these loops:
//! * [`native`]  — the pure-Rust model (manual backprop), always available.
//! * [`pjrt`]    — the AOT train-step artifacts executed through the
//!   runtime; the optimizer still lives here in Rust.

pub mod native;
pub mod pjrt;

pub use native::{NativeTrainer, TrainKind, TrainLog};
