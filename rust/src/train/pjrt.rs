//! PJRT-backed trainer: executes the AOT train-step artifacts (`fp_step`,
//! `qat_step`, `peft_step`) through the executor; the forward/backward runs
//! as XLA-compiled code, the AdamW update stays in Rust.
//!
//! This is the fast path for the QAT/PEFT experiments: JAX autodiff and the
//! custom STE vjp are frozen into the artifact, so the Rust side only
//! marshals parameters and applies updates.

use crate::config::TrainCfg;
use crate::data::corpus::Corpus;
use crate::optim::{AdamW, CosineWarmup, LrSchedule, Optimizer};
use crate::runtime::{ExecutorHandle, HostTensor};
use crate::util::Rng;

use super::native::TrainLog;

pub struct PjrtTrainer {
    pub cfg: TrainCfg,
    pub artifact: String,
    handle: ExecutorHandle,
    /// (name, tensor) in artifact input order (params only).
    pub params: Vec<(String, HostTensor)>,
    /// indices of trainable params (grads come back in this order).
    pub trainable: Vec<usize>,
    opt: AdamW,
    sched: CosineWarmup,
}

impl PjrtTrainer {
    /// Build from the manifest signature: trainable params are inferred from
    /// the artifact's *output* list (out k+1 corresponds to trainable k, as
    /// emitted by aot.py: loss first, then grads in trainable order).
    ///
    /// We identify trainables by suffix, matching `model.py`:
    /// `peft_step` → `.B` / `.A`;  `qat_step` → linear W, `.B`, `.A`;
    /// `fp_step` → every param.
    pub fn new(
        handle: ExecutorHandle,
        artifact: &str,
        cfg: TrainCfg,
        params: Vec<(String, HostTensor)>,
    ) -> Self {
        let trainable: Vec<usize> = match artifact {
            "peft_step" => params
                .iter()
                .enumerate()
                .filter(|(_, (n, _))| n.ends_with(".B") || n.ends_with(".A"))
                .map(|(i, _)| i)
                .collect(),
            "qat_step" => params
                .iter()
                .enumerate()
                .filter(|(_, (n, _))| {
                    n.ends_with(".B")
                        || n.ends_with(".A")
                        || (n.contains(".w") && !n.contains("norm") && !n.ends_with(".codes"))
                })
                .map(|(i, _)| i)
                .collect(),
            _ => (0..params.len()).collect(),
        };
        // qat trainables must be ordered (w, B, A) per linear — model.py's
        // qat_trainable order. Reorder accordingly.
        let trainable = if artifact == "qat_step" {
            let mut ordered = Vec::new();
            let names: Vec<&String> = params.iter().map(|(n, _)| n).collect();
            for (i, n) in names.iter().enumerate() {
                if n.contains(".w") && !n.contains('.') {
                    let _ = i; // unreachable: linears always contain '.'
                }
            }
            // group by linear base name in appearance order
            let mut bases = Vec::new();
            for n in &names {
                if let Some(base) = n.strip_suffix(".B") {
                    if !bases.contains(&base.to_string()) {
                        bases.push(base.to_string());
                    }
                }
            }
            for base in &bases {
                for suffix in ["", ".B", ".A"] {
                    let want = format!("{base}{suffix}");
                    if let Some(i) = names.iter().position(|n| **n == want) {
                        ordered.push(i);
                    }
                }
            }
            if ordered.is_empty() {
                trainable
            } else {
                ordered
            }
        } else {
            trainable
        };
        let sched = CosineWarmup::new(cfg.peak_lr, cfg.warmup_ratio);
        PjrtTrainer {
            artifact: artifact.to_string(),
            handle,
            params,
            trainable,
            opt: AdamW::new(cfg.weight_decay),
            sched,
            cfg,
        }
    }

    /// One step on an explicit batch; returns the loss.
    pub fn step(&mut self, tokens: &[usize], targets: &[usize]) -> anyhow::Result<f32> {
        let b = self.cfg.batch;
        let s = self.cfg.seq;
        anyhow::ensure!(tokens.len() == b * s, "batch shape");
        let mut inputs: Vec<HostTensor> = self.params.iter().map(|(_, t)| t.clone()).collect();
        inputs.push(HostTensor::I32(tokens.iter().map(|&t| t as i32).collect(), vec![b, s]));
        inputs.push(HostTensor::I32(targets.iter().map(|&t| t as i32).collect(), vec![b, s]));
        let outputs = self.handle.execute(&self.artifact, inputs)?;
        let loss = outputs[0].f32s()[0];
        anyhow::ensure!(
            outputs.len() == 1 + self.trainable.len(),
            "grad count {} vs trainable {}",
            outputs.len() - 1,
            self.trainable.len()
        );
        let lr = self.sched.lr(self.opt.current_step(), self.cfg.steps as u64);
        for (k, &pi) in self.trainable.iter().enumerate() {
            let grad = outputs[1 + k].f32s();
            if let HostTensor::F32(data, _) = &mut self.params[pi].1 {
                self.opt.step(pi, data, grad, lr);
            }
        }
        self.opt.next_step();
        Ok(loss)
    }

    /// Full loop sampling from a corpus.
    pub fn run(&mut self, corpus: &Corpus) -> anyhow::Result<TrainLog> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x9A17);
        let mut log = TrainLog::default();
        for step in 0..self.cfg.steps {
            let (tokens, targets) = corpus.sample_batch(self.cfg.batch, self.cfg.seq, &mut rng);
            let loss = self.step(&tokens, &targets)?;
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                log.losses.push((step, loss));
                crate::info!("pjrt:{} step {step}/{} loss {loss:.4}", self.artifact, self.cfg.steps);
            }
            log.final_loss = loss;
        }
        log.steps = self.cfg.steps;
        Ok(log)
    }

    /// Updated named parameters (to write back into a native model).
    pub fn trained_params(&self) -> Vec<(String, &HostTensor)> {
        self.trainable
            .iter()
            .map(|&i| (self.params[i].0.clone(), &self.params[i].1))
            .collect()
    }
}
