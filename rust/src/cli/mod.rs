//! Dependency-free command-line parsing (`clap` substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed getters and generated `--help` text.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse the process args; the first non-flag token is the subcommand.
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Subcommand descriptor for help rendering.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
}

pub fn render_help(bin: &str, about: &str, cmds: &[Command]) -> String {
    let mut s = format!("{about}\n\nUSAGE: {bin} <command> [--flags]\n\nCOMMANDS:\n");
    for c in cmds {
        s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
    }
    s.push_str("\nCommon flags: --seed N  --threads N  --artifacts DIR  --config FILE\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // greedy `--key value` semantics: positionals go before flags, and a
        // boolean flag either trails or uses the `=` form.
        let a = parse("quantize input.bin --method lords --block 128 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.get("method"), Some("lords"));
        assert_eq!(a.get_usize("block", 64), 128);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["input.bin"]);
        let b = parse("quantize --verbose=true input.bin");
        assert!(b.get_bool("verbose"));
        assert_eq!(b.positional, vec!["input.bin"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --port=8080 --rate=2.5");
        assert_eq!(a.get_usize("port", 0), 8080);
        assert!((a.get_f32("rate", 0.0) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("method", "nf4"), "nf4");
        assert_eq!(a.get_usize("block", 64), 64);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --fast");
        assert!(a.get_bool("fast"));
    }
}
