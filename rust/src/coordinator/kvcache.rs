//! Paged KV-cache block allocator (admission control + block ownership).
//!
//! The cache budget is divided into fixed-size token blocks; a sequence of
//! length L holds ⌈L / block_tokens⌉ blocks. The allocator decides
//! admission (can a new sequence's worst case fit?) and tracks
//! per-sequence block lists so completion frees exactly what was taken.
//!
//! Blocks are **ref-counted** so sealed prefix blocks can be shared: a
//! freshly reserved block has refcount 1 (its owner); [`Self::attach`]
//! lets a new sequence adopt another owner's sealed blocks as its own
//! prefix (refcount +1 per block), and the prefix cache holds refs of its
//! own via [`Self::retain`]/[`Self::release_ref`]. A block returns to the
//! free list only when its last reference drops. Writers must never
//! mutate a block with refcount > 1 — [`Self::cow_swap`] is the
//! copy-on-write escape hatch that gives a sequence a private replacement
//! for one slot of its ownership list.
//!
//! Invariants (property-tested): never exceeds capacity, no double-free,
//! every block's refcount equals the number of owning sequences plus
//! external retains, `used + free == capacity` with shared blocks counted
//! once.
//!
//! Since the quantized paged KV-cache landed, these block ids are **real
//! storage handles**: [`KvPool`](crate::kvquant::KvPool) embeds an
//! allocator and maps each owned id to that block's K/V tile slots.
//! [`Self::owned_blocks`] exposes a sequence's id list (in reservation
//! order — block *i* of a sequence holds tokens
//! `[i·block_tokens, (i+1)·block_tokens)`), and [`Self::try_release`] is
//! the recoverable release the server path uses (a stray release of an
//! unknown sequence must not panic mid-serve).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct KvBlockAllocator {
    /// total blocks in the pool.
    capacity: usize,
    /// tokens per block.
    pub block_tokens: usize,
    free: Vec<usize>,
    owned: HashMap<u64, Vec<usize>>,
    /// per-block reference count; 0 ⇔ on the free list.
    refs: Vec<u32>,
}

impl KvBlockAllocator {
    pub fn new(capacity: usize, block_tokens: usize) -> KvBlockAllocator {
        KvBlockAllocator {
            capacity,
            block_tokens,
            free: (0..capacity).rev().collect(),
            owned: HashMap::new(),
            refs: vec![0; capacity],
        }
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Can a sequence with this worst-case token count be admitted?
    pub fn can_admit(&self, worst_case_tokens: usize) -> bool {
        self.blocks_for(worst_case_tokens) <= self.free.len()
    }

    /// Reserve blocks for sequence `seq` to cover `tokens` total tokens.
    /// Grows the existing reservation; returns false (no change) if the pool
    /// cannot satisfy it. Fresh blocks start at refcount 1 (the owner).
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        let have = self.owned.get(&seq).map(|v| v.len()).unwrap_or(0);
        if need <= have {
            return true;
        }
        let extra = need - have;
        if extra > self.free.len() {
            return false;
        }
        let list = self.owned.entry(seq).or_default();
        for _ in 0..extra {
            // PANIC-OK: `extra <= free.len()` was checked just above and
            // nothing pushes to `owned` in between.
            let b = self.free.pop().unwrap();
            debug_assert_eq!(self.refs[b], 0);
            self.refs[b] = 1;
            list.push(b);
        }
        true
    }

    /// Make brand-new sequence `seq` a co-owner of `blocks` (a shared
    /// prefix, in token order). Every block must be live (refcount ≥ 1);
    /// `seq` must not already own anything. Returns false (no change) on
    /// violation. Subsequent [`Self::reserve`] calls grow past the prefix.
    pub fn attach(&mut self, seq: u64, blocks: &[usize]) -> bool {
        if self.owned.contains_key(&seq) {
            return false;
        }
        if blocks.iter().any(|&b| b >= self.capacity || self.refs[b] == 0) {
            return false;
        }
        for &b in blocks {
            self.refs[b] += 1;
        }
        self.owned.insert(seq, blocks.to_vec());
        true
    }

    /// Current reference count of a block (0 = free).
    pub fn refcount(&self, block: usize) -> usize {
        self.refs.get(block).map(|&r| r as usize).unwrap_or(0)
    }

    /// Take an extra (non-sequence) reference on a live block — used by the
    /// prefix cache to keep sealed prompt blocks alive after their last
    /// owning sequence releases. Returns false if the block is free.
    pub fn retain(&mut self, block: usize) -> bool {
        if block >= self.capacity || self.refs[block] == 0 {
            return false;
        }
        self.refs[block] += 1;
        true
    }

    /// Drop one reference on a live block. Returns true iff that was the
    /// last reference (the block is now free and its storage slots must be
    /// cleared by the caller).
    pub fn release_ref(&mut self, block: usize) -> bool {
        assert!(block < self.capacity && self.refs[block] > 0, "release_ref on free block {block}");
        self.refs[block] -= 1;
        if self.refs[block] == 0 {
            self.free.push(block);
            debug_assert!(self.free.len() <= self.capacity);
            true
        } else {
            false
        }
    }

    /// Copy-on-write: replace slot `index` of `seq`'s ownership list with a
    /// fresh private block (refcount 1), dropping the sequence's reference
    /// on the shared original. Returns the fresh id, or `None` (no change)
    /// if the pool is exhausted or `seq`/`index` is unknown. The caller
    /// re-seals its data into the fresh block; the original stays intact
    /// for its remaining owners.
    pub fn cow_swap(&mut self, seq: u64, index: usize) -> Option<usize> {
        let have = self.owned.get(&seq).map(|v| v.len()).unwrap_or(0);
        if index >= have || self.free.is_empty() {
            return None;
        }
        // PANIC-OK: `free` was checked non-empty and `seq` was checked to
        // own > `index` blocks just above.
        let fresh = self.free.pop().unwrap();
        debug_assert_eq!(self.refs[fresh], 0);
        self.refs[fresh] = 1;
        // PANIC-OK: `have > index` above proves `seq` is a resident key.
        let old = std::mem::replace(&mut self.owned.get_mut(&seq).unwrap()[index], fresh);
        let was_last = self.release_ref(old);
        debug_assert!(!was_last, "cow_swap on an unshared block {old} (callers should write in place)");
        Some(fresh)
    }

    /// Blocks owned by `seq`, in reservation order (block `i` covers
    /// tokens `[i·block_tokens, (i+1)·block_tokens)`). Empty for unknown
    /// sequences.
    pub fn owned_blocks(&self, seq: u64) -> &[usize] {
        self.owned.get(&seq).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Release `seq`'s ownership of all its blocks, returning the ids whose
    /// refcount hit zero (fully freed — a storage-backed caller clears
    /// exactly those slots; shared blocks live on under their other
    /// references). `None` (and no change) for unknown sequences — the
    /// recoverable form the server path uses.
    pub fn try_release(&mut self, seq: u64) -> Option<Vec<usize>> {
        let blocks = self.owned.remove(&seq)?;
        let mut freed = Vec::with_capacity(blocks.len());
        for b in blocks {
            if self.release_ref(b) {
                freed.push(b);
            }
        }
        Some(freed)
    }

    /// Release all blocks owned by `seq`. Panics on double-free (strict
    /// variant for callers that own the bookkeeping; serve paths use
    /// [`Self::try_release`]).
    pub fn release(&mut self, seq: u64) {
        if self.try_release(seq).is_none() {
            // PANIC-OK: the strict variant exists to turn double-frees into
            // loud bookkeeping bugs; serve paths call `try_release`.
            panic!("double free of seq {seq}");
        }
    }

    pub fn active_sequences(&self) -> usize {
        self.owned.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn basic_reserve_release() {
        let mut a = KvBlockAllocator::new(10, 16);
        assert!(a.reserve(1, 40)); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        assert!(a.reserve(1, 50)); // grow to 4
        assert_eq!(a.used_blocks(), 4);
        assert!(a.reserve(1, 20)); // shrink request = no-op
        assert_eq!(a.used_blocks(), 4);
        a.release(1);
        assert_eq!(a.used_blocks(), 0);
    }

    #[test]
    fn admission_respects_capacity() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.can_admit(32));
        assert!(!a.can_admit(33));
        assert!(a.reserve(1, 24)); // 3 blocks
        assert!(!a.reserve(2, 16)); // needs 2, only 1 free
        assert!(a.reserve(2, 8));
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = KvBlockAllocator::new(4, 8);
        a.reserve(7, 8);
        a.release(7);
        a.release(7);
    }

    #[test]
    fn try_release_is_recoverable_and_returns_ids() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.try_release(7).is_none(), "unknown seq is a no-op");
        a.reserve(7, 24); // 3 blocks
        let owned: Vec<usize> = a.owned_blocks(7).to_vec();
        assert_eq!(owned.len(), 3);
        let freed = a.try_release(7).unwrap();
        assert_eq!(freed, owned, "released ids match ownership order");
        assert!(a.try_release(7).is_none(), "second release is recoverable");
        assert_eq!(a.free_blocks(), 4);
        assert!(a.owned_blocks(7).is_empty());
    }

    #[test]
    fn owned_blocks_grow_in_order() {
        let mut a = KvBlockAllocator::new(8, 4);
        a.reserve(1, 4);
        let first = a.owned_blocks(1).to_vec();
        a.reserve(1, 12);
        let grown = a.owned_blocks(1).to_vec();
        assert_eq!(grown.len(), 3);
        assert_eq!(&grown[..1], &first[..], "growth appends, never reorders");
    }

    #[test]
    fn attach_shares_blocks_without_consuming_capacity() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.reserve(1, 16)); // 2 blocks
        let prefix = a.owned_blocks(1).to_vec();
        assert!(a.attach(2, &prefix), "fresh seq adopts live blocks");
        assert_eq!(a.used_blocks(), 2, "shared blocks counted once");
        assert_eq!(a.refcount(prefix[0]), 2);
        assert!(!a.attach(2, &prefix), "attach refuses a known seq");
        assert!(a.reserve(2, 24), "growth appends past the shared prefix");
        assert_eq!(a.owned_blocks(2).len(), 3);
        assert_eq!(&a.owned_blocks(2)[..2], &prefix[..]);

        // releasing the original owner keeps shared blocks alive
        let freed = a.try_release(1).unwrap();
        assert!(freed.is_empty(), "shared blocks must not be freed");
        assert_eq!(a.refcount(prefix[0]), 1);
        let freed = a.try_release(2).unwrap();
        assert_eq!(freed.len(), 3, "last owner frees everything");
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    fn retain_keeps_block_alive_past_release() {
        let mut a = KvBlockAllocator::new(2, 8);
        a.reserve(1, 8);
        let b = a.owned_blocks(1)[0];
        assert!(a.retain(b));
        let freed = a.try_release(1).unwrap();
        assert!(freed.is_empty());
        assert_eq!(a.used_blocks(), 1);
        assert!(a.release_ref(b), "dropping the retain frees the block");
        assert_eq!(a.used_blocks(), 0);
        assert!(!a.retain(b), "cannot retain a free block");
    }

    #[test]
    fn cow_swap_gives_private_replacement() {
        let mut a = KvBlockAllocator::new(4, 8);
        a.reserve(1, 16);
        let prefix = a.owned_blocks(1).to_vec();
        a.attach(2, &prefix);
        let fresh = a.cow_swap(2, 1).expect("free block available");
        assert_ne!(fresh, prefix[1]);
        assert_eq!(a.owned_blocks(2), &[prefix[0], fresh]);
        assert_eq!(a.owned_blocks(1), &prefix[..], "original owner untouched");
        assert_eq!(a.refcount(prefix[1]), 1, "shared ref dropped");
        assert_eq!(a.refcount(fresh), 1);
    }

    #[test]
    fn refcounts_balance_under_random_share_and_release() {
        prop_check(64, |g| {
            let cap = g.usize(2..=32);
            let mut a = KvBlockAllocator::new(cap, 8);
            let mut live: Vec<u64> = Vec::new();
            let mut retains: Vec<usize> = Vec::new(); // external refs we hold
            for step in 0..100 {
                match g.usize(0..=3) {
                    0 => {
                        let seq = step as u64;
                        let toks = g.usize(1..=64);
                        if a.reserve(seq, toks) && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                    1 if !live.is_empty() => {
                        // fork: adopt a live seq's block prefix as a new seq
                        let donor = live[g.usize(0..=live.len() - 1)];
                        let owned = a.owned_blocks(donor).to_vec();
                        if !owned.is_empty() {
                            let upto = g.usize(1..=owned.len());
                            let seq = 1_000 + step as u64;
                            if a.attach(seq, &owned[..upto]) {
                                live.push(seq);
                            }
                        }
                    }
                    2 if !live.is_empty() => {
                        // external retain (prefix-cache style)
                        let donor = live[g.usize(0..=live.len() - 1)];
                        let owned = a.owned_blocks(donor).to_vec();
                        if !owned.is_empty() {
                            let b = owned[g.usize(0..=owned.len() - 1)];
                            if a.retain(b) {
                                retains.push(b);
                            }
                        }
                    }
                    _ => {
                        // release a seq or drop an external retain
                        if !retains.is_empty() && (g.bool() || live.is_empty()) {
                            let b = retains.swap_remove(g.usize(0..=retains.len() - 1));
                            a.release_ref(b);
                        } else if !live.is_empty() {
                            let idx = g.usize(0..=live.len() - 1);
                            let seq = live.swap_remove(idx);
                            a.release(seq);
                        }
                    }
                }
                if a.used_blocks() + a.free_blocks() != cap {
                    return Err(format!("leak: used {} free {} cap {cap}", a.used_blocks(), a.free_blocks()));
                }
                // refcount consistency: every block's count equals the
                // number of owning sequences plus our external retains
                let mut expect = vec![0usize; cap];
                for blocks in a.owned.values() {
                    for &b in blocks {
                        expect[b] += 1;
                    }
                }
                for &b in &retains {
                    expect[b] += 1;
                }
                for b in 0..cap {
                    if a.refcount(b) != expect[b] {
                        return Err(format!("block {b}: refcount {} != expected {}", a.refcount(b), expect[b]));
                    }
                }
            }
            // drain everything: no block may remain allocated
            for seq in live {
                a.release(seq);
            }
            for b in retains {
                a.release_ref(b);
            }
            if a.free_blocks() != cap {
                return Err(format!("drained pool leaks: {} free of {cap}", a.free_blocks()));
            }
            Ok(())
        });
    }
}
