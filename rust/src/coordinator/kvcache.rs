//! Paged KV-cache block allocator (admission control + block ownership).
//!
//! The cache budget is divided into fixed-size token blocks; a sequence of
//! length L holds ⌈L / block_tokens⌉ blocks. The allocator decides
//! admission (can a new sequence's worst case fit?) and tracks
//! per-sequence block lists so completion frees exactly what was taken.
//! Invariants (property-tested): never exceeds capacity, no double-free,
//! no block owned by two sequences.
//!
//! Since the quantized paged KV-cache landed, these block ids are **real
//! storage handles**: [`KvPool`](crate::kvquant::KvPool) embeds an
//! allocator and maps each owned id to that block's K/V tile slots, so the
//! ownership invariants above are exactly the pool's no-aliasing
//! guarantees. [`Self::owned_blocks`] exposes a sequence's id list (in
//! reservation order — block *i* of a sequence holds tokens
//! `[i·block_tokens, (i+1)·block_tokens)`), and [`Self::try_release`] is
//! the recoverable release the server path uses (a stray release of an
//! unknown sequence must not panic mid-serve).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct KvBlockAllocator {
    /// total blocks in the pool.
    capacity: usize,
    /// tokens per block.
    pub block_tokens: usize,
    free: Vec<usize>,
    owned: HashMap<u64, Vec<usize>>,
}

impl KvBlockAllocator {
    pub fn new(capacity: usize, block_tokens: usize) -> KvBlockAllocator {
        KvBlockAllocator {
            capacity,
            block_tokens,
            free: (0..capacity).rev().collect(),
            owned: HashMap::new(),
        }
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Can a sequence with this worst-case token count be admitted?
    pub fn can_admit(&self, worst_case_tokens: usize) -> bool {
        self.blocks_for(worst_case_tokens) <= self.free.len()
    }

    /// Reserve blocks for sequence `seq` to cover `tokens` total tokens.
    /// Grows the existing reservation; returns false (no change) if the pool
    /// cannot satisfy it.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        let have = self.owned.get(&seq).map(|v| v.len()).unwrap_or(0);
        if need <= have {
            return true;
        }
        let extra = need - have;
        if extra > self.free.len() {
            return false;
        }
        let list = self.owned.entry(seq).or_default();
        for _ in 0..extra {
            list.push(self.free.pop().unwrap());
        }
        true
    }

    /// Blocks owned by `seq`, in reservation order (block `i` covers
    /// tokens `[i·block_tokens, (i+1)·block_tokens)`). Empty for unknown
    /// sequences.
    pub fn owned_blocks(&self, seq: u64) -> &[usize] {
        self.owned.get(&seq).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Release all blocks owned by `seq`, returning their ids so a
    /// storage-backed caller can clear the corresponding slots. `None`
    /// (and no change) for unknown sequences — the recoverable form the
    /// server path uses.
    pub fn try_release(&mut self, seq: u64) -> Option<Vec<usize>> {
        let blocks = self.owned.remove(&seq)?;
        let ids = blocks.clone();
        self.free.extend(blocks);
        debug_assert!(self.free.len() <= self.capacity);
        Some(ids)
    }

    /// Release all blocks owned by `seq`. Panics on double-free (strict
    /// variant for callers that own the bookkeeping; serve paths use
    /// [`Self::try_release`]).
    pub fn release(&mut self, seq: u64) {
        if self.try_release(seq).is_none() {
            panic!("double free of seq {seq}");
        }
    }

    pub fn active_sequences(&self) -> usize {
        self.owned.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn basic_reserve_release() {
        let mut a = KvBlockAllocator::new(10, 16);
        assert!(a.reserve(1, 40)); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        assert!(a.reserve(1, 50)); // grow to 4
        assert_eq!(a.used_blocks(), 4);
        assert!(a.reserve(1, 20)); // shrink request = no-op
        assert_eq!(a.used_blocks(), 4);
        a.release(1);
        assert_eq!(a.used_blocks(), 0);
    }

    #[test]
    fn admission_respects_capacity() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.can_admit(32));
        assert!(!a.can_admit(33));
        assert!(a.reserve(1, 24)); // 3 blocks
        assert!(!a.reserve(2, 16)); // needs 2, only 1 free
        assert!(a.reserve(2, 8));
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = KvBlockAllocator::new(4, 8);
        a.reserve(7, 8);
        a.release(7);
        a.release(7);
    }

    #[test]
    fn try_release_is_recoverable_and_returns_ids() {
        let mut a = KvBlockAllocator::new(4, 8);
        assert!(a.try_release(7).is_none(), "unknown seq is a no-op");
        a.reserve(7, 24); // 3 blocks
        let owned: Vec<usize> = a.owned_blocks(7).to_vec();
        assert_eq!(owned.len(), 3);
        let freed = a.try_release(7).unwrap();
        assert_eq!(freed, owned, "released ids match ownership order");
        assert!(a.try_release(7).is_none(), "second release is recoverable");
        assert_eq!(a.free_blocks(), 4);
        assert!(a.owned_blocks(7).is_empty());
    }

    #[test]
    fn owned_blocks_grow_in_order() {
        let mut a = KvBlockAllocator::new(8, 4);
        a.reserve(1, 4);
        let first = a.owned_blocks(1).to_vec();
        a.reserve(1, 12);
        let grown = a.owned_blocks(1).to_vec();
        assert_eq!(grown.len(), 3);
        assert_eq!(&grown[..1], &first[..], "growth appends, never reorders");
    }

    #[test]
    fn never_exceeds_capacity_and_no_shared_blocks() {
        prop_check(64, |g| {
            let cap = g.usize(1..=32);
            let mut a = KvBlockAllocator::new(cap, 8);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..80 {
                if g.bool() || live.is_empty() {
                    let seq = step as u64;
                    let toks = g.usize(1..=64);
                    if a.reserve(seq, toks) && !live.contains(&seq) {
                        live.push(seq);
                    }
                } else {
                    let idx = g.usize(0..=live.len() - 1);
                    let seq = live.swap_remove(idx);
                    a.release(seq);
                }
                if a.used_blocks() + a.free_blocks() != cap {
                    return Err(format!("leak: used {} free {} cap {cap}", a.used_blocks(), a.free_blocks()));
                }
                // ownership disjointness
                let mut seen = std::collections::HashSet::new();
                for blocks in a.owned.values() {
                    for b in blocks {
                        if !seen.insert(*b) {
                            return Err(format!("block {b} owned twice"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
