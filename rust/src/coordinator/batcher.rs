//! Dynamic batcher: collects arriving requests into bucketed batches under
//! a latency window.
//!
//! Policy (the one the Table-6 bench exercises):
//! * a batch is dispatched as soon as it fills the largest bucket, or
//! * when the oldest queued request has waited `window`, dispatch the
//!   largest bucket ≤ queue length (padding never exceeds the next bucket).
//!
//! Invariants (property-tested): FIFO order preserved (per tenant within a
//! batch — see below), batch sizes always equal a configured bucket, no
//! request waits more than `window` once the queue is non-empty (modulo
//! dispatch granularity).
//!
//! Multi-tenant: a batch may freely mix tenants — every tenant shares the
//! same packed base, so nothing is dequantized twice. The batcher
//! stable-groups the dispatched batch by adapter id: consecutive sequences
//! then reuse the same (B′, A′) matrices while they are cache-hot, and the
//! grouped layout is what future per-tenant batched kernels will consume.
//! (The engine still resolves the registry per sequence — a cheap map
//! lookup; correctness never depends on the grouping.) Which requests form
//! the batch is still strictly FIFO.

use super::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Batcher {
    pub buckets: Vec<usize>,
    pub window: Duration,
    pub max_queue: usize,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(mut buckets: Vec<usize>, window: Duration, max_queue: usize) -> Batcher {
        buckets.sort_unstable();
        assert!(!buckets.is_empty());
        Batcher { buckets, window, max_queue, queue: VecDeque::new() }
    }

    /// Enqueue; returns false (rejected) when the queue is full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.max_queue {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The first `n` queued requests in FIFO order — the server peeks
    /// these to size KV-aware admission before popping a batch (a popped
    /// batch is always a prefix of the queue, so the peeked lengths match
    /// what `pop_batch` will hand back).
    pub fn peek(&self, n: usize) -> impl Iterator<Item = &Request> {
        self.queue.iter().take(n)
    }

    /// Remove a queued request by id (client cancellation before
    /// admission). Preserves FIFO order of the remainder.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let pos = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(pos)
    }

    fn largest_bucket_leq(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().filter(|&b| b <= n).max()
    }

    pub fn max_bucket(&self) -> usize {
        // PANIC-OK: the constructor asserts `buckets` is non-empty and it is
        // never mutated afterwards.
        *self.buckets.last().unwrap()
    }

    /// Try to form a batch at time `now`. `capacity` limits how many new
    /// sequences the engine can still admit (KV budget).
    pub fn pop_batch(&mut self, now: Instant, capacity: usize) -> Option<Vec<Request>> {
        if self.queue.is_empty() || capacity == 0 {
            return None;
        }
        let avail = self.queue.len().min(capacity);
        let full = self.max_bucket();
        let oldest_wait = now.duration_since(self.queue.front()?.arrival);
        let target = if avail >= full {
            full
        } else if oldest_wait >= self.window {
            self.largest_bucket_leq(avail)?
        } else {
            return None;
        };
        let mut batch: Vec<Request> = self.queue.drain(..target).collect();
        // group tenants contiguously; the sort is stable, so per-tenant
        // FIFO order (and, single-tenant, global FIFO) is preserved
        batch.sort_by(|x, y| x.adapter.cmp(&y.adapter));
        Some(batch)
    }

    /// Drain everything (shutdown).
    pub fn drain(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 8)
    }

    #[test]
    fn dispatches_full_bucket_immediately() {
        let mut b = Batcher::new(vec![1, 2, 4], Duration::from_millis(5), 100);
        for i in 0..5 {
            assert!(b.push(req(i)));
        }
        let batch = b.pop_batch(Instant::now(), 99).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0); // FIFO
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_window_when_underfull() {
        let mut b = Batcher::new(vec![1, 2, 4], Duration::from_millis(50), 100);
        b.push(req(0));
        assert!(b.pop_batch(Instant::now(), 99).is_none(), "should wait for window");
        let later = Instant::now() + Duration::from_millis(60);
        let batch = b.pop_batch(later, 99).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn respects_capacity() {
        let mut b = Batcher::new(vec![1, 2, 4], Duration::from_millis(0), 100);
        for i in 0..4 {
            b.push(req(i));
        }
        let later = Instant::now() + Duration::from_millis(1);
        let batch = b.pop_batch(later, 2).unwrap();
        assert_eq!(batch.len(), 2, "capacity-limited dispatch");
    }

    #[test]
    fn rejects_when_full() {
        let mut b = Batcher::new(vec![1], Duration::from_millis(1), 2);
        assert!(b.push(req(0)));
        assert!(b.push(req(1)));
        assert!(!b.push(req(2)));
    }

    #[test]
    fn mixed_tenants_grouped_contiguously_with_per_tenant_fifo() {
        let mut b = Batcher::new(vec![8], Duration::from_millis(0), 100);
        let tenants = ["t1", "t0", "t1", "base", "t0", "t1", "base", "t0"];
        for (i, t) in tenants.iter().enumerate() {
            b.push(req(i as u64).with_adapter(t));
        }
        let later = Instant::now() + Duration::from_millis(1);
        let batch = b.pop_batch(later, 99).unwrap();
        assert_eq!(batch.len(), 8);
        // contiguous tenant runs
        let ids: Vec<&str> = batch.iter().map(|r| r.adapter.as_str()).collect();
        let mut runs = 1;
        for w in ids.windows(2) {
            if w[0] != w[1] {
                runs += 1;
            }
        }
        assert_eq!(runs, 3, "tenants not grouped: {ids:?}");
        // per-tenant FIFO preserved
        for tenant in ["base", "t0", "t1"] {
            let got: Vec<u64> =
                batch.iter().filter(|r| r.adapter == tenant).map(|r| r.id).collect();
            let mut want = got.clone();
            want.sort_unstable();
            assert_eq!(got, want, "{tenant} order");
        }
    }

    #[test]
    fn batch_sizes_always_buckets_and_fifo() {
        prop_check(48, |g| {
            let buckets = vec![1, 2, 4, 8];
            let mut b = Batcher::new(buckets.clone(), Duration::from_millis(0), 1000);
            let n = g.usize(1..=64);
            for i in 0..n {
                b.push(req(i as u64));
            }
            let mut expected_next = 0u64;
            let later = Instant::now() + Duration::from_millis(1);
            while let Some(batch) = b.pop_batch(later, g.usize(1..=16)) {
                if !buckets.contains(&batch.len()) {
                    return Err(format!("batch size {} not a bucket", batch.len()));
                }
                for r in &batch {
                    if r.id != expected_next {
                        return Err(format!("FIFO violated: {} != {expected_next}", r.id));
                    }
                    expected_next += 1;
                }
            }
            Ok(())
        });
    }
}
