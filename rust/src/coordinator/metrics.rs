//! Serving metrics: per-phase token throughput + request latency summaries
//! — exactly the Prefill / Decode / Total tokens-per-second columns of
//! Table 6, plus p50/p99 request latency for the serving example — and,
//! for the online API, streaming-latency percentiles computed from
//! per-token timestamps: TTFT (arrival → first token), ITL (gap between
//! consecutive streamed tokens of one sequence), and queue wait. Per-tenant
//! counters back the `table5_multitenant` bench's breakdown.

use crate::util::Summary;
use std::collections::HashMap;

/// Per-tenant serving counters keyed by adapter id.
#[derive(Clone, Debug, Default)]
pub struct AdapterCounters {
    /// requests admitted for this tenant
    pub requests: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub completed: usize,
    /// admitted requests cancelled by the client mid-decode (queued
    /// cancels never hit the tenant's `requests` counter, so they are
    /// not charged here either — `ServeMetrics::cancelled` counts both)
    pub cancelled: usize,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// prompt tokens actually prefilled (prefix-cache hits excluded)
    pub prefill_tokens: usize,
    /// prompt tokens served from the shared-prefix KV cache instead of
    /// being prefilled (`prefill_tokens + prefix_hit_tokens` is the total
    /// prompt volume admitted)
    pub prefix_hit_tokens: usize,
    /// chunked-prefill engine calls (each advances one sequence by up to
    /// the per-tick chunk budget; > completed ⇒ prompts were split)
    pub prefill_chunks: usize,
    pub decode_tokens: usize,
    /// batched decode ticks run (each tick advances every running
    /// sequence with one engine call; `decode_tokens / decode_ticks` is
    /// the average decode batch size)
    pub decode_ticks: usize,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub wall_secs: f64,
    pub completed: usize,
    pub rejected: usize,
    /// requests cancelled by the client (queued or mid-decode)
    pub cancelled: usize,
    /// in-flight failures (engine error, expired deadline, quarantine,
    /// drain timeout) — includes failures later recovered by retry
    pub failed: usize,
    /// retry-by-re-prefill attempts scheduled after retryable failures
    pub retries: usize,
    /// sequences quarantined for non-finite logits (terminal; a subset
    /// of `failed`)
    pub quarantined: usize,
    pub latency: Summary,
    pub queue_wait: Summary,
    /// time to first token: request arrival → first streamed token
    pub ttft: Summary,
    /// inter-token latency: gap between consecutive tokens of a sequence
    pub itl: Summary,
    /// per-tenant breakdown (adapter id → counters)
    pub per_adapter: HashMap<String, AdapterCounters>,
}

impl ServeMetrics {
    pub fn prefill_tps(&self) -> f64 {
        self.prefill_tokens as f64 / self.prefill_secs.max(1e-12)
    }

    pub fn decode_tps(&self) -> f64 {
        self.decode_tokens as f64 / self.decode_secs.max(1e-12)
    }

    /// Total throughput over wall-clock (the paper's Total column).
    pub fn total_tps(&self) -> f64 {
        (self.prefill_tokens + self.decode_tokens) as f64 / self.wall_secs.max(1e-12)
    }

    /// Average decode batch size per tick (sequences advanced per engine
    /// call — what the batched tick amortizes weight streaming over).
    pub fn avg_decode_batch(&self) -> f64 {
        self.decode_tokens as f64 / self.decode_ticks.max(1) as f64
    }

    /// Counter cell for tenant `id`, created on first touch.
    pub fn adapter(&mut self, id: &str) -> &mut AdapterCounters {
        self.per_adapter.entry(id.to_string()).or_default()
    }

    /// Fraction of admitted prompt tokens served from the shared-prefix
    /// cache instead of being prefilled (0.0 when nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefill_tokens + self.prefix_hit_tokens;
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / total as f64
        }
    }

    /// Per-tenant breakdown, sorted by adapter id.
    pub fn print_adapters(&self) {
        let mut ids: Vec<&String> = self.per_adapter.keys().collect();
        ids.sort();
        for id in ids {
            let c = &self.per_adapter[id];
            println!(
                "    tenant {id:<16} req {:>4} | prefill {:>8} tok | decode {:>8} tok | done {:>4} | can {:>3}",
                c.requests, c.prefill_tokens, c.decode_tokens, c.completed, c.cancelled,
            );
        }
    }

    pub fn print(&self, label: &str) {
        println!(
            "  {label:<16} prefill {:>9.1} tok/s | decode {:>8.1} tok/s | total {:>8.1} tok/s | prefix hit {:>5.1}% | p50 {:.1}ms p99 {:.1}ms | done {} rej {} can {}",
            self.prefill_tps(),
            self.decode_tps(),
            self.total_tps(),
            self.prefix_hit_rate() * 100.0,
            self.latency.p50() * 1e3,
            self.latency.p99() * 1e3,
            self.completed,
            self.rejected,
            self.cancelled,
        );
        if self.failed > 0 || self.retries > 0 {
            println!(
                "    failed {} (quarantined {}) | retries {}",
                self.failed, self.quarantined, self.retries,
            );
        }
    }

    /// Streaming-latency percentiles (the online serving bench's columns).
    pub fn print_streaming(&self) {
        println!(
            "    ttft p50 {:.2}ms p99 {:.2}ms | itl p50 {:.2}ms p99 {:.2}ms | queue p50 {:.2}ms p99 {:.2}ms | avg decode batch {:.1}",
            self.ttft.p50() * 1e3,
            self.ttft.p99() * 1e3,
            self.itl.p50() * 1e3,
            self.itl.p99() * 1e3,
            self.queue_wait.p50() * 1e3,
            self.queue_wait.p99() * 1e3,
            self.avg_decode_batch(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = ServeMetrics::default();
        m.prefill_tokens = 1000;
        m.prefill_secs = 0.5;
        m.decode_tokens = 100;
        m.decode_secs = 2.0;
        m.wall_secs = 2.5;
        assert!((m.prefill_tps() - 2000.0).abs() < 1e-9);
        assert!((m.decode_tps() - 50.0).abs() < 1e-9);
        assert!((m.total_tps() - 440.0).abs() < 1e-9);
    }

    #[test]
    fn zero_division_safe() {
        let m = ServeMetrics::default();
        assert!(m.prefill_tps().is_finite());
        assert_eq!(m.prefix_hit_rate(), 0.0);
    }

    #[test]
    fn prefix_hit_rate_over_total_prompt_volume() {
        let mut m = ServeMetrics::default();
        m.prefill_tokens = 75;
        m.prefix_hit_tokens = 25;
        assert!((m.prefix_hit_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn per_adapter_counters_accumulate() {
        let mut m = ServeMetrics::default();
        m.adapter("t0").requests += 1;
        m.adapter("t0").decode_tokens += 5;
        m.adapter("t1").requests += 2;
        assert_eq!(m.per_adapter["t0"].requests, 1);
        assert_eq!(m.per_adapter["t0"].decode_tokens, 5);
        assert_eq!(m.per_adapter["t1"].requests, 2);
        assert_eq!(m.per_adapter.len(), 2);
    }
}
