//! Request/response types + sampling. Every request carries a tenant
//! adapter id ([`BASE_ADAPTER`] by default), per-request [`SamplingParams`]
//! (greedy / temperature / top-k, seeded), and stop conditions
//! (`max_new_tokens` plus an optional stop-token set) that the server
//! checks as tokens stream out.

use crate::adapters::BASE_ADAPTER;
use crate::util::Rng;
use std::time::Instant;

/// Per-request sampling policy. The default (`temperature == 0`) is greedy
/// argmax — deterministic and what every paper-table bench uses. A positive
/// temperature samples from the (optionally top-k-truncated) softmax with a
/// per-request seeded RNG, so two identical runs produce identical streams.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SamplingParams {
    /// 0.0 ⇒ greedy argmax; > 0.0 ⇒ softmax sampling at this temperature.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits (0 ⇒ full vocabulary).
    pub top_k: usize,
    /// Seed for the per-sequence sampling stream (mixed with the request
    /// id, so batchmates sharing a seed still draw independent streams).
    pub seed: u64,
}

impl SamplingParams {
    /// Greedy argmax (the default: temperature 0).
    pub fn greedy() -> Self {
        Self::default()
    }

    /// The sequence's private sampling stream: seed mixed with the request
    /// id so every sequence draws independently and reproducibly.
    pub fn rng_for(&self, id: u64) -> Rng {
        Rng::new(self.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
    /// Serving tenant: a registered adapter id, or [`BASE_ADAPTER`] for the
    /// unadapted base model.
    pub adapter: String,
    /// Per-request sampling policy (default: greedy).
    pub params: SamplingParams,
    /// Generation ends early when a sampled token is in this set (the stop
    /// token is included in the output).
    pub stop_tokens: Vec<usize>,
    /// Completion deadline in milliseconds **relative to `arrival`**
    /// (0 = none). Enforced at admission — a request whose deadline is
    /// below `ServeCfg::min_deadline_ms` or already expired is rejected
    /// with [`RejectReason::DeadlineInfeasible`] — and in flight, where
    /// expiry produces a terminal `Event::Failed { reason: "deadline" }`.
    ///
    /// [`RejectReason::DeadlineInfeasible`]:
    ///     super::server::RejectReason::DeadlineInfeasible
    pub deadline_ms: u64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            adapter: BASE_ADAPTER.to_string(),
            params: SamplingParams::default(),
            stop_tokens: Vec::new(),
            deadline_ms: 0,
        }
    }

    /// Tag this request with a tenant adapter id (builder style).
    pub fn with_adapter(mut self, adapter: &str) -> Request {
        self.adapter = adapter.to_string();
        self
    }

    /// Set the sampling policy (builder style).
    pub fn with_sampling(mut self, params: SamplingParams) -> Request {
        self.params = params;
        self
    }

    /// Set the stop-token set (builder style).
    pub fn with_stop_tokens(mut self, stop: Vec<usize>) -> Request {
        self.stop_tokens = stop;
        self
    }

    /// Set a completion deadline, in milliseconds from arrival (builder
    /// style; 0 disables). The deadline survives retry-by-re-prefill:
    /// retries keep the original arrival instant, so the budget is
    /// end-to-end, not per-attempt.
    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Request {
        self.deadline_ms = deadline_ms;
        self
    }

    /// Worst-case KV footprint in tokens: the prompt plus every new token
    /// the request may generate, capped at `max_seq`. This is the exact
    /// amount the engine reserves at admission, so the batcher's KV-aware
    /// admission and the engine's reservation can never disagree.
    pub fn required_kv_tokens(&self, max_seq: usize) -> usize {
        (self.prompt.len() + self.max_new_tokens.min(max_seq.saturating_sub(1))).min(max_seq)
    }

    /// [`Self::required_kv_tokens`] minus a known shared prefix: when the
    /// engine's prefix cache already holds `shared_tokens` of this prompt
    /// (block-aligned), admission must charge only the unshared suffix —
    /// otherwise shared-prefix sessions get rejected for bytes they will
    /// never allocate.
    pub fn required_suffix_kv_tokens(&self, max_seq: usize, shared_tokens: usize) -> usize {
        self.required_kv_tokens(max_seq).saturating_sub(shared_tokens)
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<usize>,
    /// tenant adapter this request was served under
    pub adapter: String,
    /// seconds spent in queue before prefill started
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    /// seconds from arrival to the first streamed token
    pub ttft_s: f64,
}

impl Response {
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }
}

/// Greedy (argmax) sampling — deterministic, used by all benches.
pub fn greedy(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Sample a token under `params`: greedy at temperature 0, otherwise a
/// categorical draw from the top-k-truncated softmax at the given
/// temperature using the sequence's seeded RNG.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> usize {
    if params.temperature <= 0.0 || logits.len() <= 1 {
        return greedy(logits);
    }
    let k = match params.top_k {
        0 => logits.len(),
        k => k.min(logits.len()),
    };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    let max = logits[idx[0]];
    let weights: Vec<f64> =
        idx.iter().map(|&i| (((logits[i] - max) / params.temperature) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (&i, w) in idx.iter().zip(&weights) {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    idx[k - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        assert_eq!(greedy(&[0.1, 2.0, -1.0, 1.9]), 1);
        assert_eq!(greedy(&[-5.0]), 0);
    }

    #[test]
    fn response_total() {
        let r = Response {
            id: 0,
            prompt_len: 4,
            tokens: vec![],
            adapter: BASE_ADAPTER.to_string(),
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.3,
            ttft_s: 0.25,
        };
        assert!((r.total_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn requests_default_to_the_base_tenant() {
        let r = Request::new(0, vec![1], 4);
        assert_eq!(r.adapter, BASE_ADAPTER);
        assert_eq!(r.params, SamplingParams::greedy());
        assert!(r.stop_tokens.is_empty());
        let r2 = Request::new(1, vec![1], 4).with_adapter("tenant-a");
        assert_eq!(r2.adapter, "tenant-a");
    }

    #[test]
    fn required_kv_tokens_caps_at_max_seq() {
        let r = Request::new(0, vec![0; 10], 6);
        assert_eq!(r.required_kv_tokens(48), 16);
        assert_eq!(r.required_kv_tokens(12), 12);
        let greedy_cap = Request::new(1, vec![0; 10], 1000);
        assert_eq!(greedy_cap.required_kv_tokens(48), 48);
    }

    #[test]
    fn suffix_kv_tokens_discount_a_shared_prefix() {
        let r = Request::new(0, vec![0; 10], 6);
        assert_eq!(r.required_suffix_kv_tokens(48, 0), 16);
        assert_eq!(r.required_suffix_kv_tokens(48, 8), 8);
        assert_eq!(r.required_suffix_kv_tokens(48, 100), 0, "over-share clamps at zero");
    }

    #[test]
    fn zero_temperature_sampling_is_greedy() {
        let mut rng = Rng::new(0);
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        let p = SamplingParams::greedy();
        for _ in 0..8 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_respects_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.25).collect();
        let p = SamplingParams { temperature: 1.0, top_k: 4, seed: 7 };
        let mut a = p.rng_for(3);
        let mut b = p.rng_for(3);
        for _ in 0..64 {
            let ta = sample(&logits, &p, &mut a);
            let tb = sample(&logits, &p, &mut b);
            assert_eq!(ta, tb, "same seed must replay the same stream");
            assert!(ta >= 12, "top-4 of ascending logits is {{12..15}}, got {ta}");
        }
    }

    #[test]
    fn sampling_streams_differ_across_requests() {
        let logits: Vec<f32> = (0..32).map(|i| (i % 7) as f32 * 0.3).collect();
        let p = SamplingParams { temperature: 2.0, top_k: 0, seed: 9 };
        let a: Vec<usize> = {
            let mut r = p.rng_for(1);
            (0..32).map(|_| sample(&logits, &p, &mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = p.rng_for(2);
            (0..32).map(|_| sample(&logits, &p, &mut r)).collect()
        };
        assert_ne!(a, b, "different request ids must draw independent streams");
    }
}
