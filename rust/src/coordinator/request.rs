//! Request/response types + sampling. Every request carries a tenant
//! adapter id ([`BASE_ADAPTER`] by default) that the engine resolves
//! against its [`AdapterRegistry`](crate::adapters::AdapterRegistry).

use crate::adapters::BASE_ADAPTER;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
    /// Serving tenant: a registered adapter id, or [`BASE_ADAPTER`] for the
    /// unadapted base model.
    pub adapter: String,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            adapter: BASE_ADAPTER.to_string(),
        }
    }

    /// Tag this request with a tenant adapter id (builder style).
    pub fn with_adapter(mut self, adapter: &str) -> Request {
        self.adapter = adapter.to_string();
        self
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<usize>,
    /// tenant adapter this request was served under
    pub adapter: String,
    /// seconds spent in queue before prefill started
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
}

impl Response {
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }
}

/// Greedy (argmax) sampling — deterministic, used by all benches.
pub fn greedy(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        assert_eq!(greedy(&[0.1, 2.0, -1.0, 1.9]), 1);
        assert_eq!(greedy(&[-5.0]), 0);
    }

    #[test]
    fn response_total() {
        let r = Response {
            id: 0,
            prompt_len: 4,
            tokens: vec![],
            adapter: BASE_ADAPTER.to_string(),
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.3,
        };
        assert!((r.total_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn requests_default_to_the_base_tenant() {
        let r = Request::new(0, vec![1], 4);
        assert_eq!(r.adapter, BASE_ADAPTER);
        let r2 = Request::new(1, vec![1], 4).with_adapter("tenant-a");
        assert_eq!(r2.adapter, "tenant-a");
    }
}
