//! The serving coordinator — the L3 deployment layer around the quantized
//! model (the vLLM-router-shaped component of this reproduction):
//!
//! * [`request`]  — request/response types and greedy sampling.
//! * [`kvcache`]  — paged KV-block allocator (admission control: how many
//!   concurrent sequences fit the cache budget; no-double-free invariants).
//! * [`batcher`]  — dynamic batcher: arrival queue → bucketed batches under
//!   a latency window (continuous batching at the decode step level).
//! * [`engine`]   — the execution backends: native Rust model or PJRT
//!   artifacts (bucketed prefill/decode executables).
//! * [`server`]   — the serving loop: admit → prefill → interleaved decode
//!   → complete, with per-phase throughput metrics (Table 6's columns).
//! * [`metrics`]  — latency/throughput accounting.

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod server;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use request::{Request, Response};
pub use server::{ServeReport, Server};
