//! The serving coordinator — the L3 deployment layer around the quantized
//! model (the vLLM-router-shaped component of this reproduction):
//!
//! * [`request`]  — request/response types, per-request [`SamplingParams`]
//!   (greedy / temperature / top-k, seeded) and stop conditions.
//! * [`kvcache`]  — paged KV-block allocator (admission control +
//!   storage-backed block ownership; no-double-free invariants).
//! * [`batcher`]  — dynamic batcher: arrival queue → bucketed batches under
//!   a latency window (continuous batching at the decode step level).
//! * [`engine`]   — the execution backends: native Rust model or PJRT
//!   artifacts (bucketed prefill/decode executables).
//! * [`server`]   — the **online serving API**: sessioned submit / step /
//!   cancel with streaming [`Event`]s, the chunked-prefill scheduler
//!   (continuous batching), plus the `run_trace` offline shim.
//!   (The shared-prefix trie itself lives with the pool it indexes:
//!   [`kvquant::prefix`](crate::kvquant::prefix).)
//! * [`driver`]   — open-loop Poisson arrival harness (seeded,
//!   deterministic schedule) for latency-under-load measurement.
//! * [`metrics`]  — throughput + latency accounting: per-phase tok/s,
//!   request latency, TTFT / ITL / queue-wait percentiles from per-token
//!   timestamps, per-tenant counters.
//!
//! # Session lifecycle (the online API)
//!
//! ```text
//! submit ──► queued ──► admitted ──► prefill ──► decode ──► Done
//!    │          │  (KV-aware batch)    │   Event::Token per step  │
//!    │          │                      │                          │
//!    ▼          ▼                      ▼                          ▼
//! Err(Reject) Event::Rejected      cancel() ⇒ Event::Cancelled  blocks+pins
//!  (backpressure: queue full,      (KV blocks + adapter pin     released
//!   bad id/prompt/tenant)           released immediately)
//! ```
//!
//! [`Server::submit`](server::Server::submit) validates and queues one
//! request (or refuses it with a [`RejectReason`](server::RejectReason) —
//! admission is explicit, backpressure is the caller's signal).
//! [`Server::step`](server::Server::step) advances one tick in three
//! phases: **admit** a batch if KV capacity allows (reserving blocks and
//! claiming any cached shared-prefix blocks up front), **prefill** up to
//! [`ServeCfg::prefill_chunk_tokens`](crate::config::ServeCfg) prompt
//! tokens across the admitted-but-unfinished prompts (round-robin, in
//! KV-block-sized chunks — a long prompt no longer stalls the tick; 0
//! disables chunking and prefills whole prompts, the lockstep schedule),
//! then one **decode** step for every running sequence. It returns the
//! streaming events: one [`Event::Token`](server::Event) per sequence per
//! tick, then [`Event::Done`](server::Event) carrying the finished
//! [`Response`]. A sequence graduates from prefilling to running on the
//! tick its final chunk completes (producing its first token), in
//! admission order; chunking never changes tokens — the chunked schedule
//! is bitwise identical to whole-prompt prefill (chunk boundaries fall on
//! KV-block boundaries, so the sealed/dense split, the quantization
//! grid, and every logit match; gated by `tests/chunked_prefill.rs`).
//! [`Server::cancel`](server::Server::cancel) drops a queued,
//! mid-prefill, or mid-decode request; its KV blocks and adapter pin are
//! released immediately, so a cancelled sequence can never leak pool
//! capacity.
//! [`Server::run_trace`](server::Server::run_trace) reimplements the old
//! closed-loop trace player on top of submit + step (token-identical), and
//! [`driver::run_open_loop`] plays deterministic Poisson arrivals against
//! the same API for TTFT/ITL benchmarking.
//!
//! # Tenant routing (multi-tenant adapter serving)
//!
//! Every [`Request`] names a tenant via an adapter id (default:
//! [`BASE_ADAPTER`](crate::adapters::BASE_ADAPTER), the unadapted base).
//! The id rides along into [`engine::SeqState`]; the batcher freely mixes
//! tenants in one batch (stable-grouping them contiguously), because all
//! tenants share one bit-packed code base — only the rank-r scale factors
//! differ. [`NativeEngine`] resolves the id against its
//! [`AdapterRegistry`](crate::adapters::AdapterRegistry) per
//! prefill/decode call, pinning the adapter for the sequence's lifetime so
//! hot eviction is deferred, never unsafe. Cancellation releases the pin
//! with the sequence. A tenant evicted while its request is still queued
//! surfaces as `Event::Rejected`, not a failed batch. The PJRT engine
//! serves only the base tenant (per-tenant artifacts are a future
//! lowering).
//!
//! # KV memory model (quantized paged cache)
//!
//! The [`NativeEngine`] owns a [`KvPool`](crate::kvquant::KvPool): the
//! [`kvcache::KvBlockAllocator`]'s reservations are real storage handles —
//! each owned block id indexes the per-layer K/V tile slots holding that
//! block's `block_tokens` positions, either dense f32 or bit-packed 4/8-bit
//! codes with rank-r low-rank scale factors fit at seal time
//! ([`kvquant`](crate::kvquant)). Admission flows through the engine
//! ([`Engine::kv_can_admit`](engine::Engine::kv_can_admit)) and is
//! **KV-aware**: each queued request is priced at its actual worst case —
//! prompt length + requested `max_new_tokens`, capped at `max_seq` — and
//! the engine reserves exactly that at prefill, so short requests pack
//! many more concurrent sequences than the old `max_seq`-worst-case
//! accounting. `Server::new` sizes the pool from a **byte budget**
//! ([`ServeCfg::kv_budget_mib`](crate::config::ServeCfg), default = what
//! `max_concurrent` dense worst-case sequences need), so dropping
//! `kv_bits` from 32 to 8 or 4 multiplies how many sequences the same
//! bytes admit. Reservation up front means decode can never run out of
//! blocks mid-sequence; [`Engine::release`](engine::Engine::release) —
//! called on completion *and* cancellation — frees blocks and adapter
//! pins together (a stray release is recoverable, never a panic).
//!
//! # Shared-prefix KV reuse (ref-counted sealed blocks)
//!
//! The [`NativeEngine`] also carries a
//! [`PrefixCache`](crate::kvquant::prefix): a trie keyed per adapter over
//! whole prompt token *blocks*, mapping each cached prefix chain to the
//! sealed [`KvPool`](crate::kvquant::KvPool) blocks holding its KV. The
//! ownership rules:
//!
//! * The trie holds **one retain per cached block**; each sequence that
//!   forks onto a prefix adds its own retain per shared block. A block is
//!   freed only when its refcount hits zero — trie eviction and every
//!   sequence release/cancel each drop exactly the retains they added
//!   (gated by the cancel-storm test in `tests/serve_online.rs`).
//! * At **admission**, the longest cached prefix of the prompt (capped at
//!   `max_shareable`: whole blocks strictly below the prompt's last
//!   token, so the final position is always computed) is claimed; the
//!   sequence starts with `prefilled = shared` and is charged only the
//!   unshared suffix — both in prefill compute and in
//!   [`ServeMetrics::prefill_tokens`](metrics::ServeMetrics) (hits are
//!   accounted separately as `prefix_hit_tokens`).
//! * At **seal time** during prefill, each newly completed block-aligned
//!   prompt block is published back to the trie, so the first session
//!   over a system prompt warms the cache for every later one.
//! * Sealed blocks are **immutable** (copy-on-write discipline): chunk
//!   boundaries and fork points are block-aligned, so a forked sequence
//!   writes only its own dense tail, never a shared block.
//! * Under pool pressure the cache **evicts LRU leaves** (never a block
//!   some live sequence still retains);
//!   [`NativeEngine::flush_prefix_cache`](engine::NativeEngine::flush_prefix_cache)
//!   drains it completely (tenant teardown, tests).
//!
//! # The batched decode tick (weight streams per tick = tenant-groups)
//!
//! `Server::step`'s decode phase advances the **entire running set with
//! one engine call** and no per-tick cloning (the running sequences and
//! their timing state live in index-aligned vectors, so the engine
//! borrows `&mut [SeqState]` directly; engines must not reorder it). On
//! the native engine that call is
//! [`Model::decode_batch_pooled`](crate::model::Model::decode_batch_pooled):
//! the batch's activations are stacked into B×d matrices, stable-grouped
//! by tenant (re-establishing the batcher's grouping, which interleaves
//! as admission waves mix), and each fused bit-packed kernel runs **once
//! per tenant-group** — so one tick reads each packed weight
//! `tenant-groups` times instead of `batch-size` times, the traffic drop
//! the `decode_batch` bench quantifies. Pooled attention stays
//! per-sequence over each sequence's own blocks but fans the
//! per-(sequence, head) sweeps out across the global thread pool with
//! per-worker reusable scratch; all other activations live in a reusable
//! per-engine arena ([`DecodeScratch`](crate::model::DecodeScratch)).
//! Batching never changes tokens: the tick is bitwise identical to the
//! per-sequence reference loop
//! ([`NativeEngine::decode_reference`](engine::NativeEngine::decode_reference),
//! gated by `tests/decode_batch.rs`). `ServeMetrics::avg_decode_batch`
//! reports how many sequences each tick amortized over.
//!
//! # Observability (spans, metrics registry, flight recorder, quality)
//!
//! Every server owns a [`ServerObs`](server::ServerObs): a cumulative
//! [`Registry`](crate::obs::Registry) of Prometheus-style counters /
//! gauges / histograms (never reset — [`ServeMetrics`](metrics::ServeMetrics)
//! stays the windowed report) plus a bounded
//! [`FlightRecorder`](crate::obs::FlightRecorder) of per-request lifecycle
//! events. The registry is `Arc`-shared so the admin endpoint
//! ([`obs::AdminServer`](crate::obs::AdminServer), `serve --admin-addr`)
//! can render `/metrics` and `/quality` live, mid-run, without touching
//! the serving loop. Instrumentation must never perturb serving: with
//! tracing off the span macro is one relaxed atomic load, and token
//! streams are bitwise identical either way (gated by `tests/obs.rs`).
//!
//! **Quantization-quality telemetry** rides in the same registry
//! ([`obs::quality`](crate::obs::quality)), wired by
//! [`Engine::install_quality`](engine::Engine::install_quality) at
//! `Server::new`: per-layer weight quant-error gauges (base at engine
//! build, per tenant at adapter registration), per-tier KV seal-error
//! histograms recorded at every block seal (a 4-bit seal error above
//! [`ServeCfg::seal_err_threshold`](crate::config::ServeCfg) arms a
//! flight-recorder dump), per-block KV heat exported as a coldness
//! histogram each tick, and — on the deterministic cadence
//! [`ServeCfg::sentinel_every_n_ticks`](crate::config::ServeCfg), default
//! off — a **logit-drift sentinel**: one running sequence's latest decode
//! step is replayed through the per-sequence reference path on a shadow
//! KV fork, recording top-1 agreement and max-abs logit drift. The
//! sentinel is observe-only *by construction*: the shadow sequence shares
//! sealed blocks copy-on-write, copies the dense tail bit-exactly, and is
//! released before the next tick, so served token streams are bitwise
//! identical with the sentinel on or off across every KV tier (gated by
//! `tests/obs.rs`).
//!
//! **Span points** (emitted via [`obs::span!`](macro@crate::span) when
//! [`obs::trace::set_enabled`](crate::obs::trace::set_enabled) is on, drained
//! with [`obs::trace::drain`](crate::obs::trace::drain) and exported as
//! Chrome-trace JSON by `serve --trace-out`):
//!
//! ```text
//! server.tick                 one step(): admit + prefill + decode
//! ├─ server.admit             KV-aware admission of one batch
//! ├─ server.prefill           chunked-prefill phase of the tick
//! │  └─ engine.prefill_chunk  one sequence advancing ≤ chunk tokens
//! │     └─ kernel.*           fused packed-weight matmuls
//! └─ server.decode            batched decode phase of the tick
//!    └─ engine.decode         one engine call for the running set
//!       └─ model.decode_batch tenant-grouped batched forward
//!          ├─ kernel.lords_matmul / kernel.blockwise_matmul
//!          ├─ attn.pooled     paged attention over packed KV
//!          └─ kv.seal         block seal + quantize (arg = tile rows)
//! ```
//!
//! **Flight-recorder event schema** (one bounded ring, oldest evicted
//! first; dumped as JSON on demand or on an anomaly — rejection storm,
//! stall, or KV seal-error breach, thresholds configurable via
//! [`ServeCfg`](crate::config::ServeCfg) — see
//! [`FlightKind`](crate::obs::FlightKind)):
//!
//! | event | payload | emitted when |
//! |---|---|---|
//! | `submitted` | — | `submit` accepts the request |
//! | `rejected` | `reason` | admission or submit refuses it |
//! | `admitted` | `prefix_hit_tokens`, `reserved_tokens` | KV reserved, prefix claimed |
//! | `prefill_chunk` | `tokens` | one chunk of its prompt prefilled |
//! | `first_token` | — | the tick its first token streams |
//! | `done` | `generated` | completion (`Event::Done`) |
//! | `cancelled` | — | client cancel (queued or live) |
//! | `failed` | `reason`, `retryable` | an in-flight failure (see below) |
//! | `quarantined` | — | non-finite logits caught before sampling |
//! | `retried` | — | a retryable failure re-entered admission |
//! | `released` | — | KV blocks + adapter pin freed |
//!
//! # Failure model (containment, retry, quarantine, deadlines, drain)
//!
//! Failures are **contained per sequence, never per server** — a broken
//! request must not take down its batch-mates, leak KV, or wedge the
//! tick loop ([`Event::Failed`](server::Event) carries a stable `reason`
//! plus whether the server will retry):
//!
//! * **Engine errors.** A [`prefill_chunk`](engine::Engine::prefill_chunk)
//!   error fails only that sequence; an
//!   [`admit_seqs`](engine::Engine::admit_seqs) or
//!   [`decode`](engine::Engine::decode) error fails the cohort that call
//!   covered (reason `engine_error`, retryable). Every fail path calls
//!   [`Engine::release`](engine::Engine::release), which tolerates
//!   unknown or partially-admitted ids — KV blocks and adapter pins are
//!   freed exactly once no matter where the failure landed.
//! * **Retry-by-re-prefill.** A retryably failed request is rebuilt from
//!   its prompt (original arrival kept, so deadlines stay end-to-end)
//!   and re-queued after [`ServeCfg::retry_backoff_ticks`], up to
//!   [`ServeCfg::retry_budget`] attempts; its id stays live, so a
//!   duplicate client resubmission is still rejected while the retry is
//!   pending. Decode is deterministic per request, so a successful retry
//!   reproduces the clean run's tokens bitwise (gated by
//!   `tests/chaos.rs`).
//! * **Quarantine.** Each decode tick scans `last_logits` for non-finite
//!   values *before sampling* (greedy argmax would rank NaN first). A
//!   poisoned sequence fails terminally (reason `nonfinite_logits`,
//!   never retried — the same decode would poison it again), counts in
//!   `lords_quarantined_total`, and trips a flight-recorder anomaly dump.
//! * **Deadlines.** [`Request::with_deadline_ms`](request::Request)
//!   bounds a request end-to-end from arrival: infeasible deadlines are
//!   rejected at submit, expired ones at admission (before KV is
//!   spent), and in-flight expiry fails the sequence terminally (reason
//!   `deadline`).
//! * **Drain.** [`Server::drain`](server::Server::drain) stops admission
//!   (queue and retries fail with reason `draining`), steps until
//!   in-flight work finishes or `timeout_ticks` elapses (leftovers fail
//!   with `drain_timeout`), then flushes engine caches — a drained
//!   server holds zero KV blocks, staging bytes, or adapter pins.
//!   [`Server::is_ready`](server::Server::is_ready) feeds the `/readyz`
//!   probe: false while draining or under sustained backpressure
//!   ([`ServeCfg::readyz_backpressure_ticks`]).
//!
//! The named fault-injection sites that make these paths testable
//! (`engine.*`, `kv.*`, `prefix.*`, `adapter.resolve`, `http.conn`) live
//! in [`crate::fault`]; see the README fault-site table and
//! `tests/chaos.rs` for the seeded chaos invariants.
//!
//! [`ServeCfg::retry_backoff_ticks`]: crate::config::ServeCfg::retry_backoff_ticks
//! [`ServeCfg::retry_budget`]: crate::config::ServeCfg::retry_budget
//! [`ServeCfg::readyz_backpressure_ticks`]: crate::config::ServeCfg::readyz_backpressure_ticks

pub mod batcher;
pub mod driver;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod server;

pub use driver::{poisson_arrivals, run_open_loop};
pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use request::{Request, Response, SamplingParams};
pub use server::{Event, RejectReason, SeqId, ServeReport, Server, ServerObs};
