//! The serving coordinator — the L3 deployment layer around the quantized
//! model (the vLLM-router-shaped component of this reproduction):
//!
//! * [`request`]  — request/response types and greedy sampling.
//! * [`kvcache`]  — paged KV-block allocator (admission control: how many
//!   concurrent sequences fit the cache budget; no-double-free invariants).
//! * [`batcher`]  — dynamic batcher: arrival queue → bucketed batches under
//!   a latency window (continuous batching at the decode step level).
//! * [`engine`]   — the execution backends: native Rust model or PJRT
//!   artifacts (bucketed prefill/decode executables).
//! * [`server`]   — the serving loop: admit → prefill → interleaved decode
//!   → complete, with per-phase throughput metrics (Table 6's columns).
//! * [`metrics`]  — latency/throughput accounting, incl. per-tenant
//!   counters.
//!
//! # Tenant routing (multi-tenant adapter serving)
//!
//! Every [`Request`] names a tenant via an adapter id (default:
//! [`BASE_ADAPTER`](crate::adapters::BASE_ADAPTER), the unadapted base).
//! The id rides along into [`engine::SeqState`]; the batcher freely mixes
//! tenants in one batch (stable-grouping them contiguously), because all
//! tenants share one bit-packed code base — only the rank-r scale factors
//! differ. [`NativeEngine`] resolves the id against its
//! [`AdapterRegistry`](crate::adapters::AdapterRegistry) per
//! prefill/decode call, pinning the adapter for the sequence's lifetime so
//! hot eviction is deferred, never unsafe. The PJRT engine serves only the
//! base tenant (per-tenant artifacts are a future lowering).

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod server;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use request::{Request, Response};
pub use server::{ServeReport, Server};
