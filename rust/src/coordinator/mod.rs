//! The serving coordinator — the L3 deployment layer around the quantized
//! model (the vLLM-router-shaped component of this reproduction):
//!
//! * [`request`]  — request/response types and greedy sampling.
//! * [`kvcache`]  — paged KV-block allocator (admission control +
//!   storage-backed block ownership; no-double-free invariants).
//! * [`batcher`]  — dynamic batcher: arrival queue → bucketed batches under
//!   a latency window (continuous batching at the decode step level).
//! * [`engine`]   — the execution backends: native Rust model or PJRT
//!   artifacts (bucketed prefill/decode executables).
//! * [`server`]   — the serving loop: admit → prefill → interleaved decode
//!   → complete, with per-phase throughput metrics (Table 6's columns).
//! * [`metrics`]  — latency/throughput accounting, incl. per-tenant
//!   counters.
//!
//! # Tenant routing (multi-tenant adapter serving)
//!
//! Every [`Request`] names a tenant via an adapter id (default:
//! [`BASE_ADAPTER`](crate::adapters::BASE_ADAPTER), the unadapted base).
//! The id rides along into [`engine::SeqState`]; the batcher freely mixes
//! tenants in one batch (stable-grouping them contiguously), because all
//! tenants share one bit-packed code base — only the rank-r scale factors
//! differ. [`NativeEngine`] resolves the id against its
//! [`AdapterRegistry`](crate::adapters::AdapterRegistry) per
//! prefill/decode call, pinning the adapter for the sequence's lifetime so
//! hot eviction is deferred, never unsafe. The PJRT engine serves only the
//! base tenant (per-tenant artifacts are a future lowering).
//!
//! # KV memory model (quantized paged cache)
//!
//! The [`NativeEngine`] owns a [`KvPool`](crate::kvquant::KvPool): the
//! [`kvcache::KvBlockAllocator`]'s reservations are real storage handles —
//! each owned block id indexes the per-layer K/V tile slots holding that
//! block's `block_tokens` positions, either dense f32 or bit-packed 4/8-bit
//! codes with rank-r low-rank scale factors fit at seal time
//! ([`kvquant`](crate::kvquant)). Admission flows through the engine
//! ([`Engine::kv_can_admit`](engine::Engine::kv_can_admit)): `Server::new`
//! sizes the pool from a **byte budget**
//! ([`ServeCfg::kv_budget_mib`](crate::config::ServeCfg), default = what
//! `max_concurrent` dense worst-case sequences need), so dropping
//! `kv_bits` from 32 to 8 or 4 multiplies how many sequences the same
//! bytes admit. Each admitted sequence reserves its worst case up front —
//! decode can never run out of blocks mid-sequence — and
//! [`Engine::release`](engine::Engine::release) frees blocks and adapter
//! pins together (a stray release is recoverable, never a panic).

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod server;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use request::{Request, Response};
pub use server::{ServeReport, Server};
