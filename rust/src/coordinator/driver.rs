//! Open-loop arrival driver for the online serving API.
//!
//! The trace shim ([`Server::run_trace`]) is closed-loop: every request is
//! available at t=0 and the server is never idle, which measures peak
//! throughput but says nothing about latency under load. This driver plays
//! an **open-loop** workload: request arrival times are drawn from a
//! deterministic Poisson-like process (exponential inter-arrival gaps from
//! the seeded [`util::rng`](crate::util::Rng)) and submitted when the wall
//! clock reaches them, whether or not the server has caught up — exactly
//! the regime where TTFT/ITL and queue-wait percentiles become meaningful.
//!
//! The arrival *schedule* is bit-for-bit reproducible for a given seed;
//! the measured latencies are of course machine-dependent.

use super::engine::Engine;
use super::request::Request;
use super::server::{Event, ServeReport, Server};
use crate::util::Rng;
use std::time::Instant;

/// Deterministic Poisson-like arrival offsets (seconds from start) for
/// `n` requests at `rate_rps` mean arrivals per second: cumulative sums of
/// exponential inter-arrival gaps drawn from the seeded RNG.
pub fn poisson_arrivals(n: usize, rate_rps: f64, seed: u64) -> Vec<f64> {
    assert!(rate_rps > 0.0, "open-loop driver needs a positive arrival rate");
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            // inverse-CDF exponential; 1 - u avoids ln(0)
            t += -(1.0 - rng.f64()).ln() / rate_rps;
            t
        })
        .collect()
}

/// Play `requests` through the sessioned API open-loop at `rate_rps`
/// arrivals per second (arrival schedule seeded by `seed`), stepping the
/// server continuously until every request is resolved (done, rejected,
/// or cancelled). Returns the standard [`ServeReport`]; streaming
/// percentiles live in its metrics (`ttft` / `itl` / `queue_wait`).
pub fn run_open_loop<E: Engine>(
    server: &mut Server<E>,
    requests: Vec<Request>,
    rate_rps: f64,
    seed: u64,
) -> anyhow::Result<ServeReport> {
    server.reset_metrics();
    let offsets = poisson_arrivals(requests.len(), rate_rps, seed);
    let mut pending = requests.into_iter().zip(offsets).peekable();
    let mut responses = Vec::new();
    let wall0 = Instant::now();
    loop {
        // submit every request whose arrival time has passed, stamping the
        // *scheduled* arrival — a submission delayed by a long prefill or
        // decode tick still charges that delay to queue-wait/TTFT (exactly
        // the congestion the open-loop regime exists to measure)
        while let Some((mut req, at)) =
            pending.next_if(|(_, at)| wall0.elapsed().as_secs_f64() >= *at)
        {
            req.arrival = wall0 + std::time::Duration::from_secs_f64(at);
            let _ = server.submit(req); // rejections already counted
        }
        for ev in server.step()? {
            if let Event::Done { response } = ev {
                responses.push(response);
            }
        }
        if pending.peek().is_none() && server.is_idle() {
            break;
        }
        // idle gap before the next scheduled arrival: sleep most of it
        // (the last millisecond spins for sub-ms submission precision)
        if server.is_idle() {
            if let Some((_, at)) = pending.peek() {
                let gap = *at - wall0.elapsed().as_secs_f64();
                if gap > 2e-3 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(gap - 1e-3));
                } else {
                    std::hint::spin_loop();
                }
            }
        }
    }
    server.metrics.wall_secs = wall0.elapsed().as_secs_f64();
    responses.sort_by_key(|r| r.id);
    Ok(ServeReport { responses, metrics: server.reset_metrics(), engine: server.engine.name() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_schedule_is_deterministic_and_rate_shaped() {
        let a = poisson_arrivals(256, 100.0, 5);
        let b = poisson_arrivals(256, 100.0, 5);
        assert_eq!(a, b, "same seed must replay the same schedule");
        let c = poisson_arrivals(256, 100.0, 6);
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.windows(2).all(|w| w[1] > w[0]), "offsets strictly increase");
        // mean inter-arrival ≈ 1/rate (law of large numbers, loose bound)
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.01).abs() < 0.005, "mean gap {mean_gap} far from 10ms");
    }
}
