//! Execution engines behind the coordinator: the pure-Rust model and the
//! PJRT artifact path (bucketed prefill/decode executables, per-sequence
//! host-side KV slabs packed into batch tensors per step).

use super::request::{greedy, sample, Request, SamplingParams};
use crate::adapters::{AdapterFactors, AdapterRegistry, BASE_ADAPTER};
use crate::kvquant::{KvBits, KvPool, KvQuantCfg, PrefixCache};
use crate::model::{DecodeRow, DecodeScratch, Model};
use crate::obs::quality::{self, KvSealObs};
use crate::obs::{self, Counter, Gauge, Histogram, Registry};
use crate::runtime::{ExecutorHandle, HostTensor, Manifest};
use crate::tensor::Matrix;
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// In-flight sequence state owned by the server.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    /// prompt + generated tokens
    pub tokens: Vec<usize>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub last_logits: Vec<f32>,
    /// tenant adapter id this sequence is served under
    pub adapter: String,
    /// per-request sampling policy
    pub params: SamplingParams,
    /// generation ends when a sampled token lands in this set
    pub stop_tokens: Vec<usize>,
    /// the sequence's private seeded sampling stream
    pub rng: Rng,
    /// a sampled token hit the stop set (set by the server)
    pub stopped: bool,
    /// prompt tokens whose KV is committed (shared-prefix forks start > 0;
    /// chunked prefill advances it; == `prompt_len` once decodable)
    pub prefilled: usize,
    /// completion deadline relative to the request's arrival, in
    /// milliseconds (0 = none); the server fails the sequence when it
    /// expires in flight
    pub deadline_ms: u64,
}

impl SeqState {
    /// Sequence state for an admitted request. `max_seq` caps `max_new` so
    /// the sequence can never outgrow the engine.
    pub fn admit(req: &Request, max_seq: usize) -> SeqState {
        SeqState {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt.clone(),
            max_new: req.max_new_tokens.min(max_seq.saturating_sub(1)),
            last_logits: vec![],
            adapter: req.adapter.clone(),
            params: req.params.clone(),
            stop_tokens: req.stop_tokens.clone(),
            rng: req.params.rng_for(req.id),
            stopped: false,
            prefilled: 0,
            deadline_ms: req.deadline_ms,
        }
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The whole prompt's KV is committed — the sequence can decode.
    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.prompt_len
    }

    pub fn done(&self) -> bool {
        self.generated() >= self.max_new
    }

    /// Generation over: budget exhausted, stop token sampled, or the
    /// context window is full.
    pub fn finished(&self, max_seq: usize) -> bool {
        self.stopped || self.done() || self.tokens.len() >= max_seq
    }

    /// Sample the next token from `last_logits` under this sequence's
    /// sampling policy (greedy by default; advances the seeded stream
    /// otherwise).
    pub fn next_token(&mut self) -> usize {
        // split borrows: logits/params are read-only, the rng advances
        let logits = std::mem::take(&mut self.last_logits);
        let tok = sample(&logits, &self.params, &mut self.rng);
        self.last_logits = logits;
        tok
    }
}

pub trait Engine {
    /// Max total sequence length supported.
    fn max_seq(&self) -> usize;
    /// Prefill each sequence's prompt; fills `last_logits`.
    fn prefill(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()>;

    /// Can [`Self::admit_seqs`] + [`Self::prefill_chunk`] drive this
    /// engine's prefill incrementally? Engines answering false (fixed-shape
    /// artifact paths) are served with one whole-batch [`Self::prefill`]
    /// at admission — the pre-continuous-batching schedule.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Admit sequences without computing anything: validate the batch,
    /// pin tenant state, attach any shared prompt prefix (setting
    /// `prefilled` past the shared tokens), and reserve KV for the
    /// remainder. All-or-nothing: on error no sequence keeps pins or
    /// storage. Only meaningful when [`Self::supports_chunked_prefill`].
    fn admit_seqs(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        let _ = seqs;
        Ok(())
    }

    /// Advance one admitted sequence's prefill by up to `budget` tokens
    /// (rounded to the engine's chunking granularity, at least one chunk).
    /// Returns the tokens actually computed; fills `last_logits` when the
    /// prompt completes. The default whole-prompt fallback keeps
    /// non-chunking engines correct behind the same call.
    fn prefill_chunk(&mut self, seq: &mut SeqState, budget: usize) -> anyhow::Result<usize> {
        let _ = budget;
        let n = seq.prompt_len - seq.prefilled;
        self.prefill(std::slice::from_mut(seq))?;
        seq.prefilled = seq.prompt_len;
        Ok(n)
    }

    /// How many of this prompt's leading tokens a prefix cache would
    /// serve for free right now (0 for engines without one). Admission
    /// uses it to charge a request only its unshared suffix.
    fn prefix_hit_tokens(&self, adapter: &str, prompt: &[usize]) -> usize {
        let _ = (adapter, prompt);
        0
    }
    /// One decode step for all sequences (token already appended by the
    /// server); refreshes `last_logits`. Implementations may batch or
    /// regroup internally but must NOT reorder the slice — the server
    /// keeps per-sequence timing state index-aligned with it.
    fn decode(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()>;
    /// Free per-sequence state (KV storage included).
    fn release(&mut self, id: u64);
    fn name(&self) -> String;

    /// Size the engine's KV store from a byte budget (`None` = worst case:
    /// `max_concurrent` dense f32 sequences of `max_seq` tokens). Called
    /// once by `Server::new`, before any sequence is admitted. Engines
    /// without an owned KV pool ignore it.
    fn kv_init(&mut self, budget_bytes: Option<usize>, max_concurrent: usize) {
        let _ = (budget_bytes, max_concurrent);
    }

    /// Can the engine's KV store admit new sequences whose worst-case
    /// total lengths (prompt + capped `max_new_tokens`) are `seq_tokens`?
    /// Admission is by **actual** requested footprint, not `max_seq`
    /// worst case, so short requests pack far more densely. Engines
    /// without an owned pool always say yes (the server's
    /// `max_concurrent` cap still bounds them).
    fn kv_can_admit(&self, seq_tokens: &[usize]) -> bool {
        let _ = seq_tokens;
        true
    }

    /// Can this engine serve the given tenant right now? Used by the
    /// server to reject bad submissions before they consume queue slots
    /// (and again at admission, in case the adapter was evicted while the
    /// request was queued). Engines without a registry serve only the
    /// base tenant.
    fn supports_adapter(&self, adapter: &str) -> bool {
        adapter == BASE_ADAPTER
    }

    /// Export engine-owned occupancy gauges (KV pool, prefix cache,
    /// adapter registry, ...) into the server's metrics registry. Called
    /// once per [`Server::step`](super::Server::step); engines cache their
    /// handles on the first call. Default: nothing to report.
    fn observe(&mut self, reg: &Registry) {
        let _ = reg;
    }

    /// Install quantization-quality telemetry into `reg`: per-layer weight
    /// quant-error gauges, per-tier KV seal-error histograms (the int4
    /// tier arms the flight recorder above `seal_err_threshold`), and
    /// block-heat export. Strictly observe-only — served token streams
    /// must stay bitwise identical with telemetry installed. Called once
    /// by `Server::new` after [`Self::kv_init`]. Default: the engine has
    /// nothing to report.
    fn install_quality(&mut self, reg: &Arc<Registry>, seal_err_threshold: f64) {
        let _ = (reg, seal_err_threshold);
    }

    /// Logit-drift sentinel: re-run sequence `s`'s most recent decode step
    /// through the engine's reference path (against a bit-exact shadow
    /// copy of its KV state) and compare with the logits actually served.
    /// Returns `(top1_agree, max_abs_drift)`, or `None` when the engine
    /// has no reference path or the probe could not run (e.g. the pool
    /// cannot back the shadow). Must not perturb the sequence, its KV
    /// state, or its sampling stream.
    fn sentinel_probe(&mut self, s: &SeqState) -> Option<(bool, f64)> {
        let _ = s;
        None
    }

    /// Release engine-owned caches that outlive sequences (e.g. the
    /// shared-prefix trie's pinned KV blocks). `Server::drain` calls
    /// this after in-flight work finishes or fails, so a drained server
    /// leaves the pool and registry empty. Default: nothing cached.
    fn flush_caches(&mut self) {}
}

// ---------------------------------------------------------------- native

/// Fallback pool sizing for engines used without a `Server` (direct
/// prefill/decode in tests and examples): this many worst-case sequences.
const DEFAULT_POOL_SEQS: usize = 64;

/// Rust-native engine: a block-pooled (optionally quantized) KV store
/// ([`KvPool`]) on the `model::Model`, plus an [`AdapterRegistry`] of
/// hot-swappable per-tenant LoRDS scale adapters over the model's shared
/// packed base.
///
/// Every linear in the prefill/decode loop dispatches through
/// `LinearWeight::forward` (or its adapter-override variant), i.e. the
/// fused bit-packed kernels (`kernels::fused`) for quantized formats — the
/// engine never touches a dense dequantized weight, for any tenant. With
/// `kv_bits` at 8 or 4 the KV cache is bit-packed too, and attention runs
/// fused over the packed blocks (`kvquant::attention`).
///
/// Decode is **batched**: one tick stacks every running sequence's
/// activation into B×d matrices, stable-groups them by tenant, and runs
/// each fused kernel once per tenant-group
/// ([`Model::decode_batch_pooled`]) — per-tick packed-weight traffic is
/// `groups × bytes(W)`, not `B × bytes(W)` — while pooled attention for
/// the batch fans out across the global thread pool. Activations live in
/// a reusable per-engine [`DecodeScratch`] arena (no per-token
/// allocation). The old per-sequence loop survives as
/// [`NativeEngine::decode_reference`] for parity tests and benches.
///
/// Tenant routing: each sequence's adapter id is pinned in the registry at
/// prefill admission and released with the sequence, so a hot eviction of
/// an in-flight adapter is deferred, never unsafe.
pub struct NativeEngine {
    pub model: Model,
    pool: KvPool,
    kv_cfg: KvQuantCfg,
    label: String,
    registry: AdapterRegistry,
    /// adapter id pinned per in-flight sequence (base tenant omitted).
    seq_adapter: HashMap<u64, String>,
    /// reusable activation arena for the batched decode tick.
    scratch: DecodeScratch,
    /// tenant-groups formed by the last decode tick (weight streams/tick).
    last_decode_groups: usize,
    /// shared-prefix trie over sealed prompt blocks (see
    /// [`kvquant::prefix`](crate::kvquant::prefix)).
    prefix: PrefixCache,
    /// metric handles cached by the first [`Engine::observe`] call.
    obs: Option<EngineObs>,
    /// quality-telemetry state installed by [`Engine::install_quality`].
    quality: Option<QualityState>,
}

/// Reserved sequence id for the logit-drift sentinel's shadow decode.
/// Never collides with served sequences (request ids count up from 0) and
/// is released before [`Engine::sentinel_probe`] returns.
const SENTINEL_SEQ: u64 = u64::MAX;

/// State behind [`Engine::install_quality`]: the shared metrics registry
/// plus the int4 seal-error threshold that arms the flight recorder.
struct QualityState {
    reg: Arc<Registry>,
    seal_err_threshold: f64,
}

/// Registry handles for the engine-owned gauges, resolved once (the
/// registry lookup locks; the handles are plain atomics).
struct EngineObs {
    kv_blocks_used: Gauge,
    kv_blocks_capacity: Gauge,
    kv_staging_bytes: Gauge,
    kv_used_bytes: Gauge,
    kv_peak_bytes: Gauge,
    kv_active_sequences: Gauge,
    prefix_cached_blocks: Gauge,
    adapter_resident_bytes: Gauge,
    adapter_residents: Gauge,
    adapter_evictions: Counter,
    /// registry evictions already exported (the stat is cumulative).
    evictions_seen: u64,
    /// tenant-groups per batched decode tick (weight streams per tick).
    decode_tenant_groups: Histogram,
    /// ticks since each referenced KV block was last read (per tick).
    kv_block_coldness: Histogram,
}

impl EngineObs {
    fn new(reg: &Registry) -> EngineObs {
        EngineObs {
            kv_blocks_used: reg.gauge_with_help(
                "lords_kv_blocks_used",
                &[],
                "Sealed KV blocks currently allocated.",
            ),
            kv_blocks_capacity: reg.gauge_with_help(
                "lords_kv_blocks_capacity",
                &[],
                "Total KV blocks the pool can hold.",
            ),
            kv_staging_bytes: reg.gauge_with_help(
                "lords_kv_staging_bytes",
                &[],
                "Dense f32 staging-tail bytes held by active sequences.",
            ),
            kv_used_bytes: reg.gauge_with_help(
                "lords_kv_used_bytes",
                &[],
                "Bytes of sealed KV storage currently in use.",
            ),
            kv_peak_bytes: reg.gauge_with_help(
                "lords_kv_peak_bytes",
                &[],
                "High-water mark of sealed KV bytes since pool creation.",
            ),
            kv_active_sequences: reg.gauge_with_help(
                "lords_kv_active_sequences",
                &[],
                "Sequences holding KV reservations.",
            ),
            prefix_cached_blocks: reg.gauge_with_help(
                "lords_prefix_cached_blocks",
                &[],
                "Sealed blocks pinned by the shared-prefix cache.",
            ),
            adapter_resident_bytes: reg.gauge_with_help(
                "lords_adapter_resident_bytes",
                &[],
                "Bytes of resident adapter factors.",
            ),
            adapter_residents: reg.gauge_with_help(
                "lords_adapter_residents",
                &[],
                "Adapters currently resident in the registry.",
            ),
            adapter_evictions: reg.counter_with_help(
                "lords_adapter_evictions_total",
                &[],
                "Adapters evicted from the registry to fit the budget.",
            ),
            evictions_seen: 0,
            decode_tenant_groups: reg.histogram_with_help(
                "lords_decode_tenant_groups",
                &[],
                &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0],
                "Tenant groups formed per batched decode tick.",
            ),
            kv_block_coldness: reg.histogram_with_help(
                quality::COLDNESS_FAMILY,
                &[],
                quality::COLDNESS_BOUNDS,
                "Ticks since each referenced KV block was last read, sampled every tick.",
            ),
        }
    }
}

impl NativeEngine {
    pub fn new(model: Model, label: &str) -> NativeEngine {
        Self::with_registry_kv(model, label, AdapterRegistry::unbounded(), KvQuantCfg::default())
    }

    /// Engine with an explicit KV-cache format (f32 | int8 | int4 blocks).
    pub fn with_kv(model: Model, label: &str, kv: KvQuantCfg) -> NativeEngine {
        Self::with_registry_kv(model, label, AdapterRegistry::unbounded(), kv)
    }

    /// Engine with an explicit adapter registry (byte-budgeted multi-tenant
    /// serving).
    pub fn with_registry(model: Model, label: &str, registry: AdapterRegistry) -> NativeEngine {
        Self::with_registry_kv(model, label, registry, KvQuantCfg::default())
    }

    /// Engine with both an adapter registry and a KV-cache format.
    pub fn with_registry_kv(
        model: Model,
        label: &str,
        registry: AdapterRegistry,
        kv: KvQuantCfg,
    ) -> NativeEngine {
        crate::info!(
            "native engine[{label}]: {:.2} MiB packed weights ({} fp32 side-car params), {} KV",
            model.weight_bytes() as f64 / (1024.0 * 1024.0),
            model.float_params(),
            kv.bits.name()
        );
        let cfg = &model.cfg;
        let per_seq = cfg.max_seq.div_ceil(kv.block_tokens);
        let pool =
            KvPool::new(kv, cfg.n_layers, cfg.d_model, DEFAULT_POOL_SEQS * per_seq);
        NativeEngine {
            model,
            pool,
            kv_cfg: kv,
            label: label.to_string(),
            registry,
            seq_adapter: HashMap::new(),
            scratch: DecodeScratch::new(),
            last_decode_groups: 0,
            prefix: PrefixCache::new(),
            obs: None,
            quality: None,
        }
    }

    /// The engine's KV pool (capacity, peak bytes, per-block cost).
    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// The shared-prefix cache (hit/miss counters, cached block count).
    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.prefix
    }

    /// Drop every cached prefix block. After this (with no sequences in
    /// flight) the pool is exactly as empty as before serving — the
    /// leak-check tests' final step.
    pub fn flush_prefix_cache(&mut self) {
        self.prefix.flush(&mut self.pool);
    }

    /// Enable/disable prefix sharing (flushes the cache when turning it
    /// off). The serve bench's no-sharing baseline.
    pub fn set_prefix_sharing(&mut self, enabled: bool) {
        if !enabled {
            self.prefix.flush(&mut self.pool);
            self.prefix = PrefixCache::disabled();
        } else if !self.prefix.enabled() {
            self.prefix = PrefixCache::new();
        }
    }

    /// Validate a tenant's factors against this engine's model, then
    /// hot-register them (evicting LRU adapters to fit the byte budget).
    pub fn register_adapter(&mut self, id: &str, factors: AdapterFactors) -> anyhow::Result<()> {
        factors.validate_against(&self.model)?;
        if let Some(q) = &self.quality {
            quality::record_adapter_weight_errors(&q.reg, id, &self.model, &factors);
        }
        self.registry.register(id, factors)
    }

    /// Evict a tenant; deferred (returns false) while in-flight sequences
    /// pin it.
    pub fn evict_adapter(&mut self, id: &str) -> bool {
        self.registry.evict(id)
    }

    pub fn registry(&self) -> &AdapterRegistry {
        &self.registry
    }

    /// Serving weight footprint in bytes: the shared packed base (counted
    /// once) + fp32 side-cars + every resident tenant adapter.
    pub fn weight_bytes(&self) -> usize {
        self.model.weight_bytes() + self.registry.used_bytes()
    }

    /// Worst-case KV tokens one sequence reserves (prompt + capped
    /// `max_new`, never past `max_seq`) — must agree with
    /// [`Request::required_kv_tokens`] so admission and reservation see
    /// the same number.
    fn seq_reservation(&self, s: &SeqState) -> usize {
        (s.prompt_len + s.max_new).min(self.model.cfg.max_seq)
    }

    /// Tenant-groups formed by the most recent decode tick — the number
    /// of times each packed weight was streamed that tick (vs. once per
    /// sequence on the old per-sequence loop).
    pub fn last_decode_groups(&self) -> usize {
        self.last_decode_groups
    }

    /// The pre-batching decode path — one [`Model::decode_pooled`] call
    /// per sequence, each re-streaming every packed weight. Kept as the
    /// token-identity reference for the batched tick (tests and the
    /// decode_batch bench); the serving loop uses [`Engine::decode`].
    pub fn decode_reference(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        for s in seqs.iter_mut() {
            let tok = *s
                .tokens
                .last()
                .ok_or_else(|| anyhow::anyhow!("sequence {} has no tokens to decode", s.id))?;
            let factors = self.registry.get(&s.adapter);
            s.last_logits = self.model.decode_pooled(tok, &mut self.pool, s.id, factors)?;
        }
        Ok(())
    }

    /// (Re)install the pool's seal-error sink from the current quality
    /// state. Packed KV tiers get a per-tier seal-error histogram; only
    /// the int4 tier arms the flight-recorder breach threshold (int8 seal
    /// error sits orders of magnitude below any useful alarm level).
    fn install_seal_obs(&mut self) {
        let Some(q) = &self.quality else { return };
        let obs = match self.kv_cfg.bits {
            KvBits::F32 => None,
            bits => {
                let threshold =
                    if matches!(bits, KvBits::Int4) { q.seal_err_threshold } else { 0.0 };
                Some(KvSealObs::new(&q.reg, bits.name(), threshold))
            }
        };
        self.pool.set_seal_obs(obs);
    }

    /// Build the sentinel's shadow KV state for `s` — fork its sealed
    /// blocks zero-copy, then copy the dense staging tail bit-exactly —
    /// and run one reference decode step over it. `len` is the token
    /// count *before* the step being replayed; `blocks` are the sealed
    /// block ids covering `len / block_tokens` whole blocks. The caller
    /// releases [`SENTINEL_SEQ`] whether or not this succeeds.
    ///
    /// The tail copy is exact for every KV tier: staging rows are dense
    /// f32, a seal never clears them, and the decode tick wrote only slot
    /// `len % block_tokens` — outside the copied range.
    fn sentinel_decode(
        &mut self,
        s: &SeqState,
        token: usize,
        len: usize,
        blocks: &[usize],
    ) -> anyhow::Result<Vec<f32>> {
        let bt = self.pool.block_tokens();
        let shared = blocks.len() * bt;
        anyhow::ensure!(
            self.pool.fork_at_block(SENTINEL_SEQ, blocks, shared),
            "sentinel shadow fork failed"
        );
        let tail = len - shared;
        if tail > 0 {
            let d = self.model.cfg.d_model;
            let mut crow = vec![0u8; d];
            for layer in 0..self.model.cfg.n_layers {
                let mut k = Matrix::zeros(tail, d);
                let mut v = Matrix::zeros(tail, d);
                {
                    let view = self.pool.view(s.id, layer, len);
                    for r in 0..tail {
                        view.k_row_into(shared + r, &mut crow, k.row_mut(r));
                        view.v_row_into(shared + r, &mut crow, v.row_mut(r));
                    }
                }
                self.pool.append_rows(SENTINEL_SEQ, layer, shared, &k, &v)?;
            }
        }
        self.pool.commit(SENTINEL_SEQ, len);
        // The serving decode already recorded this tick's seal errors for
        // `s`; the shadow's re-seal must not double-count them.
        let saved = self.pool.take_seal_obs();
        let out = self.model.decode_pooled(
            token,
            &mut self.pool,
            SENTINEL_SEQ,
            self.registry.get(&s.adapter),
        );
        self.pool.set_seal_obs(saved);
        out
    }
}

impl Engine for NativeEngine {
    fn max_seq(&self) -> usize {
        self.model.cfg.max_seq
    }

    fn kv_init(&mut self, budget_bytes: Option<usize>, max_concurrent: usize) {
        if self.pool.active_sequences() > 0 {
            crate::info!("kv_init skipped: {} sequences in flight", self.pool.active_sequences());
            return;
        }
        let cfg = &self.model.cfg;
        // the default budget fits exactly `max_concurrent` dense f32
        // sequences (sealed blocks + one staging tail each) — quantized
        // formats then fit several times more sequences in the same bytes
        let per_seq = cfg.max_seq.div_ceil(self.kv_cfg.block_tokens);
        let budget = budget_bytes.unwrap_or(
            max_concurrent * (per_seq + 1) * self.pool.dense_block_bytes(),
        );
        self.pool = KvPool::with_byte_budget(
            self.kv_cfg,
            cfg.n_layers,
            cfg.d_model,
            budget,
            cfg.max_seq,
        );
        // the old pool (and any prefix blocks pinned in it) is gone — start
        // the trie over against the new storage
        self.prefix =
            if self.prefix.enabled() { PrefixCache::new() } else { PrefixCache::disabled() };
        // the old pool took its seal-error sink with it
        self.install_seal_obs();
        crate::info!(
            "native engine[{}]: KV pool {} blocks x {} B ({} KV, {:.1} MiB budget)",
            self.label,
            self.pool.capacity_blocks(),
            self.pool.block_bytes(),
            self.kv_cfg.bits.name(),
            budget as f64 / (1024.0 * 1024.0)
        );
    }

    fn kv_can_admit(&self, seq_tokens: &[usize]) -> bool {
        // cached prefix blocks nothing references but the trie are
        // reclaimable: admit_seqs evicts them on demand before reserving
        self.pool
            .can_admit_lengths_reclaimable(seq_tokens, self.prefix.evictable_blocks(&self.pool))
    }

    fn supports_adapter(&self, adapter: &str) -> bool {
        self.registry.contains(adapter)
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefix_hit_tokens(&self, adapter: &str, prompt: &[usize]) -> usize {
        self.prefix.probe(adapter, prompt, self.pool.block_tokens())
    }

    fn admit_seqs(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        if let Some(kind) = crate::fault::point!("engine.admit") {
            crate::fault::apply_fallible("engine.admit", kind)?;
        }
        // Validate the whole batch before taking any pin or KV storage: a
        // bad tenant id or an over-committed pool must fail the batch
        // cleanly, not leak pins and blocks for the sequences processed
        // before it.
        for s in seqs.iter() {
            anyhow::ensure!(
                self.registry.contains(&s.adapter),
                "unknown or evicting adapter '{}' (seq {})",
                s.adapter,
                s.id
            );
            anyhow::ensure!(
                s.prompt_len <= self.model.cfg.max_seq,
                "prompt {} > max_seq {} (seq {})",
                s.prompt_len,
                self.model.cfg.max_seq,
                s.id
            );
            anyhow::ensure!(
                self.pool.seq_len(s.id).is_none(),
                "sequence id {} is already in flight",
                s.id
            );
        }
        let bt = self.pool.block_tokens();
        // longest cached prefix per sequence; each hit block gets a
        // temporary pin so the eviction below can never free it
        let hits: Vec<Vec<usize>> = seqs
            .iter()
            .map(|s| self.prefix.lookup(&s.adapter, &s.tokens[..s.prompt_len], bt))
            .collect();
        for b in hits.iter().flatten() {
            let pinned = self.pool.retain_block(*b);
            debug_assert!(pinned, "cached blocks are live");
        }
        let unpin = |pool: &mut KvPool| {
            for b in hits.iter().flatten() {
                pool.release_block(*b);
            }
        };
        // each sequence is charged only its unshared suffix (the shared
        // tokens are block-aligned, so suffix blocks = total − shared)
        let lens: Vec<usize> = seqs
            .iter()
            .zip(&hits)
            .map(|(s, h)| self.seq_reservation(s) - h.len() * bt)
            .collect();
        // reclaim idle cached blocks (LRU leaves first) until the batch fits
        while !self.pool.can_admit_lengths(&lens) && self.prefix.evict(&mut self.pool, 1) > 0 {}
        if !self.pool.can_admit_lengths(&lens) {
            unpin(&mut self.pool);
            anyhow::bail!(
                "KV pool cannot admit {} sequences needing {:?} tokens ({} blocks free)",
                seqs.len(),
                lens,
                self.pool.free_blocks()
            );
        }
        for (s, hit) in seqs.iter_mut().zip(&hits) {
            let pinned = self.registry.acquire(&s.adapter);
            debug_assert!(pinned, "adapter '{}' validated above", s.adapter);
            if s.adapter != BASE_ADAPTER {
                self.seq_adapter.insert(s.id, s.adapter.clone());
            }
            if !hit.is_empty() {
                let shared = hit.len() * bt;
                let forked = self.pool.fork_at_block(s.id, hit, shared);
                debug_assert!(forked, "hit blocks are sealed and pinned");
                if forked {
                    s.prefilled = shared;
                }
            }
            // reserve the request's actual worst case (prompt + max_new,
            // capped at max_seq): decode can never run out mid-sequence,
            // and short requests no longer hold max_seq-sized reservations
            let need = self.seq_reservation(s);
            let reserved = self.pool.reserve(s.id, need);
            debug_assert!(reserved, "admission validated above");
        }
        unpin(&mut self.pool);
        Ok(())
    }

    /// One block-aligned chunk of `seq`'s prefill: at most `budget` tokens
    /// (rounded down to whole blocks, minimum one block, capped at the
    /// remaining prompt). Newly sealed full prompt blocks are published to
    /// the prefix trie as they appear, so concurrent sessions can fork
    /// them while this prompt is still prefilling.
    fn prefill_chunk(&mut self, s: &mut SeqState, budget: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(
            s.prefilled < s.prompt_len,
            "prefill_chunk on completed sequence {}",
            s.id
        );
        if let Some(kind) = crate::fault::point!("engine.prefill") {
            crate::fault::apply_fallible("engine.prefill", kind)?;
        }
        let bt = self.pool.block_tokens();
        let pos0 = s.prefilled;
        let remaining = s.prompt_len - pos0;
        let take = if budget >= remaining {
            remaining
        } else {
            ((budget / bt).max(1) * bt).min(remaining)
        };
        let _span = obs::span!("engine.prefill_chunk", take);
        let end = pos0 + take;
        // `resolve` is the fault plane's adapter-corruption site: a fired
        // fault surfaces here as a contained per-sequence error instead
        // of silently computing base-weight logits for a tenant.
        let factors = self.registry.resolve(&s.adapter);
        anyhow::ensure!(
            s.adapter == BASE_ADAPTER || factors.is_some(),
            "adapter artifact for '{}' failed to resolve (seq {})",
            s.adapter,
            s.id
        );
        let logits = self.model.prefill_chunk_pooled(
            &s.tokens[pos0..end],
            pos0,
            s.prompt_len,
            &mut self.pool,
            s.id,
            factors,
        )?;
        s.prefilled = end;
        if let Some(l) = logits {
            s.last_logits = l;
        }
        let sealed = end / bt;
        if sealed > pos0 / bt {
            self.prefix.publish(
                &s.adapter,
                &s.tokens[..s.prompt_len],
                bt,
                sealed,
                &mut self.pool,
                s.id,
            );
        }
        Ok(take)
    }

    /// Whole-prompt prefill = admission + chunks run to completion with an
    /// unbounded budget (one chunk per sequence; a prefix hit shrinks it
    /// to the unshared suffix).
    fn prefill(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        self.admit_seqs(seqs)?;
        for s in seqs.iter_mut() {
            while !s.prefill_done() {
                self.prefill_chunk(s, usize::MAX)?;
            }
        }
        Ok(())
    }

    /// One **batched** decode tick: the whole running set advances through
    /// [`Model::decode_batch_pooled`] in one call. Sequences are
    /// stable-grouped by tenant first (re-establishing the batcher's
    /// grouping, which interleaves as batches admitted at different ticks
    /// mix), so each fused weight kernel runs once per tenant-group
    /// instead of once per sequence. Results scatter back by original
    /// index — the slice order is never changed.
    fn decode(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        if seqs.is_empty() {
            return Ok(());
        }
        if let Some(kind) = crate::fault::point!("engine.decode") {
            crate::fault::apply_fallible("engine.decode", kind)?;
        }
        let _span = obs::span!("engine.decode", seqs.len());
        // Adapter artifacts must resolve for every tenant row before any
        // KV is written: a corrupt artifact (the `adapter.resolve` fault
        // site) fails the tick as an error the server can contain, never
        // a silent fall-through to base weights.
        for s in seqs.iter() {
            anyhow::ensure!(
                s.adapter == BASE_ADAPTER || self.registry.resolve(&s.adapter).is_some(),
                "adapter artifact for '{}' failed to resolve (seq {})",
                s.adapter,
                s.id
            );
        }
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by(|&i, &j| seqs[i].adapter.cmp(&seqs[j].adapter)); // stable
        let rows: Vec<DecodeRow<'_>> = order
            .iter()
            .map(|&i| {
                let s = &seqs[i];
                DecodeRow {
                    seq: s.id,
                    // PANIC-OK: a running sequence always holds ≥1 token —
                    // admission rejects empty prompts and decode only appends.
                    token: *s.tokens.last().unwrap(),
                    // pinned at prefill ⇒ still resident even if eviction
                    // is pending
                    adapter: self.registry.get(&s.adapter),
                }
            })
            .collect();
        // the model reports the groups it actually formed (factor-instance
        // identity), the ground truth for weight streams this tick
        self.last_decode_groups =
            self.model.decode_batch_pooled(&rows, &mut self.pool, &mut self.scratch)?;
        if let Some(o) = &self.obs {
            o.decode_tenant_groups.observe(self.last_decode_groups as f64);
        }
        for (r, &i) in order.iter().enumerate() {
            let s = &mut seqs[i];
            s.last_logits.clear();
            s.last_logits.extend_from_slice(self.scratch.logits().row(r));
            if let Some(kind) = crate::fault::point!("engine.logits") {
                match kind {
                    // Non-finite numeric excursion: the server's sentinel
                    // must quarantine this sequence before sampling.
                    crate::fault::FaultKind::CorruptLogits if !s.last_logits.is_empty() => {
                        s.last_logits[0] = f32::NAN;
                    }
                    crate::fault::FaultKind::Latency => crate::fault::latency_spin(),
                    _ => {}
                }
            }
        }
        Ok(())
    }

    fn release(&mut self, id: u64) {
        self.pool.release(id);
        if let Some(adapter) = self.seq_adapter.remove(&id) {
            self.registry.release(&adapter);
        }
    }

    fn flush_caches(&mut self) {
        self.flush_prefix_cache();
    }

    fn name(&self) -> String {
        format!("native/{}", self.label)
    }

    /// Refresh the engine-owned gauges: KV pool occupancy (blocks, bytes,
    /// staging tails), prefix-cache size, and adapter-registry residency.
    /// Eviction counts export as the delta against the registry's
    /// cumulative stat.
    fn observe(&mut self, reg: &Registry) {
        let o = self.obs.get_or_insert_with(|| EngineObs::new(reg));
        // advance the heat clock, then export how stale every referenced
        // block's last read is (attention touches sealed blocks through
        // `KvPool::view`, which stamps them)
        self.pool.begin_heat_tick();
        for ticks in self.pool.block_coldness() {
            o.kv_block_coldness.observe(ticks as f64);
        }
        o.kv_blocks_used.set(self.pool.used_blocks() as i64);
        o.kv_blocks_capacity.set(self.pool.capacity_blocks() as i64);
        o.kv_staging_bytes
            .set((self.pool.active_sequences() * self.pool.staging_bytes()) as i64);
        o.kv_used_bytes.set(self.pool.used_bytes() as i64);
        o.kv_peak_bytes.set(self.pool.peak_bytes() as i64);
        o.kv_active_sequences.set(self.pool.active_sequences() as i64);
        o.prefix_cached_blocks.set(self.prefix.cached_blocks() as i64);
        let stats = self.registry.stats();
        o.adapter_resident_bytes.set(stats.used_bytes as i64);
        o.adapter_residents.set(stats.residents as i64);
        let evictions = stats.evictions as u64;
        o.adapter_evictions.add(evictions.saturating_sub(o.evictions_seen));
        o.evictions_seen = evictions;
    }

    /// Record weight quant-error gauges for the packed base (QAT shadows)
    /// and every resident tenant adapter, then install the pool's
    /// seal-error sink. Later [`NativeEngine::register_adapter`] calls
    /// keep recording against the same registry.
    fn install_quality(&mut self, reg: &Arc<Registry>, seal_err_threshold: f64) {
        self.quality = Some(QualityState { reg: Arc::clone(reg), seal_err_threshold });
        quality::record_self_weight_errors(reg, &self.model);
        for id in self.registry.resident_ids() {
            if let Some(factors) = self.registry.get(&id) {
                quality::record_adapter_weight_errors(reg, &id, &self.model, factors);
            }
        }
        self.install_seal_obs();
    }

    /// Replay `s`'s latest decode step through [`Model::decode_pooled`]
    /// (the per-sequence reference path) on a bit-exact shadow of its KV
    /// state. The sealed prefix is forked zero-copy; the staging tail is
    /// copied dense. Because the batched tick is token-identical to the
    /// reference path and the shadow state is bit-exact, a healthy engine
    /// reports `(true, 0.0)` — any drift is a real quality regression.
    fn sentinel_probe(&mut self, s: &SeqState) -> Option<(bool, f64)> {
        if s.last_logits.is_empty() {
            return None;
        }
        let token = *s.tokens.last()?;
        // the pool holds the post-decode state; the step we replay saw
        // one token less
        let len = self.pool.seq_len(s.id)?.checked_sub(1)?;
        let bt = self.pool.block_tokens();
        let mut blocks = Vec::with_capacity(len / bt);
        for bi in 0..len / bt {
            blocks.push(self.pool.block_id_at(s.id, bi * bt)?);
        }
        let probe = self.sentinel_decode(s, token, len, &blocks);
        self.pool.release(SENTINEL_SEQ);
        let probe = probe.ok()?;
        if probe.len() != s.last_logits.len() {
            return None;
        }
        let agree = greedy(&probe) == greedy(&s.last_logits);
        let drift = probe
            .iter()
            .zip(&s.last_logits)
            .map(|(a, b)| (f64::from(*a) - f64::from(*b)).abs())
            .fold(0.0, f64::max);
        Some((agree, drift))
    }
}

// ---------------------------------------------------------------- pjrt

/// Host-side KV slab for one sequence: [L, max_seq, h, hd] flattened, plus
/// the current length.
struct KvSlab {
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

/// PJRT engine executing `{mode}_prefill_b*` / `{mode}_decode_b*` artifacts.
///
/// Restrictions mirrored from the artifact shapes: prompts must be exactly
/// the prefill sequence length (the Table-6 protocol uses fixed-length
/// inputs), and batch sizes are padded up to the nearest bucket.
pub struct PjrtEngine {
    handle: ExecutorHandle,
    pub mode: String,
    /// model params in manifest order for the serving artifacts.
    params: Vec<HostTensor>,
    prefill_buckets: Vec<usize>,
    decode_buckets: Vec<usize>,
    pub prefill_seq: usize,
    max_seq: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    vocab: usize,
    slabs: HashMap<u64, KvSlab>,
}

impl PjrtEngine {
    /// `params` must match the `{mode}_prefill_b*` artifact's leading inputs
    /// (use `runtime::bridge::collect_params`).
    pub fn new(
        handle: ExecutorHandle,
        manifest: &Manifest,
        mode: &str,
        params: Vec<HostTensor>,
    ) -> anyhow::Result<PjrtEngine> {
        let m = &manifest.model;
        let mut prefill_buckets = vec![];
        let mut decode_buckets = vec![];
        let mut prefill_seq = 0;
        for (name, art) in &manifest.artifacts {
            if let Some(b) = name.strip_prefix(&format!("{mode}_prefill_b")) {
                prefill_buckets.push(b.parse()?);
                prefill_seq = art
                    .inputs
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("artifact {name} declares no inputs"))?
                    .dims[1];
            } else if let Some(b) = name.strip_prefix(&format!("{mode}_decode_b")) {
                decode_buckets.push(b.parse()?);
            }
        }
        anyhow::ensure!(!prefill_buckets.is_empty(), "no {mode} prefill artifacts");
        prefill_buckets.sort_unstable();
        decode_buckets.sort_unstable();
        Ok(PjrtEngine {
            handle,
            mode: mode.to_string(),
            params,
            prefill_buckets,
            decode_buckets,
            prefill_seq,
            max_seq: m.max_seq,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.d_model / m.n_heads,
            vocab: m.vocab,
            slabs: HashMap::new(),
        })
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.decode_buckets
    }

    fn bucket_geq(buckets: &[usize], n: usize) -> usize {
        // an empty bucket list falls back to n itself; the artifact lookup
        // then fails with a clean "no such artifact" error instead of a panic
        buckets.iter().copied().find(|&b| b >= n).or_else(|| buckets.last().copied()).unwrap_or(n)
    }

    fn slab_elems(&self) -> usize {
        self.n_layers * self.max_seq * self.n_heads * self.head_dim
    }

    /// Pack per-seq slabs into [L, b, S, h, hd].
    fn pack(&self, ids: &[u64], b: usize) -> (Vec<f32>, Vec<f32>) {
        let per_pos = self.n_heads * self.head_dim;
        let per_layer_seq = self.max_seq * per_pos;
        let total = self.n_layers * b * per_layer_seq;
        let mut k = vec![0.0f32; total];
        let mut v = vec![0.0f32; total];
        for (bi, id) in ids.iter().enumerate() {
            let slab = &self.slabs[id];
            for l in 0..self.n_layers {
                let src = l * per_layer_seq;
                let dst = (l * b + bi) * per_layer_seq;
                k[dst..dst + per_layer_seq].copy_from_slice(&slab.k[src..src + per_layer_seq]);
                v[dst..dst + per_layer_seq].copy_from_slice(&slab.v[src..src + per_layer_seq]);
            }
        }
        (k, v)
    }

    fn unpack(&mut self, ids: &[u64], b: usize, k: &[f32], v: &[f32], new_len: usize) {
        let per_pos = self.n_heads * self.head_dim;
        let per_layer_seq = self.max_seq * per_pos;
        for (bi, id) in ids.iter().enumerate() {
            // PANIC-OK: prefill inserts a slab for every id before unpack
            // runs; decode only passes resident ids.
            let slab = self.slabs.get_mut(id).unwrap();
            for l in 0..self.n_layers {
                let dst = l * per_layer_seq;
                let src = (l * b + bi) * per_layer_seq;
                slab.k[dst..dst + per_layer_seq].copy_from_slice(&k[src..src + per_layer_seq]);
                slab.v[dst..dst + per_layer_seq].copy_from_slice(&v[src..src + per_layer_seq]);
            }
            slab.len = new_len;
        }
    }

    fn cache_dims(&self, b: usize) -> Vec<usize> {
        vec![self.n_layers, b, self.max_seq, self.n_heads, self.head_dim]
    }
}

impl Engine for PjrtEngine {
    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn prefill(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        let Some(&max_prefill) = self.prefill_buckets.last() else {
            anyhow::bail!("no {}_prefill_b* artifacts", self.mode);
        };
        let mut idx = 0;
        while idx < seqs.len() {
            let n = (seqs.len() - idx).min(max_prefill);
            let b = Self::bucket_geq(&self.prefill_buckets, n);
            let chunk = &mut seqs[idx..(idx + n)];
            // tokens [b, prefill_seq] (pad rows by repeating the last seq)
            let mut toks = Vec::with_capacity(b * self.prefill_seq);
            for s in chunk.iter() {
                anyhow::ensure!(
                    s.adapter == BASE_ADAPTER,
                    "pjrt engine serves only the base tenant (seq {} asked for adapter '{}')",
                    s.id,
                    s.adapter
                );
                anyhow::ensure!(
                    s.prompt_len == self.prefill_seq,
                    "pjrt prefill requires prompt_len == {} (got {})",
                    self.prefill_seq,
                    s.prompt_len
                );
                toks.extend(s.tokens[..s.prompt_len].iter().map(|&t| t as i32));
            }
            for _ in n..b {
                let last = toks[toks.len() - self.prefill_seq..].to_vec();
                toks.extend(last);
            }
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::I32(toks, vec![b, self.prefill_seq]));
            let art = format!("{}_prefill_b{b}", self.mode);
            let out = self.handle.execute(&art, inputs)?;
            let logits = out[0].f32s();
            let kc = out[1].f32s();
            let vc = out[2].f32s();
            let ids: Vec<u64> = chunk.iter().map(|s| s.id).collect();
            for s in chunk.iter() {
                self.slabs.insert(
                    s.id,
                    KvSlab { k: vec![0.0; self.slab_elems()], v: vec![0.0; self.slab_elems()], len: 0 },
                );
            }
            self.unpack(&ids, b, kc, vc, self.prefill_seq);
            for (bi, s) in chunk.iter_mut().enumerate() {
                s.last_logits = logits[bi * self.vocab..(bi + 1) * self.vocab].to_vec();
            }
            idx += n;
        }
        Ok(())
    }

    fn decode(&mut self, seqs: &mut [SeqState]) -> anyhow::Result<()> {
        let Some(&max_bucket) = self.decode_buckets.last() else {
            anyhow::bail!("no {}_decode_b* artifacts", self.mode);
        };
        // continuous batching admits sequences at different times, so the
        // running set can be ragged in cache position; each decode artifact
        // takes a single `cur`, so group same-position sequences per call.
        // Grouping runs over an index permutation — the slice itself keeps
        // its order (the server's timing state is index-aligned with it).
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by_key(|&i| self.slabs[&seqs[i].id].len);
        let mut idx = 0;
        while idx < order.len() {
            let cur0 = self.slabs[&seqs[order[idx]].id].len;
            let mut n = 1;
            while idx + n < order.len()
                && n < max_bucket
                && self.slabs[&seqs[order[idx + n]].id].len == cur0
            {
                n += 1;
            }
            let b = Self::bucket_geq(&self.decode_buckets, n);
            let chunk = &order[idx..idx + n];
            let ids: Vec<u64> = chunk.iter().map(|&i| seqs[i].id).collect();
            let cur = cur0;
            anyhow::ensure!(cur < self.max_seq, "KV slab full");
            // PANIC-OK: a running sequence always holds ≥1 token —
            // admission rejects empty prompts and decode only appends.
            let last_tok = |i: &usize| *seqs[*i].tokens.last().unwrap() as i32;
            let mut toks: Vec<i32> = chunk.iter().map(last_tok).collect();
            // pad ids by repeating the first sequence (results discarded)
            let mut padded_ids = ids.clone();
            while padded_ids.len() < b {
                padded_ids.push(ids[0]);
                toks.push(toks[0]);
            }
            let (k, v) = self.pack(&padded_ids, b);
            let dims = self.cache_dims(b);
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::I32(toks, vec![b, 1]));
            inputs.push(HostTensor::F32(k, dims.clone()));
            inputs.push(HostTensor::F32(v, dims));
            inputs.push(HostTensor::scalar_i32(cur as i32));
            let art = format!("{}_decode_b{b}", self.mode);
            let out = self.handle.execute(&art, inputs)?;
            let logits = out[0].f32s();
            // only unpack the real (non-padded) sequences
            self.unpack(&ids, b, out[1].f32s(), out[2].f32s(), cur + 1);
            for (bi, &i) in chunk.iter().enumerate() {
                seqs[i].last_logits = logits[bi * self.vocab..(bi + 1) * self.vocab].to_vec();
            }
            idx += n;
        }
        Ok(())
    }

    fn release(&mut self, id: u64) {
        self.slabs.remove(&id);
    }

    fn name(&self) -> String {
        format!("pjrt/{}", self.mode)
    }
}
