//! The serving loop: admission (KV budget) → dynamic batching → prefill →
//! continuous decode → completion, with per-phase metrics.
//!
//! Offline-bench style driver: all requests are submitted up front with
//! synthetic arrival jitter; `run` plays the trace to completion. This is
//! how the Table-6 bench measures prefill/decode/total throughput for the
//! three weight formats.

use super::batcher::Batcher;
use super::engine::{Engine, SeqState};
use super::metrics::ServeMetrics;
use super::request::{Request, Response};
use crate::config::ServeCfg;
use std::time::{Duration, Instant};

pub struct Server<E: Engine> {
    pub engine: E,
    batcher: Batcher,
    cfg: ServeCfg,
}

#[derive(Debug)]
pub struct ServeReport {
    pub responses: Vec<Response>,
    pub metrics: ServeMetrics,
    pub engine: String,
}

impl<E: Engine> Server<E> {
    pub fn new(engine: E, cfg: ServeCfg) -> Server<E> {
        let mut engine = engine;
        // KV budget in real bytes: an explicit `kv_budget_mib`, or (by
        // default) exactly what `max_concurrent` dense f32 worst-case
        // sequences would need — quantized KV formats then fit more blocks
        // (and so more sequences) in the same bytes.
        let max_concurrent = *cfg.decode_buckets.last().unwrap();
        let budget = if cfg.kv_budget_mib > 0.0 {
            Some((cfg.kv_budget_mib * 1024.0 * 1024.0) as usize)
        } else {
            None
        };
        engine.kv_init(budget, max_concurrent);
        Server {
            engine,
            batcher: Batcher::new(
                cfg.prefill_buckets.clone(),
                Duration::from_micros(cfg.batch_window_us),
                cfg.max_queue,
            ),
            cfg,
        }
    }

    /// Play a request trace to completion.
    pub fn run(&mut self, requests: Vec<Request>) -> anyhow::Result<ServeReport> {
        let mut metrics = ServeMetrics::default();
        let mut responses = Vec::with_capacity(requests.len());
        let wall0 = Instant::now();
        let mut pending: std::collections::VecDeque<Request> = requests.into();
        let mut running: Vec<(SeqState, ReqTiming)> = Vec::new();
        let max_concurrent = *self.cfg.decode_buckets.last().unwrap();

        while !pending.is_empty() || !self.batcher.is_empty() || !running.is_empty() {
            // 1. feed the batcher (arrival process: everything available now)
            while let Some(req) = pending.pop_front() {
                if !self.batcher.push(req) {
                    metrics.rejected += 1;
                    break;
                }
            }

            // 2. admit a prefill batch if capacity allows. The engine's KV
            // pool is the storage owner and answers admission: cap the
            // batch at what it can take (monotone, so every popped batch
            // is admissible — no requeue churn).
            let slots_left = max_concurrent.saturating_sub(running.len());
            let mut admit = slots_left;
            while admit > 0 && !self.engine.kv_can_admit(admit) {
                admit -= 1;
            }
            if admit == 0 && running.is_empty() && !self.batcher.is_empty() {
                anyhow::bail!(
                    "KV pool cannot admit even one worst-case sequence — \
                     raise kv_budget_mib or lower max_seq"
                );
            }
            if admit > 0 {
                if let Some(batch) = self.batcher.pop_batch(Instant::now(), admit) {
                    let n = batch.len();
                    let mut seqs: Vec<SeqState> = Vec::with_capacity(n);
                    let mut timings = Vec::with_capacity(n);
                    for req in batch {
                        let queue_s = req.arrival.elapsed().as_secs_f64();
                        metrics.adapter(&req.adapter).requests += 1;
                        timings.push(ReqTiming {
                            id: req.id,
                            queue_s,
                            prefill_s: 0.0,
                            decode_s: 0.0,
                        });
                        seqs.push(SeqState {
                            id: req.id,
                            prompt_len: req.prompt.len(),
                            tokens: req.prompt,
                            max_new: req.max_new_tokens.min(
                                self.engine.max_seq().saturating_sub(1).saturating_sub(0),
                            ),
                            last_logits: vec![],
                            adapter: req.adapter,
                        });
                    }
                    let t0 = Instant::now();
                    self.engine.prefill(&mut seqs)?;
                    let dt = t0.elapsed().as_secs_f64();
                    metrics.prefill_secs += dt;
                    let per_prefill = dt / seqs.len() as f64;
                    for (s, t) in seqs.iter().zip(timings.iter_mut()) {
                        metrics.prefill_tokens += s.prompt_len;
                        metrics.adapter(&s.adapter).prefill_tokens += s.prompt_len;
                        t.prefill_s = per_prefill;
                    }
                    running.extend(seqs.into_iter().zip(timings));
                }
            }

            // 3. decode step for all running sequences
            if !running.is_empty() {
                // append the sampled token, then batch-decode
                for (s, _) in running.iter_mut() {
                    let next = s.next_token();
                    s.tokens.push(next);
                }
                // sequences that just produced their final token complete
                let mut still: Vec<(SeqState, ReqTiming)> = Vec::with_capacity(running.len());
                let mut decode_batch: Vec<(SeqState, ReqTiming)> = Vec::with_capacity(running.len());
                for (s, t) in running.drain(..) {
                    if s.done() || s.tokens.len() >= self.engine.max_seq() {
                        self.engine.release(s.id);
                        metrics.completed += 1;
                        metrics.adapter(&s.adapter).completed += 1;
                        metrics.latency.add(t.queue_s + t.prefill_s + t.decode_s);
                        metrics.queue_wait.add(t.queue_s);
                        responses.push(Response {
                            id: s.id,
                            prompt_len: s.prompt_len,
                            tokens: s.tokens[s.prompt_len..].to_vec(),
                            adapter: s.adapter,
                            queue_s: t.queue_s,
                            prefill_s: t.prefill_s,
                            decode_s: t.decode_s,
                        });
                    } else {
                        decode_batch.push((s, t));
                    }
                }
                if !decode_batch.is_empty() {
                    let mut seqs: Vec<SeqState> =
                        decode_batch.iter().map(|(s, _)| s.clone()).collect();
                    let t0 = Instant::now();
                    self.engine.decode(&mut seqs)?;
                    let dt = t0.elapsed().as_secs_f64();
                    metrics.decode_secs += dt;
                    metrics.decode_tokens += seqs.len();
                    for s in &seqs {
                        metrics.adapter(&s.adapter).decode_tokens += 1;
                    }
                    let per = dt / seqs.len() as f64;
                    for ((old, timing), new) in decode_batch.iter_mut().zip(seqs) {
                        *old = new;
                        timing.decode_s += per;
                    }
                    still.extend(decode_batch);
                }
                running = still;
            }
        }

        metrics.wall_secs = wall0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        Ok(ServeReport { responses, metrics, engine: self.engine.name() })
    }
}

#[derive(Clone, Debug)]
struct ReqTiming {
    #[allow(dead_code)]
    id: u64,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::coordinator::engine::NativeEngine;
    use crate::model::Model;
    use crate::util::Rng;

    fn tiny_server() -> Server<NativeEngine> {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let model = Model::init(&cfg, 0);
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 32,
            kv_budget_mib: 0.0,
        };
        Server::new(NativeEngine::new(model, "fp"), serve)
    }

    fn reqs(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
        let mut rng = Rng::new(0);
        (0..n)
            .map(|i| Request::new(i as u64, (0..prompt_len).map(|_| rng.below(32)).collect(), max_new))
            .collect()
    }

    #[test]
    fn serves_all_requests_to_completion() {
        let mut srv = tiny_server();
        let report = srv.run(reqs(9, 12, 6)).unwrap();
        assert_eq!(report.responses.len(), 9);
        assert_eq!(report.metrics.completed, 9);
        for r in &report.responses {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.tokens.iter().all(|&t| t < 32));
        }
        assert!(report.metrics.prefill_tokens == 9 * 12);
        assert!(report.metrics.decode_tokens >= 9 * 5);
        assert!(report.metrics.total_tps() > 0.0);
    }

    #[test]
    fn deterministic_outputs_per_request() {
        let mut a = tiny_server();
        let mut b = tiny_server();
        let ra = a.run(reqs(4, 10, 5)).unwrap();
        let rb = b.run(reqs(4, 10, 5)).unwrap();
        for (x, y) in ra.responses.iter().zip(&rb.responses) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn batched_serving_matches_single_stream() {
        // tokens generated must be independent of batching decisions
        let mut batched = tiny_server();
        let rep_b = batched.run(reqs(6, 10, 4)).unwrap();
        for want in rep_b.responses.iter() {
            let mut single = tiny_server();
            let one = reqs(6, 10, 4).remove(want.id as usize);
            let rep_s = single.run(vec![one]).unwrap();
            assert_eq!(rep_s.responses[0].tokens, want.tokens, "req {}", want.id);
        }
    }

    #[test]
    fn multitenant_serving_tracks_per_adapter_metrics() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let mut model = Model::init(&cfg, 0);
        model.quantize_lords(
            cfg.block,
            &crate::quant::Codebook::normal_float(4),
            crate::quant::lords::RefineCfg { steps: 2, ..Default::default() },
            false,
        );
        let mut engine = NativeEngine::new(model, "mt");
        let base = crate::adapters::AdapterFactors::from_model(&engine.model);
        let mut arng = Rng::new(3);
        engine.register_adapter("t0", base.perturbed(0.05, &mut arng)).unwrap();
        engine.register_adapter("t1", base.perturbed(0.05, &mut arng)).unwrap();
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 32,
            kv_budget_mib: 0.0,
        };
        let mut srv = Server::new(engine, serve);
        let tenants = ["base", "t0", "t1"];
        let mut requests = reqs(6, 8, 4);
        for (i, r) in requests.iter_mut().enumerate() {
            r.adapter = tenants[i % 3].to_string();
        }
        let report = srv.run(requests).unwrap();
        assert_eq!(report.metrics.completed, 6);
        for t in tenants {
            let c = &report.metrics.per_adapter[t];
            assert_eq!(c.requests, 2, "{t}");
            assert_eq!(c.completed, 2, "{t}");
            assert_eq!(c.prefill_tokens, 2 * 8, "{t}");
            assert!(c.decode_tokens >= 2 * 3, "{t}");
        }
        for r in &report.responses {
            assert_eq!(r.adapter, tenants[r.id as usize % 3]);
            assert_eq!(r.tokens.len(), 4);
        }
        // every in-flight pin was released with its sequence
        assert_eq!(srv.engine.registry().pins("t0"), 0);
        assert_eq!(srv.engine.registry().pins("t1"), 0);
    }

    #[test]
    fn unknown_adapter_fails_the_run() {
        let mut srv = tiny_server();
        let requests =
            vec![Request::new(0, vec![1, 2, 3, 4], 2).with_adapter("ghost-tenant")];
        assert!(srv.run(requests).is_err());
    }

    #[test]
    fn respects_max_seq() {
        let mut srv = tiny_server();
        let report = srv.run(reqs(1, 40, 100)).unwrap();
        // 48 max_seq - 40 prompt = at most 8 new tokens
        assert!(report.responses[0].tokens.len() <= 8);
    }

    #[test]
    fn quantized_kv_serves_to_completion_in_less_memory() {
        use crate::kvquant::{KvBits, KvQuantCfg};
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 8,
            kv_budget_mib: 0.0,
        };
        let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 8 };
        let engine = NativeEngine::with_kv(Model::init(&cfg, 0), "kv8", kv);
        let mut srv = Server::new(engine, serve);
        let report = srv.run(reqs(6, 12, 6)).unwrap();
        assert_eq!(report.metrics.completed, 6);
        for r in &report.responses {
            assert_eq!(r.tokens.len(), 6);
        }
        let pool = srv.engine.kv_pool();
        assert!(pool.block_bytes() < pool.dense_block_bytes());
        // same byte budget as the dense auto-sizing, more concurrency
        assert!(pool.max_concurrent_full_seqs(cfg.max_seq) > 4);
        // everything released on completion
        assert_eq!(pool.used_blocks(), 0);
        assert_eq!(pool.active_sequences(), 0);
    }
}
