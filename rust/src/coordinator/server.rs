//! The online serving API: sessioned **submit / step / cancel** with
//! streaming events, replacing the old closed-loop batch-trace driver.
//!
//! * [`Server::submit`] — admission with explicit backpressure: a request
//!   is validated (id, prompt, tenant) and queued, or rejected with a
//!   [`RejectReason`].
//! * [`Server::step`] — advances the serving loop one tick (admit if
//!   capacity allows, advance in-flight prompts by one chunk budget, then
//!   one decode step for every running sequence) and returns the
//!   [`Event`]s produced: streamed tokens, completions, rejections,
//!   cancellations. Engines that support chunked prefill get the
//!   **continuous batching** schedule: a long prompt admits immediately
//!   and prefills [`ServeCfg::prefill_chunk_tokens`] tokens per tick
//!   interleaved with decode, so running streams pay at most one chunk of
//!   extra inter-token latency instead of stalling for the whole prompt.
//! * [`Server::cancel`] — drops a queued or in-flight request, releasing
//!   its KV blocks and adapter pin immediately.
//! * [`Server::drain`] — graceful shutdown: admission stops, in-flight
//!   work finishes (or is failed at the tick budget), and engine caches
//!   are flushed so the KV pool and adapter registry end empty.
//! * [`Server::run_trace`] — the old offline behavior as a thin shim over
//!   `submit` + `step`: plays a request trace to completion and returns a
//!   [`ServeReport`], token-identical to the pre-redesign `run()`.
//!
//! Per-token timestamps feed the streaming latency metrics (TTFT / ITL /
//! queue wait percentiles in [`ServeMetrics`]); see
//! [`driver`](super::driver) for the open-loop Poisson arrival harness
//! that exercises them.
//!
//! Engine errors never poison a tick: each becomes a per-sequence
//! [`Event::Failed`] with bounded retry-by-re-prefill, a non-finite-logit
//! sentinel quarantines numeric excursions before sampling, and
//! per-request deadlines are enforced at admission and in flight — see
//! the failure-model notes in [`coordinator`](super) and the
//! fault-injection plane in [`crate::fault`].

use super::batcher::Batcher;
use super::engine::{Engine, SeqState};
use super::metrics::ServeMetrics;
use super::request::{Request, Response};
use crate::config::ServeCfg;
use crate::obs::quality;
use crate::obs::{self, Counter, FlightKind, FlightRecorder, Gauge, Histogram, Registry};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Handle for an accepted request (the request's own id, echoed back).
pub type SeqId = u64;

/// Why a submission (or a queued request at admission time) was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The arrival queue is at `max_queue` — backpressure; retry later.
    QueueFull,
    /// Another queued or running request already uses this id.
    DuplicateId,
    /// The engine cannot serve this tenant (unknown or evicted adapter).
    UnknownAdapter,
    /// The prompt exceeds the engine's context window.
    PromptTooLong,
    /// Empty prompts cannot be prefetched.
    EmptyPrompt,
    /// The request's KV footprint (prompt + max_new) exceeds what the
    /// pool can ever hold, even with nothing else in flight.
    KvBudgetExceeded,
    /// The request's deadline is below `min_deadline_ms`, or it already
    /// expired (at submit, or while the request waited in the queue).
    DeadlineInfeasible,
    /// The server is draining: admission is permanently stopped.
    Draining,
}

impl RejectReason {
    /// Stable snake_case key — the `reason` label on
    /// `lords_rejected_total` and the flight-recorder event payload.
    pub fn key(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::DuplicateId => "duplicate_id",
            RejectReason::UnknownAdapter => "unknown_adapter",
            RejectReason::PromptTooLong => "prompt_too_long",
            RejectReason::EmptyPrompt => "empty_prompt",
            RejectReason::KvBudgetExceeded => "kv_budget_exceeded",
            RejectReason::DeadlineInfeasible => "deadline_infeasible",
            RejectReason::Draining => "draining",
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            RejectReason::QueueFull => "queue full",
            RejectReason::DuplicateId => "duplicate request id",
            RejectReason::UnknownAdapter => "unknown adapter",
            RejectReason::PromptTooLong => "prompt too long",
            RejectReason::EmptyPrompt => "empty prompt",
            RejectReason::KvBudgetExceeded => "request exceeds the KV pool budget",
            RejectReason::DeadlineInfeasible => "deadline infeasible",
            RejectReason::Draining => "server is draining",
        };
        f.write_str(s)
    }
}

/// Streaming output of [`Server::step`].
#[derive(Clone, Debug)]
pub enum Event {
    /// A sequence produced its next token (`index` counts generated
    /// tokens from 0).
    Token { id: SeqId, token: usize, index: usize },
    /// A sequence finished (budget, stop token, or context window) —
    /// carries the complete response.
    Done { response: Response },
    /// A queued request was refused at admission (e.g. its adapter was
    /// evicted while it waited).
    Rejected { id: SeqId, reason: RejectReason },
    /// A queued or running request was cancelled by the client.
    Cancelled { id: SeqId },
    /// A sequence failed: engine error, expired deadline, quarantine, or
    /// drain timeout. `reason` is the stable key also used as the
    /// `reason` label on `lords_failed_total`. When `retryable`, the
    /// server has scheduled a retry-by-re-prefill: the stream restarts
    /// from index 0 and — decode being deterministic per request —
    /// replays the same tokens.
    Failed { id: SeqId, reason: &'static str, retryable: bool },
}

/// Cumulative observability state owned by the server: the metrics
/// registry behind the Prometheus / JSON expositions, the per-request
/// flight recorder, and the hot-path metric handles (resolved once here;
/// recording is plain atomic ops). Unlike [`ServeMetrics`] — the
/// windowed report that [`Server::reset_metrics`] takes — the registry
/// only accumulates for the life of the server.
pub struct ServerObs {
    /// Cumulative metric store (the `lords_*` families); render with
    /// [`Registry::render_prometheus`] / [`Registry::render_json`].
    /// Shared (`Arc`) so a live admin endpoint
    /// ([`obs::http::AdminServer`](crate::obs::http::AdminServer)) can
    /// render it from its own thread mid-run.
    pub registry: Arc<Registry>,
    /// Bounded ring of per-request lifecycle events with anomaly
    /// tripwires (rejection storm, stall) — see
    /// [`FlightRecorder::take_anomaly`].
    pub flight: FlightRecorder,
    completed: Counter,
    cancelled: Counter,
    /// `lords_retries_total` — retry-by-re-prefill attempts scheduled.
    retries: Counter,
    prefill_tokens: Counter,
    prefix_hit_tokens: Counter,
    prefill_chunks: Counter,
    decode_tokens: Counter,
    decode_ticks: Counter,
    queue_depth: Gauge,
    running: Gauge,
    prefilling: Gauge,
    decode_batch_size: Histogram,
    prefill_chunk_utilization: Histogram,
    ttft_seconds: Histogram,
    itl_seconds: Histogram,
    sentinel_probes: Counter,
    sentinel_skipped: Counter,
    sentinel_top1_agree: Histogram,
    sentinel_logit_drift: Histogram,
    /// `lords_kv_seal_err_breaches_total` — incremented by the engine's
    /// seal-error sink; the server reads it to arm the flight recorder.
    seal_breaches: Counter,
    /// breach count already folded into the flight-recorder tripwire.
    seal_breaches_seen: u64,
}

impl ServerObs {
    fn new() -> ServerObs {
        let registry = Arc::new(Registry::new());
        let latency = &[0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0];
        // registered lazily per adapter label in `admit`; the family help
        // is recorded up front so the exposition always carries it
        registry.set_help("lords_requests_total", "Requests admitted, by adapter.");
        registry.set_help("lords_rejected_total", "Requests rejected, by reason.");
        registry.set_help("lords_failed_total", "Requests failed in flight, by reason.");
        registry.set_help(
            "lords_quarantined_total",
            "Sequences quarantined (non-finite logits), by reason.",
        );
        ServerObs {
            completed: registry.counter_with_help(
                "lords_completed_total",
                &[],
                "Requests served to completion.",
            ),
            cancelled: registry.counter_with_help(
                "lords_cancelled_total",
                &[],
                "Requests cancelled by the client before completion.",
            ),
            retries: registry.counter_with_help(
                "lords_retries_total",
                &[],
                "Retry-by-re-prefill attempts scheduled after retryable failures.",
            ),
            prefill_tokens: registry.counter_with_help(
                "lords_prefill_tokens_total",
                &[],
                "Prompt tokens prefilled (computed, not prefix-cache hits).",
            ),
            prefix_hit_tokens: registry.counter_with_help(
                "lords_prefix_hit_tokens_total",
                &[],
                "Prompt tokens served from the shared-prefix cache.",
            ),
            prefill_chunks: registry.counter_with_help(
                "lords_prefill_chunks_total",
                &[],
                "Prefill chunks executed across all sequences.",
            ),
            decode_tokens: registry.counter_with_help(
                "lords_decode_tokens_total",
                &[],
                "Tokens produced by decode ticks.",
            ),
            decode_ticks: registry.counter_with_help(
                "lords_decode_ticks_total",
                &[],
                "Batched decode ticks stepped.",
            ),
            queue_depth: registry.gauge_with_help(
                "lords_queue_depth",
                &[],
                "Requests waiting in the admission queue.",
            ),
            running: registry.gauge_with_help(
                "lords_running_sequences",
                &[],
                "Sequences currently decoding.",
            ),
            prefilling: registry.gauge_with_help(
                "lords_prefilling_sequences",
                &[],
                "Admitted sequences still prefilling their prompts.",
            ),
            decode_batch_size: registry.histogram_with_help(
                "lords_decode_batch_size",
                &[],
                &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0],
                "Running sequences per batched decode tick.",
            ),
            prefill_chunk_utilization: registry.histogram_with_help(
                "lords_prefill_chunk_utilization",
                &[],
                &[0.25, 0.5, 0.75, 0.9, 1.0],
                "Fraction of each prefill chunk budget actually used.",
            ),
            ttft_seconds: registry.histogram_with_help(
                "lords_ttft_seconds",
                &[],
                latency,
                "Time to first token, seconds.",
            ),
            itl_seconds: registry.histogram_with_help(
                "lords_itl_seconds",
                &[],
                latency,
                "Inter-token latency, seconds.",
            ),
            sentinel_probes: registry.counter_with_help(
                quality::SENTINEL_PROBES_FAMILY,
                &[],
                "Logit-drift sentinel probes run.",
            ),
            sentinel_skipped: registry.counter_with_help(
                quality::SENTINEL_SKIPPED_FAMILY,
                &[],
                "Sentinel probes that could not run (no reference path or shadow).",
            ),
            sentinel_top1_agree: registry.histogram_with_help(
                quality::SENTINEL_AGREE_FAMILY,
                &[],
                &[0.5],
                "Top-1 agreement between served and reference logits (1 = agree).",
            ),
            sentinel_logit_drift: registry.histogram_with_help(
                quality::SENTINEL_DRIFT_FAMILY,
                &[],
                quality::DRIFT_BOUNDS,
                "Max-abs logit drift between served and reference decode.",
            ),
            seal_breaches: registry.counter_with_help(
                quality::SEAL_BREACH_FAMILY,
                &[],
                "KV seal relative errors above the configured threshold.",
            ),
            seal_breaches_seen: 0,
            registry,
            flight: FlightRecorder::default(),
        }
    }

    /// One rejection: bump the reason-labelled counter and record the
    /// flight event (which also feeds the rejection-storm tripwire).
    fn reject(&mut self, id: u64, reason: RejectReason) {
        self.registry.counter("lords_rejected_total", &[("reason", reason.key())]).inc();
        self.flight.push(id, FlightKind::Rejected { reason: reason.key() });
    }

    /// One failure: bump the reason-labelled counter and record the
    /// flight event (failures count as progress for the stall tripwire —
    /// the server *did* resolve that sequence's state this tick).
    fn fail(&mut self, id: u64, reason: &'static str, retryable: bool) {
        self.registry.counter("lords_failed_total", &[("reason", reason)]).inc();
        self.flight.push(id, FlightKind::Failed { reason, retryable });
    }

    /// One quarantine: bump the reason-labelled counter and record the
    /// flight event (callers additionally arm the ring via
    /// [`FlightRecorder::trip_anomaly`]).
    fn quarantine(&mut self, id: u64, reason: &'static str) {
        self.registry.counter("lords_quarantined_total", &[("reason", reason)]).inc();
        self.flight.push(id, FlightKind::Quarantined);
    }
}

pub struct Server<E: Engine> {
    pub engine: E,
    /// Accumulated serving metrics; reset by [`Server::reset_metrics`]
    /// (and at the start of every [`Server::run_trace`]).
    pub metrics: ServeMetrics,
    /// Cumulative metrics registry + flight recorder (never reset).
    pub obs: ServerObs,
    batcher: Batcher,
    cfg: ServeCfg,
    /// Largest decode bucket — the concurrency ceiling. Computed once at
    /// construction so serving paths never re-derive it from the config.
    max_concurrent: usize,
    /// In-flight sequences. Kept as a plain `Vec<SeqState>` (with
    /// `timings` index-aligned beside it) so the engine's batched decode
    /// tick borrows the whole running set as one `&mut [SeqState]` —
    /// no per-tick clone of every sequence's token/logit buffers.
    running: Vec<SeqState>,
    /// Per-request serving timestamps, index-aligned with `running`
    /// (engines must not reorder the slice — see [`Engine::decode`]).
    timings: Vec<ReqTiming>,
    /// Admitted sequences whose prompts are still prefilling (chunked
    /// engines only); they hold KV reservations and adapter pins but do
    /// not decode until [`SeqState::prefill_done`].
    prefilling: Vec<SeqState>,
    /// Timestamps index-aligned with `prefilling`.
    prefilling_timings: Vec<ReqTiming>,
    /// Round-robin start offset into `prefilling` so the per-tick chunk
    /// budget rotates fairly across co-resident prompts.
    prefill_cursor: usize,
    /// ids currently queued or running (duplicate-submission guard)
    live: HashSet<u64>,
    /// events produced between steps (cancellations), delivered next step
    pending_events: Vec<Event>,
    /// ticks stepped so far — the sentinel's deterministic cadence base.
    tick: u64,
    /// Failed-but-retryable requests waiting out their tick backoff before
    /// re-entering the admission queue (retry-by-re-prefill). Ids here
    /// stay in `live` — the client's handle is still valid.
    retry_queue: VecDeque<RetryEntry>,
    /// Failure attempts per live id; entries are dropped on any terminal
    /// outcome (done / cancelled / terminal failure).
    attempts: HashMap<u64, usize>,
    /// Set by [`Server::drain`]: admission is permanently stopped.
    draining: bool,
    /// A submission hit `QueueFull` since the last tick (feeds the
    /// readiness probe's backpressure streak).
    saw_queue_full: bool,
    /// Consecutive ticks that saw `QueueFull` backpressure; readiness
    /// ([`Server::is_ready`]) goes false at
    /// `ServeCfg::readyz_backpressure_ticks`.
    backpressure_streak: usize,
}

/// A failed request waiting out its retry backoff.
struct RetryEntry {
    req: Request,
    /// first tick at which the retry may re-enter the admission queue
    ready_tick: u64,
}

#[derive(Debug)]
pub struct ServeReport {
    pub responses: Vec<Response>,
    pub metrics: ServeMetrics,
    pub engine: String,
}

impl<E: Engine> Server<E> {
    /// Build a server over a validated config. Fails (rather than
    /// panicking) on config shapes that cannot serve: empty or unsorted
    /// bucket lists, a zero queue, a malformed `fault_spec`, … — see
    /// [`ServeCfg::validate`]. A non-empty `fault_spec` is installed into
    /// the process-global fault plane here.
    pub fn new(engine: E, cfg: ServeCfg) -> anyhow::Result<Server<E>> {
        cfg.validate()?;
        if !cfg.fault_spec.trim().is_empty() {
            let n = crate::fault::configure(&cfg.fault_spec)?;
            crate::warn_log!("fault plane armed: {n} spec(s) from serve config");
        }
        let mut engine = engine;
        // KV budget in real bytes: an explicit `kv_budget_mib`, or (by
        // default) exactly what `max_concurrent` dense f32 worst-case
        // sequences would need — quantized KV formats then fit more blocks
        // (and so more sequences) in the same bytes.
        let max_concurrent = *cfg
            .decode_buckets
            .last()
            .ok_or_else(|| anyhow::anyhow!("serve config: decode_buckets must be non-empty"))?;
        let budget = if cfg.kv_budget_mib > 0.0 {
            Some((cfg.kv_budget_mib * 1024.0 * 1024.0) as usize)
        } else {
            None
        };
        engine.kv_init(budget, max_concurrent);
        let mut obs = ServerObs::new();
        obs.flight.configure(
            cfg.storm_rejections,
            cfg.storm_window_ms.saturating_mul(1_000_000),
            cfg.stall_ticks,
        );
        // after kv_init: quality's seal-error sink attaches to the pool
        // the server will actually run on
        engine.install_quality(&obs.registry, cfg.seal_err_threshold);
        Ok(Server {
            engine,
            metrics: ServeMetrics::default(),
            obs,
            batcher: Batcher::new(
                cfg.prefill_buckets.clone(),
                Duration::from_micros(cfg.batch_window_us),
                cfg.max_queue,
            ),
            cfg,
            max_concurrent,
            running: Vec::new(),
            timings: Vec::new(),
            prefilling: Vec::new(),
            prefilling_timings: Vec::new(),
            prefill_cursor: 0,
            live: HashSet::new(),
            pending_events: Vec::new(),
            tick: 0,
            retry_queue: VecDeque::new(),
            attempts: HashMap::new(),
            draining: false,
            saw_queue_full: false,
            backpressure_streak: 0,
        })
    }

    /// Nothing queued, prefilling, running, retrying, or waiting to be
    /// reported.
    pub fn is_idle(&self) -> bool {
        self.batcher.is_empty()
            && self.running.is_empty()
            && self.prefilling.is_empty()
            && self.pending_events.is_empty()
            && self.retry_queue.is_empty()
    }

    /// Liveness vs readiness: the server is *ready* to accept new work
    /// unless it is draining or `readyz_backpressure_ticks` consecutive
    /// ticks saw queue-full backpressure (0 disables the streak check).
    /// The admin `/readyz` probe reports this.
    pub fn is_ready(&self) -> bool {
        if self.draining {
            return false;
        }
        let n = self.cfg.readyz_backpressure_ticks;
        n == 0 || self.backpressure_streak < n
    }

    /// True once [`Server::drain`] has started (admission stopped).
    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Number of sequences currently in the decode loop.
    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// Number of admitted sequences still prefilling their prompts.
    pub fn num_prefilling(&self) -> usize {
        self.prefilling.len()
    }

    /// Number of requests waiting in the arrival queue.
    pub fn num_queued(&self) -> usize {
        self.batcher.len()
    }

    /// Start a fresh measurement window (e.g. between open-loop phases).
    pub fn reset_metrics(&mut self) -> ServeMetrics {
        std::mem::take(&mut self.metrics)
    }

    /// Submit one request for serving. On acceptance the request is queued
    /// (its tokens will stream from subsequent [`Server::step`] calls) and
    /// its id is echoed back as the [`SeqId`] handle. On rejection nothing
    /// is retained and the caller owns the backpressure decision.
    pub fn submit(&mut self, req: Request) -> Result<SeqId, RejectReason> {
        let reason = if self.draining {
            Some(RejectReason::Draining)
        } else if self.live.contains(&req.id) {
            Some(RejectReason::DuplicateId)
        } else if req.prompt.is_empty() {
            Some(RejectReason::EmptyPrompt)
        } else if req.prompt.len() > self.engine.max_seq() {
            Some(RejectReason::PromptTooLong)
        } else if !self.engine.supports_adapter(&req.adapter) {
            Some(RejectReason::UnknownAdapter)
        } else if req.deadline_ms > 0
            && (req.deadline_ms < self.cfg.min_deadline_ms
                || req.arrival.elapsed().as_millis() as u64 >= req.deadline_ms)
        {
            // infeasible at the door: below the configured floor, or the
            // caller's clock already spent the budget before submit
            Some(RejectReason::DeadlineInfeasible)
        } else {
            None
        };
        if let Some(reason) = reason {
            self.metrics.rejected += 1;
            self.obs.reject(req.id, reason);
            return Err(reason);
        }
        let id = req.id;
        if !self.batcher.push(req) {
            self.saw_queue_full = true;
            self.metrics.rejected += 1;
            self.obs.reject(id, RejectReason::QueueFull);
            return Err(RejectReason::QueueFull);
        }
        self.live.insert(id);
        self.obs.flight.push(id, FlightKind::Submitted);
        Ok(id)
    }

    /// Cancel a queued or running request. Returns true when the request
    /// was found: its KV blocks and adapter pin are released immediately
    /// and an [`Event::Cancelled`] is delivered by the next [`step`].
    /// Unknown (or already finished) ids return false.
    ///
    /// [`step`]: Server::step
    pub fn cancel(&mut self, id: SeqId) -> bool {
        if self.batcher.remove(id).is_some() {
            // never admitted — nothing to release in the engine, and no
            // per-adapter count: those track admitted work only (the
            // tenant's `requests` counter never saw this one)
            self.live.remove(&id);
            self.attempts.remove(&id);
            self.metrics.cancelled += 1;
            self.obs.cancelled.inc();
            self.obs.flight.push(id, FlightKind::Cancelled);
            self.pending_events.push(Event::Cancelled { id });
            return true;
        }
        if let Some(pos) = self.retry_queue.iter().position(|e| e.req.id == id) {
            // failed and waiting out its retry backoff — nothing held in
            // the engine (fail_seq released everything)
            self.retry_queue.remove(pos);
            self.live.remove(&id);
            self.attempts.remove(&id);
            self.metrics.cancelled += 1;
            self.obs.cancelled.inc();
            self.obs.flight.push(id, FlightKind::Cancelled);
            self.pending_events.push(Event::Cancelled { id });
            return true;
        }
        if let Some(pos) = self.prefilling.iter().position(|s| s.id == id) {
            let s = self.prefilling.remove(pos);
            self.prefilling_timings.remove(pos);
            self.engine.release(s.id);
            self.live.remove(&id);
            self.attempts.remove(&id);
            self.metrics.cancelled += 1;
            self.metrics.adapter(&s.adapter).cancelled += 1;
            self.obs.cancelled.inc();
            self.obs.flight.push(id, FlightKind::Cancelled);
            self.obs.flight.push(id, FlightKind::Released);
            self.pending_events.push(Event::Cancelled { id });
            return true;
        }
        if let Some(pos) = self.running.iter().position(|s| s.id == id) {
            let s = self.running.remove(pos);
            self.timings.remove(pos);
            self.engine.release(s.id);
            self.live.remove(&id);
            self.attempts.remove(&id);
            self.metrics.cancelled += 1;
            self.metrics.adapter(&s.adapter).cancelled += 1;
            self.obs.cancelled.inc();
            self.obs.flight.push(id, FlightKind::Cancelled);
            self.obs.flight.push(id, FlightKind::Released);
            self.pending_events.push(Event::Cancelled { id });
            return true;
        }
        false
    }

    /// Advance the serving loop one tick: deliver pending cancellations,
    /// admit queued requests if capacity allows, advance in-flight prompts
    /// by up to one chunk budget, then run one decode step for every
    /// running sequence — streaming each produced token as an
    /// [`Event::Token`] and each completion as an [`Event::Done`].
    ///
    /// Returns an empty vector when the server is idle.
    pub fn step(&mut self) -> anyhow::Result<Vec<Event>> {
        let _tick = obs::span!("server.tick");
        // `busy` feeds the flight recorder's stall tripwire: work was in
        // flight when the tick started, so *something* should progress.
        let busy = !self.batcher.is_empty()
            || !self.running.is_empty()
            || !self.prefilling.is_empty()
            || !self.retry_queue.is_empty();
        let mut events = std::mem::take(&mut self.pending_events);
        // failure plumbing first: backoff-expired retries re-enter the
        // queue, then expired deadlines fail before any compute is spent
        self.requeue_retries(&mut events);
        self.expire_deadlines(&mut events);
        {
            let _s = obs::span!("server.admit");
            self.admit(&mut events)?;
        }
        {
            let _s = obs::span!("server.prefill");
            self.prefill_tick(&mut events)?;
        }
        {
            let _s = obs::span!("server.decode");
            self.decode_tick(&mut events)?;
        }
        self.engine.observe(&self.obs.registry);
        self.obs.queue_depth.set(self.batcher.len() as i64);
        self.obs.running.set(self.running.len() as i64);
        self.obs.prefilling.set(self.prefilling.len() as i64);
        // fresh seal-error breaches (counted by the engine's sink) arm the
        // flight recorder so the ring is dumped while context is hot
        let breaches = self.obs.seal_breaches.get();
        if breaches > self.obs.seal_breaches_seen {
            let fresh = breaches - self.obs.seal_breaches_seen;
            self.obs.seal_breaches_seen = breaches;
            self.obs
                .flight
                .trip_anomaly(format!("kv seal error above threshold ({fresh} new)"));
        }
        self.obs.flight.note_tick(busy);
        // readiness: consecutive ticks that observed queue-full rejections
        if self.saw_queue_full {
            self.backpressure_streak += 1;
        } else {
            self.backpressure_streak = 0;
        }
        self.saw_queue_full = false;
        self.tick += 1;
        Ok(events)
    }

    /// Admission: pop the largest admissible batch. Chunked engines admit
    /// without computing anything (prefix-cache fork + KV reservation
    /// only) and hand the sequences to [`Self::prefill_tick`]; legacy
    /// engines keep the old whole-batch prefill at admission.
    fn admit(&mut self, events: &mut Vec<Event>) -> anyhow::Result<()> {
        if self.draining {
            return Ok(()); // drain() already rejected the queue
        }
        let in_flight = self.running.len() + self.prefilling.len();
        let slots_left = self.max_concurrent.saturating_sub(in_flight);
        if slots_left == 0 || self.batcher.is_empty() {
            return Ok(());
        }
        // KV-aware admission: size the batch by the queued requests'
        // actual footprints (prompt + capped max_new, minus any prompt
        // prefix the engine's cache already holds — shared blocks are
        // attached, not allocated), not max_seq worst case. The engine's
        // answer is monotone in a prefix, so every popped batch is
        // admissible — no requeue churn.
        let max_seq = self.engine.max_seq();
        let want = slots_left.min(self.batcher.len());
        let lens: Vec<usize> = self
            .batcher
            .peek(want)
            .map(|r| {
                let shared = self.engine.prefix_hit_tokens(&r.adapter, &r.prompt);
                r.required_suffix_kv_tokens(max_seq, shared)
            })
            .collect();
        let mut admit = want;
        while admit > 0 && !self.engine.kv_can_admit(&lens[..admit]) {
            admit -= 1;
        }
        if admit == 0 {
            if self.running.is_empty() && self.prefilling.is_empty() {
                // nothing is in flight, so every block is free: the front
                // request can never be admitted. Reject it (rather than
                // wedging the whole queue behind it) and let the next
                // step() try its successors. Unreachable for the stock
                // engines — pool sizing always fits one worst-case
                // sequence — but a misconfigured pool must not livelock.
                let front = self.batcher.peek(1).next().map(|r| r.id);
                if let Some(req) = front.and_then(|id| self.batcher.remove(id)) {
                    self.live.remove(&req.id);
                    self.metrics.rejected += 1;
                    self.obs.reject(req.id, RejectReason::KvBudgetExceeded);
                    events.push(Event::Rejected {
                        id: req.id,
                        reason: RejectReason::KvBudgetExceeded,
                    });
                }
            }
            return Ok(()); // otherwise blocks free up as running sequences finish
        }
        let Some(batch) = self.batcher.pop_batch(Instant::now(), admit) else {
            return Ok(());
        };
        let mut seqs: Vec<SeqState> = Vec::with_capacity(batch.len());
        let mut timings: Vec<ReqTiming> = Vec::with_capacity(batch.len());
        for req in batch {
            // re-validate the tenant: it may have been evicted while the
            // request sat in the queue — reject that one request instead
            // of failing the whole batch
            if !self.engine.supports_adapter(&req.adapter) {
                self.live.remove(&req.id);
                self.attempts.remove(&req.id);
                self.metrics.rejected += 1;
                self.obs.reject(req.id, RejectReason::UnknownAdapter);
                events.push(Event::Rejected {
                    id: req.id,
                    reason: RejectReason::UnknownAdapter,
                });
                continue;
            }
            // a deadline that expired while the request waited in the
            // queue is rejected here — no KV or compute is ever spent on it
            if req.deadline_ms > 0
                && req.arrival.elapsed().as_millis() as u64 >= req.deadline_ms
            {
                self.live.remove(&req.id);
                self.attempts.remove(&req.id);
                self.metrics.rejected += 1;
                self.obs.reject(req.id, RejectReason::DeadlineInfeasible);
                events.push(Event::Rejected {
                    id: req.id,
                    reason: RejectReason::DeadlineInfeasible,
                });
                continue;
            }
            let queue_s = req.arrival.elapsed().as_secs_f64();
            self.metrics.adapter(&req.adapter).requests += 1;
            self.obs
                .registry
                .counter("lords_requests_total", &[("adapter", req.adapter.as_str())])
                .inc();
            timings.push(ReqTiming {
                arrival: req.arrival,
                queue_s,
                prefill_s: 0.0,
                decode_s: 0.0,
                ttft_s: 0.0,
                last_token: None,
            });
            seqs.push(SeqState::admit(&req, max_seq));
        }
        if seqs.is_empty() {
            return Ok(());
        }
        if self.engine.supports_chunked_prefill() {
            // Continuous batching: reserve KV + attach any shared prefix
            // now (no compute), then let prefill_tick spread the prompt
            // math across decode ticks. An engine error here fails the
            // batch's sequences individually (retryably) instead of
            // poisoning the tick — nothing else in flight is touched.
            if let Err(e) = self.engine.admit_seqs(&mut seqs) {
                crate::warn_log!("admit_seqs failed, failing batch: {e:#}");
                for (s, t) in seqs.into_iter().zip(timings) {
                    self.fail_seq(s, &t, "engine_error", true, events);
                }
                return Ok(());
            }
            for s in seqs.iter() {
                self.metrics.prefix_hit_tokens += s.prefilled;
                self.obs.prefix_hit_tokens.add(s.prefilled as u64);
                self.obs.flight.push(
                    s.id,
                    FlightKind::Admitted {
                        prefix_hit_tokens: s.prefilled,
                        reserved_tokens: (s.prompt_len + s.max_new).min(max_seq),
                    },
                );
            }
            self.prefilling.extend(seqs);
            self.prefilling_timings.extend(timings);
            return Ok(());
        }
        // Legacy lockstep schedule: one whole-batch prefill at admission.
        let t0 = Instant::now();
        if let Err(e) = self.engine.prefill(&mut seqs) {
            crate::warn_log!("prefill failed, failing batch: {e:#}");
            for (s, t) in seqs.into_iter().zip(timings) {
                self.fail_seq(s, &t, "engine_error", true, events);
            }
            return Ok(());
        }
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.prefill_secs += dt;
        let per_prefill = dt / seqs.len() as f64;
        for (s, t) in seqs.iter_mut().zip(timings.iter_mut()) {
            s.prefilled = s.prompt_len;
            self.metrics.prefill_tokens += s.prompt_len;
            self.metrics.adapter(&s.adapter).prefill_tokens += s.prompt_len;
            self.obs.prefill_tokens.add(s.prompt_len as u64);
            self.obs.flight.push(
                s.id,
                FlightKind::Admitted {
                    prefix_hit_tokens: 0,
                    reserved_tokens: (s.prompt_len + s.max_new).min(max_seq),
                },
            );
            t.prefill_s = per_prefill;
        }
        self.running.extend(seqs);
        self.timings.extend(timings);
        Ok(())
    }

    /// One chunked-prefill tick: spend up to
    /// [`ServeCfg::prefill_chunk_tokens`] prompt tokens (0 = unlimited)
    /// across the in-flight prompts, rotating the starting sequence each
    /// tick so no prompt starves. Completed prompts move to the decode
    /// set in admission order. A chunk that errors fails only its own
    /// sequence (retryably); batchmates keep prefilling.
    fn prefill_tick(&mut self, events: &mut Vec<Event>) -> anyhow::Result<()> {
        if self.prefilling.is_empty() {
            return Ok(());
        }
        let budget0 = match self.cfg.prefill_chunk_tokens {
            0 => usize::MAX,
            n => n,
        };
        let mut remaining = budget0;
        let n = self.prefilling.len();
        let t0 = Instant::now();
        let mut advanced: Vec<usize> = Vec::new();
        let mut failed: Vec<usize> = Vec::new();
        for k in 0..n {
            if remaining == 0 {
                break;
            }
            let i = (self.prefill_cursor + k) % n;
            let s = &mut self.prefilling[i];
            if s.prefill_done() || failed.contains(&i) {
                continue; // admitted this tick after the cursor wrapped
            }
            let took = match self.engine.prefill_chunk(s, remaining) {
                Ok(took) => took,
                Err(e) => {
                    crate::warn_log!("prefill_chunk failed for seq {}: {e:#}", s.id);
                    failed.push(i);
                    continue;
                }
            };
            let s = &self.prefilling[i];
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_tokens += took;
            self.metrics.adapter(&s.adapter).prefill_tokens += took;
            self.obs.prefill_chunks.inc();
            self.obs.prefill_tokens.add(took as u64);
            self.obs.flight.push(s.id, FlightKind::PrefillChunk { tokens: took });
            // a chunk is block-aligned: it may round a tiny budget up to
            // one full block, so saturate rather than underflow
            remaining = remaining.saturating_sub(took);
            advanced.push(i);
        }
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.prefill_secs += dt;
        if !advanced.is_empty() {
            let per = dt / advanced.len() as f64;
            for &i in &advanced {
                self.prefilling_timings[i].prefill_s += per;
            }
        }
        // budget utilization this tick (bounded budgets only): block
        // rounding may overshoot, so a saturated `remaining` reads as 1.0
        if budget0 != usize::MAX {
            let spent = budget0 - remaining;
            self.obs.prefill_chunk_utilization.observe(spent as f64 / budget0 as f64);
        }
        // completed prompts graduate to the decode loop in admission
        // order; errored ones leave the prefill set through fail_seq
        let seqs = std::mem::take(&mut self.prefilling);
        let timings = std::mem::take(&mut self.prefilling_timings);
        for (i, (s, t)) in seqs.into_iter().zip(timings).enumerate() {
            if failed.contains(&i) {
                self.fail_seq(s, &t, "engine_error", true, events);
            } else if s.prefill_done() {
                self.running.push(s);
                self.timings.push(t);
            } else {
                self.prefilling.push(s);
                self.prefilling_timings.push(t);
            }
        }
        self.prefill_cursor = match self.prefilling.len() {
            0 => 0,
            n => (self.prefill_cursor + 1) % n,
        };
        Ok(())
    }

    /// One decode tick: sample + stream a token for every running
    /// sequence, complete the finished ones, then advance the rest with a
    /// **single** batched engine call (`Engine::decode` over the whole
    /// running set — the engine amortizes weight streaming across it).
    fn decode_tick(&mut self, events: &mut Vec<Event>) -> anyhow::Result<()> {
        if self.running.is_empty() {
            return Ok(());
        }
        let max_seq = self.engine.max_seq();
        // Non-finite-logit quarantine sentinel: scan BEFORE sampling —
        // greedy argmax ranks NaN highest under `total_cmp`, so a
        // corrupted logit row must never reach `next_token()`. Quarantine
        // is terminal (no retry): decode is deterministic per request, so
        // replaying the same inputs would reproduce the excursion.
        let any_nonfinite = self
            .running
            .iter()
            .any(|s| s.last_logits.iter().any(|v| !v.is_finite()));
        if any_nonfinite {
            let seqs = std::mem::take(&mut self.running);
            let timings = std::mem::take(&mut self.timings);
            for (s, t) in seqs.into_iter().zip(timings) {
                if s.last_logits.iter().any(|v| !v.is_finite()) {
                    self.quarantine_seq(s, &t, events);
                } else {
                    self.running.push(s);
                    self.timings.push(t);
                }
            }
            if self.running.is_empty() {
                return Ok(());
            }
        }
        // sample + append + stream the next token for every sequence
        let now = Instant::now();
        for (s, t) in self.running.iter_mut().zip(self.timings.iter_mut()) {
            let next = s.next_token();
            s.tokens.push(next);
            if s.stop_tokens.contains(&next) {
                s.stopped = true;
            }
            events.push(Event::Token { id: s.id, token: next, index: s.generated() - 1 });
            match t.last_token {
                None => {
                    t.ttft_s = now.duration_since(t.arrival).as_secs_f64();
                    self.metrics.ttft.add(t.ttft_s);
                    self.obs.ttft_seconds.observe(t.ttft_s);
                    self.obs.flight.push(s.id, FlightKind::FirstToken);
                }
                Some(prev) => {
                    let gap = now.duration_since(prev).as_secs_f64();
                    self.metrics.itl.add(gap);
                    self.obs.itl_seconds.observe(gap);
                }
            }
            t.last_token = Some(now);
        }
        // sequences that just produced their final token complete; the
        // rest are retained in order (no clone — the engine decodes the
        // running vec in place)
        let seqs = std::mem::take(&mut self.running);
        let timings = std::mem::take(&mut self.timings);
        for (s, t) in seqs.into_iter().zip(timings) {
            if s.finished(max_seq) {
                self.engine.release(s.id);
                self.live.remove(&s.id);
                self.attempts.remove(&s.id);
                self.metrics.completed += 1;
                self.metrics.adapter(&s.adapter).completed += 1;
                self.metrics.latency.add(t.queue_s + t.prefill_s + t.decode_s);
                self.metrics.queue_wait.add(t.queue_s);
                self.obs.completed.inc();
                self.obs.flight.push(s.id, FlightKind::Done { generated: s.generated() });
                self.obs.flight.push(s.id, FlightKind::Released);
                events.push(Event::Done {
                    response: Response {
                        id: s.id,
                        prompt_len: s.prompt_len,
                        tokens: s.tokens[s.prompt_len..].to_vec(),
                        adapter: s.adapter,
                        queue_s: t.queue_s,
                        prefill_s: t.prefill_s,
                        decode_s: t.decode_s,
                        ttft_s: t.ttft_s,
                    },
                });
            } else {
                self.running.push(s);
                self.timings.push(t);
            }
        }
        if !self.running.is_empty() {
            let t0 = Instant::now();
            if let Err(e) = self.engine.decode(&mut self.running) {
                // a failed batched decode tick loses the whole batch's
                // computed state — fail every running sequence retryably
                // rather than poisoning the server. Tokens streamed this
                // tick stay valid: a retry replays them identically from
                // a fresh prefill (decode is deterministic per request).
                crate::warn_log!("decode failed, failing running set: {e:#}");
                let seqs = std::mem::take(&mut self.running);
                let timings = std::mem::take(&mut self.timings);
                for (s, t) in seqs.into_iter().zip(timings) {
                    self.fail_seq(s, &t, "engine_error", true, events);
                }
                return Ok(());
            }
            let dt = t0.elapsed().as_secs_f64();
            self.metrics.decode_secs += dt;
            self.metrics.decode_ticks += 1;
            self.metrics.decode_tokens += self.running.len();
            self.obs.decode_ticks.inc();
            self.obs.decode_tokens.add(self.running.len() as u64);
            self.obs.decode_batch_size.observe(self.running.len() as f64);
            for s in self.running.iter() {
                self.metrics.adapter(&s.adapter).decode_tokens += 1;
            }
            let per = dt / self.running.len() as f64;
            for t in self.timings.iter_mut() {
                t.decode_s += per;
            }
            // deterministic sentinel cadence: every n-th tick, replay one
            // running sequence's step through the engine's reference path
            // and record agreement/drift. Pure observation — the streams
            // above were produced before the probe ran, and the probe's
            // shadow state is released before the next tick.
            let n = self.cfg.sentinel_every_n_ticks as u64;
            if n > 0 && self.tick % n == 0 {
                let idx = ((self.tick / n) as usize) % self.running.len();
                match self.engine.sentinel_probe(&self.running[idx]) {
                    Some((agree, drift)) => {
                        self.obs.sentinel_probes.inc();
                        self.obs.sentinel_top1_agree.observe(if agree { 1.0 } else { 0.0 });
                        self.obs.sentinel_logit_drift.observe(drift);
                    }
                    None => self.obs.sentinel_skipped.inc(),
                }
            }
        }
        Ok(())
    }

    /// Fail one in-flight sequence: release its engine state, record the
    /// failure, and either schedule a retry-by-re-prefill (when
    /// `retry_wanted`, the server is not draining, and the retry budget
    /// allows) or terminate the stream. Either way the caller gets an
    /// [`Event::Failed`]; a retried id stays in `live` (the client's
    /// handle remains valid and its stream restarts from index 0).
    fn fail_seq(
        &mut self,
        s: SeqState,
        t: &ReqTiming,
        reason: &'static str,
        retry_wanted: bool,
        events: &mut Vec<Event>,
    ) {
        // engine release is tolerant of partially-admitted sequences, so
        // this never leaks KV blocks or adapter pins whatever path failed
        self.engine.release(s.id);
        let made = *self.attempts.get(&s.id).unwrap_or(&0);
        let retryable = retry_wanted && !self.draining && made < self.cfg.retry_budget;
        self.metrics.failed += 1;
        self.obs.fail(s.id, reason, retryable);
        self.obs.flight.push(s.id, FlightKind::Released);
        if retryable {
            self.attempts.insert(s.id, made + 1);
            self.metrics.retries += 1;
            self.obs.retries.inc();
            // exact regeneration: rebuild the original request from the
            // sequence's own state (its prompt is `tokens[..prompt_len]`,
            // untouched by generation) and keep the original arrival so
            // the deadline budget stays end-to-end across attempts
            let req = Request {
                id: s.id,
                prompt: s.tokens[..s.prompt_len].to_vec(),
                max_new_tokens: s.max_new,
                arrival: t.arrival,
                adapter: s.adapter,
                params: s.params,
                stop_tokens: s.stop_tokens,
                deadline_ms: s.deadline_ms,
            };
            let ready_tick = self.tick + self.cfg.retry_backoff_ticks as u64;
            self.retry_queue.push_back(RetryEntry { req, ready_tick });
        } else {
            self.live.remove(&s.id);
            self.attempts.remove(&s.id);
        }
        events.push(Event::Failed { id: s.id, reason, retryable });
    }

    /// Quarantine a sequence whose logits went non-finite: a terminal
    /// failure plus an anomaly trip, so the flight ring dumps while the
    /// context is hot.
    fn quarantine_seq(&mut self, s: SeqState, t: &ReqTiming, events: &mut Vec<Event>) {
        self.metrics.quarantined += 1;
        self.obs.quarantine(s.id, "nonfinite_logits");
        self.obs
            .flight
            .trip_anomaly(format!("non-finite logits quarantined seq {}", s.id));
        self.fail_seq(s, t, "nonfinite_logits", false, events);
    }

    /// Move backoff-expired retries back into the admission queue. A
    /// retry whose deadline lapsed during backoff fails terminally here;
    /// one that meets a full queue just waits another tick (its backoff
    /// is already spent, so no new failure is recorded).
    fn requeue_retries(&mut self, events: &mut Vec<Event>) {
        if self.retry_queue.is_empty() {
            return;
        }
        let mut later: VecDeque<RetryEntry> = VecDeque::new();
        while let Some(e) = self.retry_queue.pop_front() {
            if e.ready_tick > self.tick {
                later.push_back(e);
                continue;
            }
            let id = e.req.id;
            if e.req.deadline_ms > 0
                && e.req.arrival.elapsed().as_millis() as u64 >= e.req.deadline_ms
            {
                self.live.remove(&id);
                self.attempts.remove(&id);
                self.metrics.failed += 1;
                self.obs.fail(id, "deadline", false);
                events.push(Event::Failed { id, reason: "deadline", retryable: false });
                continue;
            }
            // `push` consumes (and on a full queue drops) its argument,
            // so hand it a clone and keep the original for the requeue
            if self.batcher.push(e.req.clone()) {
                self.obs.flight.push(id, FlightKind::Retried);
            } else {
                later.push_back(RetryEntry { req: e.req, ready_tick: self.tick + 1 });
            }
        }
        self.retry_queue = later;
    }

    /// Fail any prefilling or running sequence whose deadline expired.
    /// Terminal, never retried: decode is deterministic per request, so a
    /// request that blew its budget once would blow it again from a fresh
    /// prefill.
    fn expire_deadlines(&mut self, events: &mut Vec<Event>) {
        let expired = |s: &SeqState, t: &ReqTiming| {
            s.deadline_ms > 0 && t.arrival.elapsed().as_millis() as u64 >= s.deadline_ms
        };
        if self.prefilling.iter().zip(&self.prefilling_timings).any(|(s, t)| expired(s, t)) {
            let seqs = std::mem::take(&mut self.prefilling);
            let timings = std::mem::take(&mut self.prefilling_timings);
            for (s, t) in seqs.into_iter().zip(timings) {
                if expired(&s, &t) {
                    self.fail_seq(s, &t, "deadline", false, events);
                } else {
                    self.prefilling.push(s);
                    self.prefilling_timings.push(t);
                }
            }
        }
        if self.running.iter().zip(&self.timings).any(|(s, t)| expired(s, t)) {
            let seqs = std::mem::take(&mut self.running);
            let timings = std::mem::take(&mut self.timings);
            for (s, t) in seqs.into_iter().zip(timings) {
                if expired(&s, &t) {
                    self.fail_seq(s, &t, "deadline", false, events);
                } else {
                    self.running.push(s);
                    self.timings.push(t);
                }
            }
        }
    }

    /// Graceful shutdown: stop admission permanently, reject everything
    /// still queued, final-fail retries waiting out backoff, then keep
    /// stepping until in-flight work completes — or fail the leftovers
    /// terminally once `timeout_ticks` is spent. On return the server is
    /// empty and the engine's caches are flushed, so the KV pool holds
    /// zero blocks and the adapter registry zero pins (the chaos suite
    /// asserts exactly this). Returns every event produced while
    /// draining: completions for sequences that finished in time,
    /// `Event::Failed` with reason `"drain_timeout"` for those that
    /// did not, and `Event::Rejected` (reason [`RejectReason::Draining`])
    /// for requests that never left the queue.
    pub fn drain(&mut self, timeout_ticks: usize) -> anyhow::Result<Vec<Event>> {
        self.draining = true;
        let mut events = std::mem::take(&mut self.pending_events);
        for req in self.batcher.drain() {
            self.live.remove(&req.id);
            self.attempts.remove(&req.id);
            self.metrics.rejected += 1;
            self.obs.reject(req.id, RejectReason::Draining);
            events.push(Event::Rejected { id: req.id, reason: RejectReason::Draining });
        }
        while let Some(e) = self.retry_queue.pop_front() {
            let id = e.req.id;
            self.live.remove(&id);
            self.attempts.remove(&id);
            self.metrics.failed += 1;
            self.obs.fail(id, "draining", false);
            events.push(Event::Failed { id, reason: "draining", retryable: false });
        }
        let mut spent = 0usize;
        while !(self.running.is_empty() && self.prefilling.is_empty()) && spent < timeout_ticks
        {
            events.extend(self.step()?);
            spent += 1;
        }
        let seqs = std::mem::take(&mut self.running);
        let timings = std::mem::take(&mut self.timings);
        for (s, t) in seqs.into_iter().zip(timings) {
            self.fail_seq(s, &t, "drain_timeout", false, &mut events);
        }
        let seqs = std::mem::take(&mut self.prefilling);
        let timings = std::mem::take(&mut self.prefilling_timings);
        for (s, t) in seqs.into_iter().zip(timings) {
            self.fail_seq(s, &t, "drain_timeout", false, &mut events);
        }
        events.append(&mut self.pending_events);
        // leave nothing cached behind: shared-prefix blocks pinned by the
        // cache are returned to the pool here
        self.engine.flush_caches();
        self.engine.observe(&self.obs.registry);
        self.obs.queue_depth.set(0);
        self.obs.running.set(0);
        self.obs.prefilling.set(0);
        Ok(events)
    }

    /// Compatibility shim: play a request trace to completion through
    /// `submit` + `step`. Token-identical to the pre-redesign closed-loop
    /// `run()` — all requests arrive up front, the loop drains them, and
    /// the report carries every completed response sorted by id.
    /// Rejected submissions (queue backpressure, bad requests) are counted
    /// in the metrics and dropped, exactly as before.
    pub fn run_trace(&mut self, requests: Vec<Request>) -> anyhow::Result<ServeReport> {
        self.metrics = ServeMetrics::default();
        let mut pending: VecDeque<Request> = requests.into();
        let mut responses = Vec::new();
        let wall0 = Instant::now();
        while !pending.is_empty() || !self.is_idle() {
            // arrival process: everything available now; on the first
            // rejection (queue full), stop feeding until the next tick
            while let Some(req) = pending.pop_front() {
                if self.submit(req).is_err() {
                    break;
                }
            }
            for ev in self.step()? {
                if let Event::Done { response } = ev {
                    responses.push(response);
                }
            }
        }
        self.metrics.wall_secs = wall0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        Ok(ServeReport {
            responses,
            metrics: self.reset_metrics(),
            engine: self.engine.name(),
        })
    }
}

/// Per-request serving timestamps (queue/prefill/decode attribution plus
/// the per-token stamps behind TTFT/ITL).
#[derive(Clone, Debug)]
struct ReqTiming {
    arrival: Instant,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    ttft_s: f64,
    /// when this sequence's latest token was streamed
    last_token: Option<Instant>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::coordinator::engine::NativeEngine;
    use crate::model::Model;
    use crate::util::Rng;

    fn tiny_server() -> Server<NativeEngine> {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let model = Model::init(&cfg, 0);
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 32,
            kv_budget_mib: 0.0,
            rate_rps: 0.0,
            prefill_chunk_tokens: 0,
            ..ServeCfg::default()
        };
        Server::new(NativeEngine::new(model, "fp"), serve).unwrap()
    }

    fn reqs(n: usize, prompt_len: usize, max_new: usize) -> Vec<Request> {
        let mut rng = Rng::new(0);
        (0..n)
            .map(|i| Request::new(i as u64, (0..prompt_len).map(|_| rng.below(32)).collect(), max_new))
            .collect()
    }

    #[test]
    fn serves_all_requests_to_completion() {
        let mut srv = tiny_server();
        let report = srv.run_trace(reqs(9, 12, 6)).unwrap();
        assert_eq!(report.responses.len(), 9);
        assert_eq!(report.metrics.completed, 9);
        for r in &report.responses {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.tokens.iter().all(|&t| t < 32));
        }
        assert!(report.metrics.prefill_tokens == 9 * 12);
        assert!(report.metrics.decode_tokens >= 9 * 5);
        assert!(report.metrics.total_tps() > 0.0);
        // streaming latency percentiles came from per-token timestamps
        assert_eq!(report.metrics.ttft.len(), 9);
        assert!(report.metrics.itl.len() >= 9 * 5);
        assert!(report.metrics.ttft.p50() >= 0.0);
        // decode ran as batched ticks (max_concurrent 4 ⇒ avg batch > 1)
        assert!(report.metrics.decode_ticks > 0);
        assert!(report.metrics.avg_decode_batch() > 1.0);
    }

    #[test]
    fn deterministic_outputs_per_request() {
        let mut a = tiny_server();
        let mut b = tiny_server();
        let ra = a.run_trace(reqs(4, 10, 5)).unwrap();
        let rb = b.run_trace(reqs(4, 10, 5)).unwrap();
        for (x, y) in ra.responses.iter().zip(&rb.responses) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn batched_serving_matches_single_stream() {
        // tokens generated must be independent of batching decisions
        let mut batched = tiny_server();
        let rep_b = batched.run_trace(reqs(6, 10, 4)).unwrap();
        for want in rep_b.responses.iter() {
            let mut single = tiny_server();
            let one = reqs(6, 10, 4).remove(want.id as usize);
            let rep_s = single.run_trace(vec![one]).unwrap();
            assert_eq!(rep_s.responses[0].tokens, want.tokens, "req {}", want.id);
        }
    }

    #[test]
    fn submit_step_streams_tokens_incrementally() {
        let mut srv = tiny_server();
        let id = srv.submit(reqs(1, 10, 4).remove(0)).unwrap();
        assert_eq!(id, 0);
        assert!(!srv.is_idle());
        let mut streamed = Vec::new();
        let mut done = None;
        let mut token_events = 0;
        while done.is_none() {
            for ev in srv.step().unwrap() {
                match ev {
                    Event::Token { id: eid, token, index } => {
                        assert_eq!(eid, id);
                        assert_eq!(index, token_events, "tokens stream in order");
                        token_events += 1;
                        streamed.push(token);
                    }
                    Event::Done { response } => done = Some(response),
                    other => panic!("unexpected event {other:?}"),
                }
            }
        }
        assert!(srv.is_idle());
        let resp = done.unwrap();
        assert_eq!(resp.tokens, streamed, "Done carries exactly the streamed tokens");
        assert_eq!(resp.tokens.len(), 4);
        // and the incremental path matches the trace shim token-for-token
        let mut shim = tiny_server();
        let rep = shim.run_trace(reqs(1, 10, 4)).unwrap();
        assert_eq!(rep.responses[0].tokens, streamed);
    }

    #[test]
    fn duplicate_and_invalid_submissions_are_rejected() {
        let mut srv = tiny_server();
        srv.submit(Request::new(7, vec![1, 2, 3], 4)).unwrap();
        assert_eq!(
            srv.submit(Request::new(7, vec![1, 2, 3], 4)),
            Err(RejectReason::DuplicateId)
        );
        assert_eq!(srv.submit(Request::new(8, vec![], 4)), Err(RejectReason::EmptyPrompt));
        assert_eq!(
            srv.submit(Request::new(9, vec![1; 100], 4)),
            Err(RejectReason::PromptTooLong)
        );
        assert_eq!(
            srv.submit(Request::new(10, vec![1, 2], 2).with_adapter("ghost-tenant")),
            Err(RejectReason::UnknownAdapter)
        );
        assert_eq!(srv.metrics.rejected, 4);
        // the one accepted request still serves to completion
        let mut completed = 0;
        while !srv.is_idle() {
            for ev in srv.step().unwrap() {
                if matches!(ev, Event::Done { .. }) {
                    completed += 1;
                }
            }
        }
        assert_eq!(completed, 1);
    }

    #[test]
    fn queue_backpressure_rejects_when_full() {
        let mut srv = tiny_server();
        srv.batcher.max_queue = 2;
        assert!(srv.submit(Request::new(0, vec![1, 2], 2)).is_ok());
        assert!(srv.submit(Request::new(1, vec![1, 2], 2)).is_ok());
        assert_eq!(srv.submit(Request::new(2, vec![1, 2], 2)), Err(RejectReason::QueueFull));
        // a rejected id is not retained: it can be resubmitted once the
        // queue drains
        srv.step().unwrap();
        assert!(srv.submit(Request::new(2, vec![1, 2], 2)).is_ok());
    }

    #[test]
    fn cancel_releases_queued_and_running_requests() {
        let mut srv = tiny_server();
        for r in reqs(6, 12, 8) {
            srv.submit(r).unwrap();
        }
        // cancel one while still queued (max_concurrent = 4, so ids 4/5 wait)
        srv.step().unwrap();
        assert!(srv.cancel(5));
        // cancel one mid-decode
        assert!(srv.cancel(0));
        assert!(!srv.cancel(0), "already cancelled");
        assert!(!srv.cancel(99), "never submitted");
        let evs = srv.step().unwrap();
        let cancelled: Vec<SeqId> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Cancelled { id } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(cancelled, vec![5, 0]);
        let mut done = 0;
        while !srv.is_idle() {
            for ev in srv.step().unwrap() {
                if matches!(ev, Event::Done { .. }) {
                    done += 1;
                }
            }
        }
        assert_eq!(done, 4);
        assert_eq!(srv.metrics.cancelled, 2);
        // every block (cancelled included) went back to the pool
        assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
        assert_eq!(srv.engine.kv_pool().active_sequences(), 0);
    }

    #[test]
    fn multitenant_serving_tracks_per_adapter_metrics() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let mut model = Model::init(&cfg, 0);
        model.quantize_lords(
            cfg.block,
            &crate::quant::Codebook::normal_float(4),
            crate::quant::lords::RefineCfg { steps: 2, ..Default::default() },
            false,
        );
        let mut engine = NativeEngine::new(model, "mt");
        let base = crate::adapters::AdapterFactors::from_model(&engine.model);
        let mut arng = Rng::new(3);
        engine.register_adapter("t0", base.perturbed(0.05, &mut arng)).unwrap();
        engine.register_adapter("t1", base.perturbed(0.05, &mut arng)).unwrap();
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 32,
            kv_budget_mib: 0.0,
            rate_rps: 0.0,
            prefill_chunk_tokens: 0,
            ..ServeCfg::default()
        };
        let mut srv = Server::new(engine, serve).unwrap();
        let tenants = ["base", "t0", "t1"];
        let mut requests = reqs(6, 8, 4);
        for (i, r) in requests.iter_mut().enumerate() {
            r.adapter = tenants[i % 3].to_string();
        }
        let report = srv.run_trace(requests).unwrap();
        assert_eq!(report.metrics.completed, 6);
        for t in tenants {
            let c = &report.metrics.per_adapter[t];
            assert_eq!(c.requests, 2, "{t}");
            assert_eq!(c.completed, 2, "{t}");
            assert_eq!(c.prefill_tokens, 2 * 8, "{t}");
            assert!(c.decode_tokens >= 2 * 3, "{t}");
        }
        for r in &report.responses {
            assert_eq!(r.adapter, tenants[r.id as usize % 3]);
            assert_eq!(r.tokens.len(), 4);
        }
        // every in-flight pin was released with its sequence
        assert_eq!(srv.engine.registry().pins("t0"), 0);
        assert_eq!(srv.engine.registry().pins("t1"), 0);
    }

    #[test]
    fn unknown_adapter_is_rejected_not_fatal() {
        let mut srv = tiny_server();
        // submit-time rejection for unknown tenants
        assert_eq!(
            srv.submit(Request::new(0, vec![1, 2, 3, 4], 2).with_adapter("ghost-tenant")),
            Err(RejectReason::UnknownAdapter)
        );
        // and a trace containing one still completes the valid requests
        let mut requests = reqs(3, 8, 2);
        requests[1].adapter = "ghost-tenant".into();
        let report = srv.run_trace(requests).unwrap();
        assert_eq!(report.metrics.completed, 2);
        assert_eq!(report.metrics.rejected, 1);
        assert!(report.responses.iter().all(|r| r.id != 1));
    }

    #[test]
    fn stop_tokens_end_generation_early() {
        // a stop set covering the whole vocabulary stops every sequence at
        // exactly one generated token, whatever the model emits
        let mut srv = tiny_server();
        let requests: Vec<Request> = reqs(4, 10, 8)
            .into_iter()
            .map(|r| r.with_stop_tokens((0..32).collect()))
            .collect();
        let report = srv.run_trace(requests).unwrap();
        assert_eq!(report.metrics.completed, 4);
        for r in &report.responses {
            assert_eq!(r.tokens.len(), 1, "stop token ends the stream (and is included)");
        }
        assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
    }

    #[test]
    fn respects_max_seq() {
        let mut srv = tiny_server();
        let report = srv.run_trace(reqs(1, 40, 100)).unwrap();
        // 48 max_seq - 40 prompt = at most 8 new tokens
        assert!(report.responses[0].tokens.len() <= 8);
    }

    #[test]
    fn quantized_kv_serves_to_completion_in_less_memory() {
        use crate::kvquant::{KvBits, KvQuantCfg};
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let serve = ServeCfg {
            decode_buckets: vec![1, 2, 4],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 0,
            max_queue: 64,
            max_new_tokens: 8,
            workers: 1,
            kv_bits: 8,
            kv_budget_mib: 0.0,
            rate_rps: 0.0,
            prefill_chunk_tokens: 0,
            ..ServeCfg::default()
        };
        let kv = KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: 8 };
        let engine = NativeEngine::with_kv(Model::init(&cfg, 0), "kv8", kv);
        let mut srv = Server::new(engine, serve).unwrap();
        let report = srv.run_trace(reqs(6, 12, 6)).unwrap();
        assert_eq!(report.metrics.completed, 6);
        for r in &report.responses {
            assert_eq!(r.tokens.len(), 6);
        }
        {
            let pool = srv.engine.kv_pool();
            assert!(pool.block_bytes() < pool.dense_block_bytes());
            // same byte budget as the dense auto-sizing, more concurrency
            assert!(pool.max_concurrent_full_seqs(cfg.max_seq) > 4);
            // private (non-prefix) storage released on completion: with
            // block_tokens = 8 and 12-token prompts, the prefix cache may
            // retain each prompt's first block for future sharing
            assert_eq!(pool.active_sequences(), 0);
            assert!(pool.used_blocks() <= 6, "at most one cached block per prompt");
        }
        // flushing the prefix cache drains the pool completely
        srv.engine.flush_prefix_cache();
        assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
    }

    #[test]
    fn construction_rejects_invalid_configs() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 48,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let bad = ServeCfg { decode_buckets: vec![], ..ServeCfg::default() };
        let model = Model::init(&cfg, 0);
        assert!(Server::new(NativeEngine::new(model, "fp"), bad).is_err());
        let bad_fault = ServeCfg {
            fault_spec: "p=0.5".into(), // no site= field
            ..ServeCfg::default()
        };
        let model = Model::init(&cfg, 0);
        assert!(Server::new(NativeEngine::new(model, "fp"), bad_fault).is_err());
    }

    #[test]
    fn infeasible_deadlines_are_rejected_at_submit() {
        let mut srv = tiny_server();
        srv.cfg.min_deadline_ms = 100;
        let r = Request::new(0, vec![1, 2, 3], 4).with_deadline_ms(10);
        assert_eq!(srv.submit(r), Err(RejectReason::DeadlineInfeasible));
        // at or above the floor: admitted
        let r = Request::new(1, vec![1, 2, 3], 4).with_deadline_ms(60_000);
        assert_eq!(srv.submit(r), Ok(1));
        // no deadline at all bypasses the floor
        let r = Request::new(2, vec![1, 2, 3], 4);
        assert_eq!(srv.submit(r), Ok(2));
    }

    #[test]
    fn drain_finishes_in_flight_work_and_empties_the_server() {
        let mut srv = tiny_server();
        for r in reqs(4, 12, 6) {
            srv.submit(r).unwrap();
        }
        srv.step().unwrap(); // admit + begin prefill
        let events = srv.drain(10_000).unwrap();
        let done = events
            .iter()
            .filter(|e| matches!(e, Event::Done { .. }))
            .count();
        assert_eq!(done, 4, "in-flight work finishes during drain");
        assert!(srv.is_idle());
        assert!(srv.is_draining());
        assert!(!srv.is_ready());
        assert_eq!(srv.engine.kv_pool().active_sequences(), 0);
        assert_eq!(srv.engine.kv_pool().used_blocks(), 0, "drain flushes caches");
        // admission is permanently stopped
        let r = Request::new(99, vec![1, 2, 3], 4);
        assert_eq!(srv.submit(r), Err(RejectReason::Draining));
    }

    #[test]
    fn drain_timeout_fails_leftovers_terminally() {
        let mut srv = tiny_server();
        for r in reqs(2, 12, 6) {
            srv.submit(r).unwrap();
        }
        srv.step().unwrap();
        // zero extra ticks: whatever is still in flight fails immediately
        let events = srv.drain(0).unwrap();
        let failed: Vec<_> = events
            .iter()
            .filter_map(|e| match e {
                Event::Failed { id, reason, retryable } => Some((*id, *reason, *retryable)),
                _ => None,
            })
            .collect();
        assert!(!failed.is_empty(), "leftovers must fail at the budget");
        for (_, reason, retryable) in &failed {
            assert_eq!(*reason, "drain_timeout");
            assert!(!retryable, "drain failures are terminal");
        }
        assert!(srv.is_idle());
        assert_eq!(srv.engine.kv_pool().used_blocks(), 0);
    }
}
