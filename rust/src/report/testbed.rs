//! Testbed construction.
//!
//! * [`Testbed`] — a pre-trained tiny-Llama + its corpora + task suite,
//!   memoized to `artifacts/testbeds/` so every bench starts from the same
//!   checkpoint (and re-runs are fast).
//! * [`module_suite`] — per-module weight matrices with the paper's exact
//!   aspect ratios (Q/K/V/O/Gate/Up/Down), scaled down, with LLM-like
//!   statistics (Gaussian bulk + heavy-tail outlier channels) for the
//!   Appendix-B error-ratio tables.

use crate::config::{ModelCfg, TrainCfg};
use crate::data::corpus::{Corpus, CorpusKind};
use crate::data::tasks::TaskSuite;
use crate::model::Model;
use crate::tensor::Matrix;
use crate::train::{NativeTrainer, TrainKind};
use crate::util::Rng;

/// The standard testbed: one pre-trained model + eval assets.
pub struct Testbed {
    pub name: String,
    pub cfg: ModelCfg,
    pub model: Model,
    pub wiki: Corpus,
    pub ptb: Corpus,
    pub suite: TaskSuite,
}

/// Scaled-down stand-ins for the paper's three model families. Same
/// architecture family, different capacity — enough to show per-model
/// trends without hours of CPU pre-training. Under [`smoke_mode`] the zoo
/// collapses to a single micro config so every bench binary finishes in
/// seconds on a CI runner.
pub fn model_zoo() -> Vec<(&'static str, ModelCfg)> {
    let base = ModelCfg::default();
    if smoke_mode() {
        return vec![(
            "smoke-micro",
            ModelCfg {
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                d_ff: 128,
                max_seq: 128,
                block: 32,
                qlora_rank: 8,
                ..base
            },
        )];
    }
    vec![
        ("llama3-mini", ModelCfg { d_model: 256, n_layers: 4, d_ff: 512, ..base.clone() }),
        ("qwen3-mini", ModelCfg { d_model: 192, n_layers: 4, d_ff: 448, ..base.clone() }),
        ("qwen3-micro", ModelCfg { d_model: 128, n_layers: 3, d_ff: 320, ..base.clone() }),
    ]
}

impl Testbed {
    /// Build (or load from `artifacts/testbeds/{name}.bin`) the pre-trained
    /// testbed. `steps = 0` skips pre-training (unit-test speed); under
    /// [`smoke_mode`] pre-training is capped so CI smoke runs stay fast.
    pub fn build(name: &str, cfg: &ModelCfg, steps: usize, seed: u64) -> Testbed {
        let steps = if smoke_mode() { steps.min(20) } else { steps };
        let wiki = Corpus::generate(CorpusKind::Wiki, cfg.vocab, 200_000, 20_000, seed);
        let ptb = Corpus::generate(CorpusKind::Ptb, cfg.vocab, 50_000, 20_000, seed + 1);
        let suite = TaskSuite::generate(&wiki, 40, seed + 2);

        let path = format!("artifacts/testbeds/{name}_s{steps}_seed{seed}.bin");
        let model = match Model::load(&path, cfg) {
            Ok(m) => {
                crate::info!("testbed {name}: loaded {path}");
                m
            }
            Err(_) => {
                crate::info!("testbed {name}: pre-training {steps} steps (one-time)");
                let mut model = Model::init(cfg, seed);
                if steps > 0 {
                    let tcfg = TrainCfg {
                        steps,
                        batch: 8,
                        seq: 64,
                        peak_lr: 3e-3,
                        warmup_ratio: 0.05,
                        weight_decay: 0.01,
                        seed,
                        log_every: (steps / 5).max(1),
                    };
                    let mut tr = NativeTrainer::new(tcfg, TrainKind::Pretrain);
                    tr.run(&mut model, &wiki);
                }
                if model.save(&path).is_ok() {
                    crate::info!("testbed {name}: saved {path}");
                }
                model
            }
        };
        Testbed { name: name.to_string(), cfg: cfg.clone(), model, wiki, ptb, suite }
    }
}

/// Standard evaluation bundle for one (possibly quantized) model: the
/// Wiki/PTB PPL pair + the 7-task average — one row of Tables 1/3/4.
#[derive(Clone, Debug)]
pub struct EvalBundle {
    pub wiki: crate::eval::PplResult,
    pub ptb: crate::eval::PplResult,
    pub per_task: Vec<(&'static str, f32)>,
    pub avg: f32,
}

pub fn eval_model(model: &Model, tb: &Testbed, ppl_windows: usize, per_task: usize) -> EvalBundle {
    let wiki = crate::eval::perplexity(model, &tb.wiki, 64, ppl_windows);
    let ptb = crate::eval::perplexity(model, &tb.ptb, 64, ppl_windows);
    // trim the suite for bench-speed; FULL=1 benches pass usize::MAX
    let mut suite = tb.suite.clone();
    for t in suite.tasks.iter_mut() {
        t.examples.truncate(per_task);
    }
    let acc = crate::eval::evaluate_suite(model, &suite);
    EvalBundle { wiki, ptb, per_task: acc.per_task, avg: acc.average }
}

/// Bench scale switch: `FULL=1 cargo bench ...` runs the paper-size sweep;
/// the default is a reduced sweep that finishes in minutes on CPU.
/// [`smoke_mode`] overrides it — a smoke run is never a full run.
pub fn full_mode() -> bool {
    !smoke_mode() && std::env::var("FULL").map(|v| v == "1").unwrap_or(false)
}

/// CI bench-smoke switch: `LORDS_BENCH_SMOKE=1 cargo bench ...` shrinks
/// the model zoo to one micro config, caps testbed pre-training, and caps
/// the timing harness' warmup/measure windows, so every bench binary runs
/// end to end in seconds while still *measuring* real numbers (the JSON
/// it writes keeps `measured: true` — tiny, but not fabricated).
pub fn smoke_mode() -> bool {
    std::env::var("LORDS_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One module shape from Appendix A (Table 7), scaled by `scale` (the
/// paper's 4096 → 512 at scale 8).
#[derive(Clone, Copy, Debug)]
pub struct ModuleShape {
    pub name: &'static str,
    pub n: usize,
    pub m: usize,
}

/// The Llama3-8B module inventory at 1/`scale` linear size.
pub fn llama_modules(scale: usize) -> Vec<ModuleShape> {
    let d = 4096 / scale;
    let kv = 1024 / scale;
    let ff = 14336 / scale;
    // round ff to a multiple of 64 for blockability
    let ff = ff / 64 * 64;
    vec![
        ModuleShape { name: "Q", n: d, m: d },
        ModuleShape { name: "K", n: kv, m: d },
        ModuleShape { name: "V", n: kv, m: d },
        ModuleShape { name: "O", n: d, m: d },
        ModuleShape { name: "Gate", n: ff, m: d },
        ModuleShape { name: "Up", n: ff, m: d },
        ModuleShape { name: "Down", n: d, m: ff },
    ]
}

/// LLM-like weight generator: Gaussian bulk + heavy-tail outlier channels
/// (student-t scaled columns), the statistics block scaling struggles with.
pub fn llm_like_weight(shape: ModuleShape, rng: &mut Rng) -> Matrix {
    let mut w = Matrix::randn(shape.n, shape.m, 0.02, rng);
    let n_out = (shape.m / 24).max(1);
    let outliers = rng.choose(shape.m, n_out);
    for &c in &outliers {
        let boost = 4.0 + rng.student_t(3.0).abs().min(12.0);
        for i in 0..shape.n {
            *w.at_mut(i, c) *= boost;
        }
    }
    // a few hot rows too (attention-sink-like)
    for &r in rng.choose(shape.n, (shape.n / 48).max(1)).iter() {
        for v in w.row_mut(r) {
            *v *= 3.0;
        }
    }
    w
}

/// The per-module suite used by Tables 8–9.
pub fn module_suite(scale: usize, seed: u64) -> Vec<(ModuleShape, Matrix)> {
    let mut rng = Rng::new(seed ^ 0x5017E);
    llama_modules(scale)
        .into_iter()
        .map(|s| {
            let w = llm_like_weight(s, &mut rng);
            (s, w)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_shapes_scale() {
        let mods = llama_modules(8);
        assert_eq!(mods[0].n, 512);
        assert_eq!(mods[1].n, 128); // K
        let names: Vec<_> = mods.iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["Q", "K", "V", "O", "Gate", "Up", "Down"]);
    }

    #[test]
    fn weights_have_outliers() {
        let mut rng = Rng::new(0);
        let w = llm_like_weight(ModuleShape { name: "Q", n: 64, m: 128 }, &mut rng);
        let col_norm = |j: usize| -> f32 { (0..64).map(|i| w.at(i, j).powi(2)).sum::<f32>().sqrt() };
        let norms: Vec<f32> = (0..128).map(col_norm).collect();
        let max = norms.iter().cloned().fold(0.0f32, f32::max);
        let med = {
            let mut s = norms.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[64]
        };
        assert!(max > 3.0 * med, "outlier channels missing: max {max} med {med}");
    }

    #[test]
    fn testbed_without_pretraining_is_fast_and_cached() {
        let cfg = ModelCfg {
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let tb = Testbed::build("unit-test", &cfg, 0, 9);
        assert_eq!(tb.suite.tasks.len(), 7);
        // second build loads from disk — must be identical
        let tb2 = Testbed::build("unit-test", &cfg, 0, 9);
        assert_eq!(tb.model.tok_emb.data, tb2.model.tok_emb.data);
        std::fs::remove_file("artifacts/testbeds/unit-test_s0_seed9.bin").ok();
    }
}
