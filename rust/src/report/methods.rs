//! Method application: quantize a single weight matrix (or a whole model)
//! with any of the paper's methods under a shared interface — the engine
//! behind Tables 1, 2, 3, 8, 9.

use crate::config::{QuantCfg, QuantMethod};
use crate::model::{LinearWeight, Model};
use crate::quant::baselines::{loftq_quantize, qpissa_quantize, AwqQuant, GptqQuant, QloraLinear};
use crate::quant::lords::RefineCfg;
use crate::quant::scale::parity_rank_with_adapter;
use crate::quant::{BlockwiseQuant, Codebook, LordsQuant, QuantizedLinear};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Outcome of quantizing one matrix.
pub struct MethodResult {
    pub w_hat: Matrix,
    pub float_params: usize,
    pub method: &'static str,
}

/// Quantize a single matrix with `cfg.method`. `x_cal` is required for
/// GPTQ/AWQ (calibration activations, t×m).
pub fn apply_method(w: &Matrix, cfg: &QuantCfg, x_cal: Option<&Matrix>, seed: u64) -> MethodResult {
    let cb = Codebook::by_name(&cfg.codebook).expect("codebook");
    let refine = RefineCfg { steps: cfg.refine_steps, lr: cfg.refine_lr, requant_every: 5 };
    match cfg.method {
        QuantMethod::Nf4Blockwise => {
            let q = BlockwiseQuant::quantize(w, cfg.block, &cb);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "NF4" }
        }
        QuantMethod::Int4Blockwise => {
            let int4 = Codebook::int(4);
            let q = BlockwiseQuant::quantize(w, cfg.block, &int4);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "INT4" }
        }
        QuantMethod::Gptq => {
            let x = x_cal.expect("GPTQ needs calibration");
            let q = GptqQuant::quantize(w, x, cfg.block, &cb, 0.01);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "GPTQ" }
        }
        QuantMethod::Awq => {
            let x = x_cal.expect("AWQ needs calibration");
            let q = AwqQuant::quantize(w, x, cfg.block, &cb);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "AWQ" }
        }
        QuantMethod::LoftQ => {
            let q = loftq_quantize(w, cfg.block, cfg.adapter_rank, 5, &cb);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "LoftQ" }
        }
        QuantMethod::QPissa => {
            let q = qpissa_quantize(w, cfg.block, cfg.adapter_rank, 5, &cb);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "QPiSSA" }
        }
        QuantMethod::QLora => {
            let mut rng = Rng::new(seed);
            let q = QloraLinear::new(w, cfg.block, cfg.adapter_rank, &cb, &mut rng);
            MethodResult { w_hat: q.dequantize(), float_params: q.float_params(), method: "QLoRA" }
        }
        QuantMethod::Lords => {
            let (q, _) = if cfg.parity_with_adapter {
                let r = parity_rank_with_adapter(w.rows, w.cols, cfg.block, cfg.adapter_rank);
                LordsQuant::quantize_with_rank(w, cfg.block, r, &cb, refine)
            } else {
                LordsQuant::quantize(w, cfg.block, &cb, refine)
            };
            MethodResult {
                w_hat: q.dequantize(),
                float_params: q.float_params(),
                method: if cfg.parity_with_adapter { "LoRDS†" } else { "LoRDS" },
            }
        }
    }
}

/// Quantize every block linear of a model with `cfg.method`, producing the
/// model Tables 1/3 evaluate. Calibration activations for GPTQ/AWQ are
/// layer-agnostic here (same calib batch reused per linear input dim).
pub fn quantize_model(model: &mut Model, cfg: &QuantCfg, calib: Option<&CalibSet>, seed: u64) {
    let cb = Codebook::by_name(&cfg.codebook).expect("codebook");
    let refine = RefineCfg { steps: cfg.refine_steps, lr: cfg.refine_lr, requant_every: 5 };
    match cfg.method {
        QuantMethod::Nf4Blockwise => model.quantize_blockwise(cfg.block, &cb),
        QuantMethod::Int4Blockwise => model.quantize_blockwise(cfg.block, &Codebook::int(4)),
        QuantMethod::Lords => model.quantize_lords(cfg.block, &cb, refine, false),
        QuantMethod::QLora => model.quantize_qlora(cfg.block, cfg.adapter_rank, &cb, seed),
        QuantMethod::LoftQ => {
            model.map_linears(|w| {
                LinearWeight::Qlora(adapter_to_qlora(loftq_quantize(
                    w,
                    cfg.block,
                    cfg.adapter_rank,
                    5,
                    &cb,
                )))
            });
        }
        QuantMethod::QPissa => {
            model.map_linears(|w| {
                LinearWeight::Qlora(adapter_to_qlora(qpissa_quantize(
                    w,
                    cfg.block,
                    cfg.adapter_rank,
                    5,
                    &cb,
                )))
            });
        }
        QuantMethod::Gptq => {
            let calib = calib.expect("GPTQ needs calibration");
            model.map_linears(|w| {
                let x = calib.for_dim(w.cols);
                LinearWeight::Blockwise(as_blockwise(GptqQuant::quantize(w, &x, cfg.block, &cb, 0.01)))
            });
        }
        QuantMethod::Awq => {
            let calib = calib.expect("AWQ needs calibration");
            model.map_linears(|w| {
                let x = calib.for_dim(w.cols);
                let q = AwqQuant::quantize(w, &x, cfg.block, &cb);
                // fold to a dense effective weight wrapped as Dense? Keep as
                // blockwise-equivalent dequant for serving: use a Blockwise of
                // the folded reconstruction (scales refit post-fold).
                LinearWeight::Dense(q.dequantize())
            });
        }
    }
}

/// GPTQ/AWQ calibration activations by input dimension.
pub struct CalibSet {
    pub by_dim: std::collections::HashMap<usize, Matrix>,
}

impl CalibSet {
    /// Synthetic correlated calibration activations for each distinct input
    /// width in the model (hidden-state statistics with hot channels).
    pub fn synthetic(dims: &[usize], samples: usize, seed: u64) -> CalibSet {
        let mut rng = Rng::new(seed ^ 0xCA11);
        let mut by_dim = std::collections::HashMap::new();
        for &d in dims {
            by_dim.entry(d).or_insert_with(|| {
                let mut x = Matrix::randn(samples, d, 1.0, &mut rng);
                for &c in rng.choose(d, (d / 24).max(1)).iter() {
                    for i in 0..samples {
                        *x.at_mut(i, c) *= 6.0;
                    }
                }
                x
            });
        }
        CalibSet { by_dim }
    }

    pub fn for_dim(&self, d: usize) -> Matrix {
        self.by_dim.get(&d).cloned().unwrap_or_else(|| {
            // fall back to white noise at the right width
            let mut rng = Rng::new(d as u64);
            Matrix::randn(64, d, 1.0, &mut rng)
        })
    }
}

fn adapter_to_qlora(a: crate::quant::baselines::AdapterQuant) -> QloraLinear {
    QloraLinear { base: a.base, lora_a: a.lora_a, lora_b: a.lora_b, scaling: 1.0 }
}

fn as_blockwise(g: GptqQuant) -> BlockwiseQuant {
    // GPTQ keeps flat u8 codes during its channel-sequential sweep; pack
    // them into the serving layout on hand-off.
    BlockwiseQuant::from_parts(&g.codes, g.rows, g.cols, g.block, g.scales, &g.codebook)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::quant_error_frob;

    fn w_and_calib() -> (Matrix, Matrix) {
        let mut rng = Rng::new(0);
        let w = crate::report::testbed::llm_like_weight(
            crate::report::testbed::ModuleShape { name: "Q", n: 64, m: 128 },
            &mut rng,
        );
        let x = Matrix::randn(128, 128, 1.0, &mut rng);
        (w, x)
    }

    #[test]
    fn all_methods_run_and_reconstruct() {
        let (w, x) = w_and_calib();
        let base_cfg = QuantCfg { block: 32, refine_steps: 20, ..Default::default() };
        for method in [
            QuantMethod::Nf4Blockwise,
            QuantMethod::Int4Blockwise,
            QuantMethod::Gptq,
            QuantMethod::Awq,
            QuantMethod::LoftQ,
            QuantMethod::QPissa,
            QuantMethod::QLora,
            QuantMethod::Lords,
        ] {
            let cfg = QuantCfg { method, ..base_cfg.clone() };
            let r = apply_method(&w, &cfg, Some(&x), 0);
            let rel = quant_error_frob(&w, &r.w_hat) / w.frob_norm();
            assert!(rel < 0.5, "{}: rel err {rel}", r.method);
            assert!(r.float_params > 0);
        }
    }

    #[test]
    fn lords_dagger_uses_bigger_rank() {
        let (w, _) = w_and_calib();
        let cfg = QuantCfg { block: 32, refine_steps: 0, ..Default::default() };
        let plain = apply_method(&w, &cfg, None, 0);
        let dag = apply_method(
            &w,
            &QuantCfg { parity_with_adapter: true, ..cfg },
            None,
            0,
        );
        assert!(dag.float_params > plain.float_params);
        assert_eq!(dag.method, "LoRDS†");
    }

    #[test]
    fn quantize_model_all_methods() {
        use crate::config::ModelCfg;
        let mcfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let calib = CalibSet::synthetic(&[16, 32], 32, 0);
        for method in [
            QuantMethod::Nf4Blockwise,
            QuantMethod::Gptq,
            QuantMethod::Awq,
            QuantMethod::LoftQ,
            QuantMethod::Lords,
        ] {
            let mut model = Model::init(&mcfg, 0);
            let qcfg = QuantCfg { method, block: 8, refine_steps: 3, adapter_rank: 2, ..Default::default() };
            quantize_model(&mut model, &qcfg, Some(&calib), 0);
            let logits = model.forward(&[1, 2, 3, 4], 1, 4);
            assert!(logits.all_finite(), "{method:?}");
        }
    }
}
