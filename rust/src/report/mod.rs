//! Shared experiment plumbing for the paper-table benches: the cached
//! pre-trained testbed models, per-module weight suites with the paper's
//! real aspect ratios, and the method-application helpers every table
//! reuses.

pub mod methods;
pub mod testbed;

pub use methods::{apply_method, MethodResult};
pub use testbed::{module_suite, ModuleShape, Testbed};
