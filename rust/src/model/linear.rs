//! Linear layers: dense, LoRDS-quantized, block-wise NF4, and QLoRA, with
//! forward + backward. This is where the paper's three fine-tuning regimes
//! meet the transformer:
//!
//! * **Dense**  — full-precision W; grads to W (pre-training).
//! * **Lords**  — frozen codes Q, trainable (B, A): Ŵ = lut[Q] ⊙ (BA);
//!   PEFT grads via dŴ ⊙ Q chained through the rank-r factors (exact —
//!   no STE needed because Ŵ is linear in S). QAT mode additionally
//!   carries a dense shadow W and uses the STE rules (eqs. 4–5).
//! * **Blockwise** — frozen NF4 weight, no trainable params (serving
//!   baseline).
//! * **Qlora** — frozen NF4 base + trainable additive adapter (the
//!   unmergeable two-GEMM path).

use crate::quant::baselines::QloraLinear;
use crate::quant::ste;
use crate::quant::QuantizedLinear;
use crate::quant::{BlockwiseQuant, Codebook, LordsQuant};
use crate::tensor::{matmul, matmul_at_b, matmul_transb, matmul_transb_into, Matrix};

/// Weight representation of one linear layer (y = x·Wᵀ).
#[derive(Clone, Debug)]
pub enum LinearWeight {
    Dense(Matrix),
    /// LoRDS quantized; `shadow_w` present ⇒ QAT mode (STE grads to W too).
    Lords { q: LordsQuant, shadow_w: Option<Matrix> },
    Blockwise(BlockwiseQuant),
    Qlora(QloraLinear),
}

/// Gradients produced by a linear backward pass.
#[derive(Clone, Debug, Default)]
pub struct LinearGrads {
    pub d_w: Option<Matrix>,
    pub d_b: Option<Matrix>,
    pub d_a: Option<Matrix>,
    pub d_lora_b: Option<Matrix>,
    pub d_lora_a: Option<Matrix>,
}

/// Cached state from forward needed by backward.
///
/// Note what is *not* here: the dense effective weight. Frozen-code
/// representations run backward's `dx = g·Ŵ` through the fused packed
/// kernels (`kernels::fused`), and the QAT path reads `Ŵ` straight out of
/// the STE byproducts — so no representation pays an n×m copy per step.
pub struct LinearCache {
    /// Input x (t×m) — borrowed by value for simplicity.
    pub x: Matrix,
    /// STE fake-quant byproducts (QAT mode only); `fq.w_hat` doubles as the
    /// effective weight for backward.
    pub fq: Option<ste::FakeQuant>,
}

impl LinearWeight {
    pub fn out_features(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.rows,
            LinearWeight::Lords { q, .. } => q.rows,
            LinearWeight::Blockwise(q) => q.rows,
            LinearWeight::Qlora(q) => q.base.rows,
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.cols,
            LinearWeight::Lords { q, .. } => q.cols,
            LinearWeight::Blockwise(q) => q.cols,
            LinearWeight::Qlora(q) => q.base.cols,
        }
    }

    /// The effective full-precision weight this layer currently represents.
    pub fn effective(&self) -> Matrix {
        match self {
            LinearWeight::Dense(w) => w.clone(),
            LinearWeight::Lords { q, shadow_w } => match shadow_w {
                // QAT: fake-quantize the shadow weight through current (B, A)
                Some(w) => ste::fake_quant(w, &q.b, &q.a, &q.codebook).w_hat,
                None => q.dequantize(),
            },
            LinearWeight::Blockwise(q) => {
                use crate::quant::QuantizedLinear;
                q.dequantize()
            }
            LinearWeight::Qlora(q) => {
                use crate::quant::QuantizedLinear;
                q.dequantize()
            }
        }
    }

    /// Inference-only forward (no cache) using the fused kernels where the
    /// representation has one.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        match self {
            LinearWeight::Dense(w) => matmul_transb(x, w),
            LinearWeight::Lords { q, shadow_w: None } => q.matmul_transb(x),
            LinearWeight::Lords { q, shadow_w: Some(w) } => {
                let fq = ste::fake_quant(w, &q.b, &q.a, &q.codebook);
                matmul_transb(x, &fq.w_hat)
            }
            LinearWeight::Blockwise(q) => q.matmul_transb(x),
            LinearWeight::Qlora(q) => q.forward(x),
        }
    }

    /// Inference forward writing into a caller-owned t×n buffer (fully
    /// overwritten) — the batched decode tick's allocation-free path,
    /// numerically identical to [`Self::forward`] (both run the same
    /// kernels). QAT mode still materializes Ŵ (the STE fake-quant needs
    /// it); only its output write is allocation-free.
    pub fn forward_into(&self, x: &Matrix, out: &mut Matrix) {
        match self {
            LinearWeight::Dense(w) => matmul_transb_into(x, w, out),
            LinearWeight::Lords { q, shadow_w: None } => q.matmul_transb_opt_into(x, None, out),
            LinearWeight::Lords { q, shadow_w: Some(w) } => {
                let fq = ste::fake_quant(w, &q.b, &q.a, &q.codebook);
                matmul_transb_into(x, &fq.w_hat, out);
            }
            LinearWeight::Blockwise(q) => q.matmul_transb_into(x, out),
            LinearWeight::Qlora(q) => q.forward_into(x, out),
        }
    }

    /// Multi-tenant inference forward: dequantize the shared packed codes
    /// through a tenant adapter's (B′, A′) instead of the baked-in factors.
    /// Only meaningful for frozen-code LoRDS linears — the only
    /// representation whose adaptation is a pure scale swap.
    pub fn forward_adapted(&self, x: &Matrix, pair: &crate::adapters::BaPair) -> Matrix {
        match self {
            LinearWeight::Lords { q, shadow_w: None } => {
                q.matmul_transb_with(x, &pair.b, &pair.a)
            }
            other => panic!(
                "adapter override requires a frozen-code LoRDS linear, got {other:?}"
            ),
        }
    }

    /// [`Self::forward_adapted`] writing into a caller-owned t×n buffer
    /// (see [`Self::forward_into`]).
    pub fn forward_adapted_into(
        &self,
        x: &Matrix,
        pair: &crate::adapters::BaPair,
        out: &mut Matrix,
    ) {
        match self {
            LinearWeight::Lords { q, shadow_w: None } => {
                q.matmul_transb_opt_into(x, Some((&pair.b, &pair.a)), out)
            }
            other => panic!(
                "adapter override requires a frozen-code LoRDS linear, got {other:?}"
            ),
        }
    }

    /// Training forward: returns output + cache for backward. Frozen-code
    /// representations take the same fused packed path as [`Self::forward`];
    /// only QAT materializes Ŵ (the STE fake-quant needs it anyway, and the
    /// cache takes ownership of it — no extra n×m copy).
    pub fn forward_cached(&self, x: &Matrix) -> (Matrix, LinearCache) {
        match self {
            LinearWeight::Lords { q, shadow_w: Some(w) } => {
                let fq = ste::fake_quant(w, &q.b, &q.a, &q.codebook);
                let y = matmul_transb(x, &fq.w_hat);
                // fq (and with it w_hat) is MOVED into the cache
                (y, LinearCache { x: x.clone(), fq: Some(fq) })
            }
            _ => (self.forward(x), LinearCache { x: x.clone(), fq: None }),
        }
    }

    /// Backward: upstream g = ∂L/∂y (t×n) → (∂L/∂x, parameter grads).
    /// dx = g·Ŵ runs fused over the packed codes for frozen-code layers.
    pub fn backward(&self, cache: &LinearCache, g: &Matrix) -> (Matrix, LinearGrads) {
        let mut grads = LinearGrads::default();
        let dx = match self {
            LinearWeight::Dense(w) => {
                grads.d_w = Some(matmul_at_b(g, &cache.x));
                matmul(g, w)
            }
            LinearWeight::Lords { q, shadow_w } => {
                let d_w_hat = matmul_at_b(g, &cache.x); // n×m
                match shadow_w {
                    None => {
                        // PEFT: Ŵ = Q ⊙ (BA) is linear in (B, A):
                        // dS = dŴ ⊙ Q; dB = dS Aᵀ; dA = Bᵀ dS (exact)
                        let ds = d_w_hat.hadamard(&q.q_values());
                        grads.d_b = Some(matmul_transb(&ds, &q.a));
                        grads.d_a = Some(matmul_at_b(&q.b, &ds));
                        q.matmul(g)
                    }
                    Some(w) => {
                        // QAT: STE rules (eqs. 4–5); Ŵ lives in the cache
                        let fq = cache.fq.as_ref().expect("QAT cache");
                        let (dw, db, da) = ste::ste_grads(fq, w, &q.b, &q.a, &d_w_hat);
                        grads.d_w = Some(dw);
                        grads.d_b = Some(db);
                        grads.d_a = Some(da);
                        matmul(g, &fq.w_hat)
                    }
                }
            }
            LinearWeight::Blockwise(q) => q.matmul(g),
            LinearWeight::Qlora(q) => {
                let (d_lb, d_la) = q.adapter_grads(&cache.x, g);
                grads.d_lora_b = Some(d_lb);
                grads.d_lora_a = Some(d_la);
                // dx = g·Ŵ_base (fused) + s·(g·L_b)·L_a (adapter chain)
                let mut dx = q.base.matmul(g);
                let gt = matmul(g, &q.lora_b); // t×r
                dx.axpy(q.scaling, &matmul(&gt, &q.lora_a));
                dx
            }
        };
        (dx, grads)
    }

    /// Apply an update produced by an optimizer (same field layout as grads).
    pub fn trainable_mut(&mut self) -> Vec<(&'static str, &mut [f32])> {
        match self {
            LinearWeight::Dense(w) => vec![("w", &mut w.data)],
            LinearWeight::Lords { q, shadow_w } => {
                let mut v: Vec<(&'static str, &mut [f32])> =
                    vec![("b", &mut q.b.data), ("a", &mut q.a.data)];
                if let Some(w) = shadow_w {
                    v.push(("w", &mut w.data));
                }
                v
            }
            LinearWeight::Blockwise(_) => vec![],
            LinearWeight::Qlora(q) => vec![
                ("lora_b", &mut q.lora_b.data),
                ("lora_a", &mut q.lora_a.data),
            ],
        }
    }

    /// After a QAT run, bake the shadow weight into final codes.
    pub fn finalize_qat(&mut self) {
        if let LinearWeight::Lords { q, shadow_w } = self {
            if let Some(w) = shadow_w.take() {
                q.requantize(&w);
            }
        }
    }

    pub fn float_params(&self) -> usize {
        use crate::quant::QuantizedLinear;
        match self {
            LinearWeight::Dense(w) => w.len(),
            LinearWeight::Lords { q, .. } => q.float_params(),
            LinearWeight::Blockwise(q) => q.float_params(),
            LinearWeight::Qlora(q) => q.float_params(),
        }
    }

    /// Serving-side weight footprint in bytes: packed codes + fp32
    /// side-cars (dense = 4·n·m). QAT shadow weights are training state
    /// and excluded.
    pub fn weight_bytes(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => 4 * w.len(),
            LinearWeight::Lords { q, .. } => q.weight_bytes(),
            LinearWeight::Blockwise(q) => q.weight_bytes(),
            LinearWeight::Qlora(q) => q.weight_bytes(),
        }
    }

    /// Trainable parameter count (the #Train column of Table 5).
    pub fn train_params(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.len(),
            LinearWeight::Lords { q, shadow_w } => {
                q.b.len() + q.a.len() + shadow_w.as_ref().map(|w| w.len()).unwrap_or(0)
            }
            LinearWeight::Blockwise(_) => 0,
            LinearWeight::Qlora(q) => q.lora_a.len() + q.lora_b.len(),
        }
    }
}

/// Helpers to build quantized layers from a dense weight.
pub fn quantize_lords(
    w: &Matrix,
    block: usize,
    cb: &Codebook,
    refine: crate::quant::lords::RefineCfg,
) -> LinearWeight {
    let (q, _) = LordsQuant::quantize(w, block, cb, refine);
    LinearWeight::Lords { q, shadow_w: None }
}

pub fn quantize_lords_qat(
    w: &Matrix,
    block: usize,
    cb: &Codebook,
    refine: crate::quant::lords::RefineCfg,
) -> LinearWeight {
    let (q, _) = LordsQuant::quantize(w, block, cb, refine);
    LinearWeight::Lords { q, shadow_w: Some(w.clone()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lords::RefineCfg;
    use crate::util::Rng;

    fn fd_grad(loss: impl Fn(&LinearWeight) -> f32, lw: &LinearWeight, tweak: impl Fn(&mut LinearWeight, f32)) -> f32 {
        let eps = 1e-3;
        let mut p = lw.clone();
        tweak(&mut p, eps);
        let mut m = lw.clone();
        tweak(&mut m, -eps);
        (loss(&p) - loss(&m)) / (2.0 * eps)
    }

    #[test]
    fn dense_grads_match_fd() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(6, 10, 0.2, &mut rng);
        let lw = LinearWeight::Dense(w);
        let x = Matrix::randn(4, 10, 1.0, &mut rng);
        let (y, cache) = lw.forward_cached(&x);
        let g = Matrix::ones(4, 6);
        let (dx, grads) = lw.backward(&cache, &g);
        assert_eq!(y.shape(), (4, 6));
        let dw = grads.d_w.unwrap();
        let loss = |l: &LinearWeight| l.forward(&x).data.iter().sum::<f32>();
        let fd = fd_grad(loss, &lw, |l, e| {
            if let LinearWeight::Dense(w) = l {
                *w.at_mut(2, 3) += e;
            }
        });
        assert!((fd - dw.at(2, 3)).abs() < 1e-2 * fd.abs().max(1.0), "{fd} vs {}", dw.at(2, 3));
        // dx check
        let fd_x = {
            let eps = 1e-3;
            let mut xp = x.clone();
            let mut xm = x.clone();
            *xp.at_mut(1, 5) += eps;
            *xm.at_mut(1, 5) -= eps;
            (lw.forward(&xp).data.iter().sum::<f32>() - lw.forward(&xm).data.iter().sum::<f32>())
                / (2.0 * eps)
        };
        assert!((fd_x - dx.at(1, 5)).abs() < 1e-2 * fd_x.abs().max(1.0));
    }

    #[test]
    fn lords_peft_grads_match_fd() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let lw = quantize_lords(&w, 8, &cb, RefineCfg { steps: 5, ..Default::default() });
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let (_, cache) = lw.forward_cached(&x);
        let g = Matrix::ones(3, 8);
        let (_, grads) = lw.backward(&cache, &g);
        let db = grads.d_b.unwrap();
        let da = grads.d_a.unwrap();
        // PEFT forward is exactly linear in (B, A) — FD matches tightly
        let loss = |l: &LinearWeight| l.forward(&x).data.iter().sum::<f32>();
        let fd_b = fd_grad(loss, &lw, |l, e| {
            if let LinearWeight::Lords { q, .. } = l {
                *q.b.at_mut(3, 0) += e;
            }
        });
        assert!((fd_b - db.at(3, 0)).abs() < 2e-2 * fd_b.abs().max(1.0), "{fd_b} vs {}", db.at(3, 0));
        let fd_a = fd_grad(loss, &lw, |l, e| {
            if let LinearWeight::Lords { q, .. } = l {
                *q.a.at_mut(0, 7) += e;
            }
        });
        assert!((fd_a - da.at(0, 7)).abs() < 2e-2 * fd_a.abs().max(1.0), "{fd_a} vs {}", da.at(0, 7));
    }

    #[test]
    fn qat_mode_produces_w_grads() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let lw = quantize_lords_qat(&w, 8, &cb, RefineCfg { steps: 2, ..Default::default() });
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let (_, cache) = lw.forward_cached(&x);
        let g = Matrix::ones(3, 8);
        let (_, grads) = lw.backward(&cache, &g);
        // STE: dW = dŴ = gᵀx
        let want = matmul_at_b(&g, &x);
        let dw = grads.d_w.unwrap();
        crate::util::prop::assert_allclose(&dw.data, &want.data, 1e-5, 1e-5, "STE dW");
        assert!(grads.d_b.is_some() && grads.d_a.is_some());
    }

    #[test]
    fn qlora_only_trains_adapters() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let lw = LinearWeight::Qlora(QloraLinear::new(&w, 8, 4, &cb, &mut rng));
        assert_eq!(lw.train_params(), 4 * (8 + 16));
        let x = Matrix::randn(2, 16, 1.0, &mut rng);
        let (_, cache) = lw.forward_cached(&x);
        let (_, grads) = lw.backward(&cache, &Matrix::ones(2, 8));
        assert!(grads.d_w.is_none());
        assert!(grads.d_lora_a.is_some() && grads.d_lora_b.is_some());
    }

    #[test]
    fn backward_dx_matches_dense_reference_for_all_reprs() {
        // dx = g·Ŵ now runs through the fused packed kernels for
        // frozen-code layers — must agree with g·effective().
        let mut rng = Rng::new(5);
        let w = Matrix::randn(10, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let g = Matrix::randn(3, 10, 1.0, &mut rng);
        let reprs: Vec<LinearWeight> = vec![
            LinearWeight::Dense(w.clone()),
            quantize_lords(&w, 8, &cb, RefineCfg { steps: 3, ..Default::default() }),
            LinearWeight::Blockwise(BlockwiseQuant::quantize(&w, 8, &cb)),
            {
                let mut q = QloraLinear::new(&w, 8, 4, &cb, &mut rng);
                rng.fill_normal(&mut q.lora_b.data, 0.0, 0.05);
                LinearWeight::Qlora(q)
            },
        ];
        for lw in &reprs {
            let (_, cache) = lw.forward_cached(&x);
            let (dx, _) = lw.backward(&cache, &g);
            let dense = matmul(&g, &lw.effective());
            crate::util::prop::assert_allclose(&dx.data, &dense.data, 1e-4, 1e-4, "dx vs g·Ŵ");
        }
    }

    #[test]
    fn finalize_qat_absorbs_shadow() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let mut lw = quantize_lords_qat(&w, 8, &cb, RefineCfg { steps: 2, ..Default::default() });
        // nudge the shadow weight, finalize, and check codes moved
        if let LinearWeight::Lords { shadow_w: Some(sw), .. } = &mut lw {
            for v in sw.data.iter_mut() {
                *v += 0.03;
            }
        }
        let before = if let LinearWeight::Lords { q, .. } = &lw { q.codes.clone() } else { unreachable!() };
        lw.finalize_qat();
        if let LinearWeight::Lords { q, shadow_w } = &lw {
            assert!(shadow_w.is_none());
            assert_ne!(&before, &q.codes, "codes should change after absorbing shadow");
        }
    }
}
