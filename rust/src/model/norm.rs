//! RMSNorm forward + backward.
//!
//! y_i = x_i · γ_i / rms(x),  rms(x) = sqrt(mean(x²) + ε)
//!
//! Backward (per row, d = dim):
//!   dγ_i = Σ_rows g_i · x_i / rms
//!   dx_i = (g_i γ_i) / rms − x_i · Σ_j (g_j γ_j x_j) / (d · rms³)

use crate::tensor::Matrix;

pub const EPS: f32 = 1e-5;

pub struct NormCache {
    /// 1 / rms per row.
    pub inv_rms: Vec<f32>,
}

/// Forward: x (t×d), gamma (d) → (y, cache).
pub fn rmsnorm_fwd(x: &Matrix, gamma: &[f32]) -> (Matrix, NormCache) {
    assert_eq!(x.cols, gamma.len());
    let d = x.cols;
    let mut y = Matrix::zeros(x.rows, d);
    let mut inv_rms = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        inv_rms[i] = inv;
        let out = y.row_mut(i);
        for j in 0..d {
            out[j] = row[j] * inv * gamma[j];
        }
    }
    (y, NormCache { inv_rms })
}

/// Cache-free inference forward writing into a caller-owned t×d buffer
/// (fully overwritten) — per-row math identical to [`rmsnorm_fwd`], used
/// by the batched decode tick to reuse one norm buffer across layers and
/// tokens.
pub fn rmsnorm_fwd_into(x: &Matrix, gamma: &[f32], y: &mut Matrix) {
    assert_eq!(x.cols, gamma.len());
    assert_eq!(y.shape(), x.shape(), "out shape {:?} vs {:?}", y.shape(), x.shape());
    let d = x.cols;
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let out = y.row_mut(i);
        for j in 0..d {
            out[j] = row[j] * inv * gamma[j];
        }
    }
}

/// In-place variant of [`rmsnorm_fwd_into`]: each row's RMS is computed
/// before the row is overwritten, so the per-row math is identical.
pub fn rmsnorm_fwd_inplace(x: &mut Matrix, gamma: &[f32]) {
    assert_eq!(x.cols, gamma.len());
    let d = x.cols;
    for i in 0..x.rows {
        let row = x.row_mut(i);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for j in 0..d {
            // same association as rmsnorm_fwd: (x · inv) · γ, bit-for-bit
            row[j] = row[j] * inv * gamma[j];
        }
    }
}

/// Backward: returns (dx, dgamma).
pub fn rmsnorm_bwd(
    x: &Matrix,
    gamma: &[f32],
    cache: &NormCache,
    g: &Matrix,
) -> (Matrix, Vec<f32>) {
    let d = x.cols;
    let mut dx = Matrix::zeros(x.rows, d);
    let mut dgamma = vec![0.0f32; d];
    for i in 0..x.rows {
        let xr = x.row(i);
        let gr = g.row(i);
        let inv = cache.inv_rms[i];
        // dot = Σ_j g_j γ_j x_j
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += gr[j] * gamma[j] * xr[j];
            dgamma[j] += gr[j] * xr[j] * inv;
        }
        let coef = dot * inv * inv * inv / d as f32;
        let out = dx.row_mut(i);
        for j in 0..d {
            out[j] = gr[j] * gamma[j] * inv - xr[j] * coef;
        }
    }
    (dx, dgamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn fd_check(rows: usize, d: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(rows, d, 1.0, &mut rng);
        let gamma: Vec<f32> = (0..d).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        let upstream = Matrix::randn(rows, d, 1.0, &mut rng);

        let loss = |x: &Matrix, gamma: &[f32]| -> f32 {
            let (y, _) = rmsnorm_fwd(x, gamma);
            y.data.iter().zip(&upstream.data).map(|(a, b)| a * b).sum()
        };

        let (_, cache) = rmsnorm_fwd(&x, &gamma);
        let (dx, dgamma) = rmsnorm_bwd(&x, &gamma, &cache, &upstream);

        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (rows - 1, d - 1), (0, d / 2)] {
            let mut xp = x.clone();
            let mut xm = x.clone();
            *xp.at_mut(i, j) += eps;
            *xm.at_mut(i, j) -= eps;
            let fd = (loss(&xp, &gamma) - loss(&xm, &gamma)) / (2.0 * eps);
            assert!(
                (fd - dx.at(i, j)).abs() < 2e-2 * fd.abs().max(1.0),
                "dx[{i},{j}]: fd {fd} vs {}",
                dx.at(i, j)
            );
        }
        for j in [0, d - 1] {
            let mut gp = gamma.clone();
            let mut gm = gamma.clone();
            gp[j] += eps;
            gm[j] -= eps;
            let fd = (loss(&x, &gp) - loss(&x, &gm)) / (2.0 * eps);
            assert!(
                (fd - dgamma[j]).abs() < 2e-2 * fd.abs().max(1.0),
                "dγ[{j}]: fd {fd} vs {}",
                dgamma[j]
            );
        }
    }

    #[test]
    fn unit_gamma_normalizes() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(4, 32, 3.0, &mut rng);
        let gamma = vec![1.0f32; 32];
        let (y, _) = rmsnorm_fwd(&x, &gamma);
        for i in 0..4 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 32.0;
            assert!((ms - 1.0).abs() < 0.01, "row {i} ms {ms}");
        }
    }

    #[test]
    fn gradients_match_finite_difference() {
        fd_check(3, 16, 1);
        fd_check(1, 8, 2);
    }
}
