//! The transformer assembly: embedding → N blocks (RMSNorm, RoPE, causal
//! MHA, SwiGLU MLP, residuals) → final norm → LM head, with full manual
//! backprop and a KV-cache inference path.
//!
//! Parameter names/shapes mirror `python/compile/model.py` one-to-one.

use super::attention::{
    attention_bwd, attention_decode, attention_fwd, rope_bwd, rope_fwd, rope_row, AttnCache,
};
use super::linear::{LinearCache, LinearGrads, LinearWeight};
use crate::adapters::{AdapterFactors, BaPair};
use crate::kvquant::KvPool;
use super::loss::{cross_entropy_bwd, cross_entropy_fwd};
use super::norm::{rmsnorm_bwd, rmsnorm_fwd, rmsnorm_fwd_inplace, rmsnorm_fwd_into, NormCache};
use crate::config::ModelCfg;
use crate::quant::lords::RefineCfg;
use crate::quant::{BlockwiseQuant, Codebook};
use crate::tensor::{matmul, matmul_at_b, Matrix};
use crate::util::Rng;

/// One transformer block's weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: LinearWeight,
    pub wk: LinearWeight,
    pub wv: LinearWeight,
    pub wo: LinearWeight,
    pub mlp_norm: Vec<f32>,
    pub w_gate: LinearWeight,
    pub w_up: LinearWeight,
    pub w_down: LinearWeight,
}

impl LayerWeights {
    pub fn linears(&self) -> [(&'static str, &LinearWeight); 7] {
        [
            ("wq", &self.wq),
            ("wk", &self.wk),
            ("wv", &self.wv),
            ("wo", &self.wo),
            ("w_gate", &self.w_gate),
            ("w_up", &self.w_up),
            ("w_down", &self.w_down),
        ]
    }

    pub fn linears_mut(&mut self) -> [(&'static str, &mut LinearWeight); 7] {
        [
            ("wq", &mut self.wq),
            ("wk", &mut self.wk),
            ("wv", &mut self.wv),
            ("wo", &mut self.wo),
            ("w_gate", &mut self.w_gate),
            ("w_up", &mut self.w_up),
            ("w_down", &mut self.w_down),
        ]
    }
}

/// Gradients for one block.
#[derive(Clone, Debug, Default)]
pub struct LayerGrads {
    pub attn_norm: Vec<f32>,
    pub wq: LinearGrads,
    pub wk: LinearGrads,
    pub wv: LinearGrads,
    pub wo: LinearGrads,
    pub mlp_norm: Vec<f32>,
    pub w_gate: LinearGrads,
    pub w_up: LinearGrads,
    pub w_down: LinearGrads,
}

/// Full-model gradients.
#[derive(Clone, Debug, Default)]
pub struct ModelGrads {
    pub tok_emb: Option<Matrix>,
    pub layers: Vec<LayerGrads>,
    pub final_norm: Vec<f32>,
    pub lm_head: Option<Matrix>,
}

/// The model.
#[derive(Clone, Debug)]
pub struct Model {
    pub cfg: ModelCfg,
    pub tok_emb: Matrix,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Matrix,
}

/// Per-sequence KV cache for incremental decoding.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// per layer: cap×D matrices.
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub len: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelCfg) -> KvCache {
        KvCache {
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
        }
    }
}

struct BlockCache {
    nc1: NormCache,
    h1: Matrix,
    cq: LinearCache,
    ck: LinearCache,
    cv: LinearCache,
    /// post-RoPE q/k and raw v, per batch element
    q: Vec<Matrix>,
    k: Vec<Matrix>,
    v: Vec<Matrix>,
    attn: Vec<AttnCache>,
    co: LinearCache,
    x_mid: Matrix,
    nc2: NormCache,
    h2: Matrix,
    cg: LinearCache,
    cu: LinearCache,
    gate_pre: Matrix,
    up: Matrix,
    cd: LinearCache,
    x_in: Matrix,
}

pub struct ForwardCache {
    blocks: Vec<BlockCache>,
    ncf: NormCache,
    x_pre_final: Matrix,
    x_final: Matrix,
    tokens: Vec<usize>,
}

/// One sequence's slot in a batched decode tick
/// ([`Model::decode_batch_pooled`]).
#[derive(Clone, Copy)]
pub struct DecodeRow<'a> {
    /// KV-pool sequence id.
    pub seq: u64,
    /// The token to decode (sampled from the previous tick's logits).
    pub token: usize,
    /// Resolved tenant factors (`None` = the base tenant). Rows sharing
    /// an adapter should be contiguous: each maximal run forms one
    /// tenant-group, and every packed weight streams once per group.
    pub adapter: Option<&'a AdapterFactors>,
}

/// Reusable activation arena for the batched decode tick: every buffer is
/// reshaped in place (`fit`, capacity kept) instead of freshly allocated
/// per token per layer, so a steady-state serving loop performs no
/// per-tick activation allocations beyond the per-group attention views.
#[derive(Debug)]
pub struct DecodeScratch {
    /// running activation (B×d)
    x: Matrix,
    /// RMSNorm output, shared by the attention and MLP halves (B×d)
    norm: Matrix,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    att: Matrix,
    /// wo / w_down projection output before the residual add (B×d)
    proj: Matrix,
    gate: Matrix,
    up: Matrix,
    /// whole-batch final hidden state: each tenant-group deposits its
    /// rows here so the (adapter-independent) final norm + lm_head run
    /// once per tick, not once per group
    hidden: Matrix,
    logits: Matrix,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        let z = || Matrix::zeros(0, 0);
        DecodeScratch {
            x: z(),
            norm: z(),
            q: z(),
            k: z(),
            v: z(),
            att: z(),
            proj: z(),
            gate: z(),
            up: z(),
            hidden: z(),
            logits: z(),
        }
    }

    /// The last tick's logits: one row per [`DecodeRow`], in call order.
    pub fn logits(&self) -> &Matrix {
        &self.logits
    }
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Reshape a scratch matrix in place, reusing its allocation whenever the
/// size already matches (the steady-state tick: no fill, no realloc).
/// Contents are unspecified afterwards; every consumer fully overwrites.
fn fit(m: &mut Matrix, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    if m.data.len() != rows * cols {
        m.data.clear();
        m.data.resize(rows * cols, 0.0);
    }
}

impl Model {
    /// Init matching `python/compile/model.py::init_params` (independent RNG).
    pub fn init(cfg: &ModelCfg, seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let resid = 0.02 / (2.0 * cfg.n_layers as f32).sqrt();
        let lin = |rng: &mut Rng, n: usize, m: usize, std: f32| {
            LinearWeight::Dense(Matrix::randn(n, m, std, rng))
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; cfg.d_model],
                wq: lin(&mut rng, cfg.d_model, cfg.d_model, 0.02),
                wk: lin(&mut rng, cfg.d_model, cfg.d_model, 0.02),
                wv: lin(&mut rng, cfg.d_model, cfg.d_model, 0.02),
                wo: lin(&mut rng, cfg.d_model, cfg.d_model, resid),
                mlp_norm: vec![1.0; cfg.d_model],
                w_gate: lin(&mut rng, cfg.d_ff, cfg.d_model, 0.02),
                w_up: lin(&mut rng, cfg.d_ff, cfg.d_model, 0.02),
                w_down: lin(&mut rng, cfg.d_model, cfg.d_ff, resid),
            })
            .collect();
        Model {
            cfg: cfg.clone(),
            tok_emb: Matrix::randn(cfg.vocab, cfg.d_model, 0.02, &mut rng),
            layers,
            final_norm: vec![1.0; cfg.d_model],
            lm_head: Matrix::randn(cfg.vocab, cfg.d_model, 0.02, &mut rng),
        }
    }

    /// Replace every block linear via `f(dense_weight) -> LinearWeight`.
    pub fn map_linears(&mut self, mut f: impl FnMut(&Matrix) -> LinearWeight) {
        self.map_linears_by_layer(|_, w| f(w));
    }

    /// Layer-indexed variant (mixed-precision schedules quantize different
    /// layers with different codebooks — §4.1 ultra-low-bit).
    pub fn map_linears_by_layer(&mut self, mut f: impl FnMut(usize, &Matrix) -> LinearWeight) {
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (_, lw) in layer.linears_mut() {
                if let LinearWeight::Dense(w) = lw {
                    *lw = f(li, w);
                } else {
                    let w = lw.effective();
                    *lw = f(li, &w);
                }
            }
        }
    }

    /// Convenience quantizers for the whole model.
    pub fn quantize_lords(&mut self, block: usize, cb: &Codebook, refine: RefineCfg, qat: bool) {
        self.map_linears(|w| {
            if qat {
                super::linear::quantize_lords_qat(w, block, cb, refine)
            } else {
                super::linear::quantize_lords(w, block, cb, refine)
            }
        });
    }

    /// LoRDS with an explicit rank (PEFT at adapter-parity budgets: the
    /// paper's Table 5 gives LoRDS the same #Train as the LoRA baselines).
    pub fn quantize_lords_rank(&mut self, block: usize, rank: usize, cb: &Codebook, refine: RefineCfg) {
        self.map_linears(|w| {
            let (q, _) = crate::quant::LordsQuant::quantize_with_rank(w, block, rank, cb, refine);
            LinearWeight::Lords { q, shadow_w: None }
        });
    }

    pub fn quantize_blockwise(&mut self, block: usize, cb: &Codebook) {
        self.map_linears(|w| LinearWeight::Blockwise(BlockwiseQuant::quantize(w, block, cb)));
    }

    pub fn quantize_qlora(&mut self, block: usize, rank: usize, cb: &Codebook, seed: u64) {
        let mut rng = Rng::new(seed);
        self.map_linears(|w| {
            LinearWeight::Qlora(crate::quant::baselines::QloraLinear::new(
                w, block, rank, cb, &mut rng,
            ))
        });
    }

    /// Total trainable / floating-point parameter counts (Table 5 columns).
    pub fn train_params(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.linears().into_iter().map(|(_, w)| w.train_params()))
            .sum()
    }

    pub fn float_params(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.linears().into_iter().map(|(_, w)| w.float_params()))
            .sum()
    }

    /// Serving weight footprint of all block linears in bytes (packed codes
    /// + fp32 side-cars) — the memory-traffic number behind Table 6.
    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.linears().into_iter().map(|(_, w)| w.weight_bytes()))
            .sum()
    }

    // ---------------------------------------------------------------- fwd

    fn embed(&self, tokens: &[usize]) -> Matrix {
        let mut x = Matrix::zeros(tokens.len(), self.cfg.d_model);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.tok_emb.row(t));
        }
        x
    }

    /// Training forward over a (batch × seq) token grid (row-major flat).
    /// Returns (logits (B·S × V), cache).
    pub fn forward_train(&self, tokens: &[usize], batch: usize, seq: usize) -> (Matrix, ForwardCache) {
        assert_eq!(tokens.len(), batch * seq);
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let mut x = self.embed(tokens);
        let mut blocks = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let x_in = x.clone();
            let (h1, nc1) = rmsnorm_fwd(&x, &layer.attn_norm);
            let (mut q, cq) = layer.wq.forward_cached(&h1);
            let (mut k, ck) = layer.wk.forward_cached(&h1);
            let (v, cv) = layer.wv.forward_cached(&h1);
            // rope + attention per batch element
            let mut att = Matrix::zeros(batch * seq, self.cfg.d_model);
            let mut qs = Vec::with_capacity(batch);
            let mut ks = Vec::with_capacity(batch);
            let mut vs = Vec::with_capacity(batch);
            let mut attns = Vec::with_capacity(batch);
            for b in 0..batch {
                let mut qb = q.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                let mut kb = k.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                let vb = v.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                rope_fwd(&mut qb, h, 0, theta);
                rope_fwd(&mut kb, h, 0, theta);
                let (ob, cache_b) = attention_fwd(&qb, &kb, &vb, h);
                att.paste(b * seq, 0, &ob);
                qs.push(qb);
                ks.push(kb);
                vs.push(vb);
                attns.push(cache_b);
            }
            // release the pre-rope copies (not needed by backward)
            q = Matrix::zeros(0, 0);
            k = Matrix::zeros(0, 0);
            let _ = (&q, &k);
            let (o, co) = layer.wo.forward_cached(&att);
            let mut x_mid = x_in.clone();
            x_mid.add_assign(&o);
            let (h2, nc2) = rmsnorm_fwd(&x_mid, &layer.mlp_norm);
            let (gate_pre, cg) = layer.w_gate.forward_cached(&h2);
            let (up, cu) = layer.w_up.forward_cached(&h2);
            let ff_in = swiglu(&gate_pre, &up);
            let (down, cd) = layer.w_down.forward_cached(&ff_in);
            let mut x_out = x_mid.clone();
            x_out.add_assign(&down);
            blocks.push(BlockCache {
                nc1,
                h1,
                cq,
                ck,
                cv,
                q: qs,
                k: ks,
                v: vs,
                attn: attns,
                co,
                x_mid,
                nc2,
                h2,
                cg,
                cu,
                gate_pre,
                up,
                cd,
                x_in,
            });
            x = x_out;
        }
        let (x_final, ncf) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = crate::tensor::matmul_transb(&x_final, &self.lm_head);
        let cache = ForwardCache {
            blocks,
            ncf,
            x_pre_final: x,
            x_final,
            tokens: tokens.to_vec(),
        };
        (logits, cache)
    }

    /// Loss + gradients for next-token prediction.
    pub fn loss_and_grads(
        &self,
        tokens: &[usize],
        targets: &[usize],
        batch: usize,
        seq: usize,
    ) -> (f32, ModelGrads) {
        let (logits, cache) = self.forward_train(tokens, batch, seq);
        let (loss, probs) = cross_entropy_fwd(&logits, targets);
        let dlogits = cross_entropy_bwd(&probs, targets);
        let grads = self.backward(&cache, &dlogits, batch, seq);
        (loss, grads)
    }

    fn backward(&self, cache: &ForwardCache, dlogits: &Matrix, batch: usize, seq: usize) -> ModelGrads {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let d = self.cfg.d_model;
        let mut grads = ModelGrads {
            layers: (0..self.layers.len()).map(|_| LayerGrads::default()).collect(),
            ..Default::default()
        };

        // head: logits = x_final · lm_headᵀ
        grads.lm_head = Some(matmul_at_b(dlogits, &cache.x_final));
        let dx_final = matmul(dlogits, &self.lm_head);
        let (mut dx, dgf) = rmsnorm_bwd(&cache.x_pre_final, &self.final_norm, &cache.ncf, &dx_final);
        grads.final_norm = dgf;

        for (li, layer) in self.layers.iter().enumerate().rev() {
            let bc = &cache.blocks[li];
            let lg = &mut grads.layers[li];
            // x_out = x_mid + down
            let d_down = dx.clone();
            let (d_ff_in, g_down) = layer.w_down.backward(&bc.cd, &d_down);
            lg.w_down = g_down;
            // swiglu backward
            let (d_gate_pre, d_up) = swiglu_bwd(&bc.gate_pre, &bc.up, &d_ff_in);
            let (dh2_u, g_up) = layer.w_up.backward(&bc.cu, &d_up);
            lg.w_up = g_up;
            let (dh2_g, g_gate) = layer.w_gate.backward(&bc.cg, &d_gate_pre);
            lg.w_gate = g_gate;
            let mut dh2 = dh2_u;
            dh2.add_assign(&dh2_g);
            let (dx_mlp, dg2) = rmsnorm_bwd(&bc.x_mid, &layer.mlp_norm, &bc.nc2, &dh2);
            lg.mlp_norm = dg2;
            // residual: d(x_mid) = dx (skip) + dx_mlp
            let mut dx_mid = dx;
            dx_mid.add_assign(&dx_mlp);

            // x_mid = x_in + o
            let d_o = dx_mid.clone();
            let (d_att, g_o) = layer.wo.backward(&bc.co, &d_o);
            lg.wo = g_o;
            // attention backward per batch element
            let mut dq_all = Matrix::zeros(batch * seq, d);
            let mut dk_all = Matrix::zeros(batch * seq, d);
            let mut dv_all = Matrix::zeros(batch * seq, d);
            for b in 0..batch {
                let gb = d_att.slice(b * seq, (b + 1) * seq, 0, d);
                let (mut dqb, mut dkb, dvb) =
                    attention_bwd(&bc.q[b], &bc.k[b], &bc.v[b], &bc.attn[b], &gb, h);
                rope_bwd(&mut dqb, h, 0, theta);
                rope_bwd(&mut dkb, h, 0, theta);
                dq_all.paste(b * seq, 0, &dqb);
                dk_all.paste(b * seq, 0, &dkb);
                dv_all.paste(b * seq, 0, &dvb);
            }
            let (dh1_q, g_q) = layer.wq.backward(&bc.cq, &dq_all);
            lg.wq = g_q;
            let (dh1_k, g_k) = layer.wk.backward(&bc.ck, &dk_all);
            lg.wk = g_k;
            let (dh1_v, g_v) = layer.wv.backward(&bc.cv, &dv_all);
            lg.wv = g_v;
            let mut dh1 = dh1_q;
            dh1.add_assign(&dh1_k);
            dh1.add_assign(&dh1_v);
            let (dx_attn, dg1) = rmsnorm_bwd(&bc.x_in, &layer.attn_norm, &bc.nc1, &dh1);
            lg.attn_norm = dg1;
            let mut dx_in = dx_mid;
            dx_in.add_assign(&dx_attn);
            dx = dx_in;
        }

        // embedding scatter
        let mut d_emb = Matrix::zeros(self.cfg.vocab, d);
        for (i, &t) in cache.tokens.iter().enumerate() {
            let src = dx.row(i);
            let dst = d_emb.row_mut(t);
            for (a, &b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
        grads.tok_emb = Some(d_emb);
        grads
    }

    // ----------------------------------------------------------- inference

    /// Eval forward (no caches kept): logits for every position.
    pub fn forward(&self, tokens: &[usize], batch: usize, seq: usize) -> Matrix {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let mut x = self.embed(tokens);
        for layer in &self.layers {
            let (h1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let q = layer.wq.forward(&h1);
            let k = layer.wk.forward(&h1);
            let v = layer.wv.forward(&h1);
            let mut att = Matrix::zeros(batch * seq, self.cfg.d_model);
            for b in 0..batch {
                let mut qb = q.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                let mut kb = k.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                let vb = v.slice(b * seq, (b + 1) * seq, 0, self.cfg.d_model);
                rope_fwd(&mut qb, h, 0, theta);
                rope_fwd(&mut kb, h, 0, theta);
                let (ob, _) = attention_fwd(&qb, &kb, &vb, h);
                att.paste(b * seq, 0, &ob);
            }
            let o = layer.wo.forward(&att);
            x.add_assign(&o);
            let (h2, _) = rmsnorm_fwd(&x, &layer.mlp_norm);
            let gate_pre = layer.w_gate.forward(&h2);
            let up = layer.w_up.forward(&h2);
            let down = layer.w_down.forward(&swiglu(&gate_pre, &up));
            x.add_assign(&down);
        }
        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        crate::tensor::matmul_transb(&xf, &self.lm_head)
    }

    /// Prefill one sequence into a KV cache; returns last-position logits.
    pub fn prefill(&self, tokens: &[usize], cache: &mut KvCache) -> Vec<f32> {
        self.prefill_with(tokens, cache, None)
    }

    /// Prefill through an optional tenant adapter: every frozen-code LoRDS
    /// linear dequantizes the shared packed codes through the adapter's
    /// (B′, A′) slot instead of the baked-in factors (multi-tenant serving;
    /// `None` = the base tenant).
    pub fn prefill_with(
        &self,
        tokens: &[usize],
        cache: &mut KvCache,
        adapter: Option<&AdapterFactors>,
    ) -> Vec<f32> {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let s = tokens.len();
        assert!(s <= self.cfg.max_seq);
        let mut x = self.embed(tokens);
        for (li, layer) in self.layers.iter().enumerate() {
            let lf = adapter.map(|f| &f.layers[li]);
            let ov = |slot: usize| lf.and_then(|l| l.linears[slot].as_ref());
            let (h1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let mut q = fwd(&layer.wq, &h1, ov(0));
            let mut k = fwd(&layer.wk, &h1, ov(1));
            let v = fwd(&layer.wv, &h1, ov(2));
            rope_fwd(&mut q, h, 0, theta);
            rope_fwd(&mut k, h, 0, theta);
            cache.k[li].paste(0, 0, &k);
            cache.v[li].paste(0, 0, &v);
            let (att, _) = attention_fwd(&q, &k, &v, h);
            let o = fwd(&layer.wo, &att, ov(3));
            x.add_assign(&o);
            let (h2, _) = rmsnorm_fwd(&x, &layer.mlp_norm);
            let gate_pre = fwd(&layer.w_gate, &h2, ov(4));
            let up = fwd(&layer.w_up, &h2, ov(5));
            let down = fwd(&layer.w_down, &swiglu(&gate_pre, &up), ov(6));
            x.add_assign(&down);
        }
        cache.len = s;
        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = crate::tensor::matmul_transb(&xf, &self.lm_head);
        logits.row(s - 1).to_vec()
    }

    /// One decode step for one sequence.
    pub fn decode(&self, token: usize, cache: &mut KvCache) -> Vec<f32> {
        self.decode_with(token, cache, None)
    }

    /// One decode step through an optional tenant adapter (see
    /// [`Self::prefill_with`]).
    pub fn decode_with(
        &self,
        token: usize,
        cache: &mut KvCache,
        adapter: Option<&AdapterFactors>,
    ) -> Vec<f32> {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let pos = cache.len;
        assert!(pos < self.cfg.max_seq, "KV cache full");
        let mut x = self.embed(&[token]);
        for (li, layer) in self.layers.iter().enumerate() {
            let lf = adapter.map(|f| &f.layers[li]);
            let ov = |slot: usize| lf.and_then(|l| l.linears[slot].as_ref());
            let (h1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let mut q = fwd(&layer.wq, &h1, ov(0));
            let mut k = fwd(&layer.wk, &h1, ov(1));
            let v = fwd(&layer.wv, &h1, ov(2));
            rope_fwd(&mut q, h, pos, theta);
            rope_fwd(&mut k, h, pos, theta);
            cache.k[li].paste(pos, 0, &k);
            cache.v[li].paste(pos, 0, &v);
            let att = attention_decode(&q, &cache.k[li], &cache.v[li], pos + 1, h);
            let o = fwd(&layer.wo, &att, ov(3));
            x.add_assign(&o);
            let (h2, _) = rmsnorm_fwd(&x, &layer.mlp_norm);
            let gate_pre = fwd(&layer.w_gate, &h2, ov(4));
            let up = fwd(&layer.w_up, &h2, ov(5));
            let down = fwd(&layer.w_down, &swiglu(&gate_pre, &up), ov(6));
            x.add_assign(&down);
        }
        cache.len = pos + 1;
        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = crate::tensor::matmul_transb(&xf, &self.lm_head);
        logits.row(0).to_vec()
    }

    // ------------------------------------------------- pooled (paged) KV

    /// Prefill one sequence into the block-pooled (optionally quantized)
    /// KV store; returns last-position logits. The packed-KV counterpart
    /// of [`Self::prefill_with`]: K/V rows stream into the pool (sealed
    /// blocks are quantized at append time) and attention runs fused over
    /// the packed blocks + dense tail. Errors when the pool cannot back
    /// the prompt.
    ///
    /// Implemented as a single whole-prompt chunk of
    /// [`Self::prefill_chunk_pooled`] — the chunked path with `pos0 = 0`
    /// is this path, by construction.
    pub fn prefill_pooled(
        &self,
        tokens: &[usize],
        pool: &mut KvPool,
        seq: u64,
        adapter: Option<&AdapterFactors>,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            pool.seq_len(seq).unwrap_or(0) == 0,
            "prefill into non-empty KV sequence {seq}"
        );
        let logits = self.prefill_chunk_pooled(tokens, 0, tokens.len(), pool, seq, adapter)?;
        Ok(logits.expect("whole-prompt chunk yields last-position logits"))
    }

    /// One chunk of a prefill resumed at absolute position `pos0`:
    /// `chunk[i]` is prompt token `pos0 + i` of a `prompt_len`-token
    /// prompt whose first `pos0` positions are already committed for
    /// `seq` (either by earlier chunks or shared via
    /// [`KvPool::fork_at_block`]). Returns `Some(last-position logits)`
    /// when the chunk completes the prompt, `None` otherwise.
    ///
    /// Non-final chunks must end on a pool block boundary and `pos0` must
    /// sit on one. That alignment is what makes chunked prefill **bitwise
    /// token-identical** to [`Self::prefill_pooled`]: at every chunk's
    /// attention, exactly the full blocks below it are sealed — the same
    /// sealed/dense-tail split the whole-prompt path sees at those rows
    /// (blocks seal the moment they fill in both) — and every per-row op
    /// (RMSNorm, RoPE at the absolute position, the
    /// [`prefill_packed_at`](crate::kvquant::attention::prefill_packed_at)
    /// score/softmax/V sweeps, residuals, SwiGLU, the final-norm +
    /// lm-head row) is independent of which rows share its chunk.
    pub fn prefill_chunk_pooled(
        &self,
        chunk: &[usize],
        pos0: usize,
        prompt_len: usize,
        pool: &mut KvPool,
        seq: u64,
        adapter: Option<&AdapterFactors>,
    ) -> anyhow::Result<Option<Vec<f32>>> {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let n = chunk.len();
        let end = pos0 + n;
        anyhow::ensure!(n > 0, "empty prefill chunk for seq {seq}");
        anyhow::ensure!(
            end <= prompt_len && prompt_len <= self.cfg.max_seq,
            "chunk {pos0}..{end} of prompt {prompt_len} > max_seq {}",
            self.cfg.max_seq
        );
        let bt = pool.block_tokens();
        anyhow::ensure!(
            pos0 % bt == 0,
            "chunked prefill must resume at a block boundary (pos {pos0}, block {bt})"
        );
        anyhow::ensure!(
            end == prompt_len || end % bt == 0,
            "non-final chunk must end at a block boundary (end {end}, block {bt})"
        );
        anyhow::ensure!(
            pool.seq_len(seq).unwrap_or(0) == pos0,
            "chunk resumes at {pos0} but seq {seq} has {} tokens committed",
            pool.seq_len(seq).unwrap_or(0)
        );
        let mut x = self.embed(chunk);
        for (li, layer) in self.layers.iter().enumerate() {
            let lf = adapter.map(|f| &f.layers[li]);
            let ov = |slot: usize| lf.and_then(|l| l.linears[slot].as_ref());
            let (h1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let mut q = fwd(&layer.wq, &h1, ov(0));
            let mut k = fwd(&layer.wk, &h1, ov(1));
            let v = fwd(&layer.wv, &h1, ov(2));
            rope_fwd(&mut q, h, pos0, theta);
            rope_fwd(&mut k, h, pos0, theta);
            pool.append_rows(seq, li, pos0, &k, &v)?;
            let att =
                crate::kvquant::attention::prefill_packed_at(&q, &pool.view(seq, li, end), h, pos0);
            let o = fwd(&layer.wo, &att, ov(3));
            x.add_assign(&o);
            let (h2, _) = rmsnorm_fwd(&x, &layer.mlp_norm);
            let gate_pre = fwd(&layer.w_gate, &h2, ov(4));
            let up = fwd(&layer.w_up, &h2, ov(5));
            let down = fwd(&layer.w_down, &swiglu(&gate_pre, &up), ov(6));
            x.add_assign(&down);
        }
        pool.commit(seq, end);
        if end < prompt_len {
            return Ok(None);
        }
        // final norm + lm head on the last row only — both are row-wise,
        // so this equals the whole-prompt path's row `prompt_len - 1`
        let last = x.slice(n - 1, n, 0, x.cols);
        let (xf, _) = rmsnorm_fwd(&last, &self.final_norm);
        let logits = crate::tensor::matmul_transb(&xf, &self.lm_head);
        Ok(Some(logits.row(0).to_vec()))
    }

    /// One decode step over the block-pooled KV store (packed-KV
    /// counterpart of [`Self::decode_with`]).
    ///
    /// This is the serving stack's **reference path**: the batched tick
    /// ([`Self::decode_batch_pooled`]) must stay bitwise identical to it
    /// (enforced by the decode_batch parity tests), and the logit-drift
    /// sentinel replays served steps through it to detect any divergence
    /// in production.
    pub fn decode_pooled(
        &self,
        token: usize,
        pool: &mut KvPool,
        seq: u64,
        adapter: Option<&AdapterFactors>,
    ) -> anyhow::Result<Vec<f32>> {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let pos = pool
            .seq_len(seq)
            .ok_or_else(|| anyhow::anyhow!("decode of unknown KV sequence {seq}"))?;
        anyhow::ensure!(pos < self.cfg.max_seq, "KV cache full for seq {seq}");
        let mut x = self.embed(&[token]);
        for (li, layer) in self.layers.iter().enumerate() {
            let lf = adapter.map(|f| &f.layers[li]);
            let ov = |slot: usize| lf.and_then(|l| l.linears[slot].as_ref());
            let (h1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let mut q = fwd(&layer.wq, &h1, ov(0));
            let mut k = fwd(&layer.wk, &h1, ov(1));
            let v = fwd(&layer.wv, &h1, ov(2));
            rope_fwd(&mut q, h, pos, theta);
            rope_fwd(&mut k, h, pos, theta);
            pool.append_rows(seq, li, pos, &k, &v)?;
            let att =
                crate::kvquant::attention::decode_packed(&q, &pool.view(seq, li, pos + 1), h);
            let o = fwd(&layer.wo, &att, ov(3));
            x.add_assign(&o);
            let (h2, _) = rmsnorm_fwd(&x, &layer.mlp_norm);
            let gate_pre = fwd(&layer.w_gate, &h2, ov(4));
            let up = fwd(&layer.w_up, &h2, ov(5));
            let down = fwd(&layer.w_down, &swiglu(&gate_pre, &up), ov(6));
            x.add_assign(&down);
        }
        pool.commit(seq, pos + 1);
        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = crate::tensor::matmul_transb(&xf, &self.lm_head);
        Ok(logits.row(0).to_vec())
    }

    /// One **batched** decode tick over the block-pooled KV store: row `i`
    /// advances `rows[i]` by one token, with results in
    /// [`DecodeScratch::logits`] (row per input row, in order).
    ///
    /// This is the amortized counterpart of calling [`Self::decode_pooled`]
    /// once per sequence — and token-identical to it, bitwise: every op is
    /// row-wise (RMSNorm, RoPE at each sequence's own position, residuals,
    /// SwiGLU), the fused weight kernels produce per-row dots that do not
    /// depend on the batch size, and attention runs per sequence over its
    /// own blocks ([`decode_packed_batch`]
    /// (crate::kvquant::attention::decode_packed_batch), dispatched across
    /// the global thread pool). What changes is the memory traffic: the
    /// batch is split into maximal runs of rows sharing one adapter
    /// (tenant-groups), and each [`LinearWeight`] forward runs **once per
    /// group** — every ROW_TILE of packed codes is streamed, dequantized,
    /// and scale-reconstructed once per group per tick instead of once per
    /// sequence, dropping per-tick weight reads from `B × bytes(W)` to
    /// `groups × bytes(W)`.
    ///
    /// Returns the number of tenant-groups the tick formed (the weight
    /// streams it paid).
    ///
    /// Fails — before any K/V row is appended — when a row names an
    /// unknown sequence, a full cache, or a duplicated sequence id, or
    /// when the pool cannot back every row's next position (each row's
    /// blocks are reserved up front, so a tick never partially advances
    /// the batch; reservations are idempotent growth, so pre-reserved
    /// serving sequences pay nothing here).
    pub fn decode_batch_pooled(
        &self,
        rows: &[DecodeRow<'_>],
        pool: &mut KvPool,
        scratch: &mut DecodeScratch,
    ) -> anyhow::Result<usize> {
        let _span = crate::obs::span!("model.decode_batch", rows.len());
        let mut pos = Vec::with_capacity(rows.len());
        let mut seen = std::collections::HashSet::with_capacity(rows.len());
        for r in rows {
            let p = pool
                .seq_len(r.seq)
                .ok_or_else(|| anyhow::anyhow!("decode of unknown KV sequence {}", r.seq))?;
            anyhow::ensure!(p < self.cfg.max_seq, "KV cache full for seq {}", r.seq);
            anyhow::ensure!(seen.insert(r.seq), "duplicate sequence {} in decode batch", r.seq);
            anyhow::ensure!(
                pool.reserve(r.seq, p + 1),
                "KV pool cannot back position {} of seq {} ({} blocks free)",
                p + 1,
                r.seq,
                pool.free_blocks()
            );
            pos.push(p);
        }
        fit(&mut scratch.hidden, rows.len(), self.cfg.d_model);
        let mut groups = 0;
        let mut g0 = 0;
        while g0 < rows.len() {
            let mut g1 = g0 + 1;
            while g1 < rows.len() && same_adapter(rows[g0].adapter, rows[g1].adapter) {
                g1 += 1;
            }
            self.decode_group(&rows[g0..g1], &pos[g0..g1], g0, pool, scratch)?;
            groups += 1;
            g0 = g1;
        }
        // final norm + lm_head are adapter-independent: run them once over
        // the whole tick, so the vocab×d head weight streams once — not
        // once per group
        rmsnorm_fwd_inplace(&mut scratch.hidden, &self.final_norm);
        fit(&mut scratch.logits, rows.len(), self.cfg.vocab);
        crate::tensor::matmul_transb_into(&scratch.hidden, &self.lm_head, &mut scratch.logits);
        Ok(groups)
    }

    /// One tenant-group of a batched decode tick: all rows share
    /// `rows[0].adapter`, so each linear forward streams its packed weight
    /// exactly once for the whole group.
    fn decode_group(
        &self,
        rows: &[DecodeRow<'_>],
        pos: &[usize],
        out_row0: usize,
        pool: &mut KvPool,
        scratch: &mut DecodeScratch,
    ) -> anyhow::Result<()> {
        let h = self.cfg.n_heads;
        let theta = 10_000.0f32;
        let d = self.cfg.d_model;
        let b = rows.len();
        let adapter = rows[0].adapter;
        fit(&mut scratch.x, b, d);
        for (i, r) in rows.iter().enumerate() {
            scratch.x.row_mut(i).copy_from_slice(self.tok_emb.row(r.token));
        }
        fit(&mut scratch.norm, b, d);
        fit(&mut scratch.q, b, d);
        fit(&mut scratch.k, b, d);
        fit(&mut scratch.v, b, d);
        fit(&mut scratch.att, b, d);
        fit(&mut scratch.proj, b, d);
        fit(&mut scratch.gate, b, self.cfg.d_ff);
        fit(&mut scratch.up, b, self.cfg.d_ff);
        for (li, layer) in self.layers.iter().enumerate() {
            let lf = adapter.map(|f| &f.layers[li]);
            let ov = |slot: usize| lf.and_then(|l| l.linears[slot].as_ref());
            rmsnorm_fwd_into(&scratch.x, &layer.attn_norm, &mut scratch.norm);
            fwd_into(&layer.wq, &scratch.norm, ov(0), &mut scratch.q);
            fwd_into(&layer.wk, &scratch.norm, ov(1), &mut scratch.k);
            fwd_into(&layer.wv, &scratch.norm, ov(2), &mut scratch.v);
            for i in 0..b {
                rope_row(scratch.q.row_mut(i), h, pos[i], theta);
                rope_row(scratch.k.row_mut(i), h, pos[i], theta);
            }
            for (i, r) in rows.iter().enumerate() {
                pool.append_row(r.seq, li, pos[i], scratch.k.row(i), scratch.v.row(i))?;
            }
            // appends done: the pool is read-only for the attention sweep
            let views: Vec<_> = rows
                .iter()
                .zip(pos)
                .map(|(r, &p)| pool.view(r.seq, li, p + 1))
                .collect();
            crate::kvquant::attention::decode_packed_batch(&scratch.q, &views, h, &mut scratch.att);
            drop(views);
            fwd_into(&layer.wo, &scratch.att, ov(3), &mut scratch.proj);
            scratch.x.add_assign(&scratch.proj);
            rmsnorm_fwd_into(&scratch.x, &layer.mlp_norm, &mut scratch.norm);
            fwd_into(&layer.w_gate, &scratch.norm, ov(4), &mut scratch.gate);
            fwd_into(&layer.w_up, &scratch.norm, ov(5), &mut scratch.up);
            swiglu_inplace(&mut scratch.gate, &scratch.up);
            fwd_into(&layer.w_down, &scratch.gate, ov(6), &mut scratch.proj);
            scratch.x.add_assign(&scratch.proj);
        }
        for (r, &p) in rows.iter().zip(pos) {
            pool.commit(r.seq, p + 1);
        }
        // deposit this group's final hidden rows for the batch-wide head
        for i in 0..b {
            scratch.hidden.row_mut(out_row0 + i).copy_from_slice(scratch.x.row(i));
        }
        Ok(())
    }
}

/// Two decode rows belong to one tenant-group iff they resolve to the
/// same factors instance (both base, or the same registry entry).
#[inline]
fn same_adapter(a: Option<&AdapterFactors>, b: Option<&AdapterFactors>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => std::ptr::eq(x, y),
        _ => false,
    }
}

/// One linear forward, dispatched through a tenant adapter slot when
/// present (slots positionally match [`LayerWeights::linears`] order).
#[inline]
fn fwd(lw: &LinearWeight, x: &Matrix, ov: Option<&BaPair>) -> Matrix {
    match ov {
        Some(pair) => lw.forward_adapted(x, pair),
        None => lw.forward(x),
    }
}

/// [`fwd`] into a caller-owned buffer (the batched tick's scratch arena).
#[inline]
fn fwd_into(lw: &LinearWeight, x: &Matrix, ov: Option<&BaPair>, out: &mut Matrix) {
    match ov {
        Some(pair) => lw.forward_adapted_into(x, pair, out),
        None => lw.forward_into(x, out),
    }
}

fn swiglu(gate_pre: &Matrix, up: &Matrix) -> Matrix {
    gate_pre.zip_map(up, |g, u| silu(g) * u)
}

/// In-place SwiGLU: `gate[i] = silu(gate[i]) * up[i]` — elementwise
/// identical to [`swiglu`], reusing the gate buffer as the output.
fn swiglu_inplace(gate_pre: &mut Matrix, up: &Matrix) {
    debug_assert_eq!(gate_pre.shape(), up.shape());
    for (g, &u) in gate_pre.data.iter_mut().zip(&up.data) {
        *g = silu(*g) * u;
    }
}

fn swiglu_bwd(gate_pre: &Matrix, up: &Matrix, d_out: &Matrix) -> (Matrix, Matrix) {
    let d_gate = Matrix {
        rows: gate_pre.rows,
        cols: gate_pre.cols,
        data: gate_pre
            .data
            .iter()
            .zip(&up.data)
            .zip(&d_out.data)
            .map(|((&g, &u), &go)| go * u * dsilu(g))
            .collect(),
    };
    let d_up = Matrix {
        rows: up.rows,
        cols: up.cols,
        data: gate_pre
            .data
            .iter()
            .zip(&d_out.data)
            .map(|(&g, &go)| go * silu(g))
            .collect(),
    };
    (d_gate, d_up)
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline]
fn dsilu(x: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-x).exp());
    sig * (1.0 + x * (1.0 - sig))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        }
    }

    fn toy_batch(cfg: &ModelCfg, batch: usize, seq: usize, seed: u64) -> (Vec<usize>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let tokens: Vec<usize> = (0..batch * seq).map(|_| rng.below(cfg.vocab)).collect();
        let targets: Vec<usize> = (0..batch * seq).map(|_| rng.below(cfg.vocab)).collect();
        (tokens, targets)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 0);
        let (tokens, _) = toy_batch(&cfg, 2, 8, 1);
        let logits = model.forward(&tokens, 2, 8);
        assert_eq!(logits.shape(), (16, 32));
        assert!(logits.all_finite());
    }

    #[test]
    fn train_forward_matches_eval_forward() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 0);
        let (tokens, _) = toy_batch(&cfg, 2, 6, 2);
        let (lt, _) = model.forward_train(&tokens, 2, 6);
        let le = model.forward(&tokens, 2, 6);
        crate::util::prop::assert_allclose(&lt.data, &le.data, 1e-4, 1e-4, "train vs eval fwd");
    }

    #[test]
    fn dense_grads_match_finite_difference() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 3);
        let (tokens, targets) = toy_batch(&cfg, 1, 5, 4);
        let (_, grads) = model.loss_and_grads(&tokens, &targets, 1, 5);
        let eps = 1e-2;
        let loss_of = |m: &Model| {
            let (logits, _) = m.forward_train(&tokens, 1, 5);
            cross_entropy_fwd(&logits, &targets).0
        };
        // spot-check several parameters across the net
        let checks: Vec<(&str, usize, usize, usize)> = vec![
            ("wq", 0, 1, 3),
            ("w_down", 1, 2, 5),
            ("lm_head", 0, 4, 2),
            ("tok_emb", 0, tokens[2], 1),
        ];
        for (what, li, i, j) in checks {
            let (an, fd) = match what {
                "lm_head" => {
                    let an = grads.lm_head.as_ref().unwrap().at(i, j);
                    let mut mp = model.clone();
                    *mp.lm_head.at_mut(i, j) += eps;
                    let mut mm = model.clone();
                    *mm.lm_head.at_mut(i, j) -= eps;
                    (an, (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps))
                }
                "tok_emb" => {
                    let an = grads.tok_emb.as_ref().unwrap().at(i, j);
                    let mut mp = model.clone();
                    *mp.tok_emb.at_mut(i, j) += eps;
                    let mut mm = model.clone();
                    *mm.tok_emb.at_mut(i, j) -= eps;
                    (an, (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps))
                }
                "wq" => {
                    let an = grads.layers[li].wq.d_w.as_ref().unwrap().at(i, j);
                    let tweak = |m: &mut Model, e: f32| {
                        if let LinearWeight::Dense(w) = &mut m.layers[li].wq {
                            *w.at_mut(i, j) += e;
                        }
                    };
                    let mut mp = model.clone();
                    tweak(&mut mp, eps);
                    let mut mm = model.clone();
                    tweak(&mut mm, -eps);
                    (an, (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps))
                }
                _ => {
                    let an = grads.layers[li].w_down.d_w.as_ref().unwrap().at(i, j);
                    let tweak = |m: &mut Model, e: f32| {
                        if let LinearWeight::Dense(w) = &mut m.layers[li].w_down {
                            *w.at_mut(i, j) += e;
                        }
                    };
                    let mut mp = model.clone();
                    tweak(&mut mp, eps);
                    let mut mm = model.clone();
                    tweak(&mut mm, -eps);
                    (an, (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps))
                }
            };
            assert!(
                (fd - an).abs() < 5e-2 * fd.abs().max(0.02),
                "{what}[{li}][{i},{j}]: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn prefill_decode_matches_full_forward() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 5);
        // also exercise the quantized path
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 4, ..Default::default() }, false);
        let mut rng = Rng::new(6);
        let tokens: Vec<usize> = (0..8).map(|_| rng.below(cfg.vocab)).collect();
        let full = model.forward(&tokens, 1, 8);
        let mut cache = KvCache::new(&cfg);
        let pre = model.prefill(&tokens[..7], &mut cache);
        crate::util::prop::assert_allclose(&pre, full.row(6), 1e-3, 1e-3, "prefill logits");
        let dec = model.decode(tokens[7], &mut cache);
        crate::util::prop::assert_allclose(&dec, full.row(7), 1e-3, 1e-3, "decode logits");
        assert_eq!(cache.len, 8);
    }

    #[test]
    fn adapted_prefill_decode_matches_merged_factors() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 13);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        let mut rng = Rng::new(14);
        let adapter = crate::adapters::AdapterFactors::from_model(&model).perturbed(0.05, &mut rng);
        // merged reference: bake the tenant factors into a clone
        let mut merged = model.clone();
        adapter.apply_to(&mut merged).unwrap();
        let tokens: Vec<usize> = (0..6).map(|_| rng.below(cfg.vocab)).collect();
        let mut c1 = KvCache::new(&cfg);
        let mut c2 = KvCache::new(&cfg);
        let a = model.prefill_with(&tokens[..5], &mut c1, Some(&adapter));
        let b = merged.prefill(&tokens[..5], &mut c2);
        crate::util::prop::assert_allclose(&a, &b, 1e-6, 1e-6, "adapted prefill");
        let d1 = model.decode_with(tokens[5], &mut c1, Some(&adapter));
        let d2 = merged.decode(tokens[5], &mut c2);
        crate::util::prop::assert_allclose(&d1, &d2, 1e-6, 1e-6, "adapted decode");
    }

    #[test]
    fn pooled_f32_kv_matches_contiguous_cache() {
        // the paged dense pool must reproduce the per-sequence cache path
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 21);
        let mut rng = Rng::new(22);
        let tokens: Vec<usize> = (0..10).map(|_| rng.below(cfg.vocab)).collect();
        let mut cache = KvCache::new(&cfg);
        let pre_ref = model.prefill(&tokens[..9], &mut cache);
        let dec_ref = model.decode(tokens[9], &mut cache);

        let kv = crate::kvquant::KvQuantCfg { block_tokens: 4, ..Default::default() };
        let mut pool = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 8);
        let pre = model.prefill_pooled(&tokens[..9], &mut pool, 1, None).unwrap();
        crate::util::prop::assert_allclose(&pre, &pre_ref, 1e-6, 1e-6, "pooled prefill");
        let dec = model.decode_pooled(tokens[9], &mut pool, 1, None).unwrap();
        crate::util::prop::assert_allclose(&dec, &dec_ref, 1e-6, 1e-6, "pooled decode");
        assert_eq!(pool.seq_len(1), Some(10));
    }

    #[test]
    fn pooled_int8_kv_within_logit_tolerance() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 23);
        let mut rng = Rng::new(24);
        let tokens: Vec<usize> = (0..12).map(|_| rng.below(cfg.vocab)).collect();
        let mut cache = KvCache::new(&cfg);
        let pre_ref = model.prefill(&tokens[..11], &mut cache);
        let dec_ref = model.decode(tokens[11], &mut cache);

        let kv = crate::kvquant::KvQuantCfg {
            bits: crate::kvquant::KvBits::Int8,
            rank: 1,
            block_tokens: 4,
        };
        let mut pool = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 8);
        let pre = model.prefill_pooled(&tokens[..11], &mut pool, 1, None).unwrap();
        let dec = model.decode_pooled(tokens[11], &mut pool, 1, None).unwrap();
        let dp = crate::util::prop::max_abs_diff(&pre, &pre_ref);
        let dd = crate::util::prop::max_abs_diff(&dec, &dec_ref);
        assert!(dp <= 1e-2 && dd <= 1e-2, "int8 KV logit drift: prefill {dp}, decode {dd}");
    }

    fn argmax(v: &[f32]) -> usize {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }

    #[test]
    fn batched_decode_tick_is_bitwise_identical_to_per_sequence_loop() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 41);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        let mut rng = Rng::new(42);
        let kv = crate::kvquant::KvQuantCfg { block_tokens: 4, ..Default::default() };
        let mut pa = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 64);
        let mut pb = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 64);
        let lens = [5usize, 3, 7]; // ragged cache positions
        let mut last: Vec<usize> = Vec::new();
        for (i, &l) in lens.iter().enumerate() {
            let prompt: Vec<usize> = (0..l).map(|_| rng.below(cfg.vocab)).collect();
            let seq = i as u64 + 1;
            let la = model.prefill_pooled(&prompt, &mut pa, seq, None).unwrap();
            let lb = model.prefill_pooled(&prompt, &mut pb, seq, None).unwrap();
            assert_eq!(la, lb);
            last.push(argmax(&la));
        }
        let mut scratch = DecodeScratch::new();
        for tick in 0..4 {
            let mut ref_logits = Vec::new();
            for (i, &t) in last.iter().enumerate() {
                ref_logits.push(model.decode_pooled(t, &mut pa, i as u64 + 1, None).unwrap());
            }
            let rows: Vec<DecodeRow> = last
                .iter()
                .enumerate()
                .map(|(i, &t)| DecodeRow { seq: i as u64 + 1, token: t, adapter: None })
                .collect();
            model.decode_batch_pooled(&rows, &mut pb, &mut scratch).unwrap();
            for (i, want) in ref_logits.iter().enumerate() {
                assert_eq!(
                    scratch.logits().row(i),
                    want.as_slice(),
                    "tick {tick} row {i}: batched logits must be bitwise identical"
                );
            }
            last = ref_logits.iter().map(|l| argmax(l)).collect();
        }
    }

    #[test]
    fn batched_decode_rejects_bad_rows() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 43);
        let kv = crate::kvquant::KvQuantCfg { block_tokens: 4, ..Default::default() };
        let mut pool = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 64);
        model.prefill_pooled(&[1, 2, 3], &mut pool, 1, None).unwrap();
        let mut scratch = DecodeScratch::new();
        // unknown sequence
        let rows = [DecodeRow { seq: 9, token: 1, adapter: None }];
        assert!(model.decode_batch_pooled(&rows, &mut pool, &mut scratch).is_err());
        // duplicate sequence ids in one tick
        let rows = [
            DecodeRow { seq: 1, token: 1, adapter: None },
            DecodeRow { seq: 1, token: 2, adapter: None },
        ];
        assert!(model.decode_batch_pooled(&rows, &mut pool, &mut scratch).is_err());
        // a failed tick appended nothing
        assert_eq!(pool.seq_len(1), Some(3));
    }

    #[test]
    fn pooled_kv_pool_exhaustion_is_recoverable() {
        let cfg = tiny_cfg();
        let model = Model::init(&cfg, 25);
        let kv = crate::kvquant::KvQuantCfg { block_tokens: 4, ..Default::default() };
        // one block only: an 8-token prompt cannot fit
        let mut pool = crate::kvquant::KvPool::new(kv, cfg.n_layers, cfg.d_model, 1);
        let tokens: Vec<usize> = (0..8).collect();
        assert!(model.prefill_pooled(&tokens, &mut pool, 1, None).is_err());
        pool.release(1);
        let short: Vec<usize> = (0..4).collect();
        assert!(model.prefill_pooled(&short, &mut pool, 2, None).is_ok());
    }

    #[test]
    fn peft_grads_flow_only_to_ba() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 7);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, false);
        let (tokens, targets) = toy_batch(&cfg, 1, 6, 8);
        let (loss, grads) = model.loss_and_grads(&tokens, &targets, 1, 6);
        assert!(loss.is_finite());
        for lg in &grads.layers {
            assert!(lg.wq.d_w.is_none(), "PEFT must not produce dense W grads");
            assert!(lg.wq.d_b.is_some() && lg.wq.d_a.is_some());
            let db = lg.wq.d_b.as_ref().unwrap();
            assert!(db.data.iter().any(|&v| v != 0.0), "B grads must be nonzero");
        }
    }

    #[test]
    fn qat_grads_flow_to_w_and_ba() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 9);
        model.quantize_lords(cfg.block, &Codebook::normal_float(4),
                             RefineCfg { steps: 2, ..Default::default() }, true);
        let (tokens, targets) = toy_batch(&cfg, 1, 6, 10);
        let (_, grads) = model.loss_and_grads(&tokens, &targets, 1, 6);
        let lg = &grads.layers[0];
        assert!(lg.wq.d_w.is_some() && lg.wq.d_b.is_some() && lg.wq.d_a.is_some());
    }

    #[test]
    fn param_accounting() {
        let cfg = tiny_cfg();
        let mut model = Model::init(&cfg, 11);
        let dense_train = model.train_params();
        model.quantize_qlora(cfg.block, 4, &Codebook::normal_float(4), 0);
        let qlora_train = model.train_params();
        assert!(qlora_train < dense_train);
        // QLoRA float params include base scales + adapters; LoRDS only B/A
        let qlora_float = model.float_params();
        let mut m2 = Model::init(&cfg, 11);
        m2.quantize_lords(cfg.block, &Codebook::normal_float(4),
                          RefineCfg { steps: 0, ..Default::default() }, false);
        assert!(m2.float_params() < qlora_float, "LoRDS must use fewer float params");
    }
}
