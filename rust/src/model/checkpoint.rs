//! Dense-model checkpointing (tiny binary format, f32 little-endian).
//!
//! Only dense models are checkpointed — quantized representations are
//! cheap to re-derive and keeping a single canonical format avoids version
//! skew. Used to memoize the pre-trained testbed models that every paper
//! table starts from.

use super::{LinearWeight, Model};
use crate::config::ModelCfg;
use crate::tensor::Matrix;
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"LORDSCK1";

pub(crate) fn write_mat(w: &mut impl Write, m: &Matrix) -> std::io::Result<()> {
    w.write_all(&(m.rows as u32).to_le_bytes())?;
    w.write_all(&(m.cols as u32).to_le_bytes())?;
    for v in &m.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn read_mat(r: &mut impl Read) -> std::io::Result<Matrix> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let rows = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let cols = u32::from_le_bytes(b4) as usize;
    let mut data = vec![0f32; rows * cols];
    for v in data.iter_mut() {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

fn write_vec(w: &mut impl Write, v: &[f32]) -> std::io::Result<()> {
    write_mat(w, &Matrix::from_vec(1, v.len(), v.to_vec()))
}

fn read_vec(r: &mut impl Read) -> std::io::Result<Vec<f32>> {
    Ok(read_mat(r)?.data)
}

impl Model {
    /// Serialize (dense linears only — panics otherwise).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        for v in [
            self.cfg.vocab,
            self.cfg.d_model,
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.d_ff,
            self.cfg.max_seq,
            self.cfg.block,
        ] {
            f.write_all(&(v as u32).to_le_bytes())?;
        }
        write_mat(&mut f, &self.tok_emb)?;
        write_mat(&mut f, &self.lm_head)?;
        write_vec(&mut f, &self.final_norm)?;
        for layer in &self.layers {
            write_vec(&mut f, &layer.attn_norm)?;
            write_vec(&mut f, &layer.mlp_norm)?;
            for (_, lw) in layer.linears() {
                match lw {
                    LinearWeight::Dense(w) => write_mat(&mut f, w)?,
                    other => panic!("checkpoint requires dense model, got {other:?}"),
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &str, cfg: &ModelCfg) -> std::io::Result<Model> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad magic"));
        }
        let mut b4 = [0u8; 4];
        let mut dims = [0usize; 7];
        for d in dims.iter_mut() {
            f.read_exact(&mut b4)?;
            *d = u32::from_le_bytes(b4) as usize;
        }
        if dims
            != [cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.max_seq, cfg.block]
        {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("checkpoint dims {dims:?} mismatch config"),
            ));
        }
        let mut model = Model::init(cfg, 0);
        model.tok_emb = read_mat(&mut f)?;
        model.lm_head = read_mat(&mut f)?;
        model.final_norm = read_vec(&mut f)?;
        for layer in model.layers.iter_mut() {
            layer.attn_norm = read_vec(&mut f)?;
            layer.mlp_norm = read_vec(&mut f)?;
            for (_, lw) in layer.linears_mut() {
                *lw = LinearWeight::Dense(read_mat(&mut f)?);
            }
        }
        Ok(model)
    }

    /// Export this model's LoRDS scale factors as a named adapter artifact
    /// (the PEFT trainer's hand-off to the serving side).
    pub fn save_adapter(&self, id: &str, path: &str) -> anyhow::Result<()> {
        let art = crate::adapters::AdapterArtifact::from_model(self, id)?;
        art.save(path)?;
        Ok(())
    }

    /// Load a PEFT adapter artifact and dense-merge its (B′, A′) factors
    /// into this LoRDS-quantized model; returns the adapter id. Online
    /// multi-tenant serving registers the artifact with an
    /// [`AdapterRegistry`](crate::adapters::AdapterRegistry) instead.
    pub fn load_adapter(&mut self, path: &str) -> anyhow::Result<String> {
        let art = crate::adapters::AdapterArtifact::load(path)?;
        art.factors.apply_to(self)?;
        Ok(art.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let model = Model::init(&cfg, 42);
        let path = std::env::temp_dir().join("lords_ck_test.bin");
        let path = path.to_str().unwrap();
        model.save(path).unwrap();
        let loaded = Model::load(path, &cfg).unwrap();
        assert_eq!(model.tok_emb.data, loaded.tok_emb.data);
        if let (LinearWeight::Dense(a), LinearWeight::Dense(b)) =
            (&model.layers[1].w_down, &loaded.layers[1].w_down)
        {
            assert_eq!(a.data, b.data);
        } else {
            panic!();
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn adapter_export_import_roundtrip() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let mut model = Model::init(&cfg, 7);
        model.quantize_lords(
            cfg.block,
            &crate::quant::Codebook::normal_float(4),
            crate::quant::lords::RefineCfg { steps: 2, ..Default::default() },
            false,
        );
        let pristine = model.clone();
        // simulate a PEFT run: nudge the scale factors
        for layer in model.layers.iter_mut() {
            for (_, lw) in layer.linears_mut() {
                if let LinearWeight::Lords { q, .. } = lw {
                    for v in q.b.data.iter_mut() {
                        *v += 0.01;
                    }
                }
            }
        }
        let path = std::env::temp_dir().join("lords_model_adapter_test.bin");
        let path = path.to_str().unwrap();
        model.save_adapter("tuned", path).unwrap();
        let mut fresh = pristine;
        let id = fresh.load_adapter(path).unwrap();
        assert_eq!(id, "tuned");
        assert_eq!(
            crate::adapters::AdapterFactors::from_model(&fresh),
            crate::adapters::AdapterFactors::from_model(&model)
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn config_mismatch_rejected() {
        let cfg = ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let model = Model::init(&cfg, 0);
        let path = std::env::temp_dir().join("lords_ck_test2.bin");
        let path = path.to_str().unwrap();
        model.save(path).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.d_model = 32;
        cfg2.n_heads = 4;
        assert!(Model::load(path, &cfg2).is_err());
        std::fs::remove_file(path).ok();
    }
}
