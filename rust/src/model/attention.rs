//! Rotary embeddings + causal multi-head self-attention, forward and
//! backward (softmax Jacobian handled row-wise), plus the incremental
//! (KV-cache) attention used by the serving path.
//!
//! Shapes: a sequence is S×D row-major; heads are contiguous hd-sized column
//! groups. RoPE matches `python/compile/model.py`: pairs (2i, 2i+1) rotated
//! by θ_i(pos) = pos / theta^(2i/hd).
//!
//! Score and weighted-V dot products run through the shared
//! [`kernels::dot`](crate::kernels::dot) 4-accumulator microkernel — the
//! same op order as the fused packed attention in
//! [`kvquant::attention`](crate::kvquant::attention), keeping the pooled
//! f32 path bit-identical to this dense reference.

use crate::kernels::dot;
use crate::tensor::Matrix;

/// Apply RoPE in place to an S×D matrix of H heads, positions pos0..pos0+S.
pub fn rope_fwd(x: &mut Matrix, n_heads: usize, pos0: usize, theta: f32) {
    rope_apply(x, n_heads, pos0, theta, false);
}

/// RoPE backward = rotation by −θ (the transpose of an orthogonal map).
pub fn rope_bwd(g: &mut Matrix, n_heads: usize, pos0: usize, theta: f32) {
    rope_apply(g, n_heads, pos0, theta, true);
}

/// Apply RoPE to one D-row at absolute position `pos` — the batched
/// decode tick rotates each stacked row at its own cache position.
/// Identical per-row math to [`rope_fwd`].
pub fn rope_row(row: &mut [f32], n_heads: usize, pos: usize, theta: f32) {
    rope_apply_row(row, n_heads, pos, theta, false);
}

fn rope_apply(x: &mut Matrix, n_heads: usize, pos0: usize, theta: f32, inverse: bool) {
    assert_eq!(x.cols % n_heads, 0);
    for s in 0..x.rows {
        rope_apply_row(x.row_mut(s), n_heads, pos0 + s, theta, inverse);
    }
}

fn rope_apply_row(row: &mut [f32], n_heads: usize, pos: usize, theta: f32, inverse: bool) {
    let d = row.len();
    assert_eq!(d % n_heads, 0, "row width {d} not divisible into {n_heads} heads");
    let hd = d / n_heads;
    let pos = pos as f32;
    for h in 0..n_heads {
        let base = h * hd;
        for i in 0..hd / 2 {
            let freq = 1.0 / theta.powf(2.0 * i as f32 / hd as f32);
            let ang = pos * freq;
            let (sin, cos) = ang.sin_cos();
            let sin = if inverse { -sin } else { sin };
            let x1 = row[base + 2 * i];
            let x2 = row[base + 2 * i + 1];
            row[base + 2 * i] = x1 * cos - x2 * sin;
            row[base + 2 * i + 1] = x1 * sin + x2 * cos;
        }
    }
}

/// Cache for attention backward: post-softmax probabilities per head.
pub struct AttnCache {
    /// probs[h]: S×S row-stochastic (causal-masked softmax).
    pub probs: Vec<Matrix>,
}

/// Causal self-attention over one sequence: q, k, v are S×D (post-RoPE).
/// Returns (out S×D, cache).
pub fn attention_fwd(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> (Matrix, AttnCache) {
    let s = q.rows;
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(s, d);
    let mut probs = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let base = h * hd;
        let mut p = Matrix::zeros(s, s);
        for i in 0..s {
            // scores for row i over keys 0..=i (causal)
            let qi = &q.row(i)[base..base + hd];
            let mut maxv = f32::NEG_INFINITY;
            for j in 0..=i {
                let kj = &k.row(j)[base..base + hd];
                let sc = dot(qi, kj) * scale;
                p.set(i, j, sc);
                maxv = maxv.max(sc);
            }
            let mut denom = 0.0f32;
            for j in 0..=i {
                let e = (p.at(i, j) - maxv).exp();
                p.set(i, j, e);
                denom += e;
            }
            let inv = 1.0 / denom;
            for j in 0..=i {
                *p.at_mut(i, j) *= inv;
            }
            // out_i = Σ_j p_ij v_j
            let out_row = &mut out.row_mut(i)[base..base + hd];
            for j in 0..=i {
                let pij = p.at(i, j);
                if pij == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[base..base + hd];
                for (o, &vv) in out_row.iter_mut().zip(vj) {
                    *o += pij * vv;
                }
            }
        }
        probs.push(p);
    }
    (out, AttnCache { probs })
}

/// Backward through causal attention: returns (dq, dk, dv), all S×D.
pub fn attention_bwd(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    cache: &AttnCache,
    g: &Matrix,
    n_heads: usize,
) -> (Matrix, Matrix, Matrix) {
    let s = q.rows;
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = Matrix::zeros(s, d);
    let mut dk = Matrix::zeros(s, d);
    let mut dv = Matrix::zeros(s, d);
    for h in 0..n_heads {
        let base = h * hd;
        let p = &cache.probs[h];
        for i in 0..s {
            let gi = &g.row(i)[base..base + hd];
            // dp_ij = g_i · v_j ; dv_j += p_ij g_i
            let mut dp = vec![0.0f32; i + 1];
            for j in 0..=i {
                let vj = &v.row(j)[base..base + hd];
                dp[j] = dot(gi, vj);
                let pij = p.at(i, j);
                let dvj = &mut dv.row_mut(j)[base..base + hd];
                for (o, &gv) in dvj.iter_mut().zip(gi) {
                    *o += pij * gv;
                }
            }
            // softmax backward: ds_ij = p_ij (dp_ij − Σ_k p_ik dp_ik)
            let pdp: f32 = (0..=i).map(|j| p.at(i, j) * dp[j]).sum();
            // dq_i += Σ_j ds_ij k_j · scale ; dk_j += ds_ij q_i · scale
            let qi: Vec<f32> = q.row(i)[base..base + hd].to_vec();
            let dqi = &mut dq.row_mut(i)[base..base + hd];
            for j in 0..=i {
                let ds = p.at(i, j) * (dp[j] - pdp) * scale;
                if ds == 0.0 {
                    continue;
                }
                let kj = &k.row(j)[base..base + hd];
                for (o, &kv) in dqi.iter_mut().zip(kj) {
                    *o += ds * kv;
                }
                let dkj = &mut dk.row_mut(j)[base..base + hd];
                for (o, &qv) in dkj.iter_mut().zip(&qi) {
                    *o += ds * qv;
                }
            }
        }
    }
    (dq, dk, dv)
}

/// Incremental attention for decode: one query row attends over `len`
/// cached keys/values (cap×D matrices, rows 0..len valid). q: 1×D post-RoPE.
pub fn attention_decode(
    q: &Matrix,
    k_cache: &Matrix,
    v_cache: &Matrix,
    len: usize,
    n_heads: usize,
) -> Matrix {
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(1, d);
    for h in 0..n_heads {
        let base = h * hd;
        let qh = &q.row(0)[base..base + hd];
        let mut scores = vec![0.0f32; len];
        let mut maxv = f32::NEG_INFINITY;
        for (j, sc) in scores.iter_mut().enumerate() {
            let kj = &k_cache.row(j)[base..base + hd];
            *sc = dot(qh, kj) * scale;
            maxv = maxv.max(*sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - maxv).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        let oh = &mut out.row_mut(0)[base..base + hd];
        for (j, &sc) in scores.iter().enumerate() {
            let w = sc * inv;
            let vj = &v_cache.row(j)[base..base + hd];
            for (o, &vv) in oh.iter_mut().zip(vj) {
                *o += w * vv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rope_roundtrip() {
        let mut rng = Rng::new(0);
        let x0 = Matrix::randn(5, 16, 1.0, &mut rng);
        let mut x = x0.clone();
        rope_fwd(&mut x, 2, 3, 10000.0);
        rope_bwd(&mut x, 2, 3, 10000.0);
        crate::util::prop::assert_allclose(&x.data, &x0.data, 1e-5, 1e-5, "rope inverse");
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(1);
        let x0 = Matrix::randn(4, 8, 1.0, &mut rng);
        let mut x = x0.clone();
        rope_fwd(&mut x, 2, 0, 10000.0);
        assert!((x.frob_norm() - x0.frob_norm()).abs() < 1e-4);
    }

    #[test]
    fn rope_relative_property() {
        // dot(rope(q, p1), rope(k, p2)) depends only on p1 − p2
        let mut rng = Rng::new(2);
        let q0 = Matrix::randn(1, 8, 1.0, &mut rng);
        let k0 = Matrix::randn(1, 8, 1.0, &mut rng);
        let dot_at = |pq: usize, pk: usize| -> f32 {
            let mut q = q0.clone();
            let mut k = k0.clone();
            rope_fwd(&mut q, 1, pq, 100.0);
            rope_fwd(&mut k, 1, pk, 100.0);
            q.row(0).iter().zip(k.row(0)).map(|(a, b)| a * b).sum()
        };
        assert!((dot_at(5, 3) - dot_at(9, 7)).abs() < 1e-4);
    }

    #[test]
    fn attention_rows_sum_to_one_and_causal() {
        let mut rng = Rng::new(3);
        let s = 6;
        let q = Matrix::randn(s, 8, 1.0, &mut rng);
        let k = Matrix::randn(s, 8, 1.0, &mut rng);
        let v = Matrix::randn(s, 8, 1.0, &mut rng);
        let (_, cache) = attention_fwd(&q, &k, &v, 2);
        for p in &cache.probs {
            for i in 0..s {
                let sum: f32 = (0..s).map(|j| p.at(i, j)).sum();
                assert!((sum - 1.0).abs() < 1e-5);
                for j in i + 1..s {
                    assert_eq!(p.at(i, j), 0.0, "causality violated at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn attention_grads_match_finite_difference() {
        let mut rng = Rng::new(4);
        let s = 4;
        let d = 8;
        let q = Matrix::randn(s, d, 0.5, &mut rng);
        let k = Matrix::randn(s, d, 0.5, &mut rng);
        let v = Matrix::randn(s, d, 0.5, &mut rng);
        let upstream = Matrix::randn(s, d, 1.0, &mut rng);
        let loss = |q: &Matrix, k: &Matrix, v: &Matrix| -> f32 {
            let (o, _) = attention_fwd(q, k, v, 2);
            o.data.iter().zip(&upstream.data).map(|(a, b)| a * b).sum()
        };
        let (_, cache) = attention_fwd(&q, &k, &v, 2);
        let (dq, dk, dv) = attention_bwd(&q, &k, &v, &cache, &upstream, 2);
        let eps = 1e-3;
        let checks: [(&Matrix, Box<dyn Fn(&mut Matrix) -> &mut f32>, f32); 3] = [
            (&dq, Box::new(|m: &mut Matrix| m.at_mut(2, 3)), 0.0),
            (&dk, Box::new(|m: &mut Matrix| m.at_mut(1, 6)), 0.0),
            (&dv, Box::new(|m: &mut Matrix| m.at_mut(0, 4)), 0.0),
        ];
        // dq check
        for (idx, (grad, pick, _)) in checks.into_iter().enumerate() {
            let (mut p1, mut m1) = (q.clone(), q.clone());
            let (mut p2, mut m2) = (k.clone(), k.clone());
            let (mut p3, mut m3) = (v.clone(), v.clone());
            let (fd, an) = match idx {
                0 => {
                    *pick(&mut p1) += eps;
                    *pick(&mut m1) -= eps;
                    ((loss(&p1, &k, &v) - loss(&m1, &k, &v)) / (2.0 * eps), grad.at(2, 3))
                }
                1 => {
                    *pick(&mut p2) += eps;
                    *pick(&mut m2) -= eps;
                    ((loss(&q, &p2, &v) - loss(&q, &m2, &v)) / (2.0 * eps), grad.at(1, 6))
                }
                _ => {
                    *pick(&mut p3) += eps;
                    *pick(&mut m3) -= eps;
                    ((loss(&q, &k, &p3) - loss(&q, &k, &m3)) / (2.0 * eps), grad.at(0, 4))
                }
            };
            assert!((fd - an).abs() < 3e-2 * fd.abs().max(0.5), "grad {idx}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn decode_matches_full_attention_last_row() {
        let mut rng = Rng::new(5);
        let s = 7;
        let d = 8;
        let q = Matrix::randn(s, d, 0.5, &mut rng);
        let k = Matrix::randn(s, d, 0.5, &mut rng);
        let v = Matrix::randn(s, d, 0.5, &mut rng);
        let (full, _) = attention_fwd(&q, &k, &v, 2);
        let q_last = q.slice(s - 1, s, 0, d);
        let out = attention_decode(&q_last, &k, &v, s, 2);
        crate::util::prop::assert_allclose(
            out.row(0),
            full.row(s - 1),
            1e-4,
            1e-4,
            "decode vs full",
        );
    }
}
