//! Token-level cross-entropy loss, forward + backward.

use crate::tensor::Matrix;

/// Forward: logits (t×V), targets (len t). Returns (mean NLL, probs cache).
pub fn cross_entropy_fwd(logits: &Matrix, targets: &[usize]) -> (f32, Matrix) {
    assert_eq!(logits.rows, targets.len());
    let v = logits.cols;
    let mut probs = Matrix::zeros(logits.rows, v);
    let mut nll = 0.0f64;
    for i in 0..logits.rows {
        let row = logits.row(i);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut denom = 0.0f32;
        let out = probs.row_mut(i);
        for j in 0..v {
            let e = (row[j] - maxv).exp();
            out[j] = e;
            denom += e;
        }
        let inv = 1.0 / denom;
        for p in out.iter_mut() {
            *p *= inv;
        }
        nll -= (out[targets[i]].max(1e-20) as f64).ln();
    }
    ((nll / logits.rows as f64) as f32, probs)
}

/// Backward: dlogits = (probs − onehot(target)) / t.
pub fn cross_entropy_bwd(probs: &Matrix, targets: &[usize]) -> Matrix {
    let t = probs.rows as f32;
    let mut g = probs.clone();
    for (i, &y) in targets.iter().enumerate() {
        *g.at_mut(i, y) -= 1.0;
    }
    g.scale(1.0 / t)
}

/// Perplexity from a mean-NLL loss.
pub fn perplexity(mean_nll: f32) -> f32 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn uniform_logits_give_log_v() {
        let logits = Matrix::zeros(3, 8);
        let (loss, _) = cross_entropy_fwd(&logits, &[0, 3, 7]);
        assert!((loss - (8.0f32).ln()).abs() < 1e-5);
        assert!((perplexity(loss) - 8.0).abs() < 1e-3);
    }

    #[test]
    fn confident_correct_is_low_loss() {
        let mut logits = Matrix::zeros(1, 4);
        logits.set(0, 2, 10.0);
        let (loss, _) = cross_entropy_fwd(&logits, &[2]);
        assert!(loss < 1e-3);
    }

    #[test]
    fn grads_match_finite_difference() {
        let mut rng = Rng::new(0);
        let logits = Matrix::randn(4, 6, 1.0, &mut rng);
        let targets = [1usize, 0, 5, 3];
        let (_, probs) = cross_entropy_fwd(&logits, &targets);
        let g = cross_entropy_bwd(&probs, &targets);
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 1usize), (2, 5), (3, 0)] {
            let mut lp = logits.clone();
            let mut lm = logits.clone();
            *lp.at_mut(i, j) += eps;
            *lm.at_mut(i, j) -= eps;
            let (fp, _) = cross_entropy_fwd(&lp, &targets);
            let (fm, _) = cross_entropy_fwd(&lm, &targets);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - g.at(i, j)).abs() < 1e-3, "({i},{j}): {fd} vs {}", g.at(i, j));
        }
    }

    #[test]
    fn grad_rows_sum_to_zero() {
        let mut rng = Rng::new(1);
        let logits = Matrix::randn(3, 5, 1.0, &mut rng);
        let targets = [0usize, 2, 4];
        let (_, probs) = cross_entropy_fwd(&logits, &targets);
        let g = cross_entropy_bwd(&probs, &targets);
        for i in 0..3 {
            let s: f32 = g.row(i).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }
}
