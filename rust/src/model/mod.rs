//! The Llama-style transformer testbed, implemented natively in Rust with
//! **manual backpropagation** — no autodiff framework exists in the vendored
//! crate set, so every layer implements its own backward pass (verified
//! against finite differences in the module tests).
//!
//! Roles:
//! * the *pre-training testbed* producing realistic weight statistics for
//!   the quantization experiments (Tables 1–4),
//! * the *QAT / PEFT substrate*: quantized linears carry (B, A) scale
//!   factors whose gradients flow via the STE rules (eqs. 4–5),
//! * the *Rust-native serving path* with KV-cache decode (one of the Table-6
//!   operating points; the PJRT artifact path is the other).
//!
//! Layout mirrors `python/compile/model.py` exactly (same parameter names,
//! same shapes) so checkpoints can flow across the PJRT boundary.

pub mod attention;
pub mod checkpoint;
pub mod linear;
pub mod loss;
pub mod norm;
pub mod transformer;

pub use linear::{LinearGrads, LinearWeight};
pub use transformer::{DecodeRow, DecodeScratch, KvCache, LayerWeights, Model};
