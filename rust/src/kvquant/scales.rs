//! Low-rank scale fitting for one KV block's token×channel tile.
//!
//! The element-wise optimal scale manifold of a tile X ∈ R^{T×D} is
//! S* = |X| (every element exactly representable). Storing S* would cost
//! as much as the tile itself, so — in the spirit of the paper's weight
//! treatment — we keep only rank-r factors (B, A) with S = B·A:
//!
//! * **r = 1, the positive envelope**: `a_d = max_t |X_td|` (per-channel
//!   absmax), `b_t = max_d |X_td| / a_d` (per-token headroom). By
//!   construction `b_t · a_d ≥ |X_td|` everywhere, so no element is ever
//!   clipped — quantization error is bounded by `s_td · Δ/2` with Δ the
//!   codebook step. This is the per-token × per-channel dual granularity
//!   of KV-quant systems expressed as a single outer product.
//! * **r ≥ 2**: the envelope seeds multiplicative NMF updates toward S*
//!   (all factors stay non-negative), after which the per-row envelope
//!   guarantee is folded back into B (`B[t, :] *= max_d |X_td| / S_td`),
//!   keeping the result rank-r and clip-free per row.
//!
//! The fit runs once per sealed block at append time — `O(T·D·r)` per
//! refinement sweep, negligible next to the attention work that follows.

use crate::kernels::PackedCodes;
use crate::quant::Codebook;
use crate::tensor::{matmul, matmul_at_b, matmul_transb, Matrix};

/// NMF refinement sweeps for rank ≥ 2 fits.
const NMF_ITERS: usize = 10;

/// Fit rank-r factors (B: T×r, A: r×D) to the absolute tile `absx`
/// (entries must be ≥ 0). See the module doc for the construction.
pub fn fit_scale_factors(absx: &Matrix, rank: usize) -> (Matrix, Matrix) {
    assert!(rank >= 1, "scale rank must be >= 1");
    let (t, d) = absx.shape();
    // component 0: the clip-free positive envelope
    let mut a0 = vec![0.0f32; d];
    for i in 0..t {
        for (j, a) in a0.iter_mut().enumerate() {
            *a = a.max(absx.at(i, j));
        }
    }
    for a in a0.iter_mut() {
        if *a == 0.0 {
            *a = 1.0; // all-zero channel: any scale reproduces 0 exactly
        }
    }
    let mut b0 = vec![0.0f32; t];
    for (i, b) in b0.iter_mut().enumerate() {
        let mut m = 0.0f32;
        for (j, a) in a0.iter().enumerate() {
            m = m.max(absx.at(i, j) / a);
        }
        *b = m;
    }
    let mut b = Matrix::zeros(t, rank);
    let mut a = Matrix::zeros(rank, d);
    for (i, &v) in b0.iter().enumerate() {
        b.set(i, 0, v);
    }
    for (j, &v) in a0.iter().enumerate() {
        a.set(0, j, v);
    }
    if rank == 1 {
        return (b, a);
    }

    // extra components: seed small copies of the envelope, then run
    // multiplicative NMF updates toward the element-wise manifold
    for p in 1..rank {
        for (i, &v) in b0.iter().enumerate() {
            b.set(i, p, 0.1 * v);
        }
        for (j, &v) in a0.iter().enumerate() {
            a.set(p, j, 0.1 * v);
        }
    }
    for _ in 0..NMF_ITERS {
        // B *= (X Aᵀ) ⊘ (B A Aᵀ)
        let s = matmul(&b, &a);
        let num = matmul_transb(absx, &a);
        let den = matmul_transb(&s, &a);
        for (bv, (nv, dv)) in b.data.iter_mut().zip(num.data.iter().zip(&den.data)) {
            *bv *= nv / dv.max(1e-12);
        }
        // A *= (Bᵀ X) ⊘ (Bᵀ B A)
        let s = matmul(&b, &a);
        let num = matmul_at_b(&b, absx);
        let den = matmul_at_b(&b, &s);
        for (av, (nv, dv)) in a.data.iter_mut().zip(num.data.iter().zip(&den.data)) {
            *av *= nv / dv.max(1e-12);
        }
    }
    // fold the per-row envelope guarantee back into B: no element of a
    // row may exceed its reconstructed scale
    let s = matmul(&b, &a);
    for i in 0..t {
        let mut gamma = 0.0f32;
        for j in 0..d {
            gamma = gamma.max(absx.at(i, j) / s.at(i, j).max(1e-12));
        }
        let gamma = gamma.max(1e-12);
        for p in 0..rank {
            *b.at_mut(i, p) *= gamma;
        }
    }
    (b, a)
}

/// One sealed, quantized KV tile: bit-packed codes + rank-r scale factors.
#[derive(Clone, Debug)]
pub struct PackedTile {
    pub codes: PackedCodes,
    /// T×r token factors.
    pub b: Matrix,
    /// r×D channel factors.
    pub a: Matrix,
}

impl PackedTile {
    /// Quantize a dense tile with rank-r factors fit at seal time.
    pub fn quantize(x: &Matrix, rank: usize, cb: &Codebook) -> PackedTile {
        let absx = x.map(f32::abs);
        let (b, a) = fit_scale_factors(&absx, rank);
        let s = matmul(&b, &a);
        let bits = PackedCodes::bits_needed(cb.len());
        let mut flat = vec![0u8; x.rows * x.cols];
        for i in 0..x.rows {
            for j in 0..x.cols {
                flat[i * x.cols + j] = cb.quantize_one(x.at(i, j), s.at(i, j)) as u8;
            }
        }
        PackedTile { codes: PackedCodes::from_flat(bits, x.rows, x.cols, &flat), b, a }
    }

    /// Dequantize row `i` into `out` (scratch `crow` must hold ≥ cols
    /// codes): `out[j] = lut[Q_ij] · Σ_p B_ip A_pj`. The scale row is
    /// reconstructed directly into `out`, then multiplied by the looked-up
    /// level — no separate scale buffer.
    #[inline]
    pub fn dequant_row_into(&self, i: usize, lut: &[f32], crow: &mut [u8], out: &mut [f32]) {
        let d = self.codes.cols();
        debug_assert!(crow.len() >= d && out.len() >= d);
        for o in out[..d].iter_mut() {
            *o = 0.0;
        }
        for p in 0..self.b.cols {
            let bip = self.b.at(i, p);
            if bip == 0.0 {
                continue;
            }
            for (o, &av) in out[..d].iter_mut().zip(self.a.row(p)) {
                *o += bip * av;
            }
        }
        self.codes.unpack_row_into(i, crow);
        for (o, &c) in out[..d].iter_mut().zip(crow[..d].iter()) {
            *o *= lut[c as usize];
        }
    }

    /// Bytes of packed codes + fp32 factor side-cars.
    pub fn mem_bytes(&self) -> usize {
        self.codes.mem_bytes() + 4 * (self.b.len() + self.a.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    /// LLM-activation-like tile: Gaussian bulk + a few hot channels.
    fn activation_tile(rng: &mut crate::util::Rng, t: usize, d: usize) -> Matrix {
        let mut x = Matrix::randn(t, d, 0.5, rng);
        let hot = rng.choose(d, (d / 8).max(1));
        for &c in &hot {
            for i in 0..t {
                *x.at_mut(i, c) *= 6.0;
            }
        }
        x
    }

    #[test]
    fn rank1_envelope_never_clips() {
        prop_check(32, |g| {
            let t = g.usize(1..=24);
            let d = g.usize(1..=32);
            let mut rng = g.rng().fork(1);
            let x = activation_tile(&mut rng, t, d);
            let absx = x.map(f32::abs);
            let (b, a) = fit_scale_factors(&absx, 1);
            let s = matmul(&b, &a);
            for i in 0..t {
                for j in 0..d {
                    if s.at(i, j) + 1e-6 < absx.at(i, j) {
                        return Err(format!(
                            "clipped at ({i},{j}): s {} < |x| {}",
                            s.at(i, j),
                            absx.at(i, j)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rank2_keeps_row_envelope_and_improves_fit() {
        let mut rng = crate::util::Rng::new(2);
        let x = activation_tile(&mut rng, 16, 32);
        let absx = x.map(f32::abs);
        let (b1, a1) = fit_scale_factors(&absx, 1);
        let (b2, a2) = fit_scale_factors(&absx, 2);
        let s2 = matmul(&b2, &a2);
        for i in 0..16 {
            for j in 0..32 {
                assert!(s2.at(i, j) + 1e-4 >= absx.at(i, j), "rank-2 clipped ({i},{j})");
            }
        }
        // rank 2 stays in the same fit regime as the rank-1 envelope (the
        // per-row gamma fold can trade a little Frobenius for clip-freedom)
        let e1 = matmul(&b1, &a1).sub(&absx).frob_norm();
        let e2 = s2.sub(&absx).frob_norm();
        assert!(e2 <= e1 * 2.0, "rank-2 fit degenerated: {e2} vs rank-1 {e1}");
    }

    #[test]
    fn int8_tile_roundtrip_error_bounded() {
        let cb = Codebook::int(8);
        let mut rng = crate::util::Rng::new(3);
        for rank in [1usize, 2] {
            let x = activation_tile(&mut rng, 16, 24);
            let tile = PackedTile::quantize(&x, rank, &cb);
            let mut crow = vec![0u8; 24];
            let mut row = vec![0.0f32; 24];
            let lut = &cb.levels;
            let mut max_err = 0.0f32;
            for i in 0..16 {
                tile.dequant_row_into(i, lut, &mut crow, &mut row);
                for (j, &v) in row.iter().enumerate() {
                    assert!(v.is_finite());
                    max_err = max_err.max((v - x.at(i, j)).abs());
                }
            }
            // int8 + clip-free scales: error ≤ 3% of the tile absmax
            assert!(max_err <= 0.03 * x.abs_max(), "rank {rank}: err {max_err}");
        }
    }

    #[test]
    fn int4_tile_degrades_gracefully() {
        let cb = Codebook::int(4);
        let mut rng = crate::util::Rng::new(4);
        let x = activation_tile(&mut rng, 16, 24);
        let tile = PackedTile::quantize(&x, 1, &cb);
        let mut crow = vec![0u8; 24];
        let mut row = vec![0.0f32; 24];
        let mut max_err = 0.0f32;
        for i in 0..16 {
            tile.dequant_row_into(i, &cb.levels, &mut crow, &mut row);
            for (j, &v) in row.iter().enumerate() {
                assert!(v.is_finite(), "non-finite dequant at ({i},{j})");
                max_err = max_err.max((v - x.at(i, j)).abs());
            }
        }
        assert!(max_err <= 0.35 * x.abs_max(), "int4 err {max_err} unbounded");
    }

    #[test]
    fn zero_tile_is_exact() {
        let cb = Codebook::int(8);
        let x = Matrix::zeros(8, 8);
        let tile = PackedTile::quantize(&x, 2, &cb);
        let mut crow = vec![0u8; 8];
        let mut row = vec![0.0f32; 8];
        for i in 0..8 {
            tile.dequant_row_into(i, &cb.levels, &mut crow, &mut row);
            assert!(row.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn packed_tile_bytes_beat_dense() {
        let cb = Codebook::int(4);
        let mut rng = crate::util::Rng::new(5);
        let x = activation_tile(&mut rng, 16, 256);
        let tile = PackedTile::quantize(&x, 2, &cb);
        let dense = 4 * 16 * 256;
        assert!(
            (dense as f64) / (tile.mem_bytes() as f64) >= 3.5,
            "4-bit tile {} B vs dense {} B",
            tile.mem_bytes(),
            dense
        );
    }
}
