//! Quantized paged KV-cache — the serving-memory counterpart of the
//! paper's weight story: element-wise quantization made cheap by modeling
//! the scale manifold as continuous low-rank factors, applied to the KV
//! cache instead of the weights.
//!
//! Serving memory is dominated by per-sequence K/V tensors, not weights:
//! a dense f32 cache costs `2 · L · S · D · 4` bytes per sequence. This
//! module stores K and V as **fixed-token blocks** of bit-packed codes
//! ([`PackedCodes`](crate::kernels::PackedCodes), 4 or 8 bits) with the
//! per-block token×channel scale tile held as **rank-r factors**
//! `S ≈ B·A` (B ∈ R^{T×r}, A ∈ R^{r×D}, r = 1–2) — the LoRDS decomposition
//! over the activation-scale manifold rather than the weight-scale one.
//!
//! * [`scales`]    — the streaming low-rank scale fit: a rank-1 positive
//!   envelope (per-token × per-channel absmax outer product, clip-free by
//!   construction) plus an optional NMF refinement for r = 2, and the
//!   tile quantize/dequantize helpers.
//! * [`pool`]      — [`KvPool`]: the block-pooled store. Owns real storage
//!   behind the [`KvBlockAllocator`](crate::coordinator::kvcache::KvBlockAllocator)'s
//!   admission bookkeeping; sequences append rows into a small dense
//!   staging tail and every full block is sealed (quantized + packed)
//!   exactly once, at append time.
//! * [`attention`] — fused attention over the pool: `q·K̂ᵀ` and
//!   `softmax·V̂` walk the packed blocks row by row, reconstructing the
//!   rank-r scale row and dequantizing into one D-float scratch row —
//!   the full dequantized K/V is never materialized.
//! * [`prefix`]    — [`PrefixCache`]: a trie over prompt token blocks that
//!   pins sealed blocks so sessions sharing a system prompt fork its
//!   quantized KV instead of re-prefilling it (ref-counted, LRU-evicted,
//!   copy-on-write protected in the pool).
//!
//! The serving coordinator wires this end-to-end: `NativeEngine` holds a
//! [`KvPool`] instead of dense per-sequence caches, `ServeCfg`/CLI expose
//! a `kv_bits` knob (f32 | 8 | 4), and `Server::new` sizes the pool from a
//! byte budget, so a fixed memory budget admits ~2.6× (8-bit) to ~3.9×
//! (4-bit) more concurrent sequences than dense f32.

pub mod attention;
pub mod pool;
pub mod prefix;
pub mod scales;

pub use pool::{KvPool, KvSeqView};
pub use prefix::PrefixCache;
pub use scales::fit_scale_factors;

use crate::quant::Codebook;

/// KV-cache storage precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvBits {
    /// Dense f32 blocks (the baseline; numerically identical to the old
    /// per-sequence contiguous cache).
    F32,
    /// 8-bit symmetric integer codes + rank-r scale factors per block.
    Int8,
    /// 4-bit symmetric integer codes + rank-r scale factors per block.
    Int4,
}

impl KvBits {
    /// Parse the `kv_bits` config knob (32 | 8 | 4).
    pub fn parse(bits: u32) -> Option<KvBits> {
        match bits {
            32 => Some(KvBits::F32),
            8 => Some(KvBits::Int8),
            4 => Some(KvBits::Int4),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> u32 {
        match self {
            KvBits::F32 => 32,
            KvBits::Int8 => 8,
            KvBits::Int4 => 4,
        }
    }

    /// Codebook for the packed formats (`None` for f32).
    pub fn codebook(&self) -> Option<Codebook> {
        match self {
            KvBits::F32 => None,
            KvBits::Int8 => Some(Codebook::int(8)),
            KvBits::Int4 => Some(Codebook::int(4)),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KvBits::F32 => "f32",
            KvBits::Int8 => "int8",
            KvBits::Int4 => "int4",
        }
    }
}

/// KV-cache quantization configuration (per engine).
#[derive(Clone, Copy, Debug)]
pub struct KvQuantCfg {
    pub bits: KvBits,
    /// Rank of the per-block scale factors (1–2; 1 = the clip-free
    /// envelope, 2 adds an NMF refinement component).
    pub rank: usize,
    /// Tokens per block (the paging granularity shared with the
    /// allocator).
    pub block_tokens: usize,
}

impl Default for KvQuantCfg {
    fn default() -> Self {
        KvQuantCfg { bits: KvBits::F32, rank: 1, block_tokens: 16 }
    }
}

impl KvQuantCfg {
    pub fn with_bits(bits: KvBits) -> KvQuantCfg {
        KvQuantCfg { bits, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_parse_roundtrip() {
        for bits in [32u32, 8, 4] {
            assert_eq!(KvBits::parse(bits).unwrap().as_u32(), bits);
        }
        assert_eq!(KvBits::parse(16), None);
        assert_eq!(KvBits::F32.codebook(), None);
        assert_eq!(KvBits::Int8.codebook().unwrap().len(), 255);
        assert_eq!(KvBits::Int4.codebook().unwrap().len(), 15);
    }
}
