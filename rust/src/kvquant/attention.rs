//! Fused attention over the block-pooled KV store: `q·K̂ᵀ` and
//! `softmax·V̂` computed directly against packed codes.
//!
//! Both kernels walk a [`KvSeqView`] row by row. A packed row is
//! dequantized into a single D-float scratch buffer (rank-r scale row
//! reconstruction + LUT multiply — [`PackedTile::dequant_row_into`]
//! (super::scales::PackedTile::dequant_row_into)); dense/tail rows are
//! plain copies. Peak live dequantized state is **one row**, versus the
//! full `len × D` K and V of the dense path — the same never-materialize
//! discipline as the weight kernels in [`kernels::fused`](crate::kernels).
//!
//! Numerics: per key row, the head-sliced dot products, softmax, and
//! weighted-V accumulation happen in the same order as the dense
//! reference ([`model::attention`](crate::model::attention)), so in f32
//! mode the pooled path is bit-identical to the old contiguous cache.
//! Both paths share the [`kernels::dot`](crate::kernels::dot)
//! 4-accumulator microkernel, so attention scores vectorize exactly like
//! the fused weight GEMMs.
//!
//! Batched decode ([`decode_packed_batch`]) dispatches the per-sequence
//! score/weighted-V sweeps as work items on the global
//! [`ThreadPool`](crate::util::ThreadPool): each worker walks its
//! sequences' packed blocks once (one dequant sweep per block row serves
//! every head attending it) with a single reusable [`AttnScratch`] —
//! per-row results are identical to the serial [`decode_packed`].

use super::pool::KvSeqView;
use crate::kernels::dot;
use crate::tensor::Matrix;
use crate::util::{SharedMut, ThreadPool};

/// Reusable scratch for the decode attention sweep: the packed-row
/// dequant buffers and per-head score vector `decode_packed` used to
/// allocate on every call. [`decode_packed_batch`] keeps one per worker
/// thread (persistent across layers, groups, and ticks); the serial
/// [`decode_packed`] reference wrapper still allocates per call.
#[derive(Debug, Default)]
pub struct AttnScratch {
    crow: Vec<u8>,
    row: Vec<f32>,
    scores: Vec<f32>,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }
}

/// Decode-step attention: one query row (1×D, post-RoPE) over the first
/// `view.len` cached positions. Mirrors
/// [`attention_decode`](crate::model::attention::attention_decode) with the
/// cache read through the pool.
pub fn decode_packed(q: &Matrix, view: &KvSeqView, n_heads: usize) -> Matrix {
    let mut out = Matrix::zeros(1, q.cols);
    decode_packed_into(q.row(0), view, n_heads, &mut AttnScratch::new(), out.row_mut(0));
    out
}

/// [`decode_packed`] on slices: query row `q` (len D) → `out[..D]`
/// (zeroed then accumulated), with all working storage borrowed from a
/// caller-owned [`AttnScratch`] — the decode hot loop's allocation-free
/// entry point.
pub fn decode_packed_into(
    q: &[f32],
    view: &KvSeqView,
    n_heads: usize,
    s: &mut AttnScratch,
    out: &mut [f32],
) {
    let d = q.len();
    assert_eq!(d, view.d, "query width {} vs cache {}", d, view.d);
    assert!(out.len() >= d, "out width {} < {d}", out.len());
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let len = view.len;
    out[..d].fill(0.0);
    s.crow.resize(d, 0);
    s.row.resize(d, 0.0);
    s.scores.resize(n_heads * len, 0.0);
    for j in 0..len {
        view.k_row_into(j, &mut s.crow, &mut s.row);
        for h in 0..n_heads {
            let base = h * hd;
            let qh = &q[base..base + hd];
            s.scores[h * len + j] = dot(qh, &s.row[base..base + hd]) * scale;
        }
    }
    for h in 0..n_heads {
        softmax_inplace(&mut s.scores[h * len..(h + 1) * len]);
    }
    for j in 0..len {
        view.v_row_into(j, &mut s.crow, &mut s.row);
        for h in 0..n_heads {
            let w = s.scores[h * len + j];
            let base = h * hd;
            let oh = &mut out[base..base + hd];
            for (o, &vv) in oh.iter_mut().zip(&s.row[base..base + hd]) {
                *o += w * vv;
            }
        }
    }
}

thread_local! {
    /// Each pool worker's attention scratch. Workers are long-lived
    /// threads, so the buffers persist across layers, groups, and ticks —
    /// steady-state batched decode performs no attention-scratch
    /// allocation at all.
    static ATTN_SCRATCH: std::cell::RefCell<AttnScratch> = const {
        std::cell::RefCell::new(AttnScratch {
            crow: Vec::new(),
            row: Vec::new(),
            scores: Vec::new(),
        })
    };
}

/// One serving tick's decode attention for a whole batch: row `i` of `q`
/// attends sequence `views[i]` over its own pooled blocks, writing row
/// `i` of `out`. Sequences are independent, so the per-(sequence, head)
/// sweeps are dispatched across the global thread pool — each worker owns
/// a disjoint range of output rows and its thread's persistent
/// [`AttnScratch`]. Row-for-row identical to calling [`decode_packed`]
/// per sequence.
pub fn decode_packed_batch(q: &Matrix, views: &[KvSeqView], n_heads: usize, out: &mut Matrix) {
    let _span = crate::obs::span!("attn.pooled", views.len());
    let b = views.len();
    let d = q.cols;
    assert_eq!(q.rows, b, "query rows {} vs sequences {b}", q.rows);
    assert_eq!(out.shape(), (b, d), "out shape {:?} vs ({b}, {d})", out.shape());
    let op = SharedMut(out.data.as_mut_ptr());
    let opr = &op;
    ThreadPool::global().parallel_for(b, move |lo, hi| {
        ATTN_SCRATCH.with(|s| {
            let scratch = &mut s.borrow_mut();
            for i in lo..hi {
                // SAFETY: rows [lo, hi) of `out` are owned by this worker —
                // chunks partition the batch, so row `i` is carved exactly
                // once; `out` outlives the parallel_for join.
                let orow = unsafe { std::slice::from_raw_parts_mut(opr.0.add(i * d), d) };
                decode_packed_into(q.row(i), &views[i], n_heads, scratch, orow);
            }
        });
    });
}

/// Causal prefill attention: every query row `i` of `q` (S×D, post-RoPE)
/// attends positions `0..=i` of the pool window (`view.len` must equal
/// `q.rows`). Two sweeps over the cache — scores, then weighted V — each
/// dequantizing every packed row exactly once.
pub fn prefill_packed(q: &Matrix, view: &KvSeqView, n_heads: usize) -> Matrix {
    prefill_packed_at(q, view, n_heads, 0)
}

/// Chunked causal prefill attention: query row `i` of `q` sits at
/// absolute position `pos0 + i` and attends cache positions
/// `0..=pos0 + i` (`view.len` must equal `pos0 + q.rows`). With
/// `pos0 = 0` this is exactly [`prefill_packed`] — same sweeps, same
/// per-row op order — which is what keeps chunked prefill bitwise
/// identical to whole prefill: each row's score sweep, softmax window,
/// and weighted-V accumulation depend only on its absolute position,
/// never on which chunk carried it.
pub fn prefill_packed_at(q: &Matrix, view: &KvSeqView, n_heads: usize, pos0: usize) -> Matrix {
    let n = q.rows;
    let d = q.cols;
    let len = view.len;
    assert_eq!(pos0 + n, len, "prefill window {len} vs chunk {pos0}+{n}");
    assert_eq!(d, view.d, "query width {} vs cache {}", d, view.d);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(n, d);
    let mut crow = vec![0u8; d];
    let mut row = vec![0.0f32; d];
    let mut probs: Vec<Matrix> = (0..n_heads).map(|_| Matrix::zeros(n, len)).collect();
    for j in 0..len {
        view.k_row_into(j, &mut crow, &mut row);
        for (h, p) in probs.iter_mut().enumerate() {
            let base = h * hd;
            let kh = &row[base..base + hd];
            // causal: rows whose absolute position pos0 + i ≥ j
            for i in j.saturating_sub(pos0)..n {
                let qh = &q.row(i)[base..base + hd];
                p.set(i, j, dot(qh, kh) * scale);
            }
        }
    }
    for p in probs.iter_mut() {
        for i in 0..n {
            softmax_inplace(&mut p.row_mut(i)[..=pos0 + i]);
        }
    }
    for j in 0..len {
        view.v_row_into(j, &mut crow, &mut row);
        for (h, p) in probs.iter().enumerate() {
            let base = h * hd;
            let vh = &row[base..base + hd];
            for i in j.saturating_sub(pos0)..n {
                let w = p.at(i, j);
                if w == 0.0 {
                    continue;
                }
                let oh = &mut out.row_mut(i)[base..base + hd];
                for (o, &vv) in oh.iter_mut().zip(vh) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

#[inline]
fn softmax_inplace(s: &mut [f32]) {
    let maxv = s.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut denom = 0.0f32;
    for v in s.iter_mut() {
        *v = (*v - maxv).exp();
        denom += *v;
    }
    let inv = 1.0 / denom;
    for v in s.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvquant::{KvBits, KvPool, KvQuantCfg};
    use crate::model::attention::{attention_decode, attention_fwd};
    use crate::util::prop::{assert_allclose, max_abs_diff, prop_check};
    use crate::util::Rng;

    fn filled_pool(bits: KvBits, bt: usize, d: usize, len: usize, seed: u64) -> KvPool {
        let cfg = KvQuantCfg { bits, rank: 1, block_tokens: bt };
        let mut pool = KvPool::new(cfg, 1, d, len.div_ceil(bt) + 1);
        let mut rng = Rng::new(seed);
        let k = Matrix::randn(len, d, 0.5, &mut rng);
        let v = Matrix::randn(len, d, 0.5, &mut rng);
        pool.append_rows(1, 0, 0, &k, &v).unwrap();
        pool.commit(1, len);
        pool
    }

    #[test]
    fn decode_matches_dense_reference_over_dequantized_cache() {
        prop_check(16, |g| {
            let bt = *g.pick(&[4usize, 8]);
            let d = g.usize(1..=4) * 8;
            let len = g.usize(1..=3 * bt);
            let bits = *g.pick(&[KvBits::F32, KvBits::Int8, KvBits::Int4]);
            let heads = *g.pick(&[2usize, 4]);
            let mut rng = g.rng().fork(5);
            let pool = filled_pool(bits, bt, d, len, rng.next_u64());
            let q = Matrix::randn(1, d, 1.0, &mut rng);
            let fused = decode_packed(&q, &pool.view(1, 0, len), heads);
            let (dk, dv) = pool.dense_kv(1, 0, len);
            let want = attention_decode(&q, &dk, &dv, len, heads);
            let diff = max_abs_diff(&fused.data, &want.data);
            if diff > 1e-5 {
                return Err(format!("{bits:?} bt={bt} d={d} len={len}: diff {diff}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prefill_matches_dense_reference_and_is_causal() {
        prop_check(12, |g| {
            let bt = *g.pick(&[4usize, 8]);
            let d = g.usize(1..=3) * 8;
            let s = g.usize(1..=2 * bt + 3);
            let bits = *g.pick(&[KvBits::F32, KvBits::Int8]);
            let mut rng = g.rng().fork(7);
            let pool = filled_pool(bits, bt, d, s, rng.next_u64());
            let q = Matrix::randn(s, d, 1.0, &mut rng);
            let fused = prefill_packed(&q, &pool.view(1, 0, s), 2);
            let (dk, dv) = pool.dense_kv(1, 0, s);
            let (want, _) = attention_fwd(&q, &dk, &dv, 2);
            let diff = max_abs_diff(&fused.data, &want.data);
            if diff > 1e-5 {
                return Err(format!("{bits:?} bt={bt} d={d} s={s}: diff {diff}"));
            }
            Ok(())
        });
    }

    #[test]
    fn batch_decode_is_row_identical_to_serial() {
        // mixed lengths and bit-widths in one "tick": every row of the
        // parallel batch must equal its serial decode_packed result bitwise
        prop_check(8, |g| {
            let d = g.usize(1..=3) * 8;
            let heads = *g.pick(&[2usize, 4]);
            let b = g.usize(1..=6);
            let mut rng = g.rng().fork(17);
            let mut pools = Vec::new();
            let mut lens = Vec::new();
            for _ in 0..b {
                let bits = *g.pick(&[KvBits::F32, KvBits::Int8, KvBits::Int4]);
                let len = g.usize(1..=11);
                pools.push(filled_pool(bits, 4, d, len, rng.next_u64()));
                lens.push(len);
            }
            let q = Matrix::randn(b, d, 1.0, &mut rng);
            let views: Vec<_> =
                pools.iter().zip(&lens).map(|(p, &l)| p.view(1, 0, l)).collect();
            let mut out = Matrix::from_fn(b, d, |i, j| (i + j) as f32); // dirty
            decode_packed_batch(&q, &views, heads, &mut out);
            for i in 0..b {
                let want =
                    decode_packed(&q.slice(i, i + 1, 0, d), &views[i], heads);
                if out.row(i) != want.row(0) {
                    return Err(format!("row {i} (len {}) differs", lens[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f32_pool_decode_is_exact_vs_contiguous_cache() {
        // dense mode: the pooled path must agree with the old contiguous
        // cache to float-exactness (same data, same op order)
        let (bt, d, len) = (4usize, 16usize, 11usize);
        let pool = filled_pool(KvBits::F32, bt, d, len, 9);
        let (dk, dv) = pool.dense_kv(1, 0, len);
        let mut rng = Rng::new(10);
        let q = Matrix::randn(1, d, 1.0, &mut rng);
        let fused = decode_packed(&q, &pool.view(1, 0, len), 2);
        let want = attention_decode(&q, &dk, &dv, len, 2);
        assert_allclose(&fused.data, &want.data, 0.0, 1e-7, "f32 pooled decode");
    }
}
