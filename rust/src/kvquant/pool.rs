//! [`KvPool`] — block-pooled KV storage with admission bookkeeping.
//!
//! The pool marries the [`KvBlockAllocator`]'s admission/ownership
//! invariants (never over capacity, no double-free, ref-counted sharing)
//! to real storage: every allocator block id indexes `2 · n_layers` tile
//! slots (K and V per layer). Sequences append rows into a small dense
//! staging tail (`block_tokens × D` per layer); when a layer's tail
//! fills, that layer's K and V tiles are **sealed** — quantized with
//! rank-r scale factors and bit-packed ([`PackedTile`]) — into the
//! sequence's next owned block, exactly once. In f32 mode sealing is a
//! plain copy, making the dense pool numerically identical to the old
//! contiguous per-sequence cache.
//!
//! Sealed blocks can be **shared**: [`Self::fork_at_block`] lets a new
//! sequence adopt another sequence's sealed prompt blocks as its own
//! prefix (block-aligned, refcount +1 each, zero new storage), and the
//! prefix cache pins blocks past their owners' lifetime with
//! [`Self::retain_block`]/[`Self::release_block`]. Sharing is safe
//! because sealed tiles are immutable — the only writer-side hazard is a
//! seal landing in a shared block (possible only when a fork point is not
//! block-aligned), and [`Self::stage_row`] handles it with copy-on-write:
//! the sealing sequence swaps in a fresh private block and the original
//! stays intact for its remaining owners.
//!
//! Reads go through [`KvSeqView`], a per-(sequence, layer) window that
//! the fused attention kernels ([`super::attention`]) walk row by row —
//! dequantizing each row into one scratch buffer, never materializing
//! the full K/V.
//!
//! Two observe-only quality hooks ride along (both off unless installed,
//! and neither touches the data path):
//!
//! * **Seal error** — when a [`crate::obs::quality::KvSealObs`] sink is
//!   installed ([`Self::set_seal_obs`]), every packed seal also dequantizes
//!   the tile it just produced and records the round-trip error. The seal
//!   path is the one place dense rows and packed codes coexist, so this is
//!   the only extra dequant the telemetry ever costs.
//! * **Block heat** — each block carries an atomic last-access tick and
//!   access count, bumped by [`Self::view`] for the sealed blocks it
//!   exposes. [`Self::block_coldness`] turns that into the
//!   ticks-since-last-read signal a future precision-demotion policy (and
//!   today's coldness histogram) consumes.

use super::scales::PackedTile;
use super::{KvBits, KvQuantCfg};
use crate::coordinator::kvcache::KvBlockAllocator;
use crate::kernels::PackedCodes;
use crate::obs::quality::KvSealObs;
use crate::quant::Codebook;
use crate::tensor::Matrix;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// One sealed tile: dense copy (f32 mode) or packed codes + factors.
#[derive(Clone, Debug)]
enum Tile {
    Dense(Matrix),
    Packed(PackedTile),
}

/// Per-block access telemetry, updated from the `&self` read path.
#[derive(Debug, Default)]
struct HeatCell {
    /// Heat-clock value when the block was last exposed by a view.
    last_access: AtomicU64,
    /// Views that exposed this block since it was (re)allocated.
    accesses: AtomicU64,
}

/// Per-sequence state: committed length + the dense staging tail.
#[derive(Clone, Debug)]
struct SeqKv {
    /// Tokens fully appended across all layers.
    len: usize,
    /// Per-layer staging for the open tail block (block_tokens × D).
    tail_k: Vec<Matrix>,
    tail_v: Vec<Matrix>,
}

/// Block-pooled, optionally quantized KV store (see the module doc).
#[derive(Debug)]
pub struct KvPool {
    cfg: KvQuantCfg,
    n_layers: usize,
    d_model: usize,
    codebook: Option<Codebook>,
    alloc: KvBlockAllocator,
    /// `capacity · n_layers · 2` tile slots; `slot(b, l, kv)` indexes them.
    slots: Vec<Option<Tile>>,
    seqs: HashMap<u64, SeqKv>,
    /// High-water mark of [`Self::used_bytes`] (sealed blocks + staging).
    peak_bytes: usize,
    /// Byte budget this pool was sized from ([`Self::with_byte_budget`]);
    /// admission keeps reserved blocks + staging tails within it. `None`
    /// for capacity-sized pools.
    budget_bytes: Option<usize>,
    /// Seal-time quality sink (see the module doc); `None` = no recording.
    seal_obs: Option<KvSealObs>,
    /// One heat cell per block, indexed by block id.
    heat: Vec<HeatCell>,
    /// Logical read clock, advanced once per tick by the engine
    /// ([`Self::begin_heat_tick`]).
    heat_clock: AtomicU64,
}

impl KvPool {
    /// Pool with an explicit block capacity.
    pub fn new(cfg: KvQuantCfg, n_layers: usize, d_model: usize, capacity_blocks: usize) -> KvPool {
        assert!(cfg.block_tokens > 0 && n_layers > 0 && d_model > 0);
        let codebook = cfg.bits.codebook();
        KvPool {
            cfg,
            n_layers,
            d_model,
            codebook,
            alloc: KvBlockAllocator::new(capacity_blocks, cfg.block_tokens),
            slots: (0..capacity_blocks * n_layers * 2).map(|_| None).collect(),
            seqs: HashMap::new(),
            peak_bytes: 0,
            budget_bytes: None,
            seal_obs: None,
            heat: (0..capacity_blocks).map(|_| HeatCell::default()).collect(),
            heat_clock: AtomicU64::new(0),
        }
    }

    /// Pool sized from a byte budget. A worst-case sequence costs its
    /// sealed blocks **plus one dense staging tail**
    /// ([`Self::staging_bytes`]); capacity is the block count of as many
    /// such sequences as the budget holds, clamped so at least one fits.
    pub fn with_byte_budget(
        cfg: KvQuantCfg,
        n_layers: usize,
        d_model: usize,
        budget_bytes: usize,
        max_seq: usize,
    ) -> KvPool {
        let probe = KvPool::new(cfg, n_layers, d_model, 0);
        let per_seq_blocks = probe.blocks_for(max_seq);
        let per_seq_bytes = per_seq_blocks * probe.block_bytes() + probe.staging_bytes();
        let capacity = ((budget_bytes / per_seq_bytes) * per_seq_blocks).max(per_seq_blocks);
        let mut pool = KvPool::new(cfg, n_layers, d_model, capacity);
        // remember the budget so length-based admission also prices the
        // dense staging tail every admitted sequence holds — block
        // capacity alone would let many short sequences overshoot it
        pool.budget_bytes = Some(budget_bytes.max(per_seq_bytes));
        pool
    }

    pub fn cfg(&self) -> &KvQuantCfg {
        &self.cfg
    }

    /// Install (or clear) the seal-time quality sink. Recording only ever
    /// happens on packed seals — f32 pools never pay for it.
    pub fn set_seal_obs(&mut self, obs: Option<KvSealObs>) {
        self.seal_obs = obs;
    }

    /// Detach the seal sink, returning it (the sentinel uses this to keep
    /// its shadow decode from double-recording seal errors).
    pub fn take_seal_obs(&mut self) -> Option<KvSealObs> {
        self.seal_obs.take()
    }

    /// Advance the logical read clock — called once per decode tick, so
    /// block coldness is measured in ticks.
    pub fn begin_heat_tick(&self) {
        self.heat_clock.fetch_add(1, Ordering::Relaxed);
    }

    /// Ticks since each live, at-least-once-read block was last exposed by
    /// a view (blocks never read — e.g. a sequence's open tail block —
    /// are skipped: they have no read history to age).
    pub fn block_coldness(&self) -> Vec<u64> {
        let now = self.heat_clock.load(Ordering::Relaxed);
        (0..self.heat.len())
            .filter(|&b| self.alloc.refcount(b) > 0)
            .filter(|&b| self.heat[b].accesses.load(Ordering::Relaxed) > 0)
            .map(|b| now.saturating_sub(self.heat[b].last_access.load(Ordering::Relaxed)))
            .collect()
    }

    /// (last-access tick, access count) for one block — the raw heat
    /// signal a demotion policy would rank blocks by.
    pub fn block_heat(&self, block: usize) -> Option<(u64, u64)> {
        let cell = self.heat.get(block)?;
        Some((
            cell.last_access.load(Ordering::Relaxed),
            cell.accesses.load(Ordering::Relaxed),
        ))
    }

    pub fn block_tokens(&self) -> usize {
        self.cfg.block_tokens
    }

    /// Bytes of sealed storage per block (codes + factor side-cars for the
    /// packed formats, plain f32 for dense), across K and V of all layers.
    /// Pure arithmetic — mirrors the `PackedCodes` word-aligned row layout.
    pub fn block_bytes(&self) -> usize {
        let (bt, d) = (self.cfg.block_tokens, self.d_model);
        let per_tile = match self.cfg.bits.codebook() {
            None => 4 * bt * d,
            Some(cb) => {
                let cpw = PackedCodes::codes_per_word(PackedCodes::bits_needed(cb.len()));
                4 * bt * d.div_ceil(cpw) + 4 * (bt * self.cfg.rank + self.cfg.rank * d)
            }
        };
        2 * self.n_layers * per_tile
    }

    /// Bytes per block if this pool stored dense f32 (the budget yardstick).
    pub fn dense_block_bytes(&self) -> usize {
        2 * self.n_layers * 4 * self.cfg.block_tokens * self.d_model
    }

    /// Dense f32 staging bytes every active sequence holds for its open
    /// tail block (one dense block's worth, regardless of `kv_bits`).
    pub fn staging_bytes(&self) -> usize {
        self.dense_block_bytes()
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.alloc.blocks_for(tokens)
    }

    pub fn capacity_blocks(&self) -> usize {
        self.alloc.free_blocks() + self.alloc.used_blocks()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Bytes currently held: reserved sealed blocks + every active
    /// sequence's dense staging tail.
    pub fn used_bytes(&self) -> usize {
        self.alloc.used_blocks() * self.block_bytes() + self.seqs.len() * self.staging_bytes()
    }

    /// High-water mark of [`Self::used_bytes`] over the pool's lifetime.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    fn touch_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.used_bytes());
    }

    /// How many full `max_seq`-token sequences this pool can hold at once
    /// (block capacity; staging is already priced into
    /// [`Self::with_byte_budget`] sizing).
    pub fn max_concurrent_full_seqs(&self, max_seq: usize) -> usize {
        self.capacity_blocks() / self.blocks_for(max_seq).max(1)
    }

    /// Can `n` more sequences of this worst-case length be admitted?
    pub fn can_admit_n(&self, n: usize, worst_case_tokens: usize) -> bool {
        n * self.blocks_for(worst_case_tokens) <= self.alloc.free_blocks()
    }

    /// Can sequences with these individual worst-case token counts all be
    /// admitted? This is the KV-aware admission path: each entry is one
    /// request's actual footprint (prompt + capped `max_new`), so short
    /// requests pack many more sequences into the same blocks than
    /// `max_seq`-worst-case accounting would. Byte-budgeted pools also
    /// charge one dense staging tail per sequence (resident regardless of
    /// `kv_bits`), so admission never commits more bytes than the budget.
    pub fn can_admit_lengths(&self, lens: &[usize]) -> bool {
        let blocks: usize = lens.iter().map(|&t| self.blocks_for(t)).sum();
        if blocks > self.alloc.free_blocks() {
            return false;
        }
        match self.budget_bytes {
            None => true,
            Some(budget) => {
                (self.alloc.used_blocks() + blocks) * self.block_bytes()
                    + (self.seqs.len() + lens.len()) * self.staging_bytes()
                    <= budget
            }
        }
    }

    /// Can sequences with these worst-case token counts be admitted if up
    /// to `reclaimable` currently-used blocks (e.g. prefix-cache blocks no
    /// live sequence references) could be evicted first? Same accounting
    /// as [`Self::can_admit_lengths`], but block capacity and the byte
    /// budget both credit the evictable blocks. The caller is responsible
    /// for actually evicting before reserving.
    pub fn can_admit_lengths_reclaimable(&self, lens: &[usize], reclaimable: usize) -> bool {
        let reclaimable = reclaimable.min(self.alloc.used_blocks());
        let blocks: usize = lens.iter().map(|&t| self.blocks_for(t)).sum();
        if blocks > self.alloc.free_blocks() + reclaimable {
            return false;
        }
        match self.budget_bytes {
            None => true,
            Some(budget) => {
                // evict only as much as the block shortfall demands
                let evicted = blocks.saturating_sub(self.alloc.free_blocks());
                (self.alloc.used_blocks() - evicted + blocks) * self.block_bytes()
                    + (self.seqs.len() + lens.len()) * self.staging_bytes()
                    <= budget
            }
        }
    }

    /// Committed token count for a sequence (`None` if unknown).
    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|s| s.len)
    }

    /// The block id backing position `pos` of `seq`'s reservation (`None`
    /// when unreserved). Callers that hand ids to the prefix cache must
    /// only pass sealed positions.
    pub fn block_id_at(&self, seq: u64, pos: usize) -> Option<usize> {
        self.alloc.owned_blocks(seq).get(pos / self.cfg.block_tokens).copied()
    }

    /// Reference count of a block (0 = free).
    pub fn block_refcount(&self, block: usize) -> usize {
        self.alloc.refcount(block)
    }

    /// Take an extra non-sequence reference on a live sealed block (prefix
    /// cache pin). Returns false for free blocks.
    pub fn retain_block(&mut self, block: usize) -> bool {
        self.alloc.retain(block)
    }

    /// Drop one non-sequence reference; clears the block's tile slots when
    /// that was the last reference. Returns true iff the block was freed.
    pub fn release_block(&mut self, block: usize) -> bool {
        if self.alloc.release_ref(block) {
            self.clear_block_slots(block);
            true
        } else {
            false
        }
    }

    /// Fork: make brand-new sequence `seq` start life owning `shared`
    /// sealed blocks as its first `tokens` committed tokens (refcount +1
    /// each; zero new storage). `tokens` must equal
    /// `shared.len() · block_tokens` — forks happen at block boundaries —
    /// and every shared block must hold sealed K/V tiles for all layers.
    /// Returns false (no change) on violation. The fork's private life
    /// continues with ordinary [`Self::reserve`]/[`Self::append_rows`]
    /// from position `tokens`.
    pub fn fork_at_block(&mut self, seq: u64, shared: &[usize], tokens: usize) -> bool {
        if tokens != shared.len() * self.cfg.block_tokens || self.seqs.contains_key(&seq) {
            return false;
        }
        for &b in shared {
            for layer in 0..self.n_layers {
                if self.slots[self.slot_idx(b, layer, 0)].is_none()
                    || self.slots[self.slot_idx(b, layer, 1)].is_none()
                {
                    return false;
                }
            }
        }
        if !self.alloc.attach(seq, shared) {
            return false;
        }
        self.ensure_seq(seq).len = tokens;
        self.touch_peak();
        true
    }

    fn ensure_seq(&mut self, seq: u64) -> &mut SeqKv {
        let (bt, d, l) = (self.cfg.block_tokens, self.d_model, self.n_layers);
        self.seqs.entry(seq).or_insert_with(|| SeqKv {
            len: 0,
            tail_k: (0..l).map(|_| Matrix::zeros(bt, d)).collect(),
            tail_v: (0..l).map(|_| Matrix::zeros(bt, d)).collect(),
        })
    }

    /// Reserve blocks so the sequence can grow to `tokens` total tokens
    /// (idempotent growth, like the underlying allocator). Returns false —
    /// and changes nothing — when the pool cannot satisfy it.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> bool {
        self.ensure_seq(seq);
        let ok = self.alloc.reserve(seq, tokens);
        self.touch_peak();
        ok
    }

    #[inline]
    fn slot_idx(&self, block_id: usize, layer: usize, kv: usize) -> usize {
        (block_id * self.n_layers + layer) * 2 + kv
    }

    /// Append `k.rows` consecutive positions starting at `pos0` for one
    /// layer (k and v are rows×D, k post-RoPE). Rows land in the staging
    /// tail; each position that completes a block seals that layer's K/V
    /// tiles into the sequence's next owned block. Fails — without writing
    /// anything — when the pool cannot back the required blocks.
    pub fn append_rows(
        &mut self,
        seq: u64,
        layer: usize,
        pos0: usize,
        k: &Matrix,
        v: &Matrix,
    ) -> anyhow::Result<()> {
        let d = self.d_model;
        assert_eq!(k.shape(), v.shape(), "K/V shape mismatch");
        assert_eq!(k.cols, d, "row width {} != d_model {d}", k.cols);
        assert!(layer < self.n_layers, "layer {layer} out of range");
        self.ensure_seq(seq);
        if let Some(kind) = crate::fault::point!("kv.alloc") {
            crate::fault::apply_fallible("kv.alloc", kind)?;
        }
        anyhow::ensure!(
            self.alloc.reserve(seq, pos0 + k.rows),
            "KV pool exhausted: seq {seq} needs {} blocks, {} free",
            self.alloc.blocks_for(pos0 + k.rows),
            self.alloc.free_blocks()
        );
        self.touch_peak();
        for r in 0..k.rows {
            self.stage_row(seq, layer, pos0 + r, k.row(r), v.row(r))?;
        }
        Ok(())
    }

    /// Append one position for one layer from D-slices (k post-RoPE) —
    /// the batched decode tick's entry point: no 1×D `Matrix` wrapper per
    /// token per layer. Same semantics as a one-row [`Self::append_rows`].
    pub fn append_row(
        &mut self,
        seq: u64,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> anyhow::Result<()> {
        let d = self.d_model;
        assert_eq!(k_row.len(), d, "K row width {} != d_model {d}", k_row.len());
        assert_eq!(v_row.len(), d, "V row width {} != d_model {d}", v_row.len());
        assert!(layer < self.n_layers, "layer {layer} out of range");
        self.ensure_seq(seq);
        if let Some(kind) = crate::fault::point!("kv.alloc") {
            crate::fault::apply_fallible("kv.alloc", kind)?;
        }
        anyhow::ensure!(
            self.alloc.reserve(seq, pos + 1),
            "KV pool exhausted: seq {seq} needs {} blocks, {} free",
            self.alloc.blocks_for(pos + 1),
            self.alloc.free_blocks()
        );
        self.touch_peak();
        self.stage_row(seq, layer, pos, k_row, v_row)
    }

    /// Copy one position into the staging tail; seal the layer's K/V tiles
    /// into the owning block when the position completes it. Storage for
    /// `pos` must already be reserved. If the seal would land in a block
    /// other owners still reference (a non-block-aligned fork wrote into
    /// its shared tail block), copy-on-write swaps in a fresh private
    /// block first — the only fallible path (pool exhausted mid-COW).
    fn stage_row(
        &mut self,
        seq: u64,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> anyhow::Result<()> {
        let bt = self.cfg.block_tokens;
        let ti = pos % bt;
        {
            let sk = self
                .seqs
                .get_mut(&seq)
                .ok_or_else(|| anyhow::anyhow!("KV staging write for unknown sequence {seq}"))?;
            sk.tail_k[layer].row_mut(ti).copy_from_slice(k_row);
            sk.tail_v[layer].row_mut(ti).copy_from_slice(v_row);
        }
        if ti + 1 == bt {
            if let Some(kind) = crate::fault::point!("kv.seal") {
                crate::fault::apply_fallible("kv.seal", kind)?;
            }
            let bi = pos / bt;
            let mut block_id = self.alloc.owned_blocks(seq)[bi];
            if self.alloc.refcount(block_id) > 1 {
                block_id = self.alloc.cow_swap(seq, bi).ok_or_else(|| {
                    anyhow::anyhow!(
                        "KV pool exhausted during copy-on-write seal: seq {seq} block {bi}"
                    )
                })?;
            }
            let (tile_k, tile_v) = {
                let sk = self
                    .seqs
                    .get(&seq)
                    .ok_or_else(|| anyhow::anyhow!("KV seal for unknown sequence {seq}"))?;
                (
                    self.seal_tile(&sk.tail_k[layer]),
                    self.seal_tile(&sk.tail_v[layer]),
                )
            };
            let ik = self.slot_idx(block_id, layer, 0);
            let iv = self.slot_idx(block_id, layer, 1);
            self.slots[ik] = Some(tile_k);
            self.slots[iv] = Some(tile_v);
        }
        Ok(())
    }

    fn seal_tile(&self, tail: &Matrix) -> Tile {
        let _span = crate::obs::span!("kv.seal", tail.rows);
        match &self.codebook {
            None => Tile::Dense(tail.clone()),
            Some(cb) => {
                let tile = PackedTile::quantize(tail, self.cfg.rank, cb);
                if let Some(obs) = &self.seal_obs {
                    obs.record(tail, &tile, &cb.levels);
                }
                Tile::Packed(tile)
            }
        }
    }

    /// Mark `len` tokens as fully appended (all layers written).
    pub fn commit(&mut self, seq: u64, len: usize) {
        if let Some(sk) = self.seqs.get_mut(&seq) {
            sk.len = len;
        }
    }

    /// Read window over one (sequence, layer): sealed tiles + the staging
    /// tail, covering positions `0..len`.
    pub fn view(&self, seq: u64, layer: usize, len: usize) -> KvSeqView<'_> {
        // PANIC-OK: caller contract — views are only taken over sequences
        // the caller itself appended (the engine's decode path); the asserts
        // below guard the same contract for lengths.
        let sk = self.seqs.get(&seq).unwrap_or_else(|| panic!("unknown KV sequence {seq}"));
        let bt = self.cfg.block_tokens;
        let sealed = len / bt;
        let owned = self.alloc.owned_blocks(seq);
        assert!(
            sealed <= owned.len(),
            "view of {len} tokens needs {sealed} sealed blocks, seq owns {}",
            owned.len()
        );
        let mut k_tiles = Vec::with_capacity(sealed);
        let mut v_tiles = Vec::with_capacity(sealed);
        let now = self.heat_clock.load(Ordering::Relaxed);
        for bi in 0..sealed {
            let ik = self.slot_idx(owned[bi], layer, 0);
            let iv = self.slot_idx(owned[bi], layer, 1);
            k_tiles.push(self.slots[ik].as_ref().expect("sealed block has storage")); // PANIC-OK: blocks 0..sealed were sealed by stage_row
            v_tiles.push(self.slots[iv].as_ref().expect("sealed block has storage")); // PANIC-OK: seal writes both K and V tiles together
            let cell = &self.heat[owned[bi]];
            cell.last_access.store(now, Ordering::Relaxed);
            cell.accesses.fetch_add(1, Ordering::Relaxed);
        }
        KvSeqView {
            len,
            d: self.d_model,
            block_tokens: bt,
            lut: self.codebook.as_ref().map(|cb| cb.levels.as_slice()).unwrap_or(&[]),
            k_tiles,
            v_tiles,
            tail_k: &sk.tail_k[layer],
            tail_v: &sk.tail_v[layer],
        }
    }

    /// Dequantized dense K/V for `0..len` of one layer — the reference the
    /// parity tests compare the fused kernels against (and a debugging aid;
    /// the serving path never calls this).
    pub fn dense_kv(&self, seq: u64, layer: usize, len: usize) -> (Matrix, Matrix) {
        let view = self.view(seq, layer, len);
        let mut k = Matrix::zeros(len, self.d_model);
        let mut v = Matrix::zeros(len, self.d_model);
        let mut crow = vec![0u8; self.d_model];
        for j in 0..len {
            view.k_row_into(j, &mut crow, k.row_mut(j));
            view.v_row_into(j, &mut crow, v.row_mut(j));
        }
        (k, v)
    }

    fn clear_block_slots(&mut self, block: usize) {
        for layer in 0..self.n_layers {
            let ik = self.slot_idx(block, layer, 0);
            let iv = self.slot_idx(block, layer, 1);
            self.slots[ik] = None;
            self.slots[iv] = None;
        }
        // Freed storage carries no read history into its next owner.
        self.heat[block].last_access.store(0, Ordering::Relaxed);
        self.heat[block].accesses.store(0, Ordering::Relaxed);
    }

    /// Free a sequence's blocks and staging. Only blocks whose last
    /// reference dropped have their storage cleared — shared prefix blocks
    /// live on under their remaining owners or prefix-cache pins. Returns
    /// false for unknown sequences (recoverable — the server path must
    /// never panic on a stray release).
    pub fn release(&mut self, seq: u64) -> bool {
        if let Some(kind) = crate::fault::point!("kv.release") {
            // Releasing storage must never fail (that would leak the
            // blocks) — only the added-latency kind is honored here.
            if kind == crate::fault::FaultKind::Latency {
                crate::fault::latency_spin();
            }
        }
        let known = self.seqs.remove(&seq).is_some();
        if let Some(freed) = self.alloc.try_release(seq) {
            for b in freed {
                self.clear_block_slots(b);
            }
            true
        } else {
            known
        }
    }
}

/// Read-only window over one (sequence, layer) of a [`KvPool`].
pub struct KvSeqView<'p> {
    pub len: usize,
    pub d: usize,
    pub block_tokens: usize,
    /// Codebook levels (empty in f32 mode).
    pub lut: &'p [f32],
    k_tiles: Vec<&'p Tile>,
    v_tiles: Vec<&'p Tile>,
    tail_k: &'p Matrix,
    tail_v: &'p Matrix,
}

impl KvSeqView<'_> {
    #[inline]
    fn row_into(&self, tiles: &[&Tile], tail: &Matrix, j: usize, crow: &mut [u8], out: &mut [f32]) {
        debug_assert!(j < self.len);
        let bt = self.block_tokens;
        let sealed_tokens = tiles.len() * bt;
        if j >= sealed_tokens {
            out[..self.d].copy_from_slice(&tail.row(j - sealed_tokens)[..self.d]);
            return;
        }
        match tiles[j / bt] {
            Tile::Dense(m) => out[..self.d].copy_from_slice(m.row(j % bt)),
            Tile::Packed(t) => t.dequant_row_into(j % bt, self.lut, crow, out),
        }
    }

    /// Key row `j` (dequantized when packed) into `out[..d]`.
    #[inline]
    pub fn k_row_into(&self, j: usize, crow: &mut [u8], out: &mut [f32]) {
        self.row_into(&self.k_tiles, self.tail_k, j, crow, out);
    }

    /// Value row `j` (dequantized when packed) into `out[..d]`.
    #[inline]
    pub fn v_row_into(&self, j: usize, crow: &mut [u8], out: &mut [f32]) {
        self.row_into(&self.v_tiles, self.tail_v, j, crow, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(bits: KvBits, bt: usize) -> KvQuantCfg {
        KvQuantCfg { bits, rank: 1, block_tokens: bt }
    }

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        Matrix::randn(n, d, 0.5, rng)
    }

    #[test]
    fn dense_pool_roundtrips_exactly() {
        let mut pool = KvPool::new(cfg(KvBits::F32, 4), 2, 8, 16);
        let mut rng = Rng::new(0);
        let k = rows(&mut rng, 11, 8); // 2 sealed blocks + 3-row tail
        let v = rows(&mut rng, 11, 8);
        for layer in 0..2 {
            pool.append_rows(7, layer, 0, &k, &v).unwrap();
        }
        pool.commit(7, 11);
        for layer in 0..2 {
            let (dk, dv) = pool.dense_kv(7, layer, 11);
            assert_eq!(dk.data, k.data, "layer {layer} K");
            assert_eq!(dv.data, v.data, "layer {layer} V");
        }
        assert_eq!(pool.used_blocks(), 3);
        assert!(pool.release(7));
        assert_eq!(pool.used_blocks(), 0);
        assert!(!pool.release(7), "double release is recoverable");
    }

    #[test]
    fn packed_pool_bounded_error_and_bytes() {
        for bits in [KvBits::Int8, KvBits::Int4] {
            let mut pool = KvPool::new(cfg(bits, 8), 1, 16, 8);
            let mut rng = Rng::new(1);
            let k = rows(&mut rng, 20, 16);
            let v = rows(&mut rng, 20, 16);
            pool.append_rows(1, 0, 0, &k, &v).unwrap();
            pool.commit(1, 20);
            let (dk, dv) = pool.dense_kv(1, 0, 20);
            let tol = match bits {
                KvBits::Int8 => 0.03,
                _ => 0.35,
            } * k.abs_max().max(v.abs_max());
            for (a, b) in dk.data.iter().zip(&k.data) {
                assert!(a.is_finite() && (a - b).abs() <= tol, "{bits:?}: {a} vs {b}");
            }
            // tail rows (16..20) are still dense — exact
            for j in 16..20 {
                assert_eq!(dk.row(j), k.row(j));
                assert_eq!(dv.row(j), v.row(j));
            }
            assert!(pool.block_bytes() < pool.dense_block_bytes());
        }
    }

    #[test]
    fn append_row_matches_append_rows() {
        let mut a = KvPool::new(cfg(KvBits::Int8, 4), 2, 8, 8);
        let mut b = KvPool::new(cfg(KvBits::Int8, 4), 2, 8, 8);
        let mut rng = Rng::new(5);
        let k = rows(&mut rng, 10, 8);
        let v = rows(&mut rng, 10, 8);
        for layer in 0..2 {
            a.append_rows(1, layer, 0, &k, &v).unwrap();
            for r in 0..10 {
                b.append_row(1, layer, r, k.row(r), v.row(r)).unwrap();
            }
        }
        a.commit(1, 10);
        b.commit(1, 10);
        for layer in 0..2 {
            let (ak, av) = a.dense_kv(1, layer, 10);
            let (bk, bv) = b.dense_kv(1, layer, 10);
            assert_eq!(ak.data, bk.data, "layer {layer} K");
            assert_eq!(av.data, bv.data, "layer {layer} V");
        }
        assert_eq!(a.used_blocks(), b.used_blocks());
    }

    #[test]
    fn exhaustion_is_a_clean_error() {
        let mut pool = KvPool::new(cfg(KvBits::F32, 4), 1, 4, 2); // 8 tokens max
        let mut rng = Rng::new(2);
        let k = rows(&mut rng, 12, 4);
        let v = rows(&mut rng, 12, 4);
        assert!(pool.append_rows(1, 0, 0, &k, &v).is_err());
        // failed append reserved nothing beyond what fit — nothing sealed
        assert!(pool.can_admit_n(1, 8));
        let k8 = k.slice(0, 8, 0, 4);
        let v8 = v.slice(0, 8, 0, 4);
        pool.append_rows(1, 0, 0, &k8, &v8).unwrap();
        pool.commit(1, 8);
        assert!(!pool.can_admit_n(1, 1));
    }

    #[test]
    fn budget_sizing_scales_with_bits() {
        let budget = 4 << 20; // 4 MiB
        let dense = KvPool::with_byte_budget(cfg(KvBits::F32, 16), 4, 256, budget, 256);
        let int4 = KvPool::with_byte_budget(cfg(KvBits::Int4, 16), 4, 256, budget, 256);
        let ratio =
            int4.max_concurrent_full_seqs(256) as f64 / dense.max_concurrent_full_seqs(256) as f64;
        assert!(ratio >= 2.0, "4-bit concurrency gain {ratio} < 2x");
    }

    #[test]
    fn peak_tracks_high_water_including_staging() {
        let mut pool = KvPool::new(cfg(KvBits::F32, 4), 1, 4, 8);
        assert!(pool.reserve(1, 16));
        assert!(pool.reserve(2, 16));
        let peak = pool.peak_bytes();
        assert_eq!(peak, 8 * pool.block_bytes() + 2 * pool.staging_bytes());
        pool.release(1);
        pool.release(2);
        assert_eq!(pool.peak_bytes(), peak, "peak survives release");
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn budgeted_admission_charges_staging_tails() {
        // budget = exactly one worst-case sequence: 3 blocks + 1 tail
        // (4 x 128 B with bt=4, 1 layer, d=4, max_seq=12)
        let pool = KvPool::with_byte_budget(cfg(KvBits::F32, 4), 1, 4, 512, 12);
        assert_eq!(pool.capacity_blocks(), 3);
        // one worst-case sequence: exactly the budget
        assert!(pool.can_admit_lengths(&[12]));
        // two short sequences: 2 blocks + 2 tails = the budget
        assert!(pool.can_admit_lengths(&[4, 4]));
        // three short sequences fit the blocks but their tails overshoot
        // the byte budget — admission must refuse
        assert!(!pool.can_admit_lengths(&[4, 4, 4]));
        // capacity-sized pools (no budget) admit by blocks alone
        let unbudgeted = KvPool::new(cfg(KvBits::F32, 4), 1, 4, 3);
        assert!(unbudgeted.can_admit_lengths(&[4, 4, 4]));
    }

    #[test]
    fn fork_shares_sealed_prefix_without_new_storage() {
        let mut pool = KvPool::new(cfg(KvBits::Int8, 4), 2, 8, 8);
        let mut rng = Rng::new(11);
        let k = rows(&mut rng, 8, 8);
        let v = rows(&mut rng, 8, 8);
        for layer in 0..2 {
            pool.append_rows(1, layer, 0, &k, &v).unwrap();
        }
        pool.commit(1, 8);
        assert_eq!(pool.used_blocks(), 2);
        let shared: Vec<usize> =
            (0..2).map(|bi| pool.block_id_at(1, bi * 4).unwrap()).collect();

        assert!(pool.fork_at_block(2, &shared, 8), "fork adopts sealed blocks");
        assert_eq!(pool.used_blocks(), 2, "fork allocates no new storage");
        assert_eq!(pool.seq_len(2), Some(8));
        for layer in 0..2 {
            let (k1, v1) = pool.dense_kv(1, layer, 8);
            let (k2, v2) = pool.dense_kv(2, layer, 8);
            assert_eq!(k1.data, k2.data, "layer {layer} K identical through the fork");
            assert_eq!(v1.data, v2.data, "layer {layer} V identical through the fork");
        }

        // the fork grows privately past the shared prefix
        let k2 = rows(&mut rng, 4, 8);
        let v2 = rows(&mut rng, 4, 8);
        for layer in 0..2 {
            pool.append_rows(2, layer, 8, &k2, &v2).unwrap();
        }
        pool.commit(2, 12);
        assert_eq!(pool.used_blocks(), 3, "only the private suffix block is new");

        // donor's release keeps the shared blocks alive for the fork
        assert!(pool.release(1));
        assert_eq!(pool.used_blocks(), 3);
        let (fk, _) = pool.dense_kv(2, 0, 12);
        assert_eq!(&fk.data[..8 * 8], &k.data[..], "shared prefix survives donor release");
        assert!(pool.release(2));
        assert_eq!(pool.used_blocks(), 0, "last owner frees everything");
    }

    #[test]
    fn retained_block_survives_all_owners_and_frees_on_release() {
        let mut pool = KvPool::new(cfg(KvBits::F32, 4), 1, 4, 4);
        let mut rng = Rng::new(3);
        let k = rows(&mut rng, 4, 4);
        let v = rows(&mut rng, 4, 4);
        pool.append_rows(1, 0, 0, &k, &v).unwrap();
        pool.commit(1, 4);
        let b = pool.block_id_at(1, 0).unwrap();
        assert!(pool.retain_block(b));
        assert!(pool.release(1));
        assert_eq!(pool.used_blocks(), 1, "prefix-cache pin keeps the block");
        // a fresh sequence can still fork from the pinned block
        assert!(pool.fork_at_block(9, &[b], 4));
        let (fk, _) = pool.dense_kv(9, 0, 4);
        assert_eq!(fk.data, k.data);
        assert!(pool.release(9));
        assert!(pool.release_block(b), "dropping the pin frees the block");
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn forked_writes_never_alias_mutate_after_fork_isolation() {
        // property: whatever a fork writes over shared positions, the
        // donor's sealed data stays bitwise intact (COW redirects the seal)
        crate::util::prop::prop_check(16, |g| {
            let bt = [2, 4, 8][g.usize(0..=2)];
            let blocks = g.usize(2..=4);
            let layers = g.usize(1..=2);
            let d = 4;
            let mut pool =
                KvPool::new(cfg(KvBits::F32, bt), layers, d, blocks + 4);
            let mut rng = Rng::new(g.usize(0..=10_000) as u64);
            let n = blocks * bt;
            let k = rows(&mut rng, n, d);
            let v = rows(&mut rng, n, d);
            for layer in 0..layers {
                pool.append_rows(1, layer, 0, &k, &v).unwrap();
            }
            pool.commit(1, n);
            let shared: Vec<usize> =
                (0..blocks).map(|bi| pool.block_id_at(1, bi * bt).unwrap()).collect();
            assert!(pool.fork_at_block(2, &shared, n));

            // fork rewrites a suffix of the shared region starting inside
            // block `from_block` — seals over shared blocks trigger COW
            let from_block = g.usize(0..=blocks - 1);
            let pos0 = from_block * bt;
            let fk = rows(&mut rng, n - pos0, d);
            let fv = rows(&mut rng, n - pos0, d);
            for layer in 0..layers {
                pool.append_rows(2, layer, pos0, &fk, &fv).unwrap();
            }
            pool.commit(2, n);

            for layer in 0..layers {
                let (dk, dv) = pool.dense_kv(1, layer, n);
                if dk.data != k.data || dv.data != v.data {
                    return Err(format!(
                        "donor data corrupted by forked writes (layer {layer}, bt {bt}, from block {from_block})"
                    ));
                }
                let (ck, _) = pool.dense_kv(2, layer, n);
                if ck.data[pos0 * d..] != fk.data[..] {
                    return Err("fork lost its own writes".into());
                }
                if ck.data[..pos0 * d] != k.data[..pos0 * d] {
                    return Err("fork lost the untouched shared prefix".into());
                }
            }
            pool.release(1);
            pool.release(2);
            if pool.used_blocks() != 0 {
                return Err(format!("leak: {} blocks after release", pool.used_blocks()));
            }
            Ok(())
        });
    }

    #[test]
    fn reclaimable_admission_credits_evictable_blocks() {
        let mut pool = KvPool::new(cfg(KvBits::F32, 4), 1, 4, 3);
        let mut rng = Rng::new(4);
        let k = rows(&mut rng, 8, 4);
        let v = rows(&mut rng, 8, 4);
        pool.append_rows(1, 0, 0, &k, &v).unwrap();
        pool.commit(1, 8);
        let pinned: Vec<usize> = (0..2).map(|bi| pool.block_id_at(1, bi * 4).unwrap()).collect();
        for &b in &pinned {
            pool.retain_block(b);
        }
        pool.release(1);
        // 2 of 3 blocks are cache-pinned; a 12-token sequence needs all 3
        assert!(!pool.can_admit_lengths(&[12]));
        assert!(pool.can_admit_lengths_reclaimable(&[12], 2));
        assert!(!pool.can_admit_lengths_reclaimable(&[16], 2), "beyond capacity stays refused");
        for &b in &pinned {
            pool.release_block(b);
        }
        assert!(pool.can_admit_lengths(&[12]));
    }

    #[test]
    fn budget_covers_blocks_plus_staging() {
        // with_byte_budget must price the staging tails in: the worst-case
        // resident bytes of `max_concurrent_full_seqs` sequences never
        // exceed the budget
        let budget = 4 << 20;
        for bits in [KvBits::F32, KvBits::Int8, KvBits::Int4] {
            let pool = KvPool::with_byte_budget(cfg(bits, 16), 4, 256, budget, 256);
            let seqs = pool.max_concurrent_full_seqs(256);
            let worst =
                seqs * (pool.blocks_for(256) * pool.block_bytes() + pool.staging_bytes());
            assert!(worst <= budget, "{bits:?}: worst {worst} B > budget {budget} B");
        }
    }
}
