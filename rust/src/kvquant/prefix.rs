//! [`PrefixCache`] — shared-prefix KV reuse over the block pool.
//!
//! A trie over **prompt token blocks**: each node covers one
//! `block_tokens`-sized slice of some previously-served prompt and pins
//! the sealed [`KvPool`] block holding that slice's quantized K/V (one
//! [`KvPool::retain_block`] reference per node). Edges are keyed by a
//! rolling hash chained from the adapter id through each token block —
//! K/V depend on the projection weights, so the same text under two
//! adapters caches separately — with the actual tokens stored on the
//! node and verified on every walk (a hash collision degrades to a miss,
//! never to wrong KV).
//!
//! Serving flow: at admission the engine [`Self::lookup`]s the prompt and
//! [`KvPool::fork_at_block`]s the matched blocks into the new sequence —
//! N sessions over one system prompt store and prefill its KV exactly
//! once, each paying only its private suffix. As a sequence's chunked
//! prefill seals full prompt blocks, [`Self::publish`] adds them to the
//! trie. Lookups cap at the largest block multiple **strictly below** the
//! prompt length, so every admitted sequence prefills at least one token
//! and produces real last-position logits.
//!
//! Memory: cached blocks stay resident after their sequences finish
//! (refcount ≥ 1 from the trie). They are *evictable* — admission counts
//! blocks whose only reference is the trie as reclaimable, and
//! [`Self::evict`] releases least-recently-used leaves (cascading upward)
//! until enough blocks are free. Evicting a node whose block a live
//! sequence still shares merely drops the trie's pin; the block itself is
//! freed by whichever reference drops last.

use super::pool::KvPool;
use std::collections::HashMap;

const ROOT: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    parent: usize,
    /// Chained hash up to and including this block (the child-map key).
    hash: u64,
    /// The exact tokens this block covers — verified on every walk.
    tokens: Vec<usize>,
    /// Pinned pool block holding the sealed K/V.
    block: usize,
    children: usize,
    last_used: u64,
}

/// Prefix trie of sealed, ref-counted KV blocks (see the module doc).
#[derive(Debug, Default)]
pub struct PrefixCache {
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    children: HashMap<(usize, u64), usize>,
    clock: u64,
    enabled: bool,
    /// Lookups that matched at least one block / none.
    pub hits: usize,
    pub misses: usize,
    /// Total prompt tokens served from the cache across all lookups.
    pub hit_tokens: usize,
}

impl PrefixCache {
    pub fn new() -> PrefixCache {
        PrefixCache { enabled: true, ..Default::default() }
    }

    /// Disabled cache: lookups miss, publishes are dropped. The serve
    /// bench's no-sharing baseline.
    pub fn disabled() -> PrefixCache {
        PrefixCache::default()
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Live trie nodes == pool blocks the cache holds a reference on.
    pub fn cached_blocks(&self) -> usize {
        self.nodes.len() - self.free_nodes.len()
    }

    fn seed_hash(adapter: &str) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
        for b in adapter.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn chain_hash(mut h: u64, tokens: &[usize]) -> u64 {
        for &t in tokens {
            for b in (t as u64).to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Largest shareable token count for a prompt: whole blocks only, and
    /// strictly less than the prompt (at least one token must be privately
    /// prefilled so the sequence computes genuine last-position logits).
    pub fn max_shareable(prompt_len: usize, block_tokens: usize) -> usize {
        (prompt_len.saturating_sub(1) / block_tokens) * block_tokens
    }

    /// Walk the trie for this (adapter, prompt): returns the pool block
    /// ids of the longest cached prefix (possibly empty), in token order,
    /// touching each matched node's LRU stamp. The result is capped at
    /// [`Self::max_shareable`] blocks.
    pub fn lookup(&mut self, adapter: &str, prompt: &[usize], block_tokens: usize) -> Vec<usize> {
        let mut out = Vec::new();
        if !self.enabled {
            return out;
        }
        if let Some(kind) = crate::fault::point!("prefix.claim") {
            // Claim is infallible by contract: an injected fault degrades
            // to a cache miss (counted), never an error.
            if crate::fault::degrades(kind) {
                self.misses += 1;
                return out;
            }
        }
        self.clock += 1;
        let max_blocks = Self::max_shareable(prompt.len(), block_tokens) / block_tokens;
        let mut parent = ROOT;
        let mut h = Self::seed_hash(adapter);
        for b in 0..max_blocks {
            let toks = &prompt[b * block_tokens..(b + 1) * block_tokens];
            h = Self::chain_hash(h, toks);
            match self.children.get(&(parent, h)) {
                Some(&ni) if self.nodes[ni].as_ref().is_some_and(|n| n.tokens == toks) => {
                    // PANIC-OK: the guard above just proved `nodes[ni]` is
                    // Some and nothing between can take it.
                    let n = self.nodes[ni].as_mut().expect("checked live");
                    n.last_used = self.clock;
                    out.push(n.block);
                    parent = ni;
                }
                _ => break,
            }
        }
        if out.is_empty() {
            self.misses += 1;
        } else {
            self.hits += 1;
            self.hit_tokens += out.len() * block_tokens;
        }
        out
    }

    /// Non-mutating [`Self::lookup`]: how many prompt tokens would be
    /// served from the cache. Admission uses this to charge a request only
    /// its unshared suffix without disturbing LRU order.
    pub fn probe(&self, adapter: &str, prompt: &[usize], block_tokens: usize) -> usize {
        if !self.enabled {
            return 0;
        }
        let max_blocks = Self::max_shareable(prompt.len(), block_tokens) / block_tokens;
        let mut parent = ROOT;
        let mut h = Self::seed_hash(adapter);
        let mut matched = 0;
        for b in 0..max_blocks {
            let toks = &prompt[b * block_tokens..(b + 1) * block_tokens];
            h = Self::chain_hash(h, toks);
            match self.children.get(&(parent, h)) {
                Some(&ni) if self.nodes[ni].as_ref().is_some_and(|n| n.tokens == toks) => {
                    matched += 1;
                    parent = ni;
                }
                _ => break,
            }
        }
        matched * block_tokens
    }

    /// Register `seq`'s first `upto_block` sealed prompt blocks in the
    /// trie (called as chunked prefill seals them). Existing nodes are
    /// kept (LRU-touched); missing ones pin the sequence's block via
    /// [`KvPool::retain_block`]. Stops early on a hash collision whose
    /// stored tokens disagree or if the sequence's block is unavailable.
    pub fn publish(
        &mut self,
        adapter: &str,
        prompt: &[usize],
        block_tokens: usize,
        upto_block: usize,
        pool: &mut KvPool,
        seq: u64,
    ) {
        if !self.enabled {
            return;
        }
        if let Some(kind) = crate::fault::point!("prefix.publish") {
            // Publish is best-effort by contract: an injected fault drops
            // this publish (future prompts just re-prefill those blocks).
            if crate::fault::degrades(kind) {
                return;
            }
        }
        self.clock += 1;
        let mut parent = ROOT;
        let mut h = Self::seed_hash(adapter);
        for b in 0..upto_block.min(prompt.len() / block_tokens) {
            let toks = &prompt[b * block_tokens..(b + 1) * block_tokens];
            h = Self::chain_hash(h, toks);
            parent = match self.children.get(&(parent, h)) {
                Some(&ni) => {
                    let Some(n) = self.nodes[ni].as_mut() else { return };
                    if n.tokens != toks {
                        return; // hash collision: leave the trie alone
                    }
                    n.last_used = self.clock;
                    ni
                }
                None => {
                    let Some(block) = pool.block_id_at(seq, b * block_tokens) else { return };
                    if !pool.retain_block(block) {
                        return;
                    }
                    let node = Node {
                        parent,
                        hash: h,
                        tokens: toks.to_vec(),
                        block,
                        children: 0,
                        last_used: self.clock,
                    };
                    let ni = match self.free_nodes.pop() {
                        Some(i) => {
                            self.nodes[i] = Some(node);
                            i
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    self.children.insert((parent, h), ni);
                    if parent != ROOT {
                        // PANIC-OK: trie invariant — a child edge only ever
                        // points at a live parent (remove_node unlinks edges
                        // before freeing the node).
                        self.nodes[parent].as_mut().expect("live parent").children += 1;
                    }
                    ni
                }
            };
        }
    }

    /// Blocks whose **only** remaining reference is this trie — what
    /// admission may count as reclaimable-by-eviction.
    pub fn evictable_blocks(&self, pool: &KvPool) -> usize {
        self.nodes
            .iter()
            .flatten()
            .filter(|n| pool.block_refcount(n.block) == 1)
            .count()
    }

    fn remove_node(&mut self, ni: usize, pool: &mut KvPool) -> bool {
        // PANIC-OK: callers (evict's leaf scan) only pass live node indices;
        // freed slots are parked in `free_nodes`, never revisited.
        let n = self.nodes[ni].take().expect("live node");
        self.children.remove(&(n.parent, n.hash));
        if n.parent != ROOT {
            // PANIC-OK: trie invariant — children are removed before (and
            // cascade into) their parents, so the parent is still live.
            self.nodes[n.parent].as_mut().expect("live parent").children -= 1;
        }
        self.free_nodes.push(ni);
        pool.release_block(n.block)
    }

    /// Evict least-recently-used leaves (cascading up emptied branches)
    /// until at least `want_freed` pool blocks came free or the trie is
    /// empty. Returns the number of blocks actually freed — nodes whose
    /// block a live sequence still shares only drop the trie's pin.
    pub fn evict(&mut self, pool: &mut KvPool, want_freed: usize) -> usize {
        let mut freed = 0;
        while freed < want_freed {
            let leaf = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(i, n)| n.as_ref().filter(|n| n.children == 0).map(|n| (i, n.last_used)))
                .min_by_key(|&(_, used)| used)
                .map(|(i, _)| i);
            match leaf {
                Some(ni) => {
                    if self.remove_node(ni, pool) {
                        freed += 1;
                    }
                }
                None => break,
            }
        }
        freed
    }

    /// Drop every cached block reference and empty the trie. Tests use
    /// this to prove the server leaks nothing beyond the cache itself.
    pub fn flush(&mut self, pool: &mut KvPool) {
        while self.cached_blocks() > 0 {
            self.evict(pool, usize::MAX);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvquant::{KvBits, KvQuantCfg};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn pool(bt: usize, capacity: usize) -> KvPool {
        KvPool::new(KvQuantCfg { bits: KvBits::Int8, rank: 1, block_tokens: bt }, 1, 8, capacity)
    }

    fn fill_seq(pool: &mut KvPool, seq: u64, len: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let k = Matrix::randn(len, 8, 0.5, &mut rng);
        let v = Matrix::randn(len, 8, 0.5, &mut rng);
        pool.append_rows(seq, 0, 0, &k, &v).unwrap();
        pool.commit(seq, len);
    }

    fn prompt(n: usize, seed: u64) -> Vec<usize> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(32)).collect()
    }

    #[test]
    fn publish_then_lookup_returns_shared_blocks_capped_below_prompt_len() {
        let mut p = pool(4, 8);
        let mut c = PrefixCache::new();
        let toks = prompt(12, 1); // 3 full blocks
        fill_seq(&mut p, 1, 12, 2);
        c.publish("base", &toks, 4, 3, &mut p, 1);
        assert_eq!(c.cached_blocks(), 3);

        // identical prompt: share everything except the last block
        // (12 tokens = 3 blocks, cap at (12-1)/4 = 2 blocks)
        let hit = c.lookup("base", &toks, 4);
        assert_eq!(hit.len(), 2);
        assert_eq!(hit[0], p.block_id_at(1, 0).unwrap());
        assert_eq!(c.probe("base", &toks, 4), 8);

        // longer prompt with the same prefix: all 3 published blocks match
        let mut longer = toks.clone();
        longer.extend(prompt(8, 3));
        assert_eq!(c.lookup("base", &longer, 4).len(), 3);

        // diverging after one block: only that block matches
        let mut div = toks.clone();
        div[5] = div[5].wrapping_add(1) % 32;
        assert_eq!(c.lookup("base", &div, 4).len(), 1);

        // same text, different adapter: no match (different K/V)
        assert!(c.lookup("lora0", &toks, 4).is_empty());
        assert_eq!(c.probe("lora0", &toks, 4), 0);
        assert!(c.hits >= 3 && c.misses == 1);
    }

    #[test]
    fn shared_blocks_survive_publisher_and_evict_in_lru_order() {
        let mut p = pool(4, 8);
        let mut c = PrefixCache::new();
        let a = prompt(12, 10);
        let b = prompt(12, 11);
        fill_seq(&mut p, 1, 8, 12);
        fill_seq(&mut p, 2, 8, 13);
        c.publish("base", &a, 4, 2, &mut p, 1);
        c.publish("base", &b, 4, 2, &mut p, 2);
        p.release(1);
        p.release(2);
        assert_eq!(p.used_blocks(), 4, "cache pins survive the publishers");
        assert_eq!(c.evictable_blocks(&p), 4);

        // touch both of `a`'s nodes so `b`'s chain is least recently used
        assert_eq!(c.lookup("base", &a, 4).len(), 2);
        let freed = c.evict(&mut p, 2);
        assert_eq!(freed, 2);
        assert_eq!(c.lookup("base", &b, 4).len(), 0, "b evicted");
        assert_eq!(c.lookup("base", &a, 4).len(), 2, "a survives");

        c.flush(&mut p);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(p.used_blocks(), 0, "flush releases every pin");
    }

    #[test]
    fn disabled_cache_never_matches_or_pins() {
        let mut p = pool(4, 4);
        let mut c = PrefixCache::disabled();
        let toks = prompt(8, 20);
        fill_seq(&mut p, 1, 8, 21);
        c.publish("base", &toks, 4, 2, &mut p, 1);
        assert_eq!(c.cached_blocks(), 0);
        assert!(c.lookup("base", &toks, 4).is_empty());
        p.release(1);
        assert_eq!(p.used_blocks(), 0);
    }
}
