//! `lords` — the command-line launcher for the LoRDS framework.
//!
//! Subcommands cover the whole lifecycle the paper unifies:
//! pre-train a testbed → PTQ-quantize (any method) → QAT recover →
//! PEFT adapt → serve through the coordinator (native or PJRT engine).

use lords::cli::{render_help, Args, Command};
use lords::config::{ModelCfg, QuantCfg, QuantMethod, ServeCfg, TomlDoc, TrainCfg};
use lords::coordinator::{NativeEngine, PjrtEngine, Request, Server};
use lords::data::corpus::{Corpus, CorpusKind};
use lords::data::TaskSuite;
use lords::report::methods::{quantize_model, CalibSet};
use lords::report::testbed::Testbed;
use lords::runtime::executor::Executor;
use lords::train::{NativeTrainer, TrainKind};
use lords::util::Rng;

const COMMANDS: &[Command] = &[
    Command { name: "pretrain", about: "pre-train the tiny-Llama testbed on the synthetic corpus" },
    Command { name: "quantize", about: "PTQ-quantize the testbed with --method and report PPL/acc" },
    Command { name: "qat", about: "quantization-aware training (LoRDS STE or INT4 baseline)" },
    Command { name: "peft", about: "PEFT fine-tune scaling factors (LoRDS) vs QLoRA adapters" },
    Command { name: "serve", about: "serve requests (--engine native|pjrt, --format lords|nf4|qlora, --kv-bits 32|8|4, --rate RPS for open-loop streaming, --temperature/--top-k/--sample-seed, --trace-out FILE for Chrome-trace spans, --metrics-out FILE for Prometheus text, --admin-addr HOST:PORT for the live admin endpoint with /healthz+/readyz probes, --sentinel-every N for the logit-drift sentinel, --fault 'site=kv.seal,p=0.01,kind=err,seed=7' to arm the fault-injection plane, --drain-ticks N for the graceful-drain budget)" },
    Command { name: "eval", about: "evaluate a checkpoint: perplexity + 7-task zero-shot suite" },
    Command { name: "rank-table", about: "print Appendix-A Table 7 (parity ranks, exact paper shapes)" },
    Command { name: "info", about: "environment + artifact manifest summary" },
];

fn main() {
    lords::util::logging::init();
    let args = Args::parse_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "quantize" => cmd_quantize(&args),
        "qat" => cmd_qat(&args),
        "peft" => cmd_peft(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "rank-table" => cmd_rank_table(),
        "info" => cmd_info(&args),
        _ => {
            print!("{}", render_help("lords", "LoRDS: unified LLM quantization + adaptation", COMMANDS));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn model_cfg(args: &Args) -> ModelCfg {
    match args.get("config") {
        Some(path) => match TomlDoc::load(path) {
            Ok(doc) => ModelCfg::from_doc(&doc),
            Err(e) => {
                eprintln!("config: {e}; using defaults");
                ModelCfg::default()
            }
        },
        None => ModelCfg::default(),
    }
}

fn cmd_pretrain(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let steps = args.get_usize("steps", 300);
    let seed = args.get_u64("seed", 0);
    let tb = Testbed::build(args.get_or("name", "llama3-mini"), &cfg, steps, seed);
    let ppl = lords::eval::perplexity(&tb.model, &tb.wiki, 64, 16);
    println!("pre-trained {} for {steps} steps; wiki PPL {}", tb.name, ppl.display());
    Ok(())
}

fn cmd_quantize(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let steps = args.get_usize("pretrain-steps", 300);
    let tb = Testbed::build(args.get_or("name", "llama3-mini"), &cfg, steps, args.get_u64("seed", 0));
    let method = QuantMethod::parse(args.get_or("method", "lords"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let qcfg = QuantCfg {
        method,
        block: args.get_usize("block", cfg.block),
        refine_steps: args.get_usize("refine-steps", 100),
        refine_lr: args.get_f32("refine-lr", 0.05),
        adapter_rank: args.get_usize("adapter-rank", 16),
        ..Default::default()
    };
    let dims: Vec<usize> = vec![cfg.d_model, cfg.d_ff];
    let calib = CalibSet::synthetic(&dims, 128, 7);
    let mut model = tb.model.clone();
    let (_, secs) = lords::util::stats::timed(|| quantize_model(&mut model, &qcfg, Some(&calib), 0));
    let ppl = lords::eval::perplexity(&model, &tb.wiki, 64, 16);
    let acc = lords::eval::evaluate_suite(&model, &tb.suite);
    println!(
        "{} (block {}): quantized in {secs:.1}s | wiki PPL {} | avg acc {:.2}% | float params {}",
        method.name(),
        qcfg.block,
        ppl.display(),
        acc.average,
        model.float_params()
    );
    Ok(())
}

fn cmd_qat(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let tb = Testbed::build(args.get_or("name", "llama3-mini"), &cfg, args.get_usize("pretrain-steps", 300), 0);
    let mut model = tb.model.clone();
    let cb = lords::quant::Codebook::by_name(&cfg.codebook).unwrap();
    let refine = lords::quant::lords::RefineCfg {
        steps: args.get_usize("refine-steps", 50),
        ..Default::default()
    };
    model.quantize_lords(cfg.block, &cb, refine, true);
    let before = lords::eval::perplexity(&model, &tb.wiki, 64, 8);
    let tcfg = TrainCfg {
        steps: args.get_usize("steps", 100),
        peak_lr: args.get_f32("lr", 2e-4),
        warmup_ratio: 0.3,
        ..Default::default()
    };
    let mut tr = NativeTrainer::new(tcfg, TrainKind::Qat);
    let log = tr.run(&mut model, &tb.wiki);
    let after = lords::eval::perplexity(&model, &tb.wiki, 64, 8);
    println!("QAT: PPL {} -> {} (final loss {:.3})", before.display(), after.display(), log.final_loss);
    Ok(())
}

fn cmd_peft(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let tb = Testbed::build(args.get_or("name", "llama3-mini"), &cfg, args.get_usize("pretrain-steps", 300), 0);
    // adaptation target: the higher-entropy corpus (distribution shift)
    let target = Corpus::generate(CorpusKind::Ptb, cfg.vocab, 50_000, 10_000, 99);
    let method = args.get_or("method", "lords");
    let mut model = tb.model.clone();
    let cb = lords::quant::Codebook::by_name(&cfg.codebook).unwrap();
    match method {
        "qlora" => model.quantize_qlora(cfg.block, 16, &cb, 0),
        _ => model.quantize_lords(
            cfg.block,
            &cb,
            lords::quant::lords::RefineCfg { steps: 50, ..Default::default() },
            false,
        ),
    }
    let before = lords::eval::perplexity(&model, &target, 64, 8);
    let tcfg = TrainCfg {
        steps: args.get_usize("steps", 150),
        peak_lr: args.get_f32("lr", 1e-3),
        ..Default::default()
    };
    let mut tr = NativeTrainer::new(tcfg, TrainKind::Peft);
    tr.run(&mut model, &target);
    let after = lords::eval::perplexity(&model, &target, 64, 8);
    println!(
        "PEFT/{method}: target PPL {} -> {} | #Train {} | #Float {}",
        before.display(),
        after.display(),
        model.train_params(),
        model.float_params()
    );
    Ok(())
}

/// Play the requests through the server — open-loop at `rate` req/s when
/// positive, otherwise the closed-loop trace — and print the metrics
/// (streaming percentiles included for open-loop runs).
fn drive_serve<E: lords::coordinator::Engine>(
    server: &mut Server<E>,
    reqs: Vec<Request>,
    rate: f64,
    seed: u64,
) -> anyhow::Result<()> {
    let report = if rate > 0.0 {
        lords::coordinator::run_open_loop(server, reqs, rate, seed)?
    } else {
        server.run_trace(reqs)?
    };
    report.metrics.print(&report.engine);
    if rate > 0.0 {
        report.metrics.print_streaming();
    }
    Ok(())
}

/// Export the run's observability artifacts: drained tracing spans as
/// Chrome-trace JSON (`--trace-out`, load in `chrome://tracing` or
/// Perfetto) and the server's cumulative registry in Prometheus text
/// exposition format (`--metrics-out`).
fn export_obs(
    registry: &lords::obs::Registry,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> anyhow::Result<()> {
    if let Some(path) = trace_out {
        lords::obs::trace::set_enabled(false);
        let spans = lords::obs::trace::drain();
        lords::obs::trace::write_chrome(path, &spans)?;
        println!("  trace: {} spans -> {path}", spans.len());
        for (name, count, total_ns) in lords::obs::trace::phase_totals(&spans) {
            println!("    span {name:<22} x{count:<6} total {:>9.3} ms", total_ns as f64 / 1e6);
        }
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, registry.render_prometheus())?;
        println!("  metrics: prometheus text -> {path}");
    }
    Ok(())
}

/// Start the live admin endpoint when `--admin-addr` (or the
/// `LORDS_ADMIN_ADDR` environment variable) is set. The returned guard
/// keeps the background listener alive for the duration of the run.
fn start_admin(
    args: &Args,
    registry: &std::sync::Arc<lords::obs::Registry>,
) -> anyhow::Result<Option<lords::obs::AdminServer>> {
    let addr = args
        .get("admin-addr")
        .map(str::to_string)
        .or_else(|| std::env::var("LORDS_ADMIN_ADDR").ok());
    let Some(addr) = addr else { return Ok(None) };
    let admin = lords::obs::AdminServer::bind(&addr, std::sync::Arc::clone(registry))?;
    println!("  admin endpoint: http://{}", admin.local_addr());
    Ok(Some(admin))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let serve_cfg = ServeCfg {
        kv_bits: args.get_usize("kv-bits", 32) as u32,
        kv_budget_mib: args.get_f32("kv-budget-mib", 0.0) as f64,
        rate_rps: args.get_f32("rate", 0.0) as f64,
        sentinel_every_n_ticks: args.get_usize("sentinel-every", 0),
        fault_spec: args.get_or("fault", "").to_string(),
        drain_timeout_ticks: args.get_usize("drain-ticks", ServeCfg::default().drain_timeout_ticks),
        ..ServeCfg::default()
    };
    let drain_ticks = serve_cfg.drain_timeout_ticks;
    let kv_bits = lords::kvquant::KvBits::parse(serve_cfg.kv_bits)
        .ok_or_else(|| anyhow::anyhow!("--kv-bits must be 32, 8, or 4"))?;
    let n_requests = args.get_usize("requests", 16);
    let max_new = args.get_usize("max-new", 32);
    let engine_kind = args.get_or("engine", "native");
    let format = args.get_or("format", "lords");
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::new(seed);
    // per-request sampling policy: greedy unless a temperature is given
    let sampling = lords::coordinator::SamplingParams {
        temperature: args.get_f32("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        seed: args.get_u64("sample-seed", 0),
    };
    let rate = serve_cfg.rate_rps;
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    if trace_out.is_some() {
        lords::obs::trace::set_enabled(true);
    }

    if engine_kind == "pjrt" {
        anyhow::ensure!(
            serve_cfg.kv_bits == 32,
            "--kv-bits applies to the native engine (pjrt slabs are dense f32)"
        );
        let dir = args.get_or("artifacts", "artifacts");
        let exec = Executor::spawn(dir)?;
        let manifest = lords::runtime::Manifest::load(dir).map_err(anyhow::Error::msg)?;
        let mcfg = manifest.model.clone();
        let tb = Testbed::build("llama3-mini", &mcfg, args.get_usize("pretrain-steps", 300), 0);
        let mut model = tb.model.clone();
        let cb = lords::quant::Codebook::from_levels(&manifest.lut_name, manifest.lut.clone());
        match format {
            "nf4" => model.quantize_blockwise(mcfg.block, &cb),
            "qlora" => model.quantize_qlora(mcfg.block, mcfg.qlora_rank, &cb, 0),
            _ => model.quantize_lords(
                mcfg.block,
                &cb,
                lords::quant::lords::RefineCfg { steps: 30, ..Default::default() },
                false,
            ),
        }
        let art = manifest.artifact(&format!("{format}_prefill_b1")).map_err(anyhow::Error::msg)?;
        let params = lords::runtime::bridge::collect_params(&model, &art.inputs);
        let engine = PjrtEngine::new(exec.handle(), &manifest, format, params)?;
        let prompt_len = engine.prefill_seq;
        let reqs: Vec<Request> = (0..n_requests)
            .map(|i| {
                Request::new(i as u64, (0..prompt_len).map(|_| rng.below(mcfg.vocab)).collect(), max_new)
                    .with_sampling(sampling.clone())
            })
            .collect();
        let mut server = Server::new(engine, serve_cfg)?;
        let admin = start_admin(args, &server.obs.registry)?;
        drive_serve(&mut server, reqs, rate, seed)?;
        // graceful shutdown: readiness goes false first (load balancers
        // stop sending), then the drain finishes in-flight work
        if let Some(a) = &admin {
            a.set_ready(false, "draining");
        }
        server.drain(drain_ticks)?;
        if let Some(a) = &admin {
            a.publish_flight(server.obs.flight.dump());
        }
        export_obs(&server.obs.registry, trace_out.as_deref(), metrics_out.as_deref())?;
    } else {
        let tb = Testbed::build("llama3-mini", &cfg, args.get_usize("pretrain-steps", 300), 0);
        let mut model = tb.model.clone();
        let cb = lords::quant::Codebook::by_name(&cfg.codebook).unwrap();
        match format {
            "nf4" => model.quantize_blockwise(cfg.block, &cb),
            "qlora" => model.quantize_qlora(cfg.block, cfg.qlora_rank, &cb, 0),
            "fp" => {}
            _ => model.quantize_lords(
                cfg.block,
                &cb,
                lords::quant::lords::RefineCfg { steps: 30, ..Default::default() },
                false,
            ),
        }
        let prompt_len = cfg.max_seq / 2;
        let reqs: Vec<Request> = (0..n_requests)
            .map(|i| {
                Request::new(i as u64, (0..prompt_len).map(|_| rng.below(cfg.vocab)).collect(), max_new)
                    .with_sampling(sampling.clone())
            })
            .collect();
        let kv = lords::kvquant::KvQuantCfg::with_bits(kv_bits);
        let engine = NativeEngine::with_kv(model, format, kv);
        let mut server = Server::new(engine, serve_cfg)?;
        // weight quant error vs the dense pre-quantization reference (the
        // engine's own install pass only sees QAT shadows, if any)
        lords::obs::quality::record_weight_errors(
            &server.obs.registry,
            "base",
            &tb.model,
            &server.engine.model,
        );
        let admin = start_admin(args, &server.obs.registry)?;
        drive_serve(&mut server, reqs, rate, seed)?;
        // graceful shutdown: readiness goes false first (load balancers
        // stop sending), then the drain finishes in-flight work and
        // leaves the KV pool and adapter registry empty
        if let Some(a) = &admin {
            a.set_ready(false, "draining");
        }
        server.drain(drain_ticks)?;
        if let Some(a) = &admin {
            a.publish_flight(server.obs.flight.dump());
        }
        println!(
            "  kv cache: {} blocks x {} B ({}; peak {:.2} MiB)",
            server.engine.kv_pool().capacity_blocks(),
            server.engine.kv_pool().block_bytes(),
            kv_bits.name(),
            server.engine.kv_pool().peak_bytes() as f64 / (1024.0 * 1024.0)
        );
        export_obs(&server.obs.registry, trace_out.as_deref(), metrics_out.as_deref())?;
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let cfg = model_cfg(args);
    let tb = Testbed::build(args.get_or("name", "llama3-mini"), &cfg, args.get_usize("pretrain-steps", 300), 0);
    let wiki = lords::eval::perplexity(&tb.model, &tb.wiki, 64, 16);
    let ptb = lords::eval::perplexity(&tb.model, &tb.ptb, 64, 16);
    let suite = TaskSuite::generate(&tb.wiki, 40, 2);
    let acc = lords::eval::evaluate_suite(&tb.model, &suite);
    println!("wiki PPL {} | ptb PPL {}", wiki.display(), ptb.display());
    for (name, a) in &acc.per_task {
        println!("  {name:<6} {a:5.1}%");
    }
    println!("  Avg    {:5.1}%", acc.average);
    Ok(())
}

fn cmd_rank_table() -> anyhow::Result<()> {
    use lords::quant::parity_rank;
    let mut t = lords::bench::TableBuilder::new("Table 7 — parity ranks (exact paper shapes)")
        .headers(&["Model", "Module", "shape", "B=128", "B=256"]);
    let rows: &[(&str, &str, usize, usize)] = &[
        ("Llama3-8B", "Q/O", 4096, 4096),
        ("Llama3-8B", "K/V", 1024, 4096),
        ("Llama3-8B", "Up/Gate", 14336, 4096),
        ("Llama3-8B", "Down", 4096, 14336),
        ("Qwen3-8B", "Q/O", 4096, 4096),
        ("Qwen3-8B", "K/V", 1024, 4096),
        ("Qwen3-8B", "Up/Gate", 12288, 4096),
        ("Qwen3-8B", "Down", 4096, 12288),
        ("Qwen3-4B", "Q", 4096, 2560),
        ("Qwen3-4B", "O", 2560, 4096),
        ("Qwen3-4B", "K/V", 1024, 2560),
        ("Qwen3-4B", "Up/Gate", 9728, 2560),
        ("Qwen3-4B", "Down", 2560, 9728),
    ];
    for (model, module, n, m) in rows {
        t.row(vec![
            model.to_string(),
            module.to_string(),
            format!("{n}x{m}"),
            parity_rank(*n, *m, 128).to_string(),
            parity_rank(*n, *m, 256).to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    println!("lords {} — three-layer Rust+JAX+Pallas LoRDS reproduction", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", lords::util::ThreadPool::global().size());
    let dir = args.get_or("artifacts", "artifacts");
    match lords::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!(
                "artifacts: {} entries | model d={} L={} vocab={} | codebook {} ({} levels)",
                m.artifacts.len(),
                m.model.d_model,
                m.model.n_layers,
                m.model.vocab,
                m.lut_name,
                m.lut.len()
            );
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    Ok(())
}
