//! Evaluation harness: perplexity over corpus eval splits and zero-shot
//! likelihood-scored accuracy over the task suite — the two metric families
//! of Tables 1–5. Includes the divergence detector behind the paper's
//! "N.A." entries (Table 3).

use crate::data::corpus::Corpus;
use crate::data::tasks::{TaskExample, TaskSuite};
use crate::model::loss::cross_entropy_fwd;
use crate::model::Model;

/// PPL above this (or non-finite loss) is reported as divergence (the
/// paper's N.A. rows in Table 3).
pub const DIVERGENCE_PPL: f32 = 1e4;

#[derive(Clone, Debug)]
pub struct PplResult {
    pub ppl: f32,
    pub mean_nll: f32,
    pub tokens: usize,
    pub diverged: bool,
}

impl PplResult {
    pub fn display(&self) -> String {
        if self.diverged {
            "N.A.".into()
        } else {
            format!("{:.2}", self.ppl)
        }
    }
}

/// Perplexity of `model` on the eval split of `corpus`.
pub fn perplexity(model: &Model, corpus: &Corpus, seq: usize, max_windows: usize) -> PplResult {
    let windows = corpus.eval_windows(seq, max_windows);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for (tokens, targets) in &windows {
        let logits = model.forward(tokens, 1, tokens.len());
        let (nll, _) = cross_entropy_fwd(&logits, targets);
        if !nll.is_finite() {
            return PplResult { ppl: f32::INFINITY, mean_nll: f32::INFINITY, tokens: 0, diverged: true };
        }
        total_nll += nll as f64 * targets.len() as f64;
        total_tokens += targets.len();
    }
    let mean = (total_nll / total_tokens.max(1) as f64) as f32;
    let ppl = mean.exp();
    PplResult { ppl, mean_nll: mean, tokens: total_tokens, diverged: !ppl.is_finite() || ppl > DIVERGENCE_PPL }
}

/// Log-likelihood of `continuation` given `context` under `model`.
fn continuation_logprob(model: &Model, context: &[usize], continuation: &[usize]) -> f32 {
    let mut full = context.to_vec();
    full.extend_from_slice(continuation);
    let logits = model.forward(&full, 1, full.len());
    // score positions context.len()-1 .. full.len()-2 predicting continuation
    let mut lp = 0.0f32;
    for (k, &tok) in continuation.iter().enumerate() {
        let row = logits.row(context.len() + k - 1);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let denom: f32 = row.iter().map(|&v| (v - maxv).exp()).sum();
        lp += row[tok] - maxv - denom.ln();
    }
    lp
}

/// Zero-shot accuracy on one example: argmax over choice likelihoods.
pub fn score_example(model: &Model, ex: &TaskExample) -> bool {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (c, choice) in ex.choices.iter().enumerate() {
        let lp = continuation_logprob(model, &ex.context, choice);
        if lp > best.0 {
            best = (lp, c);
        }
    }
    best.1 == ex.answer
}

#[derive(Clone, Debug)]
pub struct SuiteResult {
    /// (task name, accuracy %)
    pub per_task: Vec<(&'static str, f32)>,
    pub average: f32,
}

/// Accuracy over the full suite (the Avg ↑ column).
pub fn evaluate_suite(model: &Model, suite: &TaskSuite) -> SuiteResult {
    let mut per_task = Vec::with_capacity(suite.tasks.len());
    for task in &suite.tasks {
        let correct = task.examples.iter().filter(|e| score_example(model, e)).count();
        per_task.push((task.name, 100.0 * correct as f32 / task.examples.len().max(1) as f32));
    }
    let average = per_task.iter().map(|(_, a)| a).sum::<f32>() / per_task.len().max(1) as f32;
    SuiteResult { per_task, average }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::data::corpus::CorpusKind;
    use crate::data::TaskSuite;

    fn tiny() -> (Model, Corpus) {
        let cfg = ModelCfg {
            vocab: 48,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        };
        let model = Model::init(&cfg, 0);
        let corpus = Corpus::generate(CorpusKind::Wiki, 48, 4000, 1500, 0);
        (model, corpus)
    }

    #[test]
    fn untrained_ppl_near_uniform() {
        let (model, corpus) = tiny();
        let r = perplexity(&model, &corpus, 32, 4);
        assert!(!r.diverged);
        // untrained model ≈ uniform over vocab
        assert!((r.ppl - 48.0).abs() < 24.0, "ppl {}", r.ppl);
    }

    #[test]
    fn untrained_accuracy_near_chance() {
        let (model, corpus) = tiny();
        let suite = TaskSuite::generate(&corpus, 12, 0);
        let res = evaluate_suite(&model, &suite);
        assert_eq!(res.per_task.len(), 7);
        // chance is 25–50% depending on n_choices; untrained should be in a
        // broad band around it
        assert!(res.average > 10.0 && res.average < 75.0, "avg {}", res.average);
    }

    #[test]
    fn divergence_detection() {
        let (mut model, corpus) = tiny();
        // blow up the lm head → NaN/huge logits
        for v in model.lm_head.data.iter_mut() {
            *v *= 1e20;
        }
        let r = perplexity(&model, &corpus, 16, 2);
        assert!(r.diverged);
        assert_eq!(r.display(), "N.A.");
    }

    #[test]
    fn continuation_logprob_is_additive() {
        let (model, _) = tiny();
        let ctx = vec![1usize, 2, 3];
        let a = continuation_logprob(&model, &ctx, &[4]);
        let b = {
            let mut c2 = ctx.clone();
            c2.push(4);
            continuation_logprob(&model, &c2, &[5])
        };
        let ab = continuation_logprob(&model, &ctx, &[4, 5]);
        assert!((ab - (a + b)).abs() < 1e-3, "{ab} vs {}", a + b);
    }
}
