//! Scale-matrix algebra (Section 3.1–3.2): the block-wise scaling matrix
//! S = s ⊗ 1_{1×B}, the parameter-parity rank rule of Appendix A, and the
//! truncated-SVD initialization S ≈ BA (eq. 3).

use crate::linalg::truncated_svd;
use crate::tensor::Matrix;

/// Appendix A: r = ⌊nm / (B(n+m))⌋, clamped to ≥ 1 — the rank at which the
/// (B, A) parameter count r(n+m) equals the block-scale count nm/B.
pub fn parity_rank(n: usize, m: usize, block: usize) -> usize {
    ((n * m) / (block * (n + m))).max(1)
}

/// Parameter-aligned rank for comparison with adapter-based baselines
/// (Appendix B, LoRDS†): r = ⌊nm/(B(n+m))⌋ + r_q.
pub fn parity_rank_with_adapter(n: usize, m: usize, block: usize, r_q: usize) -> usize {
    parity_rank(n, m, block) + r_q
}

/// Per-block absmax scales s ∈ R^{n × m/B} (zero-safe).
pub fn blockwise_scales(w: &Matrix, block: usize) -> Matrix {
    assert!(w.cols % block == 0);
    let nb = w.cols / block;
    Matrix::from_fn(w.rows, nb, |i, b| {
        let s = w.row(i)[b * block..(b + 1) * block]
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()));
        if s == 0.0 {
            1.0
        } else {
            s
        }
    })
}

/// Expand block scales to the dense scale matrix S = s ⊗ 1_{1×B}.
pub fn expand_scales(s: &Matrix, block: usize) -> Matrix {
    Matrix::from_fn(s.rows, s.cols * block, |i, j| s.at(i, j / block))
}

/// Eq. 3: truncated-SVD split of the block-wise scale matrix into
/// (B, A) = (U_r Σ_r^{1/2}, Σ_r^{1/2} V_rᵀ).
pub fn lords_init(w: &Matrix, block: usize, rank: usize) -> (Matrix, Matrix) {
    let s_full = expand_scales(&blockwise_scales(w, block), block);
    truncated_svd(&s_full, rank).split_ba(rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn parity_rank_matches_paper_table7() {
        // Appendix A Table 7, all 18 entries
        let cases = [
            (4096, 4096, 128, 16),
            (4096, 4096, 256, 8),
            (1024, 4096, 128, 6),
            (1024, 4096, 256, 3),
            (14336, 4096, 128, 24),
            (14336, 4096, 256, 12),
            (4096, 14336, 128, 24),
            (4096, 14336, 256, 12),
            (12288, 4096, 128, 24),
            (12288, 4096, 256, 12),
            (4096, 12288, 128, 24),
            (4096, 12288, 256, 12),
            (4096, 2560, 128, 12),
            (4096, 2560, 256, 6),
            (1024, 2560, 128, 5),
            (1024, 2560, 256, 2),
            (9728, 2560, 128, 15),
            (9728, 2560, 256, 7),
        ];
        for (n, m, b, want) in cases {
            assert_eq!(parity_rank(n, m, b), want, "({n},{m},{b})");
        }
    }

    #[test]
    fn parity_budget_never_exceeds_blockwise() {
        // r(n+m) ≤ nm/B by construction of the floor
        prop_check(64, |g| {
            let n = g.usize(16..=512);
            let m = g.usize(16..=512);
            let block = *g.pick(&[16usize, 32, 64, 128]);
            let r = parity_rank(n, m, block);
            if r == 1 && n * m < block * (n + m) {
                return Ok(()); // clamp case: rank-1 minimum is allowed to exceed
            }
            if r * (n + m) <= n * m / block {
                Ok(())
            } else {
                Err(format!("budget violated: r={r} n={n} m={m} B={block}"))
            }
        });
    }

    #[test]
    fn adapter_aligned_rank() {
        assert_eq!(parity_rank_with_adapter(4096, 4096, 128, 16), 32);
    }

    #[test]
    fn svd_init_recovers_blockwise_at_full_rank() {
        // eq. 3: with rank = m/B the init reproduces S exactly
        let mut rng = Rng::new(0);
        let w = Matrix::randn(24, 32, 1.0, &mut rng);
        let block = 8;
        let (b, a) = lords_init(&w, block, 32 / block);
        let ba = matmul(&b, &a);
        let s = expand_scales(&blockwise_scales(&w, block), block);
        let rel = ba.sub(&s).frob_norm() / s.frob_norm();
        assert!(rel < 1e-4, "rel err {rel}");
    }

    #[test]
    fn truncated_init_is_positive_dominant() {
        // absmax scales are positive; a good low-rank approx keeps most mass positive
        let mut rng = Rng::new(1);
        let w = Matrix::randn(32, 64, 1.0, &mut rng);
        let (b, a) = lords_init(&w, 16, 2);
        let ba = matmul(&b, &a);
        let pos = ba.data.iter().filter(|&&v| v > 0.0).count();
        assert!(pos as f32 / ba.len() as f32 > 0.95);
    }

    #[test]
    fn expand_scales_layout() {
        let s = Matrix::from_vec(1, 2, vec![2.0, 3.0]);
        let full = expand_scales(&s, 4);
        assert_eq!(full.data, vec![2.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0]);
    }
}
