//! Mixed-precision bit schedules (Section 4.1 "Pushing the Limits" and
//! Appendix B Table 9).
//!
//! The paper's 3 / 2.5 / 2.25-bit configurations quantize the first
//! 50% / 25% / 12.5% of the model's layers with NF4 and the remainder with
//! NF2; 2-bit is NF2 everywhere. [`MixedSchedule`] reproduces that layer
//! assignment and the resulting average bit width / #Float accounting.

use super::codebook::Codebook;

/// Per-layer codebook assignment for a target average bit width.
#[derive(Clone, Debug)]
pub struct MixedSchedule {
    /// bits label as the paper writes it (3, 2.5, 2.25, 2 or 4).
    pub bits_label: String,
    /// Fraction of leading layers quantized at NF4.
    pub nf4_fraction: f32,
    pub n_layers: usize,
}

impl MixedSchedule {
    /// Paper mapping: 3-bit → 50% NF4, 2.5 → 25%, 2.25 → 12.5%, 2 → 0%,
    /// 4 → 100%.
    pub fn for_bits(bits: f32, n_layers: usize) -> MixedSchedule {
        let nf4_fraction = ((bits - 2.0) / 2.0).clamp(0.0, 1.0);
        let label = if (bits.fract()).abs() < 1e-6 {
            format!("{}", bits as u32)
        } else {
            format!("{bits}")
        };
        MixedSchedule { bits_label: label, nf4_fraction, n_layers }
    }

    /// Number of leading layers in NF4.
    pub fn nf4_layers(&self) -> usize {
        (self.nf4_fraction * self.n_layers as f32).round() as usize
    }

    /// Codebook for layer `l` (0-based).
    pub fn codebook_for_layer(&self, l: usize) -> Codebook {
        assert!(l < self.n_layers);
        if l < self.nf4_layers() {
            Codebook::normal_float(4)
        } else {
            Codebook::normal_float(2)
        }
    }

    /// Average bits per weight across layers (assuming equal layer sizes).
    pub fn average_bits(&self) -> f32 {
        let k = self.nf4_layers() as f32;
        let rest = self.n_layers as f32 - k;
        (4.0 * k + 2.0 * rest) / self.n_layers as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fractions() {
        assert_eq!(MixedSchedule::for_bits(3.0, 32).nf4_layers(), 16);
        assert_eq!(MixedSchedule::for_bits(2.5, 32).nf4_layers(), 8);
        assert_eq!(MixedSchedule::for_bits(2.25, 32).nf4_layers(), 4);
        assert_eq!(MixedSchedule::for_bits(2.0, 32).nf4_layers(), 0);
        assert_eq!(MixedSchedule::for_bits(4.0, 32).nf4_layers(), 32);
    }

    #[test]
    fn average_bits_match_label() {
        for (bits, layers) in [(3.0f32, 32usize), (2.5, 32), (2.25, 32), (2.0, 32), (4.0, 32)] {
            let s = MixedSchedule::for_bits(bits, layers);
            assert!((s.average_bits() - bits).abs() < 1e-6, "{bits}");
        }
    }

    #[test]
    fn layer_assignment_is_prefix() {
        let s = MixedSchedule::for_bits(2.5, 8);
        let widths: Vec<usize> = (0..8).map(|l| s.codebook_for_layer(l).len()).collect();
        assert_eq!(widths, vec![16, 16, 4, 4, 4, 4, 4, 4]);
    }

    #[test]
    fn rounding_with_odd_layer_counts() {
        let s = MixedSchedule::for_bits(2.25, 4); // 12.5% of 4 = 0.5 → rounds to 1? (0.125*4=0.5→1)
        assert!(s.nf4_layers() <= 1);
        let s3 = MixedSchedule::for_bits(3.0, 5);
        assert_eq!(s3.nf4_layers(), 3); // 2.5 rounds to 3
    }
}
