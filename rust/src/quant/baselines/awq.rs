//! AWQ (Lin et al., 2024): activation-aware weight quantization.
//!
//! Salient weight channels (by mean activation magnitude) are protected by
//! an equivalent transformation: scale channel j of W up by s_j before
//! quantization and fold 1/s_j into the (virtual) preceding op. The
//! per-channel scales are s_j = mean|x_j|^α with α grid-searched to
//! minimize the layer-wise output error on the calibration set.

use crate::quant::blockwise::BlockwiseQuant;
use crate::quant::codebook::Codebook;
use crate::quant::QuantizedLinear;
use crate::tensor::{matmul_transb, Matrix};

#[derive(Clone, Debug)]
pub struct AwqQuant {
    pub inner: BlockwiseQuant,
    /// Per-input-channel protection scales (folded out at dequant).
    pub channel_scales: Vec<f32>,
    pub alpha: f32,
}

impl AwqQuant {
    pub fn quantize(
        w: &Matrix,
        x_cal: &Matrix,
        block: usize,
        codebook: &Codebook,
    ) -> AwqQuant {
        assert_eq!(x_cal.cols, w.cols);
        let m = w.cols;
        // mean |x_j| per channel, normalized to geometric mean 1
        let mut act: Vec<f32> = (0..m)
            .map(|j| {
                let s: f32 = (0..x_cal.rows).map(|i| x_cal.at(i, j).abs()).sum();
                (s / x_cal.rows as f32).max(1e-8)
            })
            .collect();
        let log_mean = act.iter().map(|v| v.ln()).sum::<f32>() / m as f32;
        let norm = log_mean.exp();
        for v in act.iter_mut() {
            *v /= norm;
        }

        let y_ref = matmul_transb(x_cal, w);
        let mut best: Option<(f32, f32, BlockwiseQuant, Vec<f32>)> = None;
        for step in 0..=10 {
            let alpha = step as f32 / 10.0;
            let scales: Vec<f32> = act.iter().map(|v| v.powf(alpha).max(1e-4)).collect();
            // W' = W ⊙ s (per column), quantize, then evaluate the folded
            // reconstruction Ŵ = Ŵ' ⊘ s
            let w_scaled = Matrix::from_fn(w.rows, m, |i, j| w.at(i, j) * scales[j]);
            let q = BlockwiseQuant::quantize(&w_scaled, block, codebook);
            let w_hat = fold(&q.dequantize(), &scales);
            let err = matmul_transb(x_cal, &w_hat).sub(&y_ref).frob_norm();
            if best.as_ref().map(|(e, ..)| err < *e).unwrap_or(true) {
                best = Some((err, alpha, q, scales));
            }
        }
        let (_, alpha, inner, channel_scales) = best.unwrap();
        AwqQuant { inner, channel_scales, alpha }
    }
}

fn fold(w_hat_scaled: &Matrix, scales: &[f32]) -> Matrix {
    Matrix::from_fn(w_hat_scaled.rows, w_hat_scaled.cols, |i, j| {
        w_hat_scaled.at(i, j) / scales[j]
    })
}

impl QuantizedLinear for AwqQuant {
    fn dequantize(&self) -> Matrix {
        fold(&self.inner.dequantize(), &self.channel_scales)
    }

    /// Block scales + the per-channel protection scales.
    fn float_params(&self) -> usize {
        self.inner.float_params() + self.channel_scales.len()
    }

    fn code_bits(&self) -> f32 {
        self.inner.code_bits()
    }

    fn method_name(&self) -> &'static str {
        "AWQ"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Activations with pronounced hot channels — AWQ's home turf.
    fn hot_calib(rng: &mut Rng, t: usize, m: usize) -> (Matrix, Vec<usize>) {
        let mut x = Matrix::randn(t, m, 1.0, rng);
        let hot: Vec<usize> = (0..m).step_by(11).collect();
        for &c in &hot {
            for i in 0..t {
                *x.at_mut(i, c) *= 10.0;
            }
        }
        (x, hot)
    }

    #[test]
    fn beats_plain_blockwise_on_calibration_objective() {
        let mut rng = Rng::new(0);
        let (n, m, block) = (32, 64, 16);
        let w = Matrix::randn(n, m, 0.1, &mut rng);
        let (x, _) = hot_calib(&mut rng, 128, m);
        let cb = Codebook::normal_float(4);

        let rtn = BlockwiseQuant::quantize(&w, block, &cb);
        let awq = AwqQuant::quantize(&w, &x, block, &cb);

        let y = matmul_transb(&x, &w);
        let e_rtn = matmul_transb(&x, &rtn.dequantize()).sub(&y).frob_norm();
        let e_awq = matmul_transb(&x, &awq.dequantize()).sub(&y).frob_norm();
        assert!(e_awq <= e_rtn, "AWQ {e_awq} !≤ RTN {e_rtn}");
    }

    #[test]
    fn uniform_activations_choose_small_alpha() {
        // with no salient channels there is nothing to protect
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 32, 0.1, &mut rng);
        let x = Matrix::randn(64, 32, 1.0, &mut rng);
        let awq = AwqQuant::quantize(&w, &x, 16, &Codebook::normal_float(4));
        // α can be anything if errors tie, but scales must stay ≈ 1
        let dev: f32 = awq
            .channel_scales
            .iter()
            .map(|s| (s - 1.0).abs())
            .fold(0.0, f32::max);
        assert!(dev < 0.5, "scales drifted {dev} with uniform activations");
    }

    #[test]
    fn protected_channels_have_lower_weight_error() {
        let mut rng = Rng::new(2);
        let (n, m, block) = (24, 44, 11);
        let w = Matrix::randn(n, m, 0.1, &mut rng);
        let (x, hot) = hot_calib(&mut rng, 128, m);
        let cb = Codebook::normal_float(4);
        let awq = AwqQuant::quantize(&w, &x, block, &cb);
        if awq.alpha == 0.0 {
            return; // grid picked no protection; nothing to assert
        }
        let rtn = BlockwiseQuant::quantize(&w, block, &cb);
        let err = |wh: &Matrix, cols: &[usize]| -> f32 {
            cols.iter()
                .map(|&j| (0..n).map(|i| (w.at(i, j) - wh.at(i, j)).powi(2)).sum::<f32>())
                .sum::<f32>()
                .sqrt()
        };
        let e_awq_hot = err(&awq.dequantize(), &hot);
        let e_rtn_hot = err(&rtn.dequantize(), &hot);
        assert!(e_awq_hot <= e_rtn_hot * 1.05, "hot-channel error {e_awq_hot} vs {e_rtn_hot}");
    }
}
