//! Baseline quantizers the paper compares against (Tables 1, 3, 5, 8, 9):
//! GPTQ (second-order PTQ), AWQ (activation-aware scaling), LoftQ / QPiSSA
//! (quantization + SVD residual adapters), and QLoRA (NF4 + zero-init
//! additive adapter for fine-tuning).

pub mod awq;
pub mod gptq;
pub mod loftq;
pub mod qlora;

pub use awq::AwqQuant;
pub use gptq::GptqQuant;
pub use loftq::{AdapterQuant, loftq_quantize, qpissa_quantize};
pub use qlora::QloraLinear;
