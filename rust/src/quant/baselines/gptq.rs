//! GPTQ (Frantar et al., 2022): layer-wise PTQ using second-order
//! (Hessian) information from calibration activations.
//!
//! For a linear y = W x the layer-wise objective is ‖WX − ŴX‖², whose
//! Hessian w.r.t. each weight row is H = 2 X Xᵀ (shared across rows).
//! Weights are quantized one input-channel at a time; the quantization
//! error of channel j is propagated into the not-yet-quantized channels
//! via the inverse-Hessian row, exactly as in the reference implementation
//! (Cholesky form, with dampening).

use crate::linalg::qr::cholesky;
use crate::quant::codebook::Codebook;
use crate::quant::scale::blockwise_scales;
use crate::quant::QuantizedLinear;
use crate::tensor::{matmul_transb, Matrix};

#[derive(Clone, Debug)]
pub struct GptqQuant {
    pub codes: Vec<u8>,
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub scales: Matrix,
    pub codebook: Codebook,
}

impl GptqQuant {
    /// Quantize `w` (n×m) given calibration activations `x_cal` (t×m).
    ///
    /// `percdamp`: dampening fraction of mean diagonal (reference: 0.01).
    pub fn quantize(
        w: &Matrix,
        x_cal: &Matrix,
        block: usize,
        codebook: &Codebook,
        percdamp: f32,
    ) -> GptqQuant {
        assert_eq!(x_cal.cols, w.cols);
        let m = w.cols;
        let n = w.rows;

        // H = 2 XᵀX + λI  (m×m)
        let mut h = matmul_transb(&x_cal.transpose(), &x_cal.transpose());
        let mean_diag: f32 = (0..m).map(|i| h.at(i, i)).sum::<f32>() / m as f32;
        let damp = (percdamp * mean_diag).max(1e-6);
        for i in 0..m {
            *h.at_mut(i, i) += damp;
        }

        // Hinv via Cholesky: H = LLᵀ ⇒ H⁻¹ = L⁻ᵀL⁻¹; we need the upper
        // Cholesky factor of H⁻¹, i.e. U with H⁻¹ = UᵀU ... the reference
        // uses `cholesky(inv(H), upper=True)`. Compute inv(H) column-wise
        // by solves, then its upper Cholesky.
        let l = cholesky(&h).expect("damped Hessian must be SPD");
        let mut hinv = Matrix::zeros(m, m);
        for j in 0..m {
            let mut e = vec![0.0f32; m];
            e[j] = 1.0;
            let y = crate::linalg::qr::solve_lower(&l, &e);
            let x = crate::linalg::qr::solve_upper_t(&l, &y);
            for i in 0..m {
                hinv.set(i, j, x[i]);
            }
        }
        // upper Cholesky of Hinv = (cholesky of reversed)… the reference
        // trick: chol(Hinv) lower → transpose gives the upper factor used
        // in the update rule.
        let linv = cholesky(&hinv).expect("H⁻¹ SPD");
        let u = linv.transpose(); // upper triangular, u[j, k] for k ≥ j

        // Per-block absmax scales from the *original* weights (GPTQ keeps
        // the scale grid fixed and only optimizes rounding).
        let scales = blockwise_scales(w, block);

        let mut wk = w.clone(); // working copy, updated in place
        let mut codes = vec![0u8; n * m];
        for j in 0..m {
            let ujj = u.at(j, j).max(1e-12);
            let sb = j / block;
            for i in 0..n {
                let s = scales.at(i, sb);
                let code = codebook.quantize_one(wk.at(i, j), s);
                codes[i * m + j] = code as u8;
                let qv = codebook.level(code) * s;
                let err = (wk.at(i, j) - qv) / ujj;
                // propagate into remaining channels
                let urow = u.row(j);
                let wrow = wk.row_mut(i);
                for k in (j + 1)..m {
                    wrow[k] -= err * urow[k];
                }
            }
        }

        GptqQuant { codes, rows: n, cols: m, block, scales, codebook: codebook.clone() }
    }
}

impl QuantizedLinear for GptqQuant {
    fn dequantize(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| {
            self.codebook.level(self.codes[i * self.cols + j] as usize)
                * self.scales.at(i, j / self.block)
        })
    }

    fn float_params(&self) -> usize {
        self.scales.len()
    }

    fn code_bits(&self) -> f32 {
        self.codebook.bits()
    }

    fn method_name(&self) -> &'static str {
        "GPTQ"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::BlockwiseQuant;
    use crate::util::Rng;

    fn calib(rng: &mut Rng, t: usize, m: usize) -> Matrix {
        // correlated activations with a few hot channels, as in real LLMs
        let mut x = Matrix::randn(t, m, 1.0, rng);
        for c in (0..m).step_by(7) {
            for i in 0..t {
                *x.at_mut(i, c) *= 4.0;
            }
        }
        x
    }

    #[test]
    fn reduces_layerwise_output_error_vs_rtn() {
        let mut rng = Rng::new(0);
        let (n, m, t, block) = (24, 48, 256, 16);
        let w = Matrix::randn(n, m, 0.1, &mut rng);
        let x = calib(&mut rng, t, m);
        let cb = Codebook::normal_float(4);

        let rtn = BlockwiseQuant::quantize(&w, block, &cb);
        let gptq = GptqQuant::quantize(&w, &x, block, &cb, 0.01);

        // layer-wise objective: ‖XWᵀ − XŴᵀ‖_F
        let y_ref = matmul_transb(&x, &w);
        let e_rtn = matmul_transb(&x, &rtn.dequantize()).sub(&y_ref).frob_norm();
        let e_gptq = matmul_transb(&x, &gptq.dequantize()).sub(&y_ref).frob_norm();
        assert!(
            e_gptq < e_rtn,
            "GPTQ {e_gptq} must beat round-to-nearest {e_rtn} on the calib objective"
        );
    }

    #[test]
    fn same_budget_as_blockwise() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 32, 0.1, &mut rng);
        let x = calib(&mut rng, 64, 32);
        let cb = Codebook::normal_float(4);
        let g = GptqQuant::quantize(&w, &x, 16, &cb, 0.01);
        assert_eq!(g.float_params(), 16 * 2);
        assert_eq!(g.code_bits(), 4.0);
    }

    #[test]
    fn identity_activations_reduce_to_rtn() {
        // With X = I (uncorrelated, equal-power channels), the Hessian is
        // diagonal and GPTQ's compensation ~vanishes: codes match RTN.
        let mut rng = Rng::new(2);
        let m = 24;
        let w = Matrix::randn(8, m, 0.1, &mut rng);
        let x = Matrix::eye(m);
        let cb = Codebook::normal_float(4);
        let g = GptqQuant::quantize(&w, &x, 8, &cb, 1e-4);
        let rtn = BlockwiseQuant::quantize(&w, 8, &cb);
        let rtn_flat = rtn.codes.to_flat();
        let same = g
            .codes
            .iter()
            .zip(&rtn_flat)
            .filter(|(a, b)| a == b)
            .count();
        assert!(same as f32 / g.codes.len() as f32 > 0.95, "{same}/{}", g.codes.len());
    }
}
