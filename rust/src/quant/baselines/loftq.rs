//! LoftQ (Li et al., 2023) and QPiSSA (Meng et al., 2024): quantization with
//! SVD low-rank *additive* adapters that restore reconstruction fidelity.
//!
//! * LoftQ alternates: Q_t = quant(W − L_b L_a), (L_b, L_a) = SVD_k(W − Q̂_t).
//! * QPiSSA peels the principal rank-k subspace into the adapter first, then
//!   quantizes the residual (and may iterate identically).
//!
//! Both produce `Ŵ = Q̂ + L_b L_a` — the additive structure whose adapter
//! cannot be merged into the quantized weight at inference (the latency cost
//! LoRDS eliminates).

use crate::linalg::truncated_svd;
use crate::quant::blockwise::BlockwiseQuant;
use crate::quant::codebook::Codebook;
use crate::quant::QuantizedLinear;
use crate::tensor::{matmul, Matrix};

/// Quantized base + additive low-rank adapter (LoftQ / QPiSSA / QLoRA-init).
#[derive(Clone, Debug)]
pub struct AdapterQuant {
    pub base: BlockwiseQuant,
    /// n × k
    pub lora_b: Matrix,
    /// k × m
    pub lora_a: Matrix,
    pub method: &'static str,
}

impl AdapterQuant {
    pub fn rank(&self) -> usize {
        self.lora_b.cols
    }

    pub fn adapter(&self) -> Matrix {
        matmul(&self.lora_b, &self.lora_a)
    }
}

impl QuantizedLinear for AdapterQuant {
    fn dequantize(&self) -> Matrix {
        self.base.dequantize().add(&self.adapter())
    }

    fn float_params(&self) -> usize {
        self.base.float_params() + self.lora_b.len() + self.lora_a.len()
    }

    fn code_bits(&self) -> f32 {
        self.base.code_bits()
    }

    fn method_name(&self) -> &'static str {
        self.method
    }
}

/// LoftQ: `iters` rounds of alternating quantization / SVD fitting
/// (paper setting: rank 16, 5 iterations).
pub fn loftq_quantize(
    w: &Matrix,
    block: usize,
    rank: usize,
    iters: usize,
    codebook: &Codebook,
) -> AdapterQuant {
    let mut lora_b = Matrix::zeros(w.rows, rank);
    let mut lora_a = Matrix::zeros(rank, w.cols);
    let mut base = BlockwiseQuant::quantize(w, block, codebook);
    for _ in 0..iters {
        // quantize the adapter-compensated weight
        let resid = w.sub(&matmul(&lora_b, &lora_a));
        base = BlockwiseQuant::quantize(&resid, block, codebook);
        // refit the adapter to the quantization residual
        let err = w.sub(&base.dequantize());
        let svd = truncated_svd(&err, rank);
        let (b, a) = svd.split_ba(rank);
        lora_b = b;
        lora_a = a;
    }
    AdapterQuant { base, lora_b, lora_a, method: "LoftQ" }
}

/// QPiSSA: principal singular subspace into the adapter, residual quantized.
pub fn qpissa_quantize(
    w: &Matrix,
    block: usize,
    rank: usize,
    iters: usize,
    codebook: &Codebook,
) -> AdapterQuant {
    // principal subspace first
    let svd = truncated_svd(w, rank);
    let (mut lora_b, mut lora_a) = svd.split_ba(rank);
    let mut base = BlockwiseQuant::quantize(&w.sub(&matmul(&lora_b, &lora_a)), block, codebook);
    // optional LoftQ-style polishing rounds
    for _ in 1..iters.max(1) {
        let err = w.sub(&base.dequantize());
        let s = truncated_svd(&err, rank);
        let (b, a) = s.split_ba(rank);
        lora_b = b;
        lora_a = a;
        base = BlockwiseQuant::quantize(&w.sub(&matmul(&lora_b, &lora_a)), block, codebook);
    }
    AdapterQuant { base, lora_b, lora_a, method: "QPiSSA" }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn llm_like(rng: &mut Rng, n: usize, m: usize) -> Matrix {
        let mut w = Matrix::randn(n, m, 0.05, rng);
        for &c in rng.choose(m, m / 12).iter() {
            for i in 0..n {
                *w.at_mut(i, c) *= 6.0;
            }
        }
        w
    }

    #[test]
    fn loftq_beats_plain_nf4() {
        let mut rng = Rng::new(0);
        let w = llm_like(&mut rng, 48, 64);
        let cb = Codebook::normal_float(4);
        let nf4 = BlockwiseQuant::quantize(&w, 16, &cb);
        let lq = loftq_quantize(&w, 16, 8, 5, &cb);
        let e_nf4 = w.sub(&nf4.dequantize()).frob_norm();
        let e_lq = w.sub(&lq.dequantize()).frob_norm();
        assert!(e_lq < e_nf4, "LoftQ {e_lq} !< NF4 {e_nf4}");
    }

    #[test]
    fn qpissa_beats_plain_nf4() {
        let mut rng = Rng::new(1);
        let w = llm_like(&mut rng, 48, 64);
        let cb = Codebook::normal_float(4);
        let nf4 = BlockwiseQuant::quantize(&w, 16, &cb);
        let qp = qpissa_quantize(&w, 16, 8, 1, &cb);
        let e_nf4 = w.sub(&nf4.dequantize()).frob_norm();
        let e_qp = w.sub(&qp.dequantize()).frob_norm();
        assert!(e_qp < e_nf4, "QPiSSA {e_qp} !< NF4 {e_nf4}");
    }

    #[test]
    fn more_iterations_do_not_hurt() {
        let mut rng = Rng::new(2);
        let w = llm_like(&mut rng, 32, 48);
        let cb = Codebook::normal_float(4);
        let e1 = w.sub(&loftq_quantize(&w, 16, 6, 1, &cb).dequantize()).frob_norm();
        let e5 = w.sub(&loftq_quantize(&w, 16, 6, 5, &cb).dequantize()).frob_norm();
        assert!(e5 <= e1 * 1.02, "iter5 {e5} vs iter1 {e1}");
    }

    #[test]
    fn float_param_accounting() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(32, 64, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let lq = loftq_quantize(&w, 16, 4, 2, &cb);
        // scales nm/B + adapter r(n+m)
        assert_eq!(lq.float_params(), 32 * 64 / 16 + 4 * (32 + 64));
        assert_eq!(lq.method_name(), "LoftQ");
        assert_eq!(lq.rank(), 4);
    }

    #[test]
    fn adapter_rank_is_bounded() {
        // additive adapters are strictly rank-k — the contrast with LoRDS
        let mut rng = Rng::new(4);
        let w = llm_like(&mut rng, 40, 40);
        let cb = Codebook::normal_float(4);
        let lq = loftq_quantize(&w, 8, 4, 3, &cb);
        let sv = crate::linalg::svd(&lq.adapter()).s;
        let eff = sv.iter().filter(|&&s| s > 1e-4 * sv[0].max(1e-12)).count();
        assert!(eff <= 4, "adapter rank {eff} > 4");
    }
}
