//! QLoRA (Dettmers et al., 2023): NF4 block-wise base + trainable additive
//! LoRA adapter. The base is frozen; fine-tuning updates (L_a, L_b) only.
//! Standard init: L_a ~ N(0, 1/r), L_b = 0 (so the initial adapter is a
//! no-op). The adapter is *unmergeable* into the quantized base — its two
//! extra GEMMs run on every forward (Figure 2's latency gap).

use crate::quant::blockwise::BlockwiseQuant;
use crate::quant::codebook::Codebook;
use crate::quant::QuantizedLinear;
use crate::tensor::{matmul, matmul_at_b, matmul_transb, Matrix};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct QloraLinear {
    pub base: BlockwiseQuant,
    /// r × m (down-projection)
    pub lora_a: Matrix,
    /// n × r (up-projection)
    pub lora_b: Matrix,
    /// LoRA scaling factor (alpha / r); paper-standard alpha = 2r ⇒ 2.0.
    pub scaling: f32,
}

impl QloraLinear {
    pub fn new(w: &Matrix, block: usize, rank: usize, codebook: &Codebook, rng: &mut Rng) -> Self {
        let base = BlockwiseQuant::quantize(w, block, codebook);
        let mut lora_a = Matrix::zeros(rank, w.cols);
        rng.fill_normal(&mut lora_a.data, 0.0, 1.0 / (rank as f32).sqrt());
        let lora_b = Matrix::zeros(w.rows, rank);
        QloraLinear { base, lora_a, lora_b, scaling: 2.0 }
    }

    pub fn rank(&self) -> usize {
        self.lora_a.rows
    }

    /// Forward: y = x·Ŵᵀ + s · (x·L_aᵀ)·L_bᵀ — the base path fused, the
    /// adapter path necessarily separate (unmergeable).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.base.rows);
        self.forward_into(x, &mut y);
        y
    }

    /// [`Self::forward`] writing the base term into a caller-owned t×n
    /// output, then accumulating the adapter term (the small t×r
    /// intermediates still allocate — the unmergeable two-GEMM tax).
    pub fn forward_into(&self, x: &Matrix, y: &mut Matrix) {
        self.base.matmul_transb_into(x, y);
        let t = matmul_transb(x, &self.lora_a); // x·L_aᵀ : t×r
        let adapter = matmul_transb(&t, &self.lora_b); // ·L_bᵀ : t×n
        y.axpy(self.scaling, &adapter);
    }

    /// Adapter gradients given x (t×m) and upstream g = ∂L/∂y (t×n):
    /// ∇L_b = s·gᵀ·(x L_aᵀ), ∇L_a = s·(L_bᵀ gᵀ)·x.
    pub fn adapter_grads(&self, x: &Matrix, g: &Matrix) -> (Matrix, Matrix) {
        let t = matmul_transb(x, &self.lora_a); // t×r
        let gb = matmul_at_b(g, &t).scale(self.scaling); // (t×n)ᵀ(t×r) = n×r
        let gt = matmul(g, &self.lora_b); // t×r  (dL/dt)
        let ga = matmul_at_b(&gt, x).scale(self.scaling); // (t×r)ᵀ(t×m) = r×m
        (gb, ga)
    }

    /// The additive update ΔW = s·L_b L_a (strictly rank ≤ r — Figure 3).
    pub fn delta_w(&self) -> Matrix {
        matmul(&self.lora_b, &self.lora_a).scale(self.scaling)
    }

    /// Bytes of packed base storage + fp32 adapter side-cars.
    pub fn weight_bytes(&self) -> usize {
        self.base.weight_bytes() + 4 * (self.lora_a.len() + self.lora_b.len())
    }
}

impl QuantizedLinear for QloraLinear {
    fn dequantize(&self) -> Matrix {
        self.base.dequantize().add(&self.delta_w())
    }

    fn float_params(&self) -> usize {
        self.base.float_params() + self.lora_a.len() + self.lora_b.len()
    }

    fn code_bits(&self) -> f32 {
        self.base.code_bits()
    }

    fn method_name(&self) -> &'static str {
        "QLoRA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_allclose;

    #[test]
    fn zero_init_is_noop() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(24, 32, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let q = QloraLinear::new(&w, 16, 8, &cb, &mut rng);
        let x = Matrix::randn(5, 32, 1.0, &mut rng);
        let y_adapter = q.forward(&x);
        let y_base = q.base.matmul_transb(&x);
        assert_allclose(&y_adapter.data, &y_base.data, 1e-6, 1e-6, "zero-init adapter");
    }

    #[test]
    fn forward_matches_dense_dequant() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 32, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let mut q = QloraLinear::new(&w, 16, 4, &cb, &mut rng);
        rng.fill_normal(&mut q.lora_b.data, 0.0, 0.05); // make adapter nontrivial
        let x = Matrix::randn(7, 32, 1.0, &mut rng);
        let fused = q.forward(&x);
        let dense = matmul_transb(&x, &q.dequantize());
        assert_allclose(&fused.data, &dense.data, 1e-4, 1e-4, "qlora forward");
    }

    #[test]
    fn adapter_grads_match_finite_difference() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let mut q = QloraLinear::new(&w, 8, 3, &cb, &mut rng);
        rng.fill_normal(&mut q.lora_b.data, 0.0, 0.05);
        let x = Matrix::randn(4, 16, 1.0, &mut rng);
        // L = Σ y  ⇒ g = 1
        let g = Matrix::ones(4, 8);
        let (gb, ga) = q.adapter_grads(&x, &g);
        let eps = 1e-3;
        let loss = |q: &QloraLinear| -> f32 { q.forward(&x).data.iter().sum() };
        // check two entries of each
        for (mat, grad, i, j) in [(0, &gb, 2usize, 1usize), (1, &ga, 1, 5)] {
            let mut qp = q.clone();
            let mut qm = q.clone();
            let (tp, tm) = if mat == 0 {
                (qp.lora_b.at_mut(i, j), qm.lora_b.at_mut(i, j))
            } else {
                (qp.lora_a.at_mut(i, j), qm.lora_a.at_mut(i, j))
            };
            *tp += eps;
            *tm -= eps;
            let fd = (loss(&qp) - loss(&qm)) / (2.0 * eps);
            let an = grad.at(i, j);
            assert!((fd - an).abs() < 2e-2 * fd.abs().max(1.0), "mat{mat}[{i},{j}]: fd {fd} vs {an}");
        }
    }

    #[test]
    fn delta_w_rank_bounded() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(20, 20, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let mut q = QloraLinear::new(&w, 10, 4, &cb, &mut rng);
        rng.fill_normal(&mut q.lora_b.data, 0.0, 0.1);
        let sv = crate::linalg::svd(&q.delta_w()).s;
        let eff = sv.iter().filter(|&&s| s > 1e-4 * sv[0].max(1e-12)).count();
        assert!(eff <= 4, "additive ΔW must be rank ≤ r, got {eff}");
    }

    #[test]
    fn float_params_include_adapter() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(32, 64, 0.1, &mut rng);
        let cb = Codebook::normal_float(4);
        let q = QloraLinear::new(&w, 16, 8, &cb, &mut rng);
        assert_eq!(q.float_params(), 32 * 64 / 16 + 8 * (32 + 64));
    }
}
