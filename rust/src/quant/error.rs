//! Quantization-error metrics.
//!
//! * QuantError = ‖W − Ŵ‖_* (nuclear norm of the residual) — Table 2.
//! * Reduction ratio = 1 − ‖W−Ŵ‖_* / ‖W−nf4(W)‖_* — Appendix B
//!   (Tables 8–9); higher is better, NF4 is the zero baseline.

use super::blockwise::BlockwiseQuant;
use super::codebook::Codebook;
use super::QuantizedLinear;
use crate::linalg::nuclear_norm;
use crate::tensor::Matrix;

/// ‖W − Ŵ‖_* — the paper's QuantError.
pub fn quant_error_nuclear(w: &Matrix, w_hat: &Matrix) -> f32 {
    nuclear_norm(&w.sub(w_hat))
}

/// ‖W − Ŵ‖_F — cheaper tracking metric used inside refinement loops.
pub fn quant_error_frob(w: &Matrix, w_hat: &Matrix) -> f32 {
    w.sub(w_hat).frob_norm()
}

/// ‖W − Ŵ‖_F / ‖W‖_F — scale-free variant the quality telemetry exports,
/// comparable across layers of very different magnitude. 0 when `w` is
/// all-zero (a zero reference reconstructed as zero is exact).
pub fn quant_error_rel_frob(w: &Matrix, w_hat: &Matrix) -> f32 {
    let denom = w.frob_norm();
    if denom == 0.0 {
        return 0.0;
    }
    quant_error_frob(w, w_hat) / denom
}

/// Appendix B reduction ratio vs. the NF4 block-wise baseline, in percent.
pub fn reduction_ratio_pct(w: &Matrix, w_hat: &Matrix, block: usize) -> f32 {
    let nf4 = BlockwiseQuant::quantize(w, block, &Codebook::normal_float(4));
    let base = quant_error_nuclear(w, &nf4.dequantize());
    if base == 0.0 {
        return 0.0;
    }
    100.0 * (1.0 - quant_error_nuclear(w, w_hat) / base)
}

/// Reduction ratio against an explicit baseline reconstruction.
pub fn reduction_ratio_vs(w: &Matrix, w_hat: &Matrix, w_base: &Matrix) -> f32 {
    let base = quant_error_nuclear(w, w_base);
    if base == 0.0 {
        return 0.0;
    }
    100.0 * (1.0 - quant_error_nuclear(w, w_hat) / base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zero_error_for_identical() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(12, 12, 1.0, &mut rng);
        assert!(quant_error_nuclear(&w, &w) < 1e-4);
        assert!(quant_error_frob(&w, &w) < 1e-6);
        assert!(quant_error_rel_frob(&w, &w) < 1e-6);
    }

    #[test]
    fn rel_frob_is_scale_free() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(16, 16, 1.0, &mut rng);
        let w_hat = w.scale(0.9);
        let r1 = quant_error_rel_frob(&w, &w_hat);
        let r2 = quant_error_rel_frob(&w.scale(100.0), &w_hat.scale(100.0));
        assert!((r1 - r2).abs() < 1e-5, "{r1} vs {r2}");
        assert!((r1 - 0.1).abs() < 1e-4, "‖W−0.9W‖/‖W‖ = 0.1, got {r1}");
        // All-zero reference: defined as exact, not NaN.
        let z = Matrix::zeros(4, 4);
        assert_eq!(quant_error_rel_frob(&z, &z), 0.0);
    }

    #[test]
    fn nf4_baseline_ratio_is_zero() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(32, 32, 0.1, &mut rng);
        let nf4 = BlockwiseQuant::quantize(&w, 16, &Codebook::normal_float(4));
        let r = reduction_ratio_pct(&w, &nf4.dequantize(), 16);
        assert!(r.abs() < 1e-3, "NF4 vs itself must be 0, got {r}");
    }

    #[test]
    fn better_reconstruction_higher_ratio() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(24, 24, 0.1, &mut rng);
        let nf4 = BlockwiseQuant::quantize(&w, 8, &Codebook::normal_float(4));
        let w_nf4 = nf4.dequantize();
        // mix toward the exact weights = strictly better reconstruction
        let better = w_nf4.scale(0.5).add(&w.scale(0.5));
        let r = reduction_ratio_vs(&w, &better, &w_nf4);
        assert!(r > 0.0);
        let perfect = reduction_ratio_vs(&w, &w, &w_nf4);
        assert!((perfect - 100.0).abs() < 1e-3);
    }

    #[test]
    fn nuclear_dominates_frobenius() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(16, 20, 1.0, &mut rng);
        let w_hat = Matrix::zeros(16, 20);
        assert!(quant_error_nuclear(&w, &w_hat) >= quant_error_frob(&w, &w_hat) - 1e-3);
    }
}
