//! LoRDS — Low-Rank Decomposed Scaling (Sections 3.2–3.3, Algorithm 1).
//!
//! The quantized representation is `Ŵ = lut[Q] ⊙ (BA)` with
//! B ∈ R^{n×r}, A ∈ R^{r×m}. Construction:
//!
//! 1. **Init** — truncated SVD of the block-wise scale matrix (eq. 3), so
//!    the starting point exactly reproduces block-wise statistics.
//! 2. **Iterative refinement** — alternate (2.1) the quantization step
//!    `Q_ij = argmin_v (S_ij·v − W_ij)²` with S = BA fixed, and (2.2) the
//!    adaptation step: AdamW on `‖W − (BA) ⊙ Q‖_F²` with Q fixed.
//!
//! The same struct doubles as the PEFT adapter (Section 3.4): fine-tuning
//! updates only `b`/`a`, yielding the multiplicative high-rank update
//! `ΔW = Q ⊙ (B'A' − BA)` at zero inference overhead.

use super::codebook::Codebook;
use super::scale::{lords_init, parity_rank};
use super::QuantizedLinear;
use crate::kernels::{self, PackedCodes};
use crate::optim::{AdamW, Optimizer};
use crate::tensor::{matmul, matmul_at_b, matmul_transb, Matrix};
use crate::util::{SharedMut, ThreadPool};

/// Refinement hyper-parameters (paper §4.1: 500 steps, lr 0.05).
#[derive(Clone, Copy, Debug)]
pub struct RefineCfg {
    pub steps: usize,
    pub lr: f32,
    /// Re-run the quantization step every `requant_every` adaptation steps.
    /// 1 = strict Algorithm 1; larger values trade fidelity for speed.
    pub requant_every: usize,
}

impl Default for RefineCfg {
    fn default() -> Self {
        RefineCfg { steps: 100, lr: 0.05, requant_every: 5 }
    }
}

/// Trace of the refinement run (Table 2's before/after evidence).
#[derive(Clone, Debug, Default)]
pub struct RefineReport {
    /// ‖W − Ŵ‖_F at SVD init (step 0).
    pub initial_frob: f32,
    /// ‖W − Ŵ‖_F after refinement.
    pub final_frob: f32,
    /// (step, frob error) samples along the way.
    pub trace: Vec<(usize, f32)>,
}

/// The LoRDS quantized weight. Codes live bit-packed (2/3/4 bits per
/// element — [`PackedCodes`]), not one `u8` per element.
#[derive(Clone, Debug)]
pub struct LordsQuant {
    pub codes: PackedCodes,
    pub rows: usize,
    pub cols: usize,
    pub rank: usize,
    pub b: Matrix,
    pub a: Matrix,
    pub codebook: Codebook,
}

impl LordsQuant {
    /// Quantize with the parameter-parity rank of Appendix A.
    pub fn quantize(w: &Matrix, block: usize, codebook: &Codebook, cfg: RefineCfg) -> (Self, RefineReport) {
        let r = parity_rank(w.rows, w.cols, block);
        Self::quantize_with_rank(w, block, r, codebook, cfg)
    }

    /// Quantize with an explicit rank (LoRDS† parameter alignment, ablations).
    pub fn quantize_with_rank(
        w: &Matrix,
        block: usize,
        rank: usize,
        codebook: &Codebook,
        cfg: RefineCfg,
    ) -> (Self, RefineReport) {
        // Step 1: SVD init from block-wise statistics (eq. 3)
        let (b, a) = lords_init(w, block, rank);
        let bits = PackedCodes::bits_needed(codebook.len());
        let mut q = LordsQuant {
            codes: PackedCodes::zeros(bits, w.rows, w.cols),
            rows: w.rows,
            cols: w.cols,
            rank,
            b,
            a,
            codebook: codebook.clone(),
        };
        q.requantize(w);
        let mut report = RefineReport {
            initial_frob: q.dequantize().sub(w).frob_norm(),
            ..Default::default()
        };
        report.trace.push((0, report.initial_frob));

        // Step 2: alternating refinement
        if cfg.steps > 0 {
            q.refine(w, cfg, &mut report);
        }
        report.final_frob = q.dequantize().sub(w).frob_norm();
        (q, report)
    }

    /// Algorithm 1 step 2.1: recompute Q = argmin_v (S·v − W)² with S = BA.
    pub fn requantize(&mut self, w: &Matrix) {
        let s = matmul(&self.b, &self.a);
        let cols = self.cols;
        let cb = &self.codebook;
        let bits = self.codes.bits();
        let wpr = self.codes.words_per_row();
        // rows are word-aligned, so parallel workers repack disjoint words
        let words_ptr = SharedMut(self.codes.words_mut().as_mut_ptr());
        let wp = &words_ptr;
        ThreadPool::global().parallel_for(self.rows, move |lo, hi| {
            let mut rowbuf = vec![0u8; cols];
            for i in lo..hi {
                let wrow = w.row(i);
                let srow = s.row(i);
                for j in 0..cols {
                    rowbuf[j] = cb.quantize_one(wrow[j], srow[j]) as u8;
                }
                // SAFETY: packed rows are word-aligned (`words_per_row`
                // words each), so row `i`'s word slice is disjoint across
                // workers; the code store outlives the parallel_for join.
                let out = unsafe { std::slice::from_raw_parts_mut(wp.0.add(i * wpr), wpr) };
                PackedCodes::pack_row(bits, &rowbuf, out);
            }
        });
    }

    /// Algorithm 1 step 2.2 loop: AdamW on B, A minimizing ‖W − (BA)⊙Q‖_F².
    fn refine(&mut self, w: &Matrix, cfg: RefineCfg, report: &mut RefineReport) {
        let mut opt = AdamW::new(0.0);
        let sample_every = (cfg.steps / 10).max(1);
        for t in 0..cfg.steps {
            if t > 0 && t % cfg.requant_every == 0 {
                self.requantize(w);
            }
            // residual R = (BA)⊙Q − W ; dL/dS = 2 R ⊙ Q
            let s = matmul(&self.b, &self.a);
            let qv = self.q_values();
            let mut gs = Matrix::zeros(self.rows, self.cols);
            let mut frob2 = 0.0f64;
            for idx in 0..s.data.len() {
                let r = s.data[idx] * qv.data[idx] - w.data[idx];
                frob2 += (r as f64) * (r as f64);
                gs.data[idx] = 2.0 * r * qv.data[idx];
            }
            let gb = matmul_transb(&gs, &self.a); // (n×m)·(r×m)ᵀ = n×r
            let ga = matmul_at_b(&self.b, &gs); // (n×r)ᵀ·(n×m) = r×m
            // normalize by element count to keep lr scale-free across sizes
            let inv = 1.0 / (self.rows * self.cols) as f32;
            let gb = gb.scale(inv);
            let ga = ga.scale(inv);
            opt.step(0, &mut self.b.data, &gb.data, cfg.lr);
            opt.step(1, &mut self.a.data, &ga.data, cfg.lr);
            opt.next_step();
            if t % sample_every == 0 {
                report.trace.push((t + 1, (frob2.sqrt()) as f32));
            }
        }
        self.requantize(w);
    }

    /// lut[Q] as a dense matrix.
    pub fn q_values(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let mut crow = vec![0u8; self.cols];
        for i in 0..self.rows {
            self.codes.unpack_row_into(i, &mut crow);
            for (dst, &c) in out.row_mut(i).iter_mut().zip(&crow) {
                *dst = self.codebook.level(c as usize);
            }
        }
        out
    }

    /// The continuous scale manifold S = BA.
    pub fn scale_matrix(&self) -> Matrix {
        matmul(&self.b, &self.a)
    }

    /// Fused y = x · Ŵᵀ without materializing Ŵ: tiled packed kernel
    /// reconstructing the scale tile S[j0..j1, :] = B[j0..j1, :]·A per
    /// row-tile, mirroring the Pallas kernel (`kernels::fused`).
    pub fn matmul_transb(&self, x: &Matrix) -> Matrix {
        self.matmul_transb_opt(x, None)
    }

    /// Fused y = g · Ŵ (the backward-dx pattern), also Ŵ-free.
    pub fn matmul(&self, g: &Matrix) -> Matrix {
        self.matmul_opt(g, None)
    }

    /// Fused forward with an optional per-call scale override — the
    /// multi-tenant serving entry point: `None` dequantizes through the
    /// baked-in factors, `Some((B′, A′))` through a tenant adapter's (same
    /// shared packed codes either way; the adapter rank may differ — §3.4).
    pub fn matmul_transb_opt(&self, x: &Matrix, adapter: Option<(&Matrix, &Matrix)>) -> Matrix {
        kernels::lords_matmul_transb_adapter(
            x,
            &self.codes,
            &self.codebook.levels,
            &self.b,
            &self.a,
            adapter,
        )
    }

    /// [`Self::matmul_transb_opt`] writing into a caller-owned t×n output
    /// (fully overwritten) — the allocation-free path of the batched
    /// decode tick.
    pub fn matmul_transb_opt_into(
        &self,
        x: &Matrix,
        adapter: Option<(&Matrix, &Matrix)>,
        y: &mut Matrix,
    ) {
        kernels::lords_matmul_transb_adapter_into(
            x,
            &self.codes,
            &self.codebook.levels,
            &self.b,
            &self.a,
            adapter,
            y,
        );
    }

    /// Fused backward-dx with an optional per-call scale override (see
    /// [`Self::matmul_transb_opt`]).
    pub fn matmul_opt(&self, g: &Matrix, adapter: Option<(&Matrix, &Matrix)>) -> Matrix {
        kernels::lords_matmul_adapter(g, &self.codes, &self.codebook.levels, &self.b, &self.a, adapter)
    }

    /// Tenant-view forward y = x · Ŵ′ᵀ with Ŵ′ = lut[Q] ⊙ (B′A′).
    pub fn matmul_transb_with(&self, x: &Matrix, b: &Matrix, a: &Matrix) -> Matrix {
        self.matmul_transb_opt(x, Some((b, a)))
    }

    /// Tenant-view y = g · Ŵ′ (see [`Self::matmul_transb_with`]).
    pub fn matmul_with(&self, g: &Matrix, b: &Matrix, a: &Matrix) -> Matrix {
        self.matmul_opt(g, Some((b, a)))
    }

    /// Dense-merged tenant weight Ŵ′ = lut[Q] ⊙ (B′A′) — the reference the
    /// fused adapter path is tested against.
    pub fn dequantize_with(&self, b: &Matrix, a: &Matrix) -> Matrix {
        self.q_values().hadamard(&matmul(b, a))
    }

    /// Bytes of packed code storage + fp32 side-cars (B, A).
    pub fn weight_bytes(&self) -> usize {
        self.codes.mem_bytes() + 4 * (self.b.len() + self.a.len())
    }

    /// PEFT view: the multiplicative weight update induced by moving the
    /// scale factors from (B, A) to (B', A'): ΔW = Q ⊙ (B'A' − BA).
    pub fn delta_w(&self, b_new: &Matrix, a_new: &Matrix) -> Matrix {
        let ds = matmul(b_new, a_new).sub(&self.scale_matrix());
        self.q_values().hadamard(&ds)
    }
}

impl QuantizedLinear for LordsQuant {
    fn dequantize(&self) -> Matrix {
        self.q_values().hadamard(&self.scale_matrix())
    }

    fn float_params(&self) -> usize {
        self.b.len() + self.a.len()
    }

    fn code_bits(&self) -> f32 {
        self.codebook.bits()
    }

    fn method_name(&self) -> &'static str {
        "LoRDS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::BlockwiseQuant;
    use crate::util::prop::{assert_allclose, prop_check};
    use crate::util::Rng;

    fn nf4() -> Codebook {
        Codebook::normal_float(4)
    }

    /// LLM-like weights: Gaussian bulk + a few heavy outlier channels.
    fn llm_like(rng: &mut Rng, n: usize, m: usize) -> Matrix {
        let mut w = Matrix::randn(n, m, 0.05, rng);
        let outliers = rng.choose(m, (m / 16).max(1));
        for &c in &outliers {
            for i in 0..n {
                *w.at_mut(i, c) *= 8.0;
            }
        }
        w
    }

    #[test]
    fn init_matches_blockwise_error_at_step_zero() {
        // With refinement disabled and full rank, LoRDS must equal blockwise.
        let mut rng = Rng::new(0);
        let w = Matrix::randn(32, 64, 0.1, &mut rng);
        let block = 16;
        let cfg = RefineCfg { steps: 0, ..Default::default() };
        let (q, rep) = LordsQuant::quantize_with_rank(&w, block, 64 / block, &nf4(), cfg);
        let bw = BlockwiseQuant::quantize(&w, block, &nf4());
        let err_lords = q.dequantize().sub(&w).frob_norm();
        let err_block = bw.dequantize().sub(&w).frob_norm();
        assert!((err_lords - err_block).abs() / err_block < 5e-3, "{err_lords} vs {err_block}");
        assert!((rep.initial_frob - err_lords).abs() < 1e-5);
    }

    #[test]
    fn refinement_strictly_reduces_error() {
        let mut rng = Rng::new(1);
        let w = llm_like(&mut rng, 64, 96);
        let cfg = RefineCfg { steps: 80, lr: 0.05, requant_every: 5 };
        let (_, rep) = LordsQuant::quantize_with_rank(&w, 16, 4, &nf4(), cfg);
        assert!(
            rep.final_frob < rep.initial_frob * 0.98,
            "refinement did not help: {} -> {}",
            rep.initial_frob,
            rep.final_frob
        );
    }

    #[test]
    fn beats_blockwise_at_parity_budget_on_outlier_weights() {
        // The paper's Table 1/8 claim at the single-matrix level.
        let mut rng = Rng::new(2);
        let w = llm_like(&mut rng, 96, 128);
        let block = 32;
        let bw = BlockwiseQuant::quantize(&w, block, &nf4());
        let cfg = RefineCfg { steps: 120, lr: 0.05, requant_every: 5 };
        let (lq, _) = LordsQuant::quantize(&w, block, &nf4(), cfg);
        assert!(lq.float_params() <= bw.float_params() + (w.rows + w.cols)); // parity (floor slack)
        let err_lords = lq.dequantize().sub(&w).frob_norm();
        let err_block = bw.dequantize().sub(&w).frob_norm();
        assert!(err_lords < err_block, "LoRDS {err_lords} !< blockwise {err_block}");
    }

    #[test]
    fn fused_matmul_matches_dense() {
        prop_check(8, |g| {
            let n = g.usize(8..=32);
            let m = g.usize(2..=6) * 16;
            let t = g.usize(1..=8);
            let mut rng = g.rng().fork(9);
            let w = llm_like(&mut rng, n, m);
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            let cfg = RefineCfg { steps: 10, ..Default::default() };
            let (q, _) = LordsQuant::quantize_with_rank(&w, 16, 3, &nf4(), cfg);
            if !q.b.all_finite() || !q.a.all_finite() {
                return Err(format!("non-finite scale factors at n={n} m={m}"));
            }
            let fused = q.matmul_transb(&x);
            let dense = matmul_transb(&x, &q.dequantize());
            assert_allclose(&fused.data, &dense.data, 1e-4, 1e-4, "fused lords matmul");
            Ok(())
        });
    }

    #[test]
    fn tenant_view_matches_dense_merged() {
        let mut rng = Rng::new(9);
        let w = llm_like(&mut rng, 24, 32);
        let cfg = RefineCfg { steps: 10, ..Default::default() };
        let (q, _) = LordsQuant::quantize_with_rank(&w, 16, 2, &nf4(), cfg);
        // tenant factors at a different rank than the quantizer's
        let mut prng = Rng::new(10);
        let b2 = Matrix::randn(24, 3, 0.2, &mut prng);
        let a2 = Matrix::randn(3, 32, 0.2, &mut prng);
        let w_merged = q.dequantize_with(&b2, &a2);
        let x = Matrix::randn(5, 32, 1.0, &mut prng);
        assert_allclose(
            &q.matmul_transb_with(&x, &b2, &a2).data,
            &matmul_transb(&x, &w_merged).data,
            1e-4,
            1e-4,
            "tenant fwd",
        );
        let g = Matrix::randn(5, 24, 1.0, &mut prng);
        assert_allclose(
            &q.matmul_with(&g, &b2, &a2).data,
            &matmul(&g, &w_merged).data,
            1e-4,
            1e-4,
            "tenant bwd",
        );
    }

    #[test]
    fn delta_w_is_high_rank() {
        // Section 3.4 / Figure 3: the multiplicative update escapes rank r.
        let mut rng = Rng::new(4);
        let w = llm_like(&mut rng, 48, 48);
        let cfg = RefineCfg { steps: 20, ..Default::default() };
        let (q, _) = LordsQuant::quantize_with_rank(&w, 16, 2, &nf4(), cfg);
        let mut b_new = q.b.clone();
        let mut a_new = q.a.clone();
        let mut prng = Rng::new(5);
        for v in b_new.data.iter_mut() {
            *v += 0.02 * prng.normal();
        }
        for v in a_new.data.iter_mut() {
            *v += 0.02 * prng.normal();
        }
        let dw = q.delta_w(&b_new, &a_new);
        let sv = crate::linalg::svd(&dw).s;
        let effective = sv.iter().filter(|&&s| s > 1e-3 * sv[0]).count();
        assert!(effective > 3 * q.rank, "ΔW rank {effective} should exceed 3r = {}", 3 * q.rank);
    }

    #[test]
    fn codes_are_optimal_given_scales() {
        let mut rng = Rng::new(6);
        let w = llm_like(&mut rng, 16, 32);
        let cfg = RefineCfg { steps: 15, ..Default::default() };
        let (q, _) = LordsQuant::quantize_with_rank(&w, 16, 2, &nf4(), cfg);
        let s = q.scale_matrix();
        let cb = nf4();
        for i in 0..w.rows {
            for j in 0..w.cols {
                let got = q.codes.get(i, j) as usize;
                let best = (0..cb.len())
                    .min_by(|&x, &y| {
                        let ex = (s.at(i, j) * cb.level(x) - w.at(i, j)).powi(2);
                        let ey = (s.at(i, j) * cb.level(y) - w.at(i, j)).powi(2);
                        ex.partial_cmp(&ey).unwrap()
                    })
                    .unwrap();
                let e_got = (s.at(i, j) * cb.level(got) - w.at(i, j)).powi(2);
                let e_best = (s.at(i, j) * cb.level(best) - w.at(i, j)).powi(2);
                assert!(e_got <= e_best + 1e-10);
            }
        }
    }

    #[test]
    fn float_param_budget_is_r_n_plus_m() {
        let mut rng = Rng::new(7);
        let w = Matrix::randn(64, 128, 1.0, &mut rng);
        let cfg = RefineCfg { steps: 0, ..Default::default() };
        let (q, _) = LordsQuant::quantize_with_rank(&w, 32, 5, &nf4(), cfg);
        assert_eq!(q.float_params(), 5 * (64 + 128));
    }
}
