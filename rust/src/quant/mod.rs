//! The paper's core: quantization with continuous low-rank decomposed
//! scaling (LoRDS), plus every baseline it is evaluated against.
//!
//! * [`codebook`]  — NormalFloat (NF4/NF3/NF2) + integer grids.
//! * [`blockwise`] — block-wise absmax quantization (the structure LoRDS
//!   "breaks"); NF4 here = QLoRA's storage format.
//! * [`scale`]     — scale-matrix algebra: S = s ⊗ 1, parity rank
//!   r = ⌊nm/(B(n+m))⌋ (Appendix A), SVD init (eq. 3).
//! * [`lords`]     — Algorithm 1: SVD init + alternating quantization /
//!   AdamW adaptation refinement; the LoRDS quantized representation.
//! * [`ste`]       — fake-quant forward + STE gradients (eqs. 4–5) used by
//!   the Rust QAT trainer.
//! * [`mixed`]     — layer-wise mixed-precision schedules (3 / 2.5 / 2.25 /
//!   2-bit: NF4 on a prefix of layers, NF2 on the rest — §4.1).
//! * [`error`]     — QuantError (nuclear norm) + reduction-ratio metrics
//!   (Table 2, Appendix B).
//! * [`baselines`] — GPTQ, AWQ, LoftQ, QPiSSA, QLoRA.
//!
//! Serving-path storage: [`LordsQuant`], [`BlockwiseQuant`], and the QLoRA
//! NF4 base keep their codes bit-packed ([`crate::kernels::PackedCodes`])
//! and forward through the fused kernels in [`crate::kernels::fused`].

pub mod baselines;
pub mod blockwise;
pub mod codebook;
pub mod error;
pub mod lords;
pub mod mixed;
pub mod scale;
pub mod ste;

pub use blockwise::BlockwiseQuant;
pub use codebook::Codebook;
pub use lords::{LordsQuant, RefineReport};
pub use scale::parity_rank;

use crate::tensor::Matrix;

/// A quantized weight that can reproduce its dequantized (effective) matrix
/// and report its floating-point parameter overhead (the #Float column of
/// Tables 3/5/8).
pub trait QuantizedLinear {
    /// Dequantized Ŵ.
    fn dequantize(&self) -> Matrix;
    /// Number of fp32 side-car parameters (scales, adapters, B/A...).
    fn float_params(&self) -> usize;
    /// Bits per weight element for the integer part.
    fn code_bits(&self) -> f32;
    /// Human-readable method name.
    fn method_name(&self) -> &'static str;
}
