//! Quantization codebooks: NormalFloat (Dettmers et al. 2023) and symmetric
//! integer grids, with fast nearest-level lookup.
//!
//! NFk places quantiles of N(0, 1) so each level is equally probable under a
//! Gaussian weight prior, rescaled to [-1, 1] with an exactly-representable
//! zero. Construction matches `python/compile/kernels/ref.py` bit-for-bit in
//! spirit (both sides are independently tested against the published NF4
//! levels), and serving paths read the authoritative LUT from the AOT
//! manifest so Rust and the HLO artifacts can never disagree.

/// A sorted table of dequantization levels in [-1, 1].
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub name: String,
    pub levels: Vec<f32>,
}

impl Codebook {
    /// NormalFloat with 2^bits levels.
    pub fn normal_float(bits: u32) -> Codebook {
        let n = 1usize << bits;
        let offset = 0.967_708_3_f64; // quantile clip, as in bitsandbytes
        let half = n / 2;
        let mut levels = Vec::with_capacity(n);
        // negative side: half+1 quantiles of [1-offset, 0.5], drop the 0.5
        for i in 0..half {
            let p = (1.0 - offset) + (0.5 - (1.0 - offset)) * i as f64 / half as f64;
            levels.push(inverse_normal_cdf(p) as f32);
        }
        // positive side: half quantiles of [0.5, offset]
        for i in 0..half {
            let p = 0.5 + (offset - 0.5) * i as f64 / (half - 1).max(1) as f64;
            levels.push(inverse_normal_cdf(p) as f32);
        }
        let max_abs = levels.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for v in levels.iter_mut() {
            *v /= max_abs;
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // snap the central level to exactly zero
        let zi = levels
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        levels[zi] = 0.0;
        Codebook { name: format!("nf{bits}"), levels }
    }

    /// Symmetric signed integer grid scaled to [-1, 1] (INT4 = -7..7 / 7).
    pub fn int(bits: u32) -> Codebook {
        let qmax = (1i64 << (bits - 1)) - 1;
        let levels = (-qmax..=qmax).map(|v| v as f32 / qmax as f32).collect();
        Codebook { name: format!("int{bits}"), levels }
    }

    pub fn by_name(name: &str) -> Option<Codebook> {
        if let Some(bits) = name.strip_prefix("nf") {
            return Some(Codebook::normal_float(bits.parse().ok()?));
        }
        if let Some(bits) = name.strip_prefix("int") {
            return Some(Codebook::int(bits.parse().ok()?));
        }
        None
    }

    /// Build from explicit levels (e.g. the AOT-manifest LUT).
    pub fn from_levels(name: &str, levels: Vec<f32>) -> Codebook {
        let mut levels = levels;
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Codebook { name: name.to_string(), levels }
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    pub fn bits(&self) -> f32 {
        (self.levels.len() as f32).log2()
    }

    /// Index of the level nearest to `x` (binary search on the sorted table).
    /// Non-finite inputs are clamped: NaN → the zero level, ±inf → the ends.
    #[inline]
    pub fn nearest(&self, x: f32) -> usize {
        let lv = &self.levels;
        if !x.is_finite() {
            if x.is_nan() {
                return lv.iter().position(|&v| v == 0.0).unwrap_or(lv.len() / 2);
            }
            return if x < 0.0 { 0 } else { lv.len() - 1 };
        }
        match lv.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => {
                if i == 0 {
                    0
                } else if i >= lv.len() {
                    lv.len() - 1
                } else if (x - lv[i - 1]).abs() <= (lv[i] - x).abs() {
                    i - 1
                } else {
                    i
                }
            }
        }
    }

    /// Algorithm 1's quantization step for one element:
    /// `argmin_v (s·v − w)²`. For s > 0 this is `nearest(w/s)`; for s < 0 the
    /// argmin flips to the mirrored ratio; s = 0 picks the zero level.
    #[inline]
    pub fn quantize_one(&self, w: f32, s: f32) -> usize {
        if s == 0.0 {
            return self.nearest(0.0);
        }
        self.nearest(w / s)
    }

    #[inline]
    pub fn level(&self, idx: usize) -> f32 {
        self.levels[idx]
    }
}

/// Acklam's rational approximation of the inverse normal CDF (|ε| < 1.15e-9,
/// plenty for codebook construction; cross-checked against scipy in tests).
pub fn inverse_normal_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p out of range: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_cdf_sanity() {
        assert!(inverse_normal_cdf(0.5).abs() < 1e-9);
        assert!((inverse_normal_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inverse_normal_cdf(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn nf4_matches_published_levels() {
        let cb = Codebook::normal_float(4);
        assert_eq!(cb.len(), 16);
        let published = [
            -1.0, -0.6961928, -0.52507305, -0.39491749, -0.28444138, -0.18477343,
            -0.09105004, 0.0, 0.0795803, 0.1609302, 0.2461123, 0.33791524,
            0.44070983, 0.562617, 0.72295684, 1.0,
        ];
        // our variant mirrors which half carries the extra level; compare the
        // sorted absolute grids
        let mut ours: Vec<f32> = cb.levels.iter().map(|v| v.abs()).collect();
        let mut pubs: Vec<f32> = published.iter().map(|v: &f32| v.abs()).collect();
        ours.sort_by(|a, b| a.partial_cmp(b).unwrap());
        pubs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (o, p) in ours.iter().zip(&pubs) {
            assert!((o - p).abs() < 2e-4, "{o} vs {p}");
        }
    }

    #[test]
    fn properties_all_widths() {
        for bits in [2u32, 3, 4] {
            let cb = Codebook::normal_float(bits);
            assert_eq!(cb.len(), 1 << bits);
            assert_eq!(cb.levels[0], -1.0);
            assert_eq!(*cb.levels.last().unwrap(), 1.0);
            assert!(cb.levels.contains(&0.0));
            assert!(cb.levels.windows(2).all(|w| w[0] < w[1]));
            assert!((cb.bits() - bits as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn int_grid() {
        let cb = Codebook::int(4);
        assert_eq!(cb.len(), 15);
        assert_eq!(cb.levels[0], -1.0);
        assert!(cb.levels.contains(&0.0));
        let diffs: Vec<f32> = cb.levels.windows(2).map(|w| w[1] - w[0]).collect();
        for d in diffs {
            assert!((d - 1.0 / 7.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nearest_is_argmin() {
        let cb = Codebook::normal_float(4);
        for x in [-2.0f32, -1.0, -0.31, -0.001, 0.0, 0.17, 0.9, 3.5] {
            let got = cb.nearest(x);
            let want = cb
                .levels
                .iter()
                .enumerate()
                .min_by(|a, b| (a.1 - x).abs().partial_cmp(&(b.1 - x).abs()).unwrap())
                .unwrap()
                .0;
            assert_eq!(cb.level(got), cb.level(want), "x={x}");
        }
    }

    #[test]
    fn quantize_one_handles_negative_and_zero_scale() {
        let cb = Codebook::normal_float(4);
        // s < 0: argmin_v (s·v − w)² still minimized by v = w/s
        let (w, s) = (0.5f32, -1.0f32);
        let idx = cb.quantize_one(w, s);
        let best = cb
            .levels
            .iter()
            .enumerate()
            .min_by(|a, b| {
                let ea = (s * a.1 - w).powi(2);
                let eb = (s * b.1 - w).powi(2);
                ea.partial_cmp(&eb).unwrap()
            })
            .unwrap()
            .0;
        assert_eq!(cb.level(idx), cb.level(best));
        // s = 0 → zero level
        assert_eq!(cb.level(cb.quantize_one(0.3, 0.0)), 0.0);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(Codebook::by_name("nf4").unwrap().len(), 16);
        assert_eq!(Codebook::by_name("nf2").unwrap().len(), 4);
        assert_eq!(Codebook::by_name("int8").unwrap().len(), 255);
        assert!(Codebook::by_name("fp4").is_none());
    }
}
