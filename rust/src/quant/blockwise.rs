//! Block-wise (group-wise) quantization — the baseline structure LoRDS
//! breaks (Section 3.1).
//!
//! A weight matrix W ∈ R^{n×m} is split into contiguous blocks of size B
//! along the row (in-features) direction; each block gets an absmax scale
//! s_b and codes Q_b = argmin‖s_b·v − w‖ over the codebook. With the NF4
//! codebook this is exactly the QLoRA/bitsandbytes storage format.

use super::codebook::Codebook;
use super::QuantizedLinear;
use crate::kernels::{self, PackedCodes};
use crate::tensor::Matrix;
use crate::util::{SharedMut, ThreadPool};

/// Block-wise quantized weight: bit-packed codes + per-block scales.
#[derive(Clone, Debug)]
pub struct BlockwiseQuant {
    pub codes: PackedCodes,
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// n × (m / block) absmax scales.
    pub scales: Matrix,
    pub codebook: Codebook,
}

impl BlockwiseQuant {
    /// Quantize `w` with block size `block` (must divide w.cols).
    pub fn quantize(w: &Matrix, block: usize, codebook: &Codebook) -> BlockwiseQuant {
        assert!(block > 0 && w.cols % block == 0, "block {block} !| cols {}", w.cols);
        let nb = w.cols / block;
        let mut scales = Matrix::zeros(w.rows, nb);
        let bits = PackedCodes::bits_needed(codebook.len());
        let mut codes = PackedCodes::zeros(bits, w.rows, w.cols);

        let wpr = codes.words_per_row();
        // rows are word-aligned in PackedCodes, so workers touch disjoint
        // words; scale rows are disjoint too.
        let codes_ptr = SharedMut(codes.words_mut().as_mut_ptr());
        let scales_ptr = SharedMut(scales.data.as_mut_ptr());
        let cp = &codes_ptr;
        let sp = &scales_ptr;
        ThreadPool::global().parallel_for(w.rows, move |lo, hi| {
            let mut rowbuf = vec![0u8; w.cols];
            for i in lo..hi {
                let row = w.row(i);
                for b in 0..nb {
                    let blk = &row[b * block..(b + 1) * block];
                    let mut s = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    if s == 0.0 {
                        s = 1.0;
                    }
                    // SAFETY: scale row `i` belongs to this worker's chunk
                    // alone; the scale matrix outlives the parallel_for join.
                    unsafe { *sp.0.add(i * nb + b) = s };
                    for (k, &v) in blk.iter().enumerate() {
                        rowbuf[b * block + k] = codebook.quantize_one(v, s) as u8;
                    }
                }
                // SAFETY: packed rows are word-aligned, so row `i`'s word
                // slice is disjoint across workers; the code store outlives
                // the parallel_for join.
                let out = unsafe { std::slice::from_raw_parts_mut(cp.0.add(i * wpr), wpr) };
                PackedCodes::pack_row(bits, &rowbuf, out);
            }
        });

        BlockwiseQuant {
            codes,
            rows: w.rows,
            cols: w.cols,
            block,
            scales,
            codebook: codebook.clone(),
        }
    }

    /// Build from already-computed flat codes + scales (GPTQ hand-off).
    pub fn from_parts(
        codes: &[u8],
        rows: usize,
        cols: usize,
        block: usize,
        scales: Matrix,
        codebook: &Codebook,
    ) -> BlockwiseQuant {
        let bits = PackedCodes::bits_needed(codebook.len());
        BlockwiseQuant {
            codes: PackedCodes::from_flat(bits, rows, cols, codes),
            rows,
            cols,
            block,
            scales,
            codebook: codebook.clone(),
        }
    }

    #[inline]
    pub fn code(&self, i: usize, j: usize) -> u8 {
        self.codes.get(i, j)
    }

    /// Scale applied to element (i, j).
    #[inline]
    pub fn scale_at(&self, i: usize, j: usize) -> f32 {
        self.scales.at(i, j / self.block)
    }

    /// The full scale matrix S = s ⊗ 1_{1×B}.
    pub fn scale_matrix(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| self.scale_at(i, j))
    }

    /// y = x · Ŵᵀ fused with on-the-fly unpack + dequantization (no Ŵ
    /// materialization) — the Rust-native analogue of the Pallas blockwise
    /// kernel (`kernels::fused`).
    pub fn matmul_transb(&self, x: &Matrix) -> Matrix {
        kernels::blockwise_matmul_transb(x, &self.codes, &self.codebook.levels, &self.scales, self.block)
    }

    /// [`Self::matmul_transb`] writing into a caller-owned t×n output
    /// (fully overwritten; see `kernels::blockwise_matmul_transb_into`).
    pub fn matmul_transb_into(&self, x: &Matrix, y: &mut Matrix) {
        kernels::blockwise_matmul_transb_into(
            x,
            &self.codes,
            &self.codebook.levels,
            &self.scales,
            self.block,
            y,
        );
    }

    /// Fused y = g · Ŵ (the backward-dx pattern), also Ŵ-free.
    pub fn matmul(&self, g: &Matrix) -> Matrix {
        kernels::blockwise_matmul(g, &self.codes, &self.codebook.levels, &self.scales, self.block)
    }

    /// Bytes of packed code storage + fp32 scale side-cars.
    pub fn weight_bytes(&self) -> usize {
        self.codes.mem_bytes() + 4 * self.scales.len()
    }
}

impl QuantizedLinear for BlockwiseQuant {
    fn dequantize(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| {
            self.codebook.level(self.code(i, j) as usize) * self.scale_at(i, j)
        })
    }

    fn float_params(&self) -> usize {
        self.scales.len()
    }

    fn code_bits(&self) -> f32 {
        self.codebook.bits()
    }

    fn method_name(&self) -> &'static str {
        "NF4"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, prop_check};
    use crate::util::Rng;

    fn nf4() -> Codebook {
        Codebook::normal_float(4)
    }

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(32, 64, 0.1, &mut rng);
        let q = BlockwiseQuant::quantize(&w, 16, &nf4());
        let w_hat = q.dequantize();
        // NF4 with absmax scaling: max elementwise error < half the coarsest gap × scale
        for i in 0..w.rows {
            for j in 0..w.cols {
                let err = (w.at(i, j) - w_hat.at(i, j)).abs();
                let bound = 0.2 * q.scale_at(i, j);
                assert!(err <= bound, "({i},{j}): err {err} > {bound}");
            }
        }
    }

    #[test]
    fn block_extremes_exact() {
        // the absmax element of every block quantizes to ±1 · s exactly
        let mut rng = Rng::new(1);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let q = BlockwiseQuant::quantize(&w, 8, &nf4());
        let w_hat = q.dequantize();
        for i in 0..8 {
            for b in 0..4 {
                let blk: Vec<f32> = (0..8).map(|k| w.at(i, b * 8 + k)).collect();
                let (k_max, v_max) = blk
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                assert!(
                    (w_hat.at(i, b * 8 + k_max) - v_max).abs() < 1e-6,
                    "absmax must be exactly representable"
                );
            }
        }
    }

    #[test]
    fn fused_matmul_matches_dequant_matmul() {
        prop_check(12, |g| {
            let n = g.usize(4..=24) * 2;
            let m = g.usize(2..=8) * 8;
            let t = g.usize(1..=12);
            let mut rng = g.rng().fork(3);
            let w = Matrix::randn(n, m, 0.2, &mut rng);
            let x = Matrix::randn(t, m, 1.0, &mut rng);
            let q = BlockwiseQuant::quantize(&w, 8, &nf4());
            let fused = q.matmul_transb(&x);
            let dense = crate::tensor::matmul_transb(&x, &q.dequantize());
            assert_allclose(&fused.data, &dense.data, 1e-4, 1e-4, "fused blockwise matmul");
            Ok(())
        });
    }

    #[test]
    fn scale_matrix_is_piecewise_constant() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(4, 32, 1.0, &mut rng);
        let q = BlockwiseQuant::quantize(&w, 16, &nf4());
        let s = q.scale_matrix();
        for i in 0..4 {
            for j in 0..16 {
                assert_eq!(s.at(i, j), s.at(i, 0));
                assert_eq!(s.at(i, 16 + j), s.at(i, 16));
            }
        }
    }

    #[test]
    fn float_params_budget() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(64, 128, 1.0, &mut rng);
        let q = BlockwiseQuant::quantize(&w, 32, &nf4());
        assert_eq!(q.float_params(), 64 * 128 / 32); // nm/B scales
        assert_eq!(q.code_bits(), 4.0);
    }

    #[test]
    fn packed_storage_and_backward_kernel() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(16, 32, 0.2, &mut rng);
        let q = BlockwiseQuant::quantize(&w, 8, &nf4());
        // 4-bit codes: half a byte per element, plus fp32 scales
        assert_eq!(q.weight_bytes(), 16 * 32 / 2 + 4 * q.scales.len());
        let g = Matrix::randn(5, 16, 1.0, &mut rng);
        let fused = q.matmul(&g);
        let dense = crate::tensor::matmul(&g, &q.dequantize());
        assert_allclose(&fused.data, &dense.data, 1e-4, 1e-4, "fused blockwise backward");
    }

    #[test]
    fn zero_block_is_safe() {
        let w = Matrix::zeros(2, 16);
        let q = BlockwiseQuant::quantize(&w, 8, &nf4());
        let w_hat = q.dequantize();
        assert!(w_hat.data.iter().all(|&v| v == 0.0));
    }
}
