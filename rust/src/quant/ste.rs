//! Straight-Through-Estimator fake quantization (Section 3.3, eqs. 4–5) —
//! the Rust-native counterpart of `model.make_fake_quant` used by the
//! in-process QAT trainer.
//!
//! Forward:  Ŵ = ROUND(W ⊘ S) ⊙ S with S = BA
//! Backward: ∇_W ≈ g            (eq. 4, STE identity)
//!           ∇_S ≈ g ⊙ (Q − W ⊘ S), chained: ∇_B = ∇_S Aᵀ, ∇_A = Bᵀ ∇_S

use super::codebook::Codebook;
use crate::tensor::{matmul, matmul_at_b, matmul_transb, Matrix};

/// Result of a fake-quant forward, retaining what the backward needs.
pub struct FakeQuant {
    /// Dequantized Ŵ (used in place of W by the forward pass).
    pub w_hat: Matrix,
    /// lut[Q].
    pub q_values: Matrix,
    /// S = BA.
    pub s: Matrix,
}

/// Forward fake-quant: Ŵ = lut[argmin (S·v − W)²] ⊙ S.
pub fn fake_quant(w: &Matrix, b: &Matrix, a: &Matrix, cb: &Codebook) -> FakeQuant {
    let s = matmul(b, a);
    let q_values = Matrix::from_fn(w.rows, w.cols, |i, j| {
        cb.level(cb.quantize_one(w.at(i, j), s.at(i, j)))
    });
    let w_hat = q_values.hadamard(&s);
    FakeQuant { w_hat, q_values, s }
}

/// STE gradients given upstream ∂L/∂Ŵ = `g`.
/// Returns (∇_W, ∇_B, ∇_A).
pub fn ste_grads(
    fq: &FakeQuant,
    w: &Matrix,
    b: &Matrix,
    a: &Matrix,
    g: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    // ∇_S = g ⊙ (Q − W ⊘ S)   (eq. 5)
    let w_over_s = w.hadamard_div(&fq.s);
    let gs = g.hadamard(&fq.q_values.sub(&w_over_s));
    let gb = matmul_transb(&gs, a); // (n×m)(r×m)ᵀ → n×r
    let ga = matmul_at_b(b, &gs); // (n×r)ᵀ(n×m) → r×m
    (g.clone(), gb, ga)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::lords_init;
    use crate::util::prop::assert_allclose;
    use crate::util::Rng;

    fn setup(seed: u64) -> (Matrix, Matrix, Matrix, Codebook) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(24, 32, 0.05, &mut rng);
        let (b, a) = lords_init(&w, 16, 3);
        (w, b, a, Codebook::normal_float(4))
    }

    #[test]
    fn forward_matches_manual() {
        let (w, b, a, cb) = setup(0);
        let fq = fake_quant(&w, &b, &a, &cb);
        let s = matmul(&b, &a);
        for i in 0..w.rows {
            for j in 0..w.cols {
                let code = cb.quantize_one(w.at(i, j), s.at(i, j));
                assert_eq!(fq.w_hat.at(i, j), cb.level(code) * s.at(i, j));
            }
        }
    }

    #[test]
    fn weight_grad_is_identity() {
        let (w, b, a, cb) = setup(1);
        let fq = fake_quant(&w, &b, &a, &cb);
        let mut rng = Rng::new(2);
        let g = Matrix::randn(w.rows, w.cols, 1.0, &mut rng);
        let (gw, _, _) = ste_grads(&fq, &w, &b, &a, &g);
        assert_allclose(&gw.data, &g.data, 0.0, 0.0, "STE ∇W");
    }

    #[test]
    fn scale_grads_shapes_and_chain_rule() {
        let (w, b, a, cb) = setup(3);
        let fq = fake_quant(&w, &b, &a, &cb);
        let g = Matrix::ones(w.rows, w.cols);
        let (_, gb, ga) = ste_grads(&fq, &w, &b, &a, &g);
        assert_eq!(gb.shape(), b.shape());
        assert_eq!(ga.shape(), a.shape());
        // manual chain check on one entry of ga: ga[p,j] = Σ_i b[i,p]·gs[i,j]
        let w_over_s = w.hadamard_div(&fq.s);
        let gs = g.hadamard(&fq.q_values.sub(&w_over_s));
        let (p, j) = (1, 4);
        let want: f32 = (0..w.rows).map(|i| b.at(i, p) * gs.at(i, j)).sum();
        assert!((ga.at(p, j) - want).abs() < 1e-5);
    }

    #[test]
    fn smooth_region_matches_finite_difference() {
        // With codes frozen (no flips for tiny eps), dŴ/dB is exact.
        let (w, b, a, cb) = setup(4);
        let fq = fake_quant(&w, &b, &a, &cb);
        let g = Matrix::ones(w.rows, w.cols);
        let (_, gb, _) = ste_grads(&fq, &w, &b, &a, &g);
        // loss(b) = Σ Q ⊙ (bA) with Q frozen; d/db[i,p] = Σ_j Q[i,j]·A[p,j]
        // eq. 5's extra −W⊘S term is the STE correction toward W; in the
        // frozen-code surface the exact grad is Σ_j Q[i,j]A[p,j]:
        let (i, p) = (2, 1);
        let exact: f32 = (0..w.cols).map(|j| fq.q_values.at(i, j) * a.at(p, j)).sum();
        let ste_term: f32 = (0..w.cols)
            .map(|j| (fq.q_values.at(i, j) - w.at(i, j) / fq.s.at(i, j)) * a.at(p, j))
            .sum();
        assert!((gb.at(i, p) - ste_term).abs() < 1e-5);
        // the STE grad equals the exact frozen-code grad minus the W⊘S pull
        let pull: f32 = (0..w.cols).map(|j| (w.at(i, j) / fq.s.at(i, j)) * a.at(p, j)).sum();
        assert!((exact - pull - ste_term).abs() < 1e-4);
    }
}
