//! Typed configuration structs assembled from a [`TomlDoc`] + CLI overrides.

use super::toml::TomlDoc;

/// Which quantization method to run (Table 1's method column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    Nf4Blockwise,
    Int4Blockwise,
    Gptq,
    Awq,
    LoftQ,
    QPissa,
    QLora,
    Lords,
}

impl QuantMethod {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "nf4" | "normalfloat" | "blockwise" => QuantMethod::Nf4Blockwise,
            "int4" => QuantMethod::Int4Blockwise,
            "gptq" => QuantMethod::Gptq,
            "awq" => QuantMethod::Awq,
            "loftq" => QuantMethod::LoftQ,
            "qpissa" => QuantMethod::QPissa,
            "qlora" => QuantMethod::QLora,
            "lords" => QuantMethod::Lords,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantMethod::Nf4Blockwise => "NF4",
            QuantMethod::Int4Blockwise => "INT4",
            QuantMethod::Gptq => "GPTQ",
            QuantMethod::Awq => "AWQ",
            QuantMethod::LoftQ => "LoftQ",
            QuantMethod::QPissa => "QPiSSA",
            QuantMethod::QLora => "QLoRA",
            QuantMethod::Lords => "LoRDS",
        }
    }
}

/// Quantization run configuration (PTQ / Algorithm 1 knobs).
#[derive(Clone, Debug)]
pub struct QuantCfg {
    pub method: QuantMethod,
    pub codebook: String,
    pub block: usize,
    /// LoRDS refinement steps T (0 = SVD init only).
    pub refine_steps: usize,
    /// Refinement learning rate η (paper: 0.05).
    pub refine_lr: f32,
    /// Adapter rank for LoftQ/QPiSSA/QLoRA baselines (paper: 16).
    pub adapter_rank: usize,
    /// Parameter-aligned LoRDS† (Appendix B): add the adapter budget to r.
    pub parity_with_adapter: bool,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg {
            method: QuantMethod::Lords,
            codebook: "nf4".into(),
            block: 64,
            refine_steps: 100,
            refine_lr: 0.05,
            adapter_rank: 16,
            parity_with_adapter: false,
        }
    }
}

impl QuantCfg {
    pub fn from_doc(doc: &TomlDoc) -> Self {
        let d = QuantCfg::default();
        QuantCfg {
            method: QuantMethod::parse(&doc.str_or("quant", "method", "lords"))
                .unwrap_or(QuantMethod::Lords),
            codebook: doc.str_or("quant", "codebook", &d.codebook),
            block: doc.usize_or("quant", "block", d.block),
            refine_steps: doc.usize_or("quant", "refine_steps", d.refine_steps),
            refine_lr: doc.f32_or("quant", "refine_lr", d.refine_lr),
            adapter_rank: doc.usize_or("quant", "adapter_rank", d.adapter_rank),
            parity_with_adapter: doc.bool_or("quant", "parity_with_adapter", d.parity_with_adapter),
        }
    }
}

/// Testbed model architecture (must match the AOT manifest for PJRT paths).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub block: usize,
    pub codebook: String,
    pub qlora_rank: usize,
}

impl Default for ModelCfg {
    fn default() -> Self {
        ModelCfg {
            vocab: 512,
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_seq: 256,
            block: 64,
            codebook: "nf4".into(),
            qlora_rank: 16,
        }
    }
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_doc(doc: &TomlDoc) -> Self {
        let d = ModelCfg::default();
        ModelCfg {
            vocab: doc.usize_or("model", "vocab", d.vocab),
            d_model: doc.usize_or("model", "d_model", d.d_model),
            n_layers: doc.usize_or("model", "n_layers", d.n_layers),
            n_heads: doc.usize_or("model", "n_heads", d.n_heads),
            d_ff: doc.usize_or("model", "d_ff", d.d_ff),
            max_seq: doc.usize_or("model", "max_seq", d.max_seq),
            block: doc.usize_or("model", "block", d.block),
            codebook: doc.str_or("model", "codebook", &d.codebook),
            qlora_rank: doc.usize_or("model", "qlora_rank", d.qlora_rank),
        }
    }
}

/// Training protocol knobs (QAT §4.2 / PEFT §4.3, scaled to the testbed).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub batch: usize,
    pub seq: usize,
    pub peak_lr: f32,
    pub warmup_ratio: f32,
    pub weight_decay: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 300,
            batch: 8,
            seq: 128,
            peak_lr: 1e-3,
            warmup_ratio: 0.1,
            weight_decay: 0.0,
            seed: 0,
            log_every: 25,
        }
    }
}

impl TrainCfg {
    pub fn from_doc(doc: &TomlDoc, section: &str) -> Self {
        let d = TrainCfg::default();
        TrainCfg {
            steps: doc.usize_or(section, "steps", d.steps),
            batch: doc.usize_or(section, "batch", d.batch),
            seq: doc.usize_or(section, "seq", d.seq),
            peak_lr: doc.f32_or(section, "peak_lr", d.peak_lr),
            warmup_ratio: doc.f32_or(section, "warmup_ratio", d.warmup_ratio),
            weight_decay: doc.f32_or(section, "weight_decay", d.weight_decay),
            seed: doc.usize_or(section, "seed", d.seed as usize) as u64,
            log_every: doc.usize_or(section, "log_every", d.log_every),
        }
    }
}

/// Serving coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeCfg {
    /// Batch-size buckets available as decode artifacts.
    pub decode_buckets: Vec<usize>,
    pub prefill_buckets: Vec<usize>,
    /// Max time a request waits for batchmates before dispatch (µs).
    pub batch_window_us: u64,
    pub max_queue: usize,
    /// Max new tokens per request (hard cap).
    pub max_new_tokens: usize,
    pub workers: usize,
    /// KV-cache storage precision for engines with an owned pool:
    /// 32 (dense f32), 8, or 4 (bit-packed blocks with low-rank scales).
    /// Consumed by engine *constructors* (CLI / bench code builds the
    /// `NativeEngine` with a matching `KvQuantCfg`); `Server` itself only
    /// reads `kv_budget_mib` — the engine's own config is authoritative.
    pub kv_bits: u32,
    /// KV pool byte budget in MiB; 0 = auto (worst case: `max_concurrent`
    /// dense f32 sequences — quantized formats then fit more sequences in
    /// the same bytes).
    pub kv_budget_mib: f64,
    /// Open-loop arrival rate in requests/second for the `serve` CLI and
    /// bench drivers; 0 = closed-loop trace (all requests at t=0).
    /// Arrivals are a deterministic seeded Poisson-like process
    /// (`coordinator::driver`).
    pub rate_rps: f64,
    /// Per-tick prefill token budget for chunked (continuous-batching)
    /// prefill: each tick advances in-flight prompts by at most this many
    /// tokens total before the decode tick runs, so a long prompt costs
    /// running streams at most one chunk of extra inter-token latency.
    /// 0 = unlimited (whole remaining prompt per tick, the lockstep
    /// schedule). Ignored by engines without chunked-prefill support.
    pub prefill_chunk_tokens: usize,
    /// Run the logit-drift sentinel every N decode ticks (0 = off): one
    /// running sequence's last decode step is replayed through the
    /// engine's reference path on a shadow KV sequence and compared
    /// against the batched logits. Observe-only — the served streams are
    /// bitwise unperturbed (`tests/obs.rs` enforces this).
    pub sentinel_every_n_ticks: usize,
    /// Flight-recorder rejection-storm threshold: this many rejections
    /// inside `storm_window_ms` trip an anomaly dump. 0 disables.
    pub storm_rejections: usize,
    /// Rejection-storm window in milliseconds.
    pub storm_window_ms: u64,
    /// Flight-recorder stall threshold: consecutive busy-but-progress-free
    /// server steps that trip an anomaly dump. 0 disables.
    pub stall_ticks: usize,
    /// Relative Frobenius seal error above which a packed KV tile counts
    /// as a breach (bumping `lords_kv_seal_err_breaches_total` and
    /// tripping a flight-recorder anomaly). 0 disables breach detection;
    /// the seal-error histogram itself always records.
    pub seal_err_threshold: f64,
    /// Fault-injection plane configuration (`fault::parse_specs`
    /// grammar, e.g. `"site=kv.seal,p=0.01,kind=err,seed=7"`). Empty =
    /// plane disabled; every `fault::point!` site then costs one
    /// relaxed atomic load. `Server::new` installs a non-empty spec as
    /// the process-global plane.
    pub fault_spec: String,
    /// Max retry-by-re-prefill attempts per request after a retryable
    /// failure (engine error). 0 = fail immediately. Retries regenerate
    /// from the prompt, which is exact because decode is deterministic
    /// per (params, id).
    pub retry_budget: usize,
    /// Server ticks a failed request waits before its retry re-enters
    /// the admission queue.
    pub retry_backoff_ticks: usize,
    /// Tick budget `Server::drain` spends finishing in-flight work
    /// before force-failing whatever remains.
    pub drain_timeout_ticks: usize,
    /// Readiness probe: after this many consecutive ticks in
    /// `QueueFull` backpressure, `Server::is_ready` reports false
    /// (and `/readyz` turns 503). 0 disables the backpressure signal;
    /// draining always reports not-ready.
    pub readyz_backpressure_ticks: usize,
    /// Requests carrying a deadline below this many milliseconds are
    /// rejected at submit as infeasible. 0 accepts any deadline.
    pub min_deadline_ms: u64,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            decode_buckets: vec![1, 2, 4, 8],
            prefill_buckets: vec![1, 2, 4],
            batch_window_us: 2_000,
            max_queue: 256,
            max_new_tokens: 128,
            workers: 1,
            kv_bits: 32,
            kv_budget_mib: 0.0,
            rate_rps: 0.0,
            prefill_chunk_tokens: 256,
            sentinel_every_n_ticks: 0,
            storm_rejections: 8,
            storm_window_ms: 1_000,
            stall_ticks: 512,
            seal_err_threshold: 0.5,
            fault_spec: String::new(),
            retry_budget: 2,
            retry_backoff_ticks: 2,
            drain_timeout_ticks: 1_024,
            readyz_backpressure_ticks: 16,
            min_deadline_ms: 0,
        }
    }
}

impl ServeCfg {
    pub fn from_doc(doc: &TomlDoc) -> Self {
        let d = ServeCfg::default();
        ServeCfg {
            batch_window_us: doc.usize_or("serve", "batch_window_us", d.batch_window_us as usize)
                as u64,
            max_queue: doc.usize_or("serve", "max_queue", d.max_queue),
            max_new_tokens: doc.usize_or("serve", "max_new_tokens", d.max_new_tokens),
            workers: doc.usize_or("serve", "workers", d.workers),
            kv_bits: doc.usize_or("serve", "kv_bits", d.kv_bits as usize) as u32,
            kv_budget_mib: doc.f32_or("serve", "kv_budget_mib", d.kv_budget_mib as f32) as f64,
            rate_rps: doc.f32_or("serve", "rate_rps", d.rate_rps as f32) as f64,
            prefill_chunk_tokens: doc.usize_or(
                "serve",
                "prefill_chunk_tokens",
                d.prefill_chunk_tokens,
            ),
            sentinel_every_n_ticks: doc.usize_or(
                "serve",
                "sentinel_every_n_ticks",
                d.sentinel_every_n_ticks,
            ),
            storm_rejections: doc.usize_or("serve", "storm_rejections", d.storm_rejections),
            storm_window_ms: doc.usize_or("serve", "storm_window_ms", d.storm_window_ms as usize)
                as u64,
            stall_ticks: doc.usize_or("serve", "stall_ticks", d.stall_ticks),
            seal_err_threshold: doc.f32_or(
                "serve",
                "seal_err_threshold",
                d.seal_err_threshold as f32,
            ) as f64,
            fault_spec: doc.str_or("serve", "fault_spec", &d.fault_spec),
            retry_budget: doc.usize_or("serve", "retry_budget", d.retry_budget),
            retry_backoff_ticks: doc.usize_or(
                "serve",
                "retry_backoff_ticks",
                d.retry_backoff_ticks,
            ),
            drain_timeout_ticks: doc.usize_or(
                "serve",
                "drain_timeout_ticks",
                d.drain_timeout_ticks,
            ),
            readyz_backpressure_ticks: doc.usize_or(
                "serve",
                "readyz_backpressure_ticks",
                d.readyz_backpressure_ticks,
            ),
            min_deadline_ms: doc.usize_or("serve", "min_deadline_ms", d.min_deadline_ms as usize)
                as u64,
            ..d
        }
    }

    /// Recoverable construction-time validation, run by `Server::new`
    /// before any engine state is touched. Covers the batching shape
    /// (bucket lists), KV precision, chunk sizing, and the
    /// fault/deadline/retry knobs.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.decode_buckets.is_empty(),
            "serve config: decode_buckets must be non-empty"
        );
        anyhow::ensure!(
            self.decode_buckets.windows(2).all(|w| w[0] < w[1]) && self.decode_buckets[0] > 0,
            "serve config: decode_buckets must be positive and strictly increasing, got {:?}",
            self.decode_buckets
        );
        anyhow::ensure!(
            !self.prefill_buckets.is_empty(),
            "serve config: prefill_buckets must be non-empty"
        );
        anyhow::ensure!(
            self.prefill_buckets.windows(2).all(|w| w[0] < w[1]) && self.prefill_buckets[0] > 0,
            "serve config: prefill_buckets must be positive and strictly increasing, got {:?}",
            self.prefill_buckets
        );
        anyhow::ensure!(
            self.max_queue > 0,
            "serve config: max_queue must be at least 1"
        );
        anyhow::ensure!(
            self.max_new_tokens > 0,
            "serve config: max_new_tokens must be at least 1"
        );
        anyhow::ensure!(
            matches!(self.kv_bits, 32 | 8 | 4),
            "serve config: kv_bits must be 32, 8, or 4, got {}",
            self.kv_bits
        );
        anyhow::ensure!(
            self.kv_budget_mib >= 0.0 && self.kv_budget_mib.is_finite(),
            "serve config: kv_budget_mib must be finite and non-negative"
        );
        crate::fault::parse_specs(&self.fault_spec)
            .map_err(|e| e.context("serve config: fault_spec"))?;
        anyhow::ensure!(
            self.retry_budget <= 64,
            "serve config: retry_budget {} is unreasonably large (max 64)",
            self.retry_budget
        );
        anyhow::ensure!(
            self.drain_timeout_ticks > 0,
            "serve config: drain_timeout_ticks must be at least 1"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parsing() {
        assert_eq!(QuantMethod::parse("LoRDS"), Some(QuantMethod::Lords));
        assert_eq!(QuantMethod::parse("nf4"), Some(QuantMethod::Nf4Blockwise));
        assert_eq!(QuantMethod::parse("unknown"), None);
        assert_eq!(QuantMethod::Lords.name(), "LoRDS");
    }

    #[test]
    fn configs_from_doc() {
        let doc = TomlDoc::parse(
            "[quant]\nmethod = gptq\nblock = 256\n[model]\nd_model = 128\n[serve]\nmax_queue = 9\nstall_ticks = 64\n[qat]\nsteps = 77\n",
        )
        .unwrap();
        let q = QuantCfg::from_doc(&doc);
        assert_eq!(q.method, QuantMethod::Gptq);
        assert_eq!(q.block, 256);
        let m = ModelCfg::from_doc(&doc);
        assert_eq!(m.d_model, 128);
        assert_eq!(m.vocab, 512);
        let s = ServeCfg::from_doc(&doc);
        assert_eq!(s.max_queue, 9);
        assert_eq!(s.kv_bits, 32);
        assert_eq!(s.kv_budget_mib, 0.0);
        assert_eq!(s.rate_rps, 0.0);
        assert_eq!(s.sentinel_every_n_ticks, 0);
        assert_eq!(s.storm_rejections, 8);
        assert_eq!(s.storm_window_ms, 1_000);
        assert_eq!(s.stall_ticks, 64);
        assert_eq!(s.seal_err_threshold, 0.5);
        assert_eq!(s.fault_spec, "");
        assert_eq!(s.retry_budget, 2);
        assert_eq!(s.retry_backoff_ticks, 2);
        assert_eq!(s.drain_timeout_ticks, 1_024);
        assert_eq!(s.readyz_backpressure_ticks, 16);
        assert_eq!(s.min_deadline_ms, 0);
        let t = TrainCfg::from_doc(&doc, "qat");
        assert_eq!(t.steps, 77);
    }

    #[test]
    fn defaults_are_sane() {
        let m = ModelCfg::default();
        assert_eq!(m.d_model % m.n_heads, 0);
        let s = ServeCfg::default();
        assert!(s.decode_buckets.windows(2).all(|w| w[0] < w[1]));
        s.validate().unwrap();
    }

    #[test]
    fn serve_validation_rejects_bad_shapes() {
        let ok = ServeCfg::default();
        ok.validate().unwrap();

        let mut bad = ok.clone();
        bad.decode_buckets = vec![];
        assert!(bad.validate().is_err());

        let mut bad = ok.clone();
        bad.decode_buckets = vec![4, 2];
        assert!(bad.validate().is_err());

        let mut bad = ok.clone();
        bad.prefill_buckets = vec![0, 1];
        assert!(bad.validate().is_err());

        let mut bad = ok.clone();
        bad.kv_bits = 16;
        assert!(bad.validate().is_err());

        let mut bad = ok.clone();
        bad.max_queue = 0;
        assert!(bad.validate().is_err());

        let mut bad = ok.clone();
        bad.fault_spec = "site=kv.seal,p=2.0".into();
        assert!(bad.validate().is_err());

        let mut good = ok.clone();
        good.fault_spec = "site=kv.seal,p=0.01,kind=err,seed=7".into();
        good.validate().unwrap();

        let mut bad = ok.clone();
        bad.drain_timeout_ticks = 0;
        assert!(bad.validate().is_err());
    }
}
