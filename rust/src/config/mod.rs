//! Run configuration: a TOML-subset parser (the vendored set has no `serde`
//! or `toml`) plus the typed configs used by the CLI, trainers, and server.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float, and boolean values, `#` comments. That covers every
//! config this project ships (see `configs/*.toml`).

pub mod toml;
pub mod types;

pub use toml::TomlDoc;
pub use types::{ModelCfg, QuantCfg, QuantMethod, ServeCfg, TrainCfg};
