//! Minimal TOML-subset parser.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        if let Value::Str(s) = self {
            Some(s)
        } else {
            None
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            Value::Float(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        if let Value::Bool(b) = self {
            Some(*b)
        } else {
            None
        }
    }
}

/// Parsed document: section → key → value. Root-level keys live in "".
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: malformed section header", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .ok_or_else(|| format!("line {}: cannot parse value {:?}", lineno + 1, v.trim()))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn load(path: &str) -> Result<TomlDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_i64()).map(|v| v as usize).unwrap_or(default)
    }

    pub fn f32_or(&self, section: &str, key: &str, default: f32) -> f32 {
        self.get(section, key).and_then(|v| v.as_f64()).map(|v| v as f32).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Some(Value::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Some(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Some(Value::Float(v));
    }
    // bare string (common for method names)
    if s.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-' || c == '.') {
        return Some(Value::Str(s.to_string()));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# top comment
name = "lords-serve"
threads = 8

[quant]
method = lords      # bare string
block = 128
lr = 0.05
refine = true

[serve]
max_batch = 8
timeout_ms = 5.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("", "name", ""), "lords-serve");
        assert_eq!(d.usize_or("", "threads", 0), 8);
        assert_eq!(d.str_or("quant", "method", ""), "lords");
        assert_eq!(d.usize_or("quant", "block", 0), 128);
        assert!((d.f32_or("quant", "lr", 0.0) - 0.05).abs() < 1e-7);
        assert!(d.bool_or("quant", "refine", false));
        assert!((d.f32_or("serve", "timeout_ms", 0.0) - 5.5).abs() < 1e-6);
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("x", "y", 7), 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("key").is_err());
        assert!(TomlDoc::parse("[open").is_err());
    }

    #[test]
    fn hash_inside_string() {
        let d = TomlDoc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(d.str_or("", "tag", ""), "a#b");
    }
}
