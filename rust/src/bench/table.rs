//! Markdown table rendering for the paper-table benches — every bench prints
//! rows in the same layout as the paper so before/after comparison is
//! eyeball-able (EXPERIMENTS.md records both).

#[derive(Clone, Debug, Default)]
pub struct TableBuilder {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: &str) -> TableBuilder {
        TableBuilder { title: title.to_string(), ..Default::default() }
    }

    pub fn headers(mut self, hs: &[&str]) -> Self {
        self.headers = hs.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by benches.
pub fn f2(v: f32) -> String {
    format!("{v:.2}")
}

pub fn f1(v: f32) -> String {
    format!("{v:.1}")
}

pub fn millions(params: usize) -> String {
    format!("{:.1}M", params as f64 / 1e6)
}

pub fn thousands(params: usize) -> String {
    if params >= 1_000_000 {
        millions(params)
    } else {
        format!("{:.1}k", params as f64 / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = TableBuilder::new("Table X").headers(&["Method", "Wiki ↓", "Avg ↑"]);
        t.row(vec!["NF4".into(), "7.90".into(), "64.85".into()]);
        t.row(vec!["LoRDS".into(), "7.77".into(), "65.37".into()]);
        let s = t.render();
        assert!(s.contains("### Table X"));
        assert!(s.contains("| NF4 "));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = TableBuilder::new("t").headers(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn number_formats() {
        assert_eq!(f2(7.768), "7.77");
        assert_eq!(millions(84_000_000), "84.0M");
        assert_eq!(thousands(5_300), "5.3k");
    }
}
