//! Machine-readable baseline export for the paper-table benches.
//!
//! The figure/serving benches (`fig2`, `decode_batch`, `kvcache`, the serve
//! pair) hand-roll `BENCH_*.json` writers around their own structured
//! measurement points. The table benches all end in one or more
//! [`TableBuilder`]s instead, so [`write_tables`] serializes those tables
//! verbatim — title, headers, rows — and CI can diff any table bench run
//! without each bench growing a bespoke writer.
//!
//! The output path is `LORDS_BENCH_JSON` when set, otherwise `file` placed
//! in the workspace root next to the other baselines. Failures to write are
//! reported on stderr but never fail the bench — a read-only checkout still
//! measures.

use super::TableBuilder;
use crate::obs::json::escaped;

fn render_table(t: &TableBuilder, indent: &str) -> String {
    let cells = |row: &[String]| -> String {
        let quoted: Vec<String> = row.iter().map(|c| escaped(c)).collect();
        format!("[{}]", quoted.join(", "))
    };
    let mut s = String::new();
    s.push_str(&format!("{indent}{{\n"));
    s.push_str(&format!("{indent}  \"title\": {},\n", escaped(&t.title)));
    s.push_str(&format!("{indent}  \"headers\": {},\n", cells(&t.headers)));
    s.push_str(&format!("{indent}  \"rows\": [\n"));
    for (i, row) in t.rows.iter().enumerate() {
        let comma = if i + 1 == t.rows.len() { "" } else { "," };
        s.push_str(&format!("{indent}    {}{comma}\n", cells(row)));
    }
    s.push_str(&format!("{indent}  ]\n"));
    s.push_str(&format!("{indent}}}"));
    s
}

/// Serialize `tables` to the baseline file for `bench`. `file` is the
/// bare baseline name (e.g. `"BENCH_table1_ptq.json"`); callers pass it as
/// a literal so the mapping from bench to artifact is greppable.
pub fn write_tables(bench: &str, file: &str, full_mode: bool, tables: &[TableBuilder]) {
    let path = std::env::var("LORDS_BENCH_JSON")
        .unwrap_or_else(|_| format!("{}/../{file}", env!("CARGO_MANIFEST_DIR")));
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"bench\": {},\n", escaped(bench)));
    s.push_str("  \"unit\": \"table\",\n");
    s.push_str(&format!("  \"full_mode\": {full_mode},\n"));
    s.push_str(&format!("  \"threads\": {},\n", crate::util::ThreadPool::global().size()));
    s.push_str("  \"measured\": true,\n");
    s.push_str("  \"tables\": [\n");
    for (i, t) in tables.iter().enumerate() {
        s.push_str(&render_table(t, "    "));
        s.push_str(if i + 1 == tables.len() { "\n" } else { ",\n" });
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[{bench}] wrote baseline {path}"),
        Err(e) => eprintln!("[{bench}] could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::Json;

    fn sample() -> TableBuilder {
        let mut t = TableBuilder::new("Table \"X\"").headers(&["Method", "Wiki ↓"]);
        t.row(vec!["NF4".into(), "7.90".into()]);
        t.row(vec!["Lo\\RDS".into(), "7.77".into()]);
        t
    }

    #[test]
    fn rendered_baseline_parses_as_json() {
        let mut body = String::from("{\n  \"measured\": true,\n  \"tables\": [\n");
        body.push_str(&render_table(&sample(), "    "));
        body.push_str("\n  ]\n}\n");
        let j = Json::parse(&body).expect("baseline JSON parses");
        let tables = j.get("tables").and_then(|t| t.as_arr()).expect("tables array");
        assert_eq!(tables.len(), 1);
        let t0 = &tables[0];
        assert_eq!(t0.get("title").and_then(|v| v.as_str()), Some("Table \"X\""));
        let rows = t0.get("rows").and_then(|r| r.as_arr()).expect("rows");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].as_arr().and_then(|r| r[0].as_str()), Some("Lo\\RDS"));
    }
}
