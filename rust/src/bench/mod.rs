//! Benchmark harness (criterion substitute): warmup + timed iterations with
//! mean/p50/p99 reporting, plus the markdown table renderer the paper-table
//! benches share.

pub mod baseline;
pub mod harness;
pub mod table;

pub use harness::{bench_fn, BenchResult};
pub use table::TableBuilder;
