//! Timing harness: adaptive warmup, fixed-duration measurement, and stable
//! statistics — enough of criterion's core loop for `cargo bench` targets
//! with `harness = false`.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }

    pub fn print(&self) {
        println!(
            "  {:<32} {:>10.3} ms/iter  (p50 {:.3}, p99 {:.3}, ±{:.3}, n={})",
            self.name,
            self.mean_ms(),
            self.p50_s * 1e3,
            self.p99_s * 1e3,
            self.std_s * 1e3,
            self.iters
        );
    }
}

/// Benchmark a closure: `warmup` seconds of warmup, then measure for
/// `measure` seconds (at least 5 iterations). Under the CI smoke switch
/// (`LORDS_BENCH_SMOKE=1`, see `report::testbed::smoke_mode`) both
/// windows are capped so every bench binary finishes in seconds.
pub fn bench_fn(name: &str, warmup: f64, measure: f64, mut f: impl FnMut()) -> BenchResult {
    let (warmup, measure) = if crate::report::testbed::smoke_mode() {
        (warmup.min(0.02), measure.min(0.1))
    } else {
        (warmup, measure)
    };
    // warmup
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < warmup {
        f();
    }
    // measure
    let mut samples = Summary::new();
    let t1 = Instant::now();
    let mut iters = 0usize;
    while t1.elapsed().as_secs_f64() < measure || iters < 5 {
        let s = Instant::now();
        f();
        samples.add(s.elapsed().as_secs_f64());
        iters += 1;
        if iters >= 100_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: samples.mean(),
        p50_s: samples.p50(),
        p99_s: samples.p99(),
        std_s: samples.std(),
    }
}

/// Convenience: run `f` once and report the duration (for long end-to-end
/// benches where repetition is impractical).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Standard bench banner so all `cargo bench` targets look alike.
pub fn banner(id: &str, what: &str) {
    println!("\n================================================================");
    println!("  {id}: {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench_fn("spin", 0.01, 0.05, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0 && r.mean_s < 0.1);
        assert!(r.p50_s <= r.p99_s + 1e-9);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 7);
        assert_eq!(v, 7);
        assert!(d.as_nanos() > 0);
    }
}
