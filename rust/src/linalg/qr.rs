//! Householder QR decomposition. Used by GPTQ's Hessian handling (as a
//! robust fallback to Cholesky on near-singular calibration Hessians) and
//! available as a general substrate.

use crate::tensor::Matrix;

/// Thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal cols) · R (n×n, upper).
pub fn qr(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.rows;
    let n = a.cols;
    assert!(m >= n, "thin QR requires m >= n (got {m}x{n})");
    let mut r = a.clone();
    // accumulate Q by applying the Householder reflectors to I
    let mut q = Matrix::eye(m);

    for k in 0..n {
        // Householder vector for column k below the diagonal
        let mut norm: f64 = 0.0;
        for i in k..m {
            norm += (r.at(i, k) as f64).powi(2);
        }
        let norm = norm.sqrt() as f32;
        if norm == 0.0 {
            continue;
        }
        let alpha = if r.at(k, k) > 0.0 { -norm } else { norm };
        let mut v = vec![0.0f32; m];
        for i in k..m {
            v[i] = r.at(i, k);
        }
        v[k] -= alpha;
        let vnorm2: f32 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        // R = (I - 2vvᵀ/|v|²) R
        for j in k..n {
            let dot: f32 = (k..m).map(|i| v[i] * r.at(i, j)).sum();
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                *r.at_mut(i, j) -= c * v[i];
            }
        }
        // Q = Q (I - 2vvᵀ/|v|²)
        for i in 0..m {
            let dot: f32 = (k..m).map(|j| q.at(i, j) * v[j]).sum();
            let c = 2.0 * dot / vnorm2;
            for j in k..m {
                *q.at_mut(i, j) -= c * v[j];
            }
        }
    }
    // thin factors
    let q_thin = q.cols_range(0, n);
    let mut r_thin = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin.set(i, j, r.at(i, j));
        }
    }
    (q_thin, r_thin)
}

/// Cholesky factorization of a symmetric positive-definite matrix: A = L Lᵀ.
/// Returns None if the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.at(j, j) as f64) as f32);
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for j in 0..i {
            sum -= l.at(i, j) as f64 * y[j] as f64;
        }
        y[i] = (sum / l.at(i, i) as f64) as f32;
    }
    y
}

/// Solve Lᵀ x = y (back substitution).
pub fn solve_upper_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for j in (i + 1)..n {
            sum -= l.at(j, i) as f64 * x[j] as f64;
        }
        x[i] = (sum / l.at(i, i) as f64) as f32;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::util::prop::assert_allclose;
    use crate::util::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let (q, r) = qr(&a);
        let rec = matmul(&q, &r);
        assert_allclose(&rec.data, &a.data, 1e-4, 1e-4, "QR");
        // Q orthonormal
        let qtq = matmul_at_b(&q, &q);
        for i in 0..7 {
            for j in 0..7 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at(i, j) - want).abs() < 1e-4);
            }
        }
        // R upper triangular
        for i in 0..7 {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(1);
        let b = Matrix::randn(8, 8, 1.0, &mut rng);
        // SPD: BBᵀ + n·I
        let mut spd = crate::tensor::matmul_transb(&b, &b);
        for i in 0..8 {
            *spd.at_mut(i, i) += 8.0;
        }
        let l = cholesky(&spd).expect("SPD");
        let rec = crate::tensor::matmul_transb(&l, &l);
        assert_allclose(&rec.data, &spd.data, 1e-4, 1e-3, "LLᵀ");
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(2);
        let b = Matrix::randn(6, 6, 1.0, &mut rng);
        let mut spd = crate::tensor::matmul_transb(&b, &b);
        for i in 0..6 {
            *spd.at_mut(i, i) += 6.0;
        }
        let l = cholesky(&spd).unwrap();
        let rhs: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
        let y = solve_lower(&l, &rhs);
        let x = solve_upper_t(&l, &y);
        // check A x = rhs
        let ax = crate::tensor::gemm::matvec(&spd, &x);
        assert_allclose(&ax, &rhs, 1e-3, 1e-3, "cholesky solve");
    }
}
