//! One-sided Jacobi SVD.
//!
//! Chosen over Golub–Kahan bidiagonalization for robustness and simplicity:
//! one-sided Jacobi applies Givens rotations to *columns* of a working copy
//! of A until all column pairs are orthogonal; singular values are then the
//! column norms, U the normalized columns, and V the accumulated rotations.
//! Accuracy is excellent (it computes small singular values to high relative
//! accuracy), and O(mn² · sweeps) is fine at this project's matrix sizes
//! (≤ ~1–2k). The scale matrices the paper decomposes (S = s ⊗ 1) are
//! numerically low-rank, which Jacobi handles without special casing.

use crate::tensor::Matrix;

/// Full SVD result: `a ≈ u * diag(s) * vt` with singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    /// m × r (r = min(m, n)), orthonormal columns.
    pub u: Matrix,
    /// r singular values, descending, non-negative.
    pub s: Vec<f32>,
    /// r × n, orthonormal rows.
    pub vt: Matrix,
}

impl Svd {
    /// Reconstruct `u[:, :k] * diag(s[:k]) * vt[:k, :]`.
    pub fn reconstruct(&self, k: usize) -> Matrix {
        let k = k.min(self.s.len());
        let mut out = Matrix::zeros(self.u.rows, self.vt.cols);
        for p in 0..k {
            let sp = self.s[p];
            if sp == 0.0 {
                continue;
            }
            for i in 0..self.u.rows {
                let up = self.u.at(i, p) * sp;
                if up == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(i);
                let vt_row = self.vt.row(p);
                for (o, &v) in out_row.iter_mut().zip(vt_row) {
                    *o += up * v;
                }
            }
        }
        out
    }

    /// Split into the paper's (B, A) = (U√Σ, √Σ Vᵀ) truncated factors (eq. 3).
    pub fn split_ba(&self, rank: usize) -> (Matrix, Matrix) {
        let r = rank.min(self.s.len());
        let mut b = Matrix::zeros(self.u.rows, r);
        let mut a = Matrix::zeros(r, self.vt.cols);
        for p in 0..r {
            let root = self.s[p].max(0.0).sqrt();
            for i in 0..self.u.rows {
                b.set(i, p, self.u.at(i, p) * root);
            }
            for j in 0..self.vt.cols {
                a.set(p, j, root * self.vt.at(p, j));
            }
        }
        (b, a)
    }
}

/// One-sided Jacobi SVD of an arbitrary matrix.
///
/// For m < n the routine runs on Aᵀ and swaps the factors back, so tall or
/// wide inputs both work.
pub fn svd(a: &Matrix) -> Svd {
    if a.rows < a.cols {
        let t = svd(&a.transpose());
        // A = (Aᵀ)ᵀ = (U Σ Vᵀ)ᵀ = V Σ Uᵀ
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let m = a.rows;
    let n = a.cols;
    let mut w = a.clone(); // working columns (m × n)
    let mut v = Matrix::eye(n);

    let tol = 1e-7_f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // gram entries for the (p, q) column pair
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = w.at(i, p) as f64;
                    let wq = w.at(i, q) as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                let denom = (app * aqq).sqrt();
                if denom <= f64::MIN_POSITIVE || apq.abs() / denom < tol {
                    continue;
                }
                off = off.max(apq.abs() / denom);
                // Jacobi rotation zeroing the (p, q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau.is_finite() {
                    tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt())
                } else {
                    // |tau| huge ⇒ rotation angle → 0
                    0.5 / tau
                };
                if !t.is_finite() {
                    continue;
                }
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    w.set(i, p, cf * wp - sf * wq);
                    w.set(i, q, sf * wp + cf * wq);
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    v.set(i, p, cf * vp - sf * vq);
                    v.set(i, q, sf * vp + cf * vq);
                }
            }
        }
        if off < tol {
            break;
        }
    }

    // singular values = column norms; U = normalized columns
    let mut order: Vec<usize> = (0..n).collect();
    let mut sv = vec![0.0f32; n];
    for (j, svj) in sv.iter_mut().enumerate() {
        let norm: f64 = (0..m).map(|i| (w.at(i, j) as f64).powi(2)).sum::<f64>().sqrt();
        *svj = norm as f32;
    }
    order.sort_by(|&x, &y| sv[y].partial_cmp(&sv[x]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0f32; n];
    let max_norm = order.first().map(|&j| sv[j]).unwrap_or(0.0);
    for (new_j, &old_j) in order.iter().enumerate() {
        let norm = sv[old_j];
        // treat numerically-zero directions as exactly zero (a subnormal
        // norm would make 1/norm overflow and poison U with inf·0 = NaN)
        let effectively_zero = norm <= 1e-12 * max_norm.max(1.0) || !norm.is_finite();
        s_sorted[new_j] = if effectively_zero { 0.0 } else { norm };
        let inv = if effectively_zero { 0.0 } else { 1.0 / norm };
        for i in 0..m {
            u.set(i, new_j, w.at(i, old_j) * inv);
        }
        for i in 0..n {
            vt.set(new_j, i, v.at(i, old_j));
        }
    }
    Svd { u, s: s_sorted, vt }
}

/// Rank-`k` truncated SVD.
///
/// For k ≪ min(m, n) this uses the randomized range-finder (Halko et al.):
/// project onto a (k + oversample)-dimensional sketch with two power
/// iterations, run exact Jacobi on the small projected matrix, and lift the
/// factors back. Perf note (EXPERIMENTS.md §Perf): this took the LoftQ/
/// QPiSSA baselines from ~0.8 s to ~10 ms per 512×256 factorization. Falls
/// back to exact Jacobi when k is a large fraction of the spectrum (where
/// the sketch would not be cheaper or accurate).
pub fn truncated_svd(a: &Matrix, k: usize) -> Svd {
    let min_dim = a.rows.min(a.cols);
    let k = k.min(min_dim);
    let oversample = 8;
    if k + oversample >= min_dim / 2 {
        let full = svd(a);
        return Svd {
            u: full.u.cols_range(0, k),
            s: full.s[..k].to_vec(),
            vt: full.vt.slice(0, k, 0, full.vt.cols),
        };
    }
    randomized_svd(a, k, oversample, 2)
}

/// Randomized truncated SVD (Halko–Martinsson–Tropp).
pub fn randomized_svd(a: &Matrix, k: usize, oversample: usize, power_iters: usize) -> Svd {
    use crate::linalg::qr::qr;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::util::Rng;

    let l = (k + oversample).min(a.rows.min(a.cols));
    let mut rng = Rng::new(0x5EED ^ ((a.rows as u64) << 20) ^ a.cols as u64);
    let omega = Matrix::randn(a.cols, l, 1.0, &mut rng);
    // range finder with power iterations: Y = (AAᵀ)^q A Ω
    let mut y = matmul(a, &omega); // m×l
    for _ in 0..power_iters {
        let (qy, _) = qr(&y);
        let z = matmul_at_b(&qy, a); // l×n
        let (qz, _) = qr(&z.transpose()); // n×l
        y = matmul(a, &qz);
    }
    let (q, _) = qr(&y); // m×l orthonormal
    let b = matmul_at_b(&q, a); // l×n — small
    let small = svd(&b);
    let kk = k.min(small.s.len());
    Svd {
        u: matmul(&q, &small.u.cols_range(0, kk)),
        s: small.s[..kk].to_vec(),
        vt: small.vt.slice(0, kk, 0, small.vt.cols),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, prop_check};
    use crate::util::Rng;

    fn reconstruct_full(d: &Svd) -> Matrix {
        d.reconstruct(d.s.len())
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let d = svd(&a);
        assert_allclose(&d.s, &[3.0, 2.0, 1.0], 1e-5, 1e-5, "singular values");
        assert_allclose(&reconstruct_full(&d).data, &a.data, 1e-4, 1e-4, "reconstruction");
    }

    #[test]
    fn reconstruction_random() {
        prop_check(16, |g| {
            let m = g.usize(2..=24);
            let n = g.usize(2..=24);
            let mut rng = g.rng().fork(2);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let d = svd(&a);
            let rec = reconstruct_full(&d);
            let err = a.sub(&rec).frob_norm() / a.frob_norm().max(1e-6);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("reconstruction error {err} at {m}x{n}"))
            }
        });
    }

    #[test]
    fn orthonormal_factors() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let d = svd(&a);
        // UᵀU = I
        let utu = crate::tensor::matmul_at_b(&d.u, &d.u);
        let vvt = crate::tensor::matmul_transb(&d.vt, &d.vt);
        for i in 0..utu.rows {
            for j in 0..utu.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-4, "UᵀU[{i},{j}]={}", utu.at(i, j));
                assert!((vvt.at(i, j) - want).abs() < 1e-4, "VVᵀ[{i},{j}]={}", vvt.at(i, j));
            }
        }
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(15, 9, 2.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn wide_matrix() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(6, 17, 1.0, &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.shape(), (6, 6));
        assert_eq!(d.vt.shape(), (6, 17));
        let rec = reconstruct_full(&d);
        assert!(a.sub(&rec).frob_norm() / a.frob_norm() < 1e-4);
    }

    #[test]
    fn truncation_is_best_approx() {
        // rank-2 matrix + noise: rank-2 truncation should capture the signal
        let mut rng = Rng::new(9);
        let b = Matrix::randn(20, 2, 1.0, &mut rng);
        let a = Matrix::randn(2, 16, 1.0, &mut rng);
        let low = crate::tensor::matmul(&b, &a);
        let d = truncated_svd(&low, 2);
        let rec = d.reconstruct(2);
        assert!(low.sub(&rec).frob_norm() / low.frob_norm() < 1e-4);
        assert_eq!(d.s.len(), 2);
    }

    #[test]
    fn split_ba_reconstructs() {
        let mut rng = Rng::new(10);
        let b0 = Matrix::randn(12, 3, 1.0, &mut rng);
        let a0 = Matrix::randn(3, 10, 1.0, &mut rng);
        let low = crate::tensor::matmul(&b0, &a0);
        let (b, a) = svd(&low).split_ba(3);
        let rec = crate::tensor::matmul(&b, &a);
        assert!(low.sub(&rec).frob_norm() / low.frob_norm() < 1e-4);
    }

    #[test]
    fn randomized_svd_matches_exact_on_lowrank_plus_noise() {
        let mut rng = Rng::new(20);
        let b = Matrix::randn(96, 6, 1.0, &mut rng);
        let a = Matrix::randn(6, 64, 1.0, &mut rng);
        let mut m = crate::tensor::matmul(&b, &a);
        let noise = Matrix::randn(96, 64, 0.01, &mut rng);
        m.add_assign(&noise);
        let exact = svd(&m);
        let rand = truncated_svd(&m, 6);
        for i in 0..6 {
            assert!(
                (rand.s[i] - exact.s[i]).abs() / exact.s[i] < 0.02,
                "sigma {i}: {} vs {}",
                rand.s[i],
                exact.s[i]
            );
        }
        let rec = rand.reconstruct(6);
        let rel = m.sub(&rec).frob_norm() / m.frob_norm();
        assert!(rel < 0.05, "reconstruction {rel}");
    }

    #[test]
    fn blockwise_scale_matrix_rank() {
        // the paper's premise: S = s ⊗ 1_{1×B} has rank ≤ m/B
        let mut rng = Rng::new(11);
        let n = 16;
        let blocks = 4;
        let block = 8;
        let s_small = Matrix::randn(n, blocks, 1.0, &mut rng).map(|v| v.abs() + 0.1);
        let mut s_full = Matrix::zeros(n, blocks * block);
        for i in 0..n {
            for jb in 0..blocks {
                for k in 0..block {
                    s_full.set(i, jb * block + k, s_small.at(i, jb));
                }
            }
        }
        let d = svd(&s_full);
        let rank = d.s.iter().filter(|&&v| v > 1e-4 * d.s[0]).count();
        assert!(rank <= blocks, "rank {rank} > {blocks}");
    }
}
