//! Matrix norms. The nuclear norm ‖·‖_* (sum of singular values) is the
//! paper's QuantError metric (Table 2, Appendix B); spectral norm backs the
//! divergence detector used in the ultra-low-bit experiments.

use super::svd::svd;
use crate::tensor::Matrix;

/// Nuclear norm ‖A‖_* = Σᵢ σᵢ.
pub fn nuclear_norm(a: &Matrix) -> f32 {
    svd(a).s.iter().sum()
}

/// Spectral norm ‖A‖₂ = σ₁ via power iteration (cheaper than full SVD).
pub fn spectral_norm(a: &Matrix) -> f32 {
    let n = a.cols;
    if n == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v: Vec<f32> = (0..n).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0 + 0.1).collect();
    let mut sigma = 0.0f32;
    for _ in 0..64 {
        // u = A v ; v = Aᵀ u ; sigma = |u|
        let u: Vec<f32> = (0..a.rows)
            .map(|i| a.row(i).iter().zip(&v).map(|(&w, &x)| w * x).sum())
            .collect();
        let un: f32 = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        if un == 0.0 {
            return 0.0;
        }
        let mut vn = vec![0.0f32; n];
        for (i, &ui) in u.iter().enumerate() {
            for (j, vj) in vn.iter_mut().enumerate() {
                *vj += a.at(i, j) * ui;
            }
        }
        let norm: f32 = vn.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        let new_sigma = norm / un;
        for (vj, &nj) in v.iter_mut().zip(&vn) {
            *vj = nj / norm;
        }
        if (new_sigma - sigma).abs() <= 1e-5 * new_sigma.max(1e-12) {
            return new_sigma;
        }
        sigma = new_sigma;
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn nuclear_of_diagonal() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((nuclear_norm(&a) - 7.0).abs() < 1e-4);
    }

    #[test]
    fn nuclear_geq_frobenius() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(10, 14, 1.0, &mut rng);
        assert!(nuclear_norm(&a) >= a.frob_norm() - 1e-4);
    }

    #[test]
    fn spectral_matches_svd_top() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(12, 9, 1.0, &mut rng);
        let top = svd(&a).s[0];
        let sp = spectral_norm(&a);
        assert!((sp - top).abs() / top < 1e-3, "{sp} vs {top}");
    }

    #[test]
    fn spectral_leq_frobenius() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(8, 8, 1.0, &mut rng);
        assert!(spectral_norm(&a) <= a.frob_norm() + 1e-4);
    }
}
