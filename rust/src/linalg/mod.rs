//! Numerical linear algebra substrate: one-sided Jacobi SVD (full +
//! truncated), Householder QR, and the norm toolkit (nuclear norm is the
//! paper's QuantError metric).

pub mod norms;
pub mod qr;
pub mod svd;

pub use norms::{nuclear_norm, spectral_norm};
pub use svd::{svd, truncated_svd, Svd};
