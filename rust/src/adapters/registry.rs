//! Hot-swappable adapter storage for multi-tenant serving.
//!
//! The registry owns every resident tenant's [`AdapterFactors`] keyed by
//! adapter id, under a byte budget. Registration evicts least-recently-used
//! *unpinned* adapters to make room; an adapter pinned by in-flight
//! sequences (ref-count > 0) is never dropped out from under a batch —
//! explicit eviction of a pinned adapter is **deferred** until its last
//! pin is released, during which it keeps serving decode steps but rejects
//! new acquisitions.
//!
//! The reserved [`BASE_ADAPTER`](super::BASE_ADAPTER) id is the zero-rank
//! base tenant: always acquirable, zero resident bytes, never evictable,
//! and [`get`](AdapterRegistry::get) resolves it to `None` (the fused
//! kernels then use the quantizer's baked-in factors).

use super::artifact::AdapterFactors;
use super::BASE_ADAPTER;
use std::collections::HashMap;

#[derive(Debug)]
struct Entry {
    factors: AdapterFactors,
    bytes: usize,
    /// In-flight sequences currently pinned to this adapter.
    refs: usize,
    /// Eviction requested while pinned; fires on the last release.
    pending_evict: bool,
    /// Logical LRU clock stamp of the last acquisition.
    last_used: u64,
}

/// Snapshot of registry occupancy (for metrics / examples).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub residents: usize,
    pub used_bytes: usize,
    pub budget_bytes: usize,
    pub evictions: usize,
    pub deferred_evictions: usize,
}

#[derive(Debug)]
pub struct AdapterRegistry {
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    evictions: usize,
    deferred_evictions: usize,
    entries: HashMap<String, Entry>,
}

impl AdapterRegistry {
    /// Registry with an LRU byte budget over resident adapter factors.
    pub fn new(budget_bytes: usize) -> AdapterRegistry {
        AdapterRegistry {
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            evictions: 0,
            deferred_evictions: 0,
            entries: HashMap::new(),
        }
    }

    /// No byte budget (nothing is ever evicted for space).
    pub fn unbounded() -> AdapterRegistry {
        AdapterRegistry::new(usize::MAX)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Resident and acquirable (not awaiting a deferred eviction).
    pub fn contains(&self, id: &str) -> bool {
        id == BASE_ADAPTER || self.entries.get(id).is_some_and(|e| !e.pending_evict)
    }

    /// Current pin count (0 for unknown ids and the base tenant).
    pub fn pins(&self, id: &str) -> usize {
        self.entries.get(id).map(|e| e.refs).unwrap_or(0)
    }

    /// Resident ids, sorted (stable output for logs/tests).
    pub fn resident_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.entries.keys().cloned().collect();
        ids.sort();
        ids
    }

    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            residents: self.entries.len(),
            used_bytes: self.used_bytes,
            budget_bytes: self.budget_bytes,
            evictions: self.evictions,
            deferred_evictions: self.deferred_evictions,
        }
    }

    /// Register (or hot-swap) a tenant's factors, evicting LRU unpinned
    /// adapters as needed to fit the budget. Fails when the id is reserved,
    /// the factors alone exceed the budget, the id is currently pinned, or
    /// every resident adapter is pinned and there is no room.
    pub fn register(&mut self, id: &str, factors: AdapterFactors) -> anyhow::Result<()> {
        anyhow::ensure!(
            id != BASE_ADAPTER,
            "adapter id '{BASE_ADAPTER}' is reserved for the unadapted base tenant"
        );
        let bytes = factors.bytes();
        anyhow::ensure!(
            bytes <= self.budget_bytes,
            "adapter '{id}' ({bytes} B) exceeds the registry budget ({} B)",
            self.budget_bytes
        );
        if let Some(existing) = self.entries.get(id) {
            anyhow::ensure!(
                existing.refs == 0,
                "cannot hot-swap adapter '{id}': pinned by {} in-flight sequence(s)",
                existing.refs
            );
        }
        // Plan the LRU victims before mutating anything: a failed
        // registration (not enough evictable bytes) must leave the registry
        // untouched — in particular a failed hot-swap must not destroy the
        // resident adapter it meant to replace.
        let reclaim = self.entries.get(id).map(|e| e.bytes).unwrap_or(0);
        let mut victims: Vec<String> = Vec::new();
        let mut freed = 0usize;
        while self.used_bytes - reclaim - freed > self.budget_bytes - bytes {
            let victim = self
                .entries
                .iter()
                .filter(|(k, e)| e.refs == 0 && k.as_str() != id && !victims.contains(*k))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, e)| (k.clone(), e.bytes));
            match victim {
                Some((k, b)) => {
                    freed += b;
                    victims.push(k);
                }
                None => anyhow::bail!(
                    "cannot register adapter '{id}': budget exhausted and every \
                     resident adapter is pinned by in-flight sequences"
                ),
            }
        }
        for k in &victims {
            let e = self.entries.remove(k).unwrap();
            self.used_bytes -= e.bytes;
            self.evictions += 1;
            crate::info!("adapter registry: evicted '{k}' ({} B) for '{id}'", e.bytes);
        }
        if let Some(old) = self.entries.remove(id) {
            self.used_bytes -= old.bytes;
        }
        self.clock += 1;
        self.used_bytes += bytes;
        self.entries.insert(
            id.to_string(),
            Entry { factors, bytes, refs: 0, pending_evict: false, last_used: self.clock },
        );
        Ok(())
    }

    /// Resolve an id to its factors. The base tenant resolves to `None`
    /// (meaning: use the baked-in quantizer factors). Adapters awaiting a
    /// deferred eviction still resolve — their in-flight sequences keep
    /// decoding against them.
    pub fn get(&self, id: &str) -> Option<&AdapterFactors> {
        self.entries.get(id).map(|e| &e.factors)
    }

    /// Serving-path artifact resolve: [`Self::get`] behind the
    /// `adapter.resolve` fault site. An injected fault models a corrupt
    /// or unreadable adapter artifact — the id fails to resolve even
    /// though it is resident. The engine's guarded paths call this at
    /// validation points and surface a per-sequence error; the decode
    /// row-building loop keeps using plain `get` so a fault can never
    /// silently swap a tenant onto base weights mid-stream.
    pub fn resolve(&self, id: &str) -> Option<&AdapterFactors> {
        if let Some(kind) = crate::fault::point!("adapter.resolve") {
            if crate::fault::degrades(kind) {
                return None;
            }
        }
        self.get(id)
    }

    /// Pin an adapter for one in-flight sequence (touches the LRU clock).
    /// Returns false for ids that are unknown or awaiting eviction; the
    /// base tenant always succeeds.
    pub fn acquire(&mut self, id: &str) -> bool {
        if id == BASE_ADAPTER {
            return true;
        }
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(id) {
            Some(e) if !e.pending_evict => {
                e.refs += 1;
                e.last_used = clock;
                true
            }
            _ => false,
        }
    }

    /// Drop one pin; fires a deferred eviction when the last pin goes.
    pub fn release(&mut self, id: &str) {
        if id == BASE_ADAPTER {
            return;
        }
        if let Some(e) = self.entries.get_mut(id) {
            debug_assert!(e.refs > 0, "release without matching acquire for '{id}'");
            e.refs = e.refs.saturating_sub(1);
            if e.refs == 0 && e.pending_evict {
                let e = self.entries.remove(id).unwrap();
                self.used_bytes -= e.bytes;
                self.evictions += 1;
                self.deferred_evictions += 1;
                crate::info!("adapter registry: deferred eviction of '{id}' completed");
            }
        }
    }

    /// Evict an adapter. Returns true when it was removed immediately;
    /// false when it is pinned (eviction deferred to the last release) or
    /// not resident. The base tenant is never evictable.
    pub fn evict(&mut self, id: &str) -> bool {
        if id == BASE_ADAPTER {
            return false;
        }
        match self.entries.get_mut(id) {
            None => false,
            Some(e) if e.refs > 0 => {
                e.pending_evict = true;
                false
            }
            Some(_) => {
                let e = self.entries.remove(id).unwrap();
                self.used_bytes -= e.bytes;
                self.evictions += 1;
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifact::{AdapterFactors, BaPair};
    use super::*;
    use crate::tensor::Matrix;

    /// One-layer, one-slot adapter of exactly `4 * (4*r + r*4)` bytes.
    fn factors(r: usize) -> AdapterFactors {
        let mut f = AdapterFactors::empty(1);
        f.layers[0].linears[0] =
            Some(BaPair { b: Matrix::ones(4, r), a: Matrix::ones(r, 4) });
        f
    }

    const UNIT: usize = 4 * 8; // factors(1).bytes()

    #[test]
    fn register_get_evict() {
        let mut reg = AdapterRegistry::unbounded();
        assert!(reg.is_empty());
        reg.register("t0", factors(1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.used_bytes(), UNIT);
        assert!(reg.contains("t0"));
        assert!(reg.get("t0").is_some());
        assert!(reg.evict("t0"));
        assert!(reg.get("t0").is_none());
        assert_eq!(reg.used_bytes(), 0);
        assert_eq!(reg.stats().evictions, 1);
    }

    #[test]
    fn base_tenant_is_reserved_free_and_unevictable() {
        let mut reg = AdapterRegistry::new(UNIT);
        assert!(reg.register(crate::adapters::BASE_ADAPTER, factors(1)).is_err());
        assert!(reg.contains(crate::adapters::BASE_ADAPTER));
        assert!(reg.acquire(crate::adapters::BASE_ADAPTER));
        reg.release(crate::adapters::BASE_ADAPTER);
        assert!(!reg.evict(crate::adapters::BASE_ADAPTER));
        assert_eq!(reg.used_bytes(), 0);
        assert!(reg.get(crate::adapters::BASE_ADAPTER).is_none());
    }

    #[test]
    fn lru_eviction_over_byte_budget() {
        let mut reg = AdapterRegistry::new(2 * UNIT);
        reg.register("t0", factors(1)).unwrap();
        reg.register("t1", factors(1)).unwrap();
        // touch t0 so t1 becomes LRU
        assert!(reg.acquire("t0"));
        reg.release("t0");
        reg.register("t2", factors(1)).unwrap();
        assert!(reg.contains("t0"), "recently-used survives");
        assert!(!reg.contains("t1"), "LRU evicted");
        assert!(reg.contains("t2"));
        assert_eq!(reg.used_bytes(), 2 * UNIT);
    }

    #[test]
    fn oversized_and_all_pinned_registrations_fail() {
        let mut reg = AdapterRegistry::new(UNIT);
        assert!(reg.register("big", factors(4)).is_err(), "bigger than the whole budget");
        reg.register("t0", factors(1)).unwrap();
        assert!(reg.acquire("t0"));
        // no unpinned victim available
        assert!(reg.register("t1", factors(1)).is_err());
        reg.release("t0");
        reg.register("t1", factors(1)).unwrap();
        assert!(!reg.contains("t0"));
    }

    #[test]
    fn failed_register_leaves_registry_unchanged() {
        let mut reg = AdapterRegistry::new(2 * UNIT);
        reg.register("t0", factors(1)).unwrap();
        reg.register("t1", factors(1)).unwrap();
        assert!(reg.acquire("t1"));
        // hot-swap t0 to a 2-unit version: would need to evict t1 (pinned)
        assert!(reg.register("t0", factors(2)).is_err());
        assert!(reg.contains("t0"), "failed swap must not destroy the old adapter");
        assert!(reg.get("t0").is_some());
        assert!(reg.contains("t1"));
        assert_eq!(reg.used_bytes(), 2 * UNIT);
        assert_eq!(reg.stats().evictions, 0, "failed registration must not evict");
        reg.release("t1");
    }

    #[test]
    fn pinned_eviction_is_deferred_not_unsafe() {
        let mut reg = AdapterRegistry::unbounded();
        reg.register("t0", factors(2)).unwrap();
        assert!(reg.acquire("t0"));
        assert!(reg.acquire("t0"));
        assert_eq!(reg.pins("t0"), 2);

        // eviction while pinned: deferred, factors stay readable
        assert!(!reg.evict("t0"));
        assert!(reg.get("t0").is_some(), "in-flight batch keeps its factors");
        assert!(!reg.contains("t0"), "but no new sequence may pin it");
        assert!(!reg.acquire("t0"));

        reg.release("t0");
        assert!(reg.get("t0").is_some(), "still one pin outstanding");
        reg.release("t0");
        assert!(reg.get("t0").is_none(), "last release fires the eviction");
        assert_eq!(reg.used_bytes(), 0);
        assert_eq!(reg.stats().deferred_evictions, 1);
    }

    #[test]
    fn hot_swap_replaces_unpinned_rejects_pinned() {
        let mut reg = AdapterRegistry::unbounded();
        reg.register("t0", factors(1)).unwrap();
        reg.register("t0", factors(2)).unwrap(); // swap in a rank-2 version
        assert_eq!(reg.used_bytes(), factors(2).bytes());
        assert_eq!(reg.len(), 1);
        assert!(reg.acquire("t0"));
        assert!(reg.register("t0", factors(1)).is_err(), "pinned: no swap");
        reg.release("t0");
        reg.register("t0", factors(1)).unwrap();
        assert_eq!(reg.used_bytes(), UNIT);
    }
}
