//! Multi-tenant LoRDS scale adapters — the serving-side payoff of the
//! paper's unification claim (§3.4): because fine-tuning moves only the
//! rank-r scale factors (B, A) while the quantization codes Q stay frozen,
//! a deployment can host **one shared packed base** and any number of
//! per-tenant adapters, each costing just ~r·(n+m) floats per linear.
//! Unlike additive adapters (QLoRA), a tenant's forward is *exactly* the
//! base fused kernel with different scale factors — zero extra matmuls,
//! zero code duplication, zero dequantization.
//!
//! * [`artifact`] — the adapter payload: per-layer (B′, A′) pairs
//!   ([`AdapterFactors`]), extraction from a PEFT-trained model,
//!   dense-merge application, and the on-disk [`AdapterArtifact`] format.
//! * [`registry`] — [`AdapterRegistry`]: hot-swappable storage keyed by
//!   adapter id with ref-counted pinning (in-flight batches defer
//!   eviction) and LRU eviction over a byte budget.
//!
//! The coordinator threads a tenant id through
//! [`Request`](crate::coordinator::Request) →
//! [`SeqState`](crate::coordinator::engine::SeqState) → the engine, which
//! resolves it against its registry per prefill/decode call. The reserved
//! id [`BASE_ADAPTER`] is the zero-rank "base" tenant: it names the
//! quantizer's own baked-in factors, occupies no registry bytes, and can
//! never be evicted.

pub mod artifact;
pub mod registry;

pub use artifact::{AdapterArtifact, AdapterFactors, BaPair, LayerFactors};
pub use registry::AdapterRegistry;

/// Reserved tenant id for the unadapted base model (baked-in quantizer
/// scale factors; not a registry resident).
pub const BASE_ADAPTER: &str = "base";
