//! Adapter payloads: per-tenant (B′, A′) scale factors for every LoRDS
//! linear, plus the on-disk artifact format the PEFT trainer exports and
//! the serving side loads.
//!
//! Layout convention: [`AdapterFactors::layers`] is indexed by transformer
//! block, and each [`LayerFactors::linears`] slot positionally matches
//! [`LayerWeights::linears()`](crate::model::transformer::LayerWeights::linears)
//! order (wq, wk, wv, wo, w_gate, w_up, w_down). A `None` slot means "use
//! the base factors for this linear" — adapters may cover any subset.

use crate::model::{LinearWeight, Model};
use crate::tensor::Matrix;
use crate::util::Rng;
use std::io::{Read, Write};

/// Number of linears per transformer block
/// ([`LayerWeights::linears`](crate::model::transformer::LayerWeights::linears)).
pub const LINEARS_PER_LAYER: usize = 7;

/// One linear's override factors: B′ ∈ R^{n×r′}, A′ ∈ R^{r′×m}. The
/// adapter rank r′ may differ from the quantizer's parity rank.
#[derive(Clone, Debug, PartialEq)]
pub struct BaPair {
    pub b: Matrix,
    pub a: Matrix,
}

impl BaPair {
    pub fn rank(&self) -> usize {
        self.b.cols
    }

    /// fp32 bytes this pair occupies when resident.
    pub fn bytes(&self) -> usize {
        4 * (self.b.len() + self.a.len())
    }
}

/// Factors for one transformer block, positionally matching
/// [`LayerWeights::linears`](crate::model::transformer::LayerWeights::linears)
/// order.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerFactors {
    pub linears: [Option<BaPair>; LINEARS_PER_LAYER],
}

impl LayerFactors {
    pub fn empty() -> LayerFactors {
        LayerFactors { linears: std::array::from_fn(|_| None) }
    }
}

/// A full tenant adapter: one [`LayerFactors`] per transformer block.
#[derive(Clone, Debug, PartialEq)]
pub struct AdapterFactors {
    pub layers: Vec<LayerFactors>,
}

impl AdapterFactors {
    pub fn empty(n_layers: usize) -> AdapterFactors {
        AdapterFactors { layers: (0..n_layers).map(|_| LayerFactors::empty()).collect() }
    }

    /// Extract the current scale factors of every frozen-code LoRDS linear
    /// (the state a PEFT run fine-tunes). Non-LoRDS and QAT linears yield
    /// `None` slots.
    pub fn from_model(model: &Model) -> AdapterFactors {
        let layers = model
            .layers
            .iter()
            .map(|layer| {
                let mut lf = LayerFactors::empty();
                for (slot, (_, lw)) in layer.linears().into_iter().enumerate() {
                    if let LinearWeight::Lords { q, shadow_w: None } = lw {
                        lf.linears[slot] = Some(BaPair { b: q.b.clone(), a: q.a.clone() });
                    }
                }
                lf
            })
            .collect();
        AdapterFactors { layers }
    }

    /// Dense-merge path: overwrite the model's baked-in factors with this
    /// adapter's (the codes are untouched). Used for offline merging and as
    /// the reference in parity tests; online serving passes the factors to
    /// the fused kernels per call instead.
    pub fn apply_to(&self, model: &mut Model) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.layers.len() == model.layers.len(),
            "adapter has {} layers, model has {}",
            self.layers.len(),
            model.layers.len()
        );
        for (lf, layer) in self.layers.iter().zip(model.layers.iter_mut()) {
            for (slot, (name, lw)) in layer.linears_mut().into_iter().enumerate() {
                let Some(pair) = &lf.linears[slot] else { continue };
                match lw {
                    LinearWeight::Lords { q, shadow_w: None } => {
                        check_pair(name, pair, q.rows, q.cols)?;
                        q.b = pair.b.clone();
                        q.a = pair.a.clone();
                        q.rank = pair.rank();
                    }
                    other => anyhow::bail!(
                        "adapter targets {name} but the model holds {other:?} there \
                         (expected a frozen-code LoRDS linear)"
                    ),
                }
            }
        }
        Ok(())
    }

    /// Shape-check every override slot against a model without mutating it
    /// (registration-time validation).
    pub fn validate_against(&self, model: &Model) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.layers.len() == model.layers.len(),
            "adapter has {} layers, model has {}",
            self.layers.len(),
            model.layers.len()
        );
        for (lf, layer) in self.layers.iter().zip(model.layers.iter()) {
            for (slot, (name, lw)) in layer.linears().into_iter().enumerate() {
                let Some(pair) = &lf.linears[slot] else { continue };
                match lw {
                    LinearWeight::Lords { q, shadow_w: None } => {
                        check_pair(name, pair, q.rows, q.cols)?;
                    }
                    other => anyhow::bail!(
                        "adapter targets {name} but the model holds {other:?} there \
                         (expected a frozen-code LoRDS linear)"
                    ),
                }
            }
        }
        Ok(())
    }

    /// Total fp32 bytes this adapter occupies when resident — the entire
    /// per-tenant serving cost (the packed codes are shared with the base).
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|lf| lf.linears.iter())
            .filter_map(|p| p.as_ref().map(BaPair::bytes))
            .sum()
    }

    /// Number of override pairs (populated slots).
    pub fn n_pairs(&self) -> usize {
        self.layers.iter().flat_map(|lf| lf.linears.iter()).filter(|p| p.is_some()).count()
    }

    /// Deterministically perturb every factor pair — a synthetic stand-in
    /// for a PEFT-trained tenant (same shapes, same serving cost, distinct
    /// outputs) used by the multi-tenant bench and tests.
    pub fn perturbed(&self, std: f32, rng: &mut Rng) -> AdapterFactors {
        let mut out = self.clone();
        for lf in out.layers.iter_mut() {
            for pair in lf.linears.iter_mut().flatten() {
                for v in pair.b.data.iter_mut() {
                    *v += std * rng.normal();
                }
                for v in pair.a.data.iter_mut() {
                    *v += std * rng.normal();
                }
            }
        }
        out
    }
}

fn check_pair(name: &str, pair: &BaPair, rows: usize, cols: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        pair.b.rows == rows && pair.a.cols == cols && pair.b.cols == pair.a.rows,
        "{name}: adapter factors B′ {}x{} / A′ {}x{} incompatible with {rows}x{cols} codes",
        pair.b.rows,
        pair.b.cols,
        pair.a.rows,
        pair.a.cols
    );
    anyhow::ensure!(pair.b.all_finite() && pair.a.all_finite(), "{name}: non-finite adapter factors");
    Ok(())
}

/// A named, serializable adapter — what the PEFT trainer exports and
/// `Model::load_adapter` / [`AdapterRegistry`](super::AdapterRegistry)
/// consume.
#[derive(Clone, Debug, PartialEq)]
pub struct AdapterArtifact {
    pub id: String,
    pub factors: AdapterFactors,
}

const MAGIC: &[u8; 8] = b"LORDSAD1";

impl AdapterArtifact {
    /// Package a PEFT-trained model's factors. Errors when the model has no
    /// LoRDS linears (nothing to adapt).
    pub fn from_model(model: &Model, id: &str) -> anyhow::Result<AdapterArtifact> {
        let factors = AdapterFactors::from_model(model);
        anyhow::ensure!(
            factors.n_pairs() > 0,
            "model has no frozen-code LoRDS linears — nothing to export as adapter '{id}'"
        );
        Ok(AdapterArtifact { id: id.to_string(), factors })
    }

    /// Serialize (tiny binary format, f32 little-endian, same conventions
    /// as the model checkpoint).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        let id_bytes = self.id.as_bytes();
        f.write_all(&(id_bytes.len() as u32).to_le_bytes())?;
        f.write_all(id_bytes)?;
        f.write_all(&(self.factors.layers.len() as u32).to_le_bytes())?;
        for lf in &self.factors.layers {
            for slot in &lf.linears {
                match slot {
                    None => f.write_all(&[0u8])?,
                    Some(pair) => {
                        f.write_all(&[1u8])?;
                        crate::model::checkpoint::write_mat(&mut f, &pair.b)?;
                        crate::model::checkpoint::write_mat(&mut f, &pair.a)?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &str) -> std::io::Result<AdapterArtifact> {
        let bad = |what: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, what.to_string());
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(bad("bad adapter magic"));
        }
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let id_len = u32::from_le_bytes(b4) as usize;
        if id_len > 4096 {
            return Err(bad("unreasonable adapter id length"));
        }
        let mut id_bytes = vec![0u8; id_len];
        f.read_exact(&mut id_bytes)?;
        let id = String::from_utf8(id_bytes).map_err(|_| bad("adapter id not utf8"))?;
        f.read_exact(&mut b4)?;
        let n_layers = u32::from_le_bytes(b4) as usize;
        if n_layers > 65_536 {
            return Err(bad("unreasonable adapter layer count"));
        }
        let mut factors = AdapterFactors::empty(n_layers);
        for lf in factors.layers.iter_mut() {
            for slot in lf.linears.iter_mut() {
                let mut flag = [0u8; 1];
                f.read_exact(&mut flag)?;
                if flag[0] == 1 {
                    let b = crate::model::checkpoint::read_mat(&mut f)?;
                    let a = crate::model::checkpoint::read_mat(&mut f)?;
                    *slot = Some(BaPair { b, a });
                } else if flag[0] != 0 {
                    return Err(bad("bad adapter slot flag"));
                }
            }
        }
        Ok(AdapterArtifact { id, factors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::quant::lords::RefineCfg;
    use crate::quant::Codebook;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
            block: 8,
            codebook: "nf4".into(),
            qlora_rank: 4,
        }
    }

    fn lords_model(seed: u64) -> Model {
        let cfg = tiny_cfg();
        let mut m = Model::init(&cfg, seed);
        m.quantize_lords(
            cfg.block,
            &Codebook::normal_float(4),
            RefineCfg { steps: 2, ..Default::default() },
            false,
        );
        m
    }

    #[test]
    fn extract_apply_roundtrip() {
        let model = lords_model(0);
        let f = AdapterFactors::from_model(&model);
        assert_eq!(f.layers.len(), 2);
        assert_eq!(f.n_pairs(), 2 * LINEARS_PER_LAYER);
        assert!(f.bytes() > 0);
        f.validate_against(&model).unwrap();

        // perturb, apply, re-extract: must get the perturbed factors back
        let mut rng = crate::util::Rng::new(1);
        let f2 = f.perturbed(0.05, &mut rng);
        assert_ne!(f, f2);
        let mut model2 = model.clone();
        f2.apply_to(&mut model2).unwrap();
        assert_eq!(AdapterFactors::from_model(&model2), f2);
    }

    #[test]
    fn validation_rejects_bad_shapes_and_dense_targets() {
        let model = lords_model(2);
        let mut f = AdapterFactors::from_model(&model);
        // break one shape
        if let Some(pair) = f.layers[0].linears[0].as_mut() {
            pair.b = Matrix::zeros(pair.b.rows + 1, pair.b.cols);
        }
        assert!(f.validate_against(&model).is_err());

        // dense model: adapters have nowhere to land
        let dense = Model::init(&tiny_cfg(), 3);
        let f2 = AdapterFactors::from_model(&lords_model(3));
        assert!(f2.validate_against(&dense).is_err());
        assert!(AdapterArtifact::from_model(&dense, "t").is_err());
    }

    #[test]
    fn artifact_save_load_roundtrip() {
        let model = lords_model(4);
        let art = AdapterArtifact::from_model(&model, "tenant-a").unwrap();
        let path = std::env::temp_dir().join("lords_adapter_test.bin");
        let path = path.to_str().unwrap();
        art.save(path).unwrap();
        let loaded = AdapterArtifact::load(path).unwrap();
        assert_eq!(loaded, art);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bytes_counts_only_populated_slots() {
        let mut f = AdapterFactors::empty(1);
        assert_eq!(f.bytes(), 0);
        f.layers[0].linears[0] =
            Some(BaPair { b: Matrix::zeros(4, 2), a: Matrix::zeros(2, 6) });
        assert_eq!(f.bytes(), 4 * (8 + 12));
        assert_eq!(f.n_pairs(), 1);
    }
}
