//! Bit-packed quantization code storage (see the [module doc](super) for
//! the word format).

/// Codes packed `32 / bits` to a `u32` word, LSB-first, rows word-aligned.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCodes {
    bits: u32,
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u32>,
}

impl PackedCodes {
    /// Narrowest width that can store codes `0..levels` (1..=8 bits).
    pub fn bits_needed(levels: usize) -> u32 {
        assert!((2..=256).contains(&levels), "codebook size {levels} out of range");
        let mut b = 1u32;
        while (1usize << b) < levels {
            b += 1;
        }
        b
    }

    /// Codes per 32-bit word at the given width.
    pub fn codes_per_word(bits: u32) -> usize {
        assert!((1..=8).contains(&bits), "unsupported code width {bits}");
        (32 / bits) as usize
    }

    #[inline]
    fn mask(bits: u32) -> u32 {
        (1u32 << bits) - 1
    }

    /// All-zero codes.
    pub fn zeros(bits: u32, rows: usize, cols: usize) -> PackedCodes {
        let cpw = Self::codes_per_word(bits);
        let words_per_row = cols.div_ceil(cpw);
        PackedCodes { bits, rows, cols, words_per_row, words: vec![0; rows * words_per_row] }
    }

    /// Pack a flat row-major `u8` code matrix.
    pub fn from_flat(bits: u32, rows: usize, cols: usize, codes: &[u8]) -> PackedCodes {
        assert_eq!(codes.len(), rows * cols, "code count mismatch");
        let mut p = Self::zeros(bits, rows, cols);
        let wpr = p.words_per_row;
        for i in 0..rows {
            Self::pack_row(bits, &codes[i * cols..(i + 1) * cols], &mut p.words[i * wpr..(i + 1) * wpr]);
        }
        p
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored codes (rows × cols).
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Raw word storage — rows are disjoint word ranges, so callers may
    /// hand out per-row sub-slices to parallel workers.
    pub fn words_mut(&mut self) -> &mut [u32] {
        &mut self.words
    }

    /// Bytes of backing storage (the memory-traffic number Figure 2 cares
    /// about; `len()` bytes in the old `Vec<u8>` layout).
    pub fn mem_bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u32>()
    }

    /// Code at (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> u8 {
        debug_assert!(i < self.rows && j < self.cols);
        let cpw = (32 / self.bits) as usize;
        let w = self.words[i * self.words_per_row + j / cpw];
        ((w >> ((j % cpw) as u32 * self.bits)) & Self::mask(self.bits)) as u8
    }

    /// Set code at (i, j) (slow path — bulk writers use [`Self::pack_row`]).
    pub fn set(&mut self, i: usize, j: usize, code: u8) {
        debug_assert!(i < self.rows && j < self.cols);
        debug_assert!((code as u32) <= Self::mask(self.bits), "code {code} exceeds {} bits", self.bits);
        let cpw = (32 / self.bits) as usize;
        let shift = (j % cpw) as u32 * self.bits;
        let w = &mut self.words[i * self.words_per_row + j / cpw];
        *w = (*w & !(Self::mask(self.bits) << shift)) | (((code as u32) & Self::mask(self.bits)) << shift);
    }

    /// Pack one row of codes into its word slice. Static so quantizers
    /// holding a raw pointer into [`Self::words_mut`] can repack disjoint
    /// rows from parallel workers.
    pub fn pack_row(bits: u32, codes: &[u8], out: &mut [u32]) {
        let cpw = Self::codes_per_word(bits);
        let mask = Self::mask(bits);
        debug_assert!(out.len() >= codes.len().div_ceil(cpw));
        for (wi, chunk) in codes.chunks(cpw).enumerate() {
            let mut w = 0u32;
            for (k, &c) in chunk.iter().enumerate() {
                debug_assert!((c as u32) <= mask, "code {c} exceeds {bits} bits");
                w |= ((c as u32) & mask) << (k as u32 * bits);
            }
            out[wi] = w;
        }
    }

    /// Replace row `i` with `codes` (len = cols).
    pub fn set_row(&mut self, i: usize, codes: &[u8]) {
        assert_eq!(codes.len(), self.cols);
        let wpr = self.words_per_row;
        Self::pack_row(self.bits, codes, &mut self.words[i * wpr..(i + 1) * wpr]);
    }

    /// Unpack row `i` into `out[..cols]` — the kernels' hot path.
    #[inline]
    pub fn unpack_row_into(&self, i: usize, out: &mut [u8]) {
        debug_assert!(out.len() >= self.cols);
        let cpw = (32 / self.bits) as usize;
        let mask = Self::mask(self.bits);
        let row = &self.words[i * self.words_per_row..(i + 1) * self.words_per_row];
        let mut j = 0usize;
        for &word in row {
            let mut w = word;
            let lim = cpw.min(self.cols - j);
            for _ in 0..lim {
                out[j] = (w & mask) as u8;
                w >>= self.bits;
                j += 1;
            }
            if j == self.cols {
                break;
            }
        }
    }

    /// Unpack everything to the old flat `Vec<u8>` layout.
    pub fn to_flat(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.cols];
        for i in 0..self.rows {
            let (lo, hi) = (i * self.cols, (i + 1) * self.cols);
            self.unpack_row_into(i, &mut out[lo..hi]);
        }
        out
    }

    /// Row-major iterator over all codes (bridge/serialization paths).
    /// One bulk unpack ([`Self::to_flat`]), not a per-row allocation.
    pub fn iter(&self) -> impl Iterator<Item = u8> {
        self.to_flat().into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bits_needed_matches_codebooks() {
        assert_eq!(PackedCodes::bits_needed(4), 2); // nf2
        assert_eq!(PackedCodes::bits_needed(8), 3); // nf3
        assert_eq!(PackedCodes::bits_needed(15), 4); // int4 (15 levels)
        assert_eq!(PackedCodes::bits_needed(16), 4); // nf4
        assert_eq!(PackedCodes::bits_needed(255), 8); // int8
    }

    #[test]
    fn word_capacity() {
        assert_eq!(PackedCodes::codes_per_word(2), 16);
        assert_eq!(PackedCodes::codes_per_word(3), 10); // 2 dead bits
        assert_eq!(PackedCodes::codes_per_word(4), 8);
        assert_eq!(PackedCodes::codes_per_word(8), 4);
    }

    #[test]
    fn roundtrip_all_widths_random_shapes() {
        let mut rng = Rng::new(0);
        for bits in [2u32, 3, 4, 8] {
            for (rows, cols) in [(1usize, 1usize), (3, 7), (5, 10), (4, 33), (2, 64)] {
                let maxc = (1u32 << bits) as usize;
                let flat: Vec<u8> = (0..rows * cols).map(|_| rng.below(maxc) as u8).collect();
                let p = PackedCodes::from_flat(bits, rows, cols, &flat);
                assert_eq!(p.to_flat(), flat, "bits={bits} {rows}x{cols}");
                assert_eq!(p.get(rows - 1, cols - 1), flat[rows * cols - 1]);
                assert_eq!(p.iter().collect::<Vec<_>>(), flat);
            }
        }
    }

    #[test]
    fn rows_are_word_aligned() {
        // 3-bit, 11 cols → 2 words per row; row 1 must not share word 1
        let flat: Vec<u8> = (0..22).map(|v| (v % 8) as u8).collect();
        let p = PackedCodes::from_flat(3, 2, 11, &flat);
        assert_eq!(p.words_per_row(), 2);
        assert_eq!(p.words().len(), 4);
        // mutating row 0 leaves row 1 intact
        let mut p2 = p.clone();
        p2.set_row(0, &[7u8; 11]);
        for j in 0..11 {
            assert_eq!(p2.get(1, j), p.get(1, j));
            assert_eq!(p2.get(0, j), 7);
        }
    }

    #[test]
    fn set_get_pointwise() {
        let mut p = PackedCodes::zeros(4, 3, 9);
        p.set(1, 8, 15);
        p.set(2, 0, 9);
        assert_eq!(p.get(1, 8), 15);
        assert_eq!(p.get(2, 0), 9);
        assert_eq!(p.get(0, 0), 0);
        p.set(1, 8, 1); // overwrite clears old bits
        assert_eq!(p.get(1, 8), 1);
    }

    #[test]
    fn memory_is_packed() {
        let p = PackedCodes::zeros(4, 128, 512);
        // 4-bit: 8 codes/word ⇒ 0.5 bytes per element vs 1 byte in Vec<u8>
        assert_eq!(p.mem_bytes(), 128 * 512 / 2);
        let p2 = PackedCodes::zeros(2, 128, 512);
        assert_eq!(p2.mem_bytes(), 128 * 512 / 4);
    }
}
