//! Tiled fused dequant-matmul kernels over [`PackedCodes`] (layout and
//! tiling strategy in the [module doc](super)).
//!
//! Forward kernels compute `y = x · Ŵᵀ`, backward kernels `y = g · Ŵ`,
//! with `Ŵ = lut[Q] ⊙ S` reconstructed one row-tile at a time:
//! `S = B·A` (LoRDS, rank-r) or `S = s ⊗ 1` (block-wise broadcast).
//! The full `Ŵ` is never materialized.

use super::packed::PackedCodes;
use crate::obs;
use crate::tensor::Matrix;
use crate::util::{SharedMut, ThreadPool};

/// Weight rows dequantized per tile; sized so the tile's scratch
/// (`ROW_TILE × m` floats) stays L1/L2-resident for the shapes the model
/// serves (m ≤ a few thousand).
pub const ROW_TILE: usize = 8;

/// Contiguous 4-accumulator dot product — the same microkernel shape as
/// `tensor::gemm::matmul_transb`, so LLVM vectorizes both identically.
///
/// Shared across the weight kernels here and the fused packed attention
/// ([`kvquant::attention`](crate::kvquant::attention)) / dense attention
/// ([`model::attention`](crate::model::attention)) score sweeps, so every
/// hot dot product in the serving path compiles to the same vectorized
/// loop (re-exported as `kernels::dot`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    debug_assert_eq!(k, b.len());
    let (mut acc0, mut acc1, mut acc2, mut acc3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let chunks = k / 4;
    for c in 0..chunks {
        let p = c * 4;
        acc0 += a[p] * b[p];
        acc1 += a[p + 1] * b[p + 1];
        acc2 += a[p + 2] * b[p + 2];
        acc3 += a[p + 3] * b[p + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for p in chunks * 4..k {
        acc += a[p] * b[p];
    }
    acc
}

/// Scale reconstruction `srow[k] = Σ_p b[j, p] · a[p, c0 + k]` for the
/// column range `[c0, c0 + srow.len())` — cost r·width, the entirety of
/// LoRDS's extra serving work. Forward kernels pass the full row
/// (`c0 = 0`), the column-partitioned backward kernels pass their slice.
#[inline]
fn reconstruct_scale_row(srow: &mut [f32], b: &Matrix, j: usize, a: &Matrix, c0: usize) {
    srow.iter_mut().for_each(|v| *v = 0.0);
    for p in 0..b.cols {
        let bjp = b.at(j, p);
        if bjp == 0.0 {
            continue;
        }
        for (sv, &av) in srow.iter_mut().zip(&a.row(p)[c0..c0 + srow.len()]) {
            *sv += bjp * av;
        }
    }
}

/// Dequantize one packed row into `wrow`: `wrow[k] = lut[crow[k]] · srow[k]`.
#[inline]
fn dequant_row(wrow: &mut [f32], crow: &[u8], lut: &[f32], srow: &[f32]) {
    for ((w, &c), &s) in wrow.iter_mut().zip(crow).zip(srow) {
        *w = lut[c as usize] * s;
    }
}

/// Block-wise dequant of columns `[c0, c0 + wrow.len())` of one row:
/// `wrow[k] = lut[crow[c0 + k]] · scales_row[(c0 + k) / block]`, with one
/// scale lookup (and one division) per touched block, not per element.
#[inline]
fn blockwise_dequant_row(
    wrow: &mut [f32],
    crow: &[u8],
    lut: &[f32],
    scales_row: &[f32],
    block: usize,
    c0: usize,
) {
    let c1 = c0 + wrow.len();
    let mut col = c0;
    while col < c1 {
        let bi = col / block;
        let end = ((bi + 1) * block).min(c1);
        let s = scales_row[bi];
        for k in col..end {
            wrow[k - c0] = lut[crow[k] as usize] * s;
        }
        col = end;
    }
}

/// Fused LoRDS forward: `y = x · (lut[Q] ⊙ (B·A))ᵀ`.
///
/// x: t×m, Q: n×m packed, B: n×r, A: r×m, lut: codebook levels → y: t×n.
pub fn lords_matmul_transb(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    b: &Matrix,
    a: &Matrix,
) -> Matrix {
    let mut y = Matrix::zeros(x.rows, codes.rows());
    lords_matmul_transb_into(x, codes, lut, b, a, &mut y);
    y
}

/// [`lords_matmul_transb`] writing into a caller-owned t×n output (every
/// element is overwritten — no zeroing required). The batched decode tick
/// reuses one activation arena across tokens/layers instead of allocating
/// a fresh output per linear per token.
pub fn lords_matmul_transb_into(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    b: &Matrix,
    a: &Matrix,
    y: &mut Matrix,
) {
    let _span = obs::span!("kernel.lords_matmul", x.rows);
    let (n, m) = (codes.rows(), codes.cols());
    assert_eq!(x.cols, m, "x width {} vs codes {}", x.cols, m);
    assert_eq!(b.rows, n, "B rows");
    assert_eq!(a.cols, m, "A cols");
    assert_eq!(b.cols, a.rows, "rank mismatch");
    let t = x.rows;
    assert_eq!(y.shape(), (t, n), "out shape {:?} vs ({t}, {n})", y.shape());
    let yp = SharedMut(y.data.as_mut_ptr());
    let ypr = &yp;
    ThreadPool::global().parallel_for(n, move |lo, hi| {
        let mut srow = vec![0.0f32; m]; // ALLOC-OK: per-worker-chunk scratch, not per token/row
        let mut crow = vec![0u8; m]; // ALLOC-OK: per-worker-chunk scratch, not per token/row
        let mut wtile = vec![0.0f32; ROW_TILE * m]; // ALLOC-OK: per-worker-chunk scratch
        let mut j0 = lo;
        while j0 < hi {
            let j1 = (j0 + ROW_TILE).min(hi);
            let tr = j1 - j0;
            // dequantize the tile's rows once...
            for (ti, j) in (j0..j1).enumerate() {
                reconstruct_scale_row(&mut srow, b, j, a, 0);
                codes.unpack_row_into(j, &mut crow);
                dequant_row(&mut wtile[ti * m..(ti + 1) * m], &crow, lut, &srow);
            }
            // ...then stream every x row against the whole tile (each x row
            // is loaded once per tile, not once per weight row)
            for xi in 0..t {
                let xrow = x.row(xi);
                let ybase = xi * n + j0;
                for ti in 0..tr {
                    let acc = dot(xrow, &wtile[ti * m..(ti + 1) * m]);
                    // SAFETY: this worker owns Ŵ rows [lo, hi) ⇒ y columns
                    // [lo, hi) of every output row — disjoint across workers;
                    // y outlives the parallel_for join.
                    unsafe { *ypr.0.add(ybase + ti) = acc };
                }
            }
            j0 = j1;
        }
    });
}

/// Fused LoRDS backward-dx: `y = g · (lut[Q] ⊙ (B·A))`.
///
/// g: t×n, Q: n×m packed → y: t×m. Parallel over **output columns** so the
/// expensive per-row scale reconstruction + dequant is partitioned across
/// workers (each worker rebuilds only its column slice of every Ŵ row);
/// only the cheap shift/mask unpack is duplicated.
pub fn lords_matmul(
    g: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    b: &Matrix,
    a: &Matrix,
) -> Matrix {
    let (n, m) = (codes.rows(), codes.cols());
    assert_eq!(g.cols, n, "g width {} vs codes rows {}", g.cols, n);
    assert_eq!(b.rows, n, "B rows");
    assert_eq!(a.cols, m, "A cols");
    assert_eq!(b.cols, a.rows, "rank mismatch");
    let t = g.rows;
    let mut y = Matrix::zeros(t, m);
    let yp = SharedMut(y.data.as_mut_ptr());
    let ypr = &yp;
    ThreadPool::global().parallel_for(m, move |c0, c1| {
        let width = c1 - c0;
        let mut crow = vec![0u8; m];
        let mut srow = vec![0.0f32; width];
        let mut wrow = vec![0.0f32; width];
        for j in 0..n {
            codes.unpack_row_into(j, &mut crow);
            // reconstruct only this worker's column slice of S[j, :]
            reconstruct_scale_row(&mut srow, b, j, a, c0);
            dequant_row(&mut wrow, &crow[c0..c1], lut, &srow);
            for gi in 0..t {
                let gv = g.at(gi, j);
                if gv == 0.0 {
                    continue;
                }
                let base = gi * m + c0;
                // SAFETY: columns [c0, c1) of every y row are owned by this
                // worker (chunks partition the columns); y outlives the
                // parallel_for join.
                let out = unsafe { std::slice::from_raw_parts_mut(ypr.0.add(base), width) };
                for (o, &wv) in out.iter_mut().zip(wrow.iter()) {
                    *o += gv * wv;
                }
            }
        }
    });
    y
}

/// Multi-tenant forward: [`lords_matmul_transb`] with per-call scale
/// factors — the adapter's (B′, A′) when present, else the quantizer's
/// baked-in (B, A). The packed codes are shared either way; serving a
/// tenant never duplicates or re-dequantizes `Q`, and the adapter rank r′
/// may differ from the base rank.
pub fn lords_matmul_transb_adapter(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    base_b: &Matrix,
    base_a: &Matrix,
    adapter: Option<(&Matrix, &Matrix)>,
) -> Matrix {
    let (b, a) = adapter.unwrap_or((base_b, base_a));
    lords_matmul_transb(x, codes, lut, b, a)
}

/// [`lords_matmul_transb_adapter`] writing into a caller-owned output
/// (see [`lords_matmul_transb_into`]).
pub fn lords_matmul_transb_adapter_into(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    base_b: &Matrix,
    base_a: &Matrix,
    adapter: Option<(&Matrix, &Matrix)>,
    y: &mut Matrix,
) {
    let (b, a) = adapter.unwrap_or((base_b, base_a));
    lords_matmul_transb_into(x, codes, lut, b, a, y);
}

/// Multi-tenant backward-dx: [`lords_matmul`] with per-call scale factors
/// (see [`lords_matmul_transb_adapter`]).
pub fn lords_matmul_adapter(
    g: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    base_b: &Matrix,
    base_a: &Matrix,
    adapter: Option<(&Matrix, &Matrix)>,
) -> Matrix {
    let (b, a) = adapter.unwrap_or((base_b, base_a));
    lords_matmul(g, codes, lut, b, a)
}

/// Fused block-wise forward: `y = x · (lut[Q] ⊙ (s ⊗ 1))ᵀ`.
///
/// scales: n × (m / block) absmax scales.
pub fn blockwise_matmul_transb(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    scales: &Matrix,
    block: usize,
) -> Matrix {
    let mut y = Matrix::zeros(x.rows, codes.rows());
    blockwise_matmul_transb_into(x, codes, lut, scales, block, &mut y);
    y
}

/// [`blockwise_matmul_transb`] writing into a caller-owned t×n output
/// (see [`lords_matmul_transb_into`]).
pub fn blockwise_matmul_transb_into(
    x: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    scales: &Matrix,
    block: usize,
    y: &mut Matrix,
) {
    let _span = obs::span!("kernel.blockwise_matmul", x.rows);
    let (n, m) = (codes.rows(), codes.cols());
    assert_eq!(x.cols, m, "x width {} vs codes {}", x.cols, m);
    assert!(block > 0 && m % block == 0, "block {block} !| cols {m}");
    assert_eq!(scales.rows, n, "scale rows");
    assert_eq!(scales.cols, m / block, "scale cols");
    let t = x.rows;
    assert_eq!(y.shape(), (t, n), "out shape {:?} vs ({t}, {n})", y.shape());
    let yp = SharedMut(y.data.as_mut_ptr());
    let ypr = &yp;
    ThreadPool::global().parallel_for(n, move |lo, hi| {
        let mut crow = vec![0u8; m]; // ALLOC-OK: per-worker-chunk scratch, not per token/row
        let mut wtile = vec![0.0f32; ROW_TILE * m]; // ALLOC-OK: per-worker-chunk scratch
        let mut j0 = lo;
        while j0 < hi {
            let j1 = (j0 + ROW_TILE).min(hi);
            let tr = j1 - j0;
            for (ti, j) in (j0..j1).enumerate() {
                codes.unpack_row_into(j, &mut crow);
                blockwise_dequant_row(&mut wtile[ti * m..(ti + 1) * m], &crow, lut, scales.row(j), block, 0);
            }
            for xi in 0..t {
                let xrow = x.row(xi);
                let ybase = xi * n + j0;
                for ti in 0..tr {
                    let acc = dot(xrow, &wtile[ti * m..(ti + 1) * m]);
                    // SAFETY: this worker owns Ŵ rows [lo, hi) ⇒ y columns
                    // [lo, hi) of every output row — disjoint across workers;
                    // y outlives the parallel_for join.
                    unsafe { *ypr.0.add(ybase + ti) = acc };
                }
            }
            j0 = j1;
        }
    });
}

/// Fused block-wise backward-dx: `y = g · (lut[Q] ⊙ (s ⊗ 1))`.
///
/// Parallel over output columns, like [`lords_matmul`].
pub fn blockwise_matmul(
    g: &Matrix,
    codes: &PackedCodes,
    lut: &[f32],
    scales: &Matrix,
    block: usize,
) -> Matrix {
    let (n, m) = (codes.rows(), codes.cols());
    assert_eq!(g.cols, n, "g width {} vs codes rows {}", g.cols, n);
    assert!(block > 0 && m % block == 0, "block {block} !| cols {m}");
    assert_eq!(scales.rows, n, "scale rows");
    assert_eq!(scales.cols, m / block, "scale cols");
    let t = g.rows;
    let mut y = Matrix::zeros(t, m);
    let yp = SharedMut(y.data.as_mut_ptr());
    let ypr = &yp;
    ThreadPool::global().parallel_for(m, move |c0, c1| {
        let width = c1 - c0;
        let mut crow = vec![0u8; m];
        let mut wrow = vec![0.0f32; width];
        for j in 0..n {
            codes.unpack_row_into(j, &mut crow);
            blockwise_dequant_row(&mut wrow, &crow, lut, scales.row(j), block, c0);
            for gi in 0..t {
                let gv = g.at(gi, j);
                if gv == 0.0 {
                    continue;
                }
                let base = gi * m + c0;
                // SAFETY: columns [c0, c1) of every y row are owned by this
                // worker (chunks partition the columns); y outlives the
                // parallel_for join.
                let out = unsafe { std::slice::from_raw_parts_mut(ypr.0.add(base), width) };
                for (o, &wv) in out.iter_mut().zip(wrow.iter()) {
                    *o += gv * wv;
                }
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_transb};
    use crate::util::prop::{assert_allclose, prop_check};

    /// Dense reference: Ŵ = lut[Q] ⊙ (B·A).
    fn dense_lords(codes: &PackedCodes, lut: &[f32], b: &Matrix, a: &Matrix) -> Matrix {
        let s = matmul(b, a);
        Matrix::from_fn(codes.rows(), codes.cols(), |i, j| lut[codes.get(i, j) as usize] * s.at(i, j))
    }

    fn dense_blockwise(codes: &PackedCodes, lut: &[f32], scales: &Matrix, block: usize) -> Matrix {
        Matrix::from_fn(codes.rows(), codes.cols(), |i, j| {
            lut[codes.get(i, j) as usize] * scales.at(i, j / block)
        })
    }

    #[test]
    fn lords_fused_matches_dense_both_directions() {
        prop_check(12, |g| {
            let n = g.usize(2..=40);
            let m = g.usize(2..=48);
            let r = g.usize(1..=4);
            let t = g.usize(1..=9);
            let bits = *g.pick(&[2u32, 3, 4]);
            let levels = 1usize << bits;
            let mut rng = g.rng().fork(11);
            let lut: Vec<f32> = (0..levels).map(|i| -1.0 + 2.0 * i as f32 / (levels - 1) as f32).collect();
            let flat: Vec<u8> = (0..n * m).map(|_| rng.below(levels) as u8).collect();
            let codes = PackedCodes::from_flat(bits, n, m, &flat);
            let b = Matrix::randn(n, r, 0.3, &mut rng);
            let a = Matrix::randn(r, m, 0.3, &mut rng);
            let w_hat = dense_lords(&codes, &lut, &b, &a);

            let x = Matrix::randn(t, m, 1.0, &mut rng);
            let fused = lords_matmul_transb(&x, &codes, &lut, &b, &a);
            assert_allclose(&fused.data, &matmul_transb(&x, &w_hat).data, 1e-4, 1e-4, "fwd");

            let gup = Matrix::randn(t, n, 1.0, &mut rng);
            let fused_bwd = lords_matmul(&gup, &codes, &lut, &b, &a);
            assert_allclose(&fused_bwd.data, &matmul(&gup, &w_hat).data, 1e-4, 1e-4, "bwd");
            Ok(())
        });
    }

    #[test]
    fn blockwise_fused_matches_dense_both_directions() {
        prop_check(12, |g| {
            let n = g.usize(2..=40);
            let nb = g.usize(1..=6);
            let block = *g.pick(&[4usize, 8]);
            let m = nb * block;
            let t = g.usize(1..=9);
            let bits = *g.pick(&[2u32, 3, 4]);
            let levels = 1usize << bits;
            let mut rng = g.rng().fork(13);
            let lut: Vec<f32> = (0..levels).map(|i| -1.0 + 2.0 * i as f32 / (levels - 1) as f32).collect();
            let flat: Vec<u8> = (0..n * m).map(|_| rng.below(levels) as u8).collect();
            let codes = PackedCodes::from_flat(bits, n, m, &flat);
            let mut scales = Matrix::randn(n, nb, 0.5, &mut rng);
            for v in scales.data.iter_mut() {
                *v = v.abs() + 0.1;
            }
            let w_hat = dense_blockwise(&codes, &lut, &scales, block);

            let x = Matrix::randn(t, m, 1.0, &mut rng);
            let fused = blockwise_matmul_transb(&x, &codes, &lut, &scales, block);
            assert_allclose(&fused.data, &matmul_transb(&x, &w_hat).data, 1e-4, 1e-4, "fwd");

            let gup = Matrix::randn(t, n, 1.0, &mut rng);
            let fused_bwd = blockwise_matmul(&gup, &codes, &lut, &scales, block);
            assert_allclose(&fused_bwd.data, &matmul(&gup, &w_hat).data, 1e-4, 1e-4, "bwd");
            Ok(())
        });
    }

    #[test]
    fn tile_boundaries_are_seamless() {
        // n spanning multiple ROW_TILE tiles and a ragged final tile
        let n = ROW_TILE * 3 + 5;
        let m = 24;
        let mut rng = crate::util::Rng::new(7);
        let lut: Vec<f32> = (0..16).map(|i| i as f32 / 15.0 - 0.5).collect();
        let flat: Vec<u8> = (0..n * m).map(|_| rng.below(16) as u8).collect();
        let codes = PackedCodes::from_flat(4, n, m, &flat);
        let b = Matrix::randn(n, 2, 0.3, &mut rng);
        let a = Matrix::randn(2, m, 0.3, &mut rng);
        let x = Matrix::randn(4, m, 1.0, &mut rng);
        let w_hat = dense_lords(&codes, &lut, &b, &a);
        let fused = lords_matmul_transb(&x, &codes, &lut, &b, &a);
        assert_allclose(&fused.data, &matmul_transb(&x, &w_hat).data, 1e-4, 1e-4, "tiling");
    }

    #[test]
    fn adapter_override_swaps_scale_factors_only() {
        let mut rng = crate::util::Rng::new(21);
        let (n, m, t) = (17, 24, 5);
        let lut: Vec<f32> = (0..16).map(|i| i as f32 / 15.0 - 0.5).collect();
        let flat: Vec<u8> = (0..n * m).map(|_| rng.below(16) as u8).collect();
        let codes = PackedCodes::from_flat(4, n, m, &flat);
        let b = Matrix::randn(n, 2, 0.3, &mut rng);
        let a = Matrix::randn(2, m, 0.3, &mut rng);
        // adapter with a different rank than the base factors
        let b2 = Matrix::randn(n, 3, 0.3, &mut rng);
        let a2 = Matrix::randn(3, m, 0.3, &mut rng);
        let x = Matrix::randn(t, m, 1.0, &mut rng);
        let gup = Matrix::randn(t, n, 1.0, &mut rng);

        // None ⇒ identical to the baked-in-factor kernel
        let none = lords_matmul_transb_adapter(&x, &codes, &lut, &b, &a, None);
        assert_eq!(none.data, lords_matmul_transb(&x, &codes, &lut, &b, &a).data);

        // Some ⇒ matches the dense-merged tenant weight Ŵ′ = lut[Q] ⊙ (B′A′)
        let w_merged = dense_lords(&codes, &lut, &b2, &a2);
        let fwd = lords_matmul_transb_adapter(&x, &codes, &lut, &b, &a, Some((&b2, &a2)));
        assert_allclose(&fwd.data, &matmul_transb(&x, &w_merged).data, 1e-4, 1e-4, "adapter fwd");
        let bwd = lords_matmul_adapter(&gup, &codes, &lut, &b, &a, Some((&b2, &a2)));
        assert_allclose(&bwd.data, &matmul(&gup, &w_merged).data, 1e-4, 1e-4, "adapter bwd");
    }

    #[test]
    fn into_variants_match_allocating_path_on_a_dirty_buffer() {
        // the decode tick reuses one arena across tokens — stale contents
        // must be fully overwritten, not accumulated into
        let mut rng = crate::util::Rng::new(31);
        let (n, m, t) = (19, 24, 6);
        let lut: Vec<f32> = (0..16).map(|i| i as f32 / 15.0 - 0.5).collect();
        let flat: Vec<u8> = (0..n * m).map(|_| rng.below(16) as u8).collect();
        let codes = PackedCodes::from_flat(4, n, m, &flat);
        let b = Matrix::randn(n, 2, 0.3, &mut rng);
        let a = Matrix::randn(2, m, 0.3, &mut rng);
        let x = Matrix::randn(t, m, 1.0, &mut rng);
        let mut dirty = Matrix::from_fn(t, n, |i, j| (i + j) as f32 + 7.0);
        lords_matmul_transb_into(&x, &codes, &lut, &b, &a, &mut dirty);
        assert_eq!(dirty.data, lords_matmul_transb(&x, &codes, &lut, &b, &a).data);

        let mut scales = Matrix::randn(n, m / 8, 0.5, &mut rng);
        for v in scales.data.iter_mut() {
            *v = v.abs() + 0.1;
        }
        let mut dirty2 = Matrix::from_fn(t, n, |i, j| (i * j) as f32 - 3.0);
        blockwise_matmul_transb_into(&x, &codes, &lut, &scales, 8, &mut dirty2);
        assert_eq!(dirty2.data, blockwise_matmul_transb(&x, &codes, &lut, &scales, 8).data);
    }

    #[test]
    fn empty_x_is_fine() {
        let codes = PackedCodes::zeros(4, 6, 8);
        let lut = vec![0.0f32; 16];
        let b = Matrix::zeros(6, 1);
        let a = Matrix::zeros(1, 8);
        let y = lords_matmul_transb(&Matrix::zeros(0, 8), &codes, &lut, &b, &a);
        assert_eq!(y.shape(), (0, 6));
    }
}
