//! Fused bit-packed inference kernels — the Rust-native counterpart of the
//! Pallas kernels in `python/compile/kernels/`, and the reason element-wise
//! scaling can match block-wise scaling's serving cost (Figure 2).
//!
//! # Packed code layout ([`PackedCodes`])
//!
//! Quantization codes are stored `cpw = 32 / bits` to a little-endian `u32`
//! word, LSB-first: code `j` of a word lives at bit offset
//! `(j % cpw) * bits`. Every **row starts on a word boundary**
//! (`words_per_row = ceil(cols / cpw)`), which buys two things:
//!
//! * rows can be packed/unpacked concurrently without two threads ever
//!   touching the same word (the quantizers repack rows from the global
//!   thread pool), and
//! * a kernel's row-tile is a contiguous `&[u32]` slice, so unpacking is a
//!   straight shift/mask sweep the compiler vectorizes.
//!
//! 4-bit codes pack 8/word (zero waste), 3-bit codes pack 10/word (2 dead
//! bits), 2-bit codes pack 16/word. Versus the seed's one-`u8`-per-element
//! storage this is a 2×/2.7×/4× cut in weight-memory traffic — the term
//! that dominates batched decode on CPU exactly as it does on GPU.
//!
//! # Fused dequant-matmul ([`fused`])
//!
//! All kernels compute `y = x · Ŵᵀ` (or `g · Ŵ` for backward) **without
//! ever materializing Ŵ**. Work is split over output rows on the global
//! [`ThreadPool`](crate::util::ThreadPool), in tiles of
//! [`fused::ROW_TILE`] = 8 weight rows:
//!
//! 1. **Scale reconstruction** — for LoRDS the tile's scale rows
//!    `S[j0..j1, :] = B[j0..j1, :] · A` are rebuilt into a per-worker
//!    scratch buffer by a rank-r axpy loop (cost `r·m` per row — the
//!    "continuous scaling is nearly free" claim); for block-wise the scale
//!    is a broadcast lookup.
//! 2. **Unpack + dequant** — the tile's packed codes are unpacked and the
//!    dequantized row `lut[Q[j,:]] ⊙ S[j,:]` is written to a scratch row.
//! 3. **Dot products** — every x row takes a contiguous, 4-accumulator
//!    dot against the scratch row (same microkernel shape as
//!    `tensor::gemm::matmul_transb`, so LLVM vectorizes identically).
//!
//! Peak live dequantized state is `ROW_TILE × m` floats per worker, versus
//! `n × m` for dequantize-then-GEMM. The backward kernels (`g · Ŵ`)
//! partition output **columns** across workers instead, so the scale
//! reconstruction + dequant sweep is divided — not duplicated — per worker
//! (only the cheap shift/mask unpack repeats).
//!
//! # Fused vs. dense path
//!
//! The fused kernels are used by every *frozen-code* forward:
//! `LordsQuant::matmul_transb`, `BlockwiseQuant::matmul_transb`, the QLoRA
//! base, `LinearWeight::forward` / `forward_cached`, and hence the
//! coordinator engine's prefill/decode loop. The dense (materializing)
//! path remains only where a dense matrix is semantically required: QAT
//! shadow weights (STE fake-quant produces Ŵ as a training byproduct) and
//! `effective()` consumers like checkpointing and the PJRT bridge.
//!
//! # Amortization across the serving batch (the batched decode tick)
//!
//! Steps 1–2 above — stream the packed tile, reconstruct its scale rows,
//! dequantize — are per-*weight* work; only step 3 scales with the number
//! of x rows. A 1×m decode forward is therefore the kernels' worst case:
//! all of the dequant cost, one dot per tile row. The serving path fixes
//! this at the tick level: `Model::decode_batch_pooled` stacks the whole
//! running batch into B×m activations (stable-grouped by tenant, since a
//! tenant swap changes the scale factors) and calls each kernel **once
//! per tenant-group**, so per tick every packed weight streams
//! `tenant-groups` times — not `batch-size` times — and steps 1–2 amortize
//! over the group's rows exactly as they do over a prefill's sequence
//! rows. The forward kernels also come in `_into` variants
//! ([`fused::lords_matmul_transb_into`] and friends) that write into a
//! caller-owned buffer, so the decode tick's activation arena is reused
//! across tokens and layers with zero per-call allocation.
//!
//! # Multi-tenant adapter override
//!
//! The LoRDS kernels take their scale factors per call, so a served tenant
//! can substitute its fine-tuned (B′, A′) for the quantizer's baked-in
//! pair ([`fused::lords_matmul_transb_adapter`] /
//! [`fused::lords_matmul_adapter`]) while every tenant shares the same
//! [`PackedCodes`] base — the zero-overhead multi-tenant serving story of
//! the [`adapters`](crate::adapters) subsystem.

pub mod fused;
pub mod packed;

pub use fused::{
    blockwise_matmul, blockwise_matmul_transb, blockwise_matmul_transb_into, dot, lords_matmul,
    lords_matmul_adapter, lords_matmul_transb, lords_matmul_transb_adapter,
    lords_matmul_transb_adapter_into, lords_matmul_transb_into,
};
pub use packed::PackedCodes;
