//! # LoRDS — Low-Rank Decomposed Scaling
//!
//! A full-system reproduction of *"Breaking the Blocks: Continuous Low-Rank
//! Decomposed Scaling for Unified LLM Quantization and Adaptation"* as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implementing the
//!   fused `x · (Q ⊙ (BA))ᵀ` dequant-matmul, AOT-lowered to HLO text.
//! * **L2** — JAX model + train steps (`python/compile/model.py`), lowered
//!   once by `python/compile/aot.py`; Python never runs at inference time.
//! * **L3** — this crate: the quantization library (LoRDS + all baselines),
//!   a tiny-LLM training/eval testbed, the PJRT runtime, and a serving
//!   coordinator (router, batcher, KV cache, scheduler).
//!
//! The crate is self-contained after `make artifacts`: the only external
//! dependency is the `xla` PJRT binding.
//!
//! ## Module map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | RNG, thread pool, stats, logging, property-test harness |
//! | [`cli`] | dependency-free argument parser |
//! | [`config`] | TOML-subset parser + typed run configs |
//! | [`tensor`] | row-major f32 matrices, threaded blocked GEMM |
//! | [`linalg`] | Jacobi SVD, truncated SVD, norms |
//! | [`optim`] | AdamW / SGD / LR schedules |
//! | [`quant`] | **the paper**: codebooks, block-wise quant, LoRDS (Alg. 1), STE, mixed precision, GPTQ/AWQ/LoftQ/QPiSSA/QLoRA baselines, error metrics |
//! | [`kernels`] | bit-packed code storage + tiled fused dequant-matmul kernels (the zero-overhead inference claim, Figure 2) |
//! | [`kvquant`] | quantized paged KV-cache: block-pooled 4/8-bit K/V codes with rank-r low-rank scale factors per block, fused packed attention, and a shared-prefix trie over ref-counted sealed blocks (the LoRDS idea applied to serving memory) |
//! | [`adapters`] | multi-tenant LoRDS scale adapters: per-tenant (B′, A′) artifacts + hot-swappable ref-counted registry over one shared packed base (§3.4 at serving time) |
//! | [`model`] | Llama-style transformer with manual backward + quantized linears |
//! | [`obs`] | observability: atomic metrics registry (Prometheus text + JSON snapshot), lock-free tracing spans with Chrome-trace export (`obs::span!`), per-request flight recorder with anomaly dumps, zero-dep JSON |
//! | [`data`] | synthetic corpus, calibration sampler, task suite |
//! | [`train`] | LM pre-training, QAT, PEFT trainers |
//! | [`eval`] | perplexity + zero-shot-style accuracy harness |
//! | [`fault`] | deterministic fault-injection plane: seeded site-pattern × probability specs behind `fault::point!` sites (one relaxed atomic load when disabled), driving the chaos suite and self-healing serving paths |
//! | [`runtime`] | PJRT client (feature `pjrt`) or stub, artifact manifest, executable cache |
//! | [`coordinator`] | online serving API (sessioned submit/stream/cancel + offline trace shim), **continuous batching** (chunked prefill interleaved with batched decode ticks; shared-prefix KV reuse at admission), dynamic batcher with KV-aware admission, fused kernels once per tenant-group per tick, open-loop arrival driver, KV-block allocator, TTFT/ITL metrics |
//! | [`bench`] | timing harness + markdown table rendering |
//! | [`report`] | paper-style table renderers shared by benches |
//!
//! The tree's working invariants — `unsafe` discipline, panic-free
//! serving paths, allocation-free decode hot loops, documented metrics,
//! this very module map, and bench baseline output — are statically
//! enforced by the `repolint` workspace tool (`rust/tools/repolint`,
//! a hard CI gate); see the README's "Static analysis" section.

// Style lints this codebase deliberately trades away: index-heavy numeric
// kernels read better with explicit loops, and the quantizer entry points
// take the paper's full hyper-parameter lists.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod adapters;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fault;
pub mod kernels;
pub mod kvquant;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod optim;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
