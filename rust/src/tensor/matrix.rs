//! Row-major dense f32 matrix with the elementwise / reduction toolkit used
//! throughout the quantization library and the transformer testbed.

use crate::util::Rng;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Gaussian init with the given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    /// Identity (square or rectangular with unit diagonal).
    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    pub fn zip_map(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a - b)
    }

    /// Hadamard (elementwise) product — the ⊙ of the paper.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a * b)
    }

    /// Hadamard (elementwise) division — the ⊘ of the paper. Zero-safe.
    pub fn hadamard_div(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| if b == 0.0 { 0.0 } else { a / b })
    }

    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|v| v * s)
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum()
    }

    pub fn mean(&self) -> f32 {
        (self.sum() / self.data.len().max(1) as f64) as f32
    }

    /// Copy a sub-block [r0..r1) × [c0..c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Paste `block` at (r0, c0).
    pub fn paste(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst = i + r0;
            self.row_mut(dst)[c0..c0 + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// Columns c0..c1 as a new matrix.
    pub fn cols_range(&self, c0: usize, c1: usize) -> Matrix {
        self.slice(0, self.rows, c0, c1)
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(3, 2), m.at(2, 3));
    }

    #[test]
    fn hadamard_ops() {
        let a = Matrix::from_vec(1, 3, vec![2.0, 4.0, 6.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 2.0, 0.0]);
        assert_eq!(a.hadamard(&b).data, vec![2.0, 8.0, 0.0]);
        assert_eq!(a.hadamard_div(&b).data, vec![2.0, 2.0, 0.0]); // zero-safe
    }

    #[test]
    fn slice_paste_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(6, 8, 1.0, &mut rng);
        let b = m.slice(2, 5, 1, 7);
        assert_eq!(b.shape(), (3, 6));
        let mut m2 = Matrix::zeros(6, 8);
        m2.paste(2, 1, &b);
        assert_eq!(m2.at(3, 3), m.at(3, 3));
        assert_eq!(m2.at(0, 0), 0.0);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-6);
        assert_eq!(m.abs_max(), 4.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.add(&b);
    }
}
